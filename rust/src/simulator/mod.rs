//! Discrete-event cluster simulator.
//!
//! Executes a [`crate::sched::Plan`] over the four exclusive DEP
//! resources with non-preemptive FIFO issue per resource, producing an
//! exact schedule (start/finish per task). This is the evaluation
//! substrate standing in for the paper's GPU testbeds: every throughput
//! number in the Tables 3-7 benches comes from here, with stage
//! durations supplied by the α-β performance models.

pub mod engine;
pub mod trace;

pub use engine::{simulate, SimResult};
pub use trace::{ScheduleTrace, TraceInterval};
