//! Hardware micro-benchmark probes (§5.2 / Fig. 7) executed through the
//! PJRT client with `XlaBuilder`-constructed computations, so the
//! calibration measures the same execution stack the serving path uses.

use anyhow::Result;

use crate::perfmodel::calibrate::{measure, Sample};

/// Build + compile an (m,k)x(k,n) matmul and measure its median runtime.
/// Returns a calibration sample with workload = m·k·n (the paper's GEMM
/// workload convention).
pub fn gemm_sample(
    client: &xla::PjRtClient,
    m: usize,
    k: usize,
    n: usize,
    warmup: usize,
    trials: usize,
) -> Result<Sample> {
    let builder = xla::XlaBuilder::new("gemm_probe");
    let a = builder.parameter_s(
        0,
        &xla::Shape::array::<f32>(vec![m as i64, k as i64]),
        "a",
    )?;
    let b = builder.parameter_s(
        1,
        &xla::Shape::array::<f32>(vec![k as i64, n as i64]),
        "b",
    )?;
    let comp = a.matmul(&b)?.build()?;
    let exe = client.compile(&comp)?;

    let av: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 * 0.1).collect();
    let bv: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 * 0.1).collect();
    let alit = xla::Literal::vec1(&av).reshape(&[m as i64, k as i64])?;
    let blit = xla::Literal::vec1(&bv).reshape(&[k as i64, n as i64])?;

    let seconds = measure(warmup, trials, || {
        let out = exe.execute::<xla::Literal>(&[alit.clone(), blit.clone()]).unwrap();
        // Force completion.
        let _ = out[0][0].to_literal_sync().unwrap();
    });
    Ok(Sample { workload: (m * k * n) as f64, seconds })
}

/// Measure a scaled-dot-product attention computation (QK^T softmax V)
/// built with the XlaBuilder; workload = n_h·B·S²·(d_k+d_v).
pub fn attention_sample(
    client: &xla::PjRtClient,
    heads_batch: usize,
    s: usize,
    d: usize,
    warmup: usize,
    trials: usize,
) -> Result<Sample> {
    let builder = xla::XlaBuilder::new("attn_probe");
    let shape = xla::Shape::array::<f32>(vec![heads_batch as i64, s as i64, d as i64]);
    let q = builder.parameter_s(0, &shape, "q")?;
    let k = builder.parameter_s(1, &shape, "k")?;
    let v = builder.parameter_s(2, &shape, "v")?;
    // scores[b, i, j] = sum_d q[b,i,d]·k[b,j,d]
    let scores = q.dot_general(&k, &[2], &[2], &[0], &[0])?;
    let probs = scores.softmax(-1)?;
    // out[b, i, d] = sum_j probs[b,i,j]·v[b,j,d]
    let comp = probs.dot_general(&v, &[2], &[1], &[0], &[0])?.build()?;
    let exe = client.compile(&comp)?;

    let qv: Vec<f32> = (0..heads_batch * s * d).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();
    let mk = |data: &[f32]| {
        xla::Literal::vec1(data)
            .reshape(&[heads_batch as i64, s as i64, d as i64])
            .unwrap()
    };
    let (ql, kl, vl) = (mk(&qv), mk(&qv), mk(&qv));
    let seconds = measure(warmup, trials, || {
        let out = exe.execute::<xla::Literal>(&[ql.clone(), kl.clone(), vl.clone()]).unwrap();
        let _ = out[0][0].to_literal_sync().unwrap();
    });
    Ok(Sample { workload: (heads_batch * s * s * 2 * d) as f64, seconds })
}

/// Measure sustained memory-streaming bandwidth by summing a buffer of
/// `n_bytes` host memory; workload = bytes per pass. This is the
/// calibration source for `Testbed::hbm_bw`, the rate the decode-phase
/// attention regime is KV-read-bound at — fitted with the same α-β
/// shape as the other components so a profile carries all four models.
pub fn hbm_stream_sample(n_bytes: usize, warmup: usize, trials: usize) -> Sample {
    let words = (n_bytes / 8).max(1);
    let buf: Vec<u64> = (0..words as u64).collect();
    let mut acc = 0u64;
    let seconds = measure(warmup, trials, || {
        let mut sum = 0u64;
        for &w in &buf {
            sum = sum.wrapping_add(w);
        }
        acc = acc.wrapping_add(std::hint::black_box(sum));
    });
    std::hint::black_box(acc);
    Sample { workload: (words * 8) as f64, seconds }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_probe_runs() {
        let client = xla::PjRtClient::cpu().unwrap();
        let s = gemm_sample(&client, 32, 32, 32, 1, 3).unwrap();
        assert_eq!(s.workload, (32 * 32 * 32) as f64);
        assert!(s.seconds > 0.0);
    }

    #[test]
    fn attention_probe_runs() {
        let client = xla::PjRtClient::cpu().unwrap();
        let s = attention_sample(&client, 2, 16, 8, 1, 3).unwrap();
        assert!(s.seconds > 0.0);
        assert_eq!(s.workload, (2 * 16 * 16 * 16) as f64);
    }

    #[test]
    fn hbm_stream_probe_runs() {
        let s = hbm_stream_sample(1 << 16, 1, 3);
        assert_eq!(s.workload, (1 << 16) as f64);
        assert!(s.seconds > 0.0);
    }
}
