//! Online adaptive scheduling (§5.5 / Table 6 scenario).
//!
//! Requests arrive with unpredictable prompt lengths; (ag, eg) is pinned
//! (reboot cost), and FinDEP re-solves (r1, r2, order) per batch against
//! each arriving shape, versus a PPPipe baseline frozen at its best
//! static configuration for the *expected* shape.
//!
//! Run: `cargo run --release --example online_adaptive`

use findep::baselines::pppipe::pppipe_fixed;
use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{solve_online, Instance, SolverParams};
use findep::util::bench::Table;
use findep::util::rng::Rng;
use findep::workload::{batch_seq_len, window_batches, OnlineWorkload};

fn main() {
    let testbed = Testbed::a();
    let model = ModelConfig::deepseek_v2(8);
    let split = GroupSplit::new(3, 5);
    let params = SolverParams::default();
    let samples_per_gpu = 4usize; // arriving batch, per AG GPU

    let mut table = Table::new(
        "Online serving: adaptive FinDEP vs static PPPipe (DeepSeek-V2, testbed A)",
        &["mean tokens", "batches", "PPPipe tok/s", "FinDEP tok/s", "speedup", "re-solve ms (max)"],
    );

    for mean_tokens in [3072usize, 6144] {
        let workload = OnlineWorkload::paper_scenario(mean_tokens);
        let mut rng = Rng::new(42);
        let reqs = workload.generate(64, &mut rng);
        let batches = window_batches(&reqs, 0.5, 16);

        // Static PPPipe: best fixed config for the *expected* S.
        let expect_inst =
            Instance::new(model.clone(), testbed.clone(), split, mean_tokens);
        let pp_best = findep::baselines::best_pppipe(&expect_inst, &params)
            .expect("static baseline feasible");

        let mut pp_time = 0.0f64;
        let mut fd_time = 0.0f64;
        let mut tokens = 0f64;
        let mut max_solve_ms = 0.0f64;
        let mut n_batches = 0usize;
        for batch in &batches {
            if batch.is_empty() {
                continue;
            }
            let s = batch_seq_len(batch);
            let inst = Instance::new(model.clone(), testbed.clone(), split, s);
            // Static baseline executes its frozen (m_a, r1) on the
            // actual shape.
            let pp = pppipe_fixed(&inst, pp_best.config.m_a, pp_best.config.r1);
            // FinDEP re-solves for the actual shape and batch.
            let Some(fd) = solve_online(&inst, samples_per_gpu, &params) else {
                continue;
            };
            max_solve_ms = max_solve_ms.max(fd.solve_seconds * 1e3);
            let batch_tokens = (samples_per_gpu * split.ag * s) as f64;
            // Normalize both to the same token budget per batch.
            pp_time += batch_tokens / pp.throughput_tokens;
            fd_time += batch_tokens / fd.throughput_tokens;
            tokens += batch_tokens;
            n_batches += 1;
        }
        let pp_tput = tokens / pp_time;
        let fd_tput = tokens / fd_time;
        table.row(&[
            format!("{mean_tokens}"),
            format!("{n_batches}"),
            format!("{pp_tput:.1}"),
            format!("{fd_tput:.1}"),
            format!("{:.2}x", fd_tput / pp_tput),
            format!("{max_solve_ms:.2}"),
        ]);
    }
    table.print();
    println!("(paper Table 6 reports 1.00x-1.24x for these scenarios; the re-solve must stay <1s)");
}
