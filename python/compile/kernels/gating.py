"""L1 Pallas kernel: gate scoring (router GEMM + softmax).

The gate computes routing scores over all experts and softmax-normalizes
them (§2.1). The GEMM+softmax is fused in one Pallas kernel (one token
tile per grid step, the full [E, M] router panel resident in VMEM —
E·M is tiny relative to expert weights); top-k selection happens in
plain jnp on the kernel output since top-k is a lane-shuffle-heavy op
the XLA lowering already handles well.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gate_kernel(x_ref, w_ref, o_ref):
    """scores = softmax(x @ w^T) for one token tile."""
    s = jnp.dot(x_ref[...], w_ref[...].T, preferred_element_type=jnp.float32)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n",))
def gate_probs(x, w_gate, block_n=128):
    """Softmax routing probabilities. x: [N, M]; w_gate: [E, M] -> [N, E]."""
    n, m = x.shape
    e = w_gate.shape[0]
    bn = min(block_n, n) if n > 0 else 1
    pad = (-n) % bn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _gate_kernel,
        grid=(x.shape[0] // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((e, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], e), x.dtype),
        interpret=True,
    )(x, w_gate)
    return out[:n]


def _iterative_topk(probs, k):
    """Top-k by k successive argmax+mask rounds.

    ``jax.lax.top_k`` lowers to the dedicated ``topk`` HLO instruction,
    which the AOT consumer (xla_extension 0.5.1's HLO text parser on the
    Rust side) predates. Iterative argmax lowers to plain reduce /
    select ops that parse everywhere, and matches top_k's tie-breaking
    (lowest index first) because argmax returns the first maximum.
    """
    n = probs.shape[0]
    rows = jnp.arange(n)
    vals, idxs = [], []
    p = probs
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        vals.append(p[rows, i])
        idxs.append(i)
        p = p.at[rows, i].set(-jnp.inf)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


@functools.partial(jax.jit, static_argnames=("top_k",))
def gate_topk(x, w_gate, top_k):
    """Full gate: probabilities -> top-k (renormalized) + indices.

    Returns (probs [N, k] f32, idx [N, k] int32), identical semantics to
    ``ref.ref_gate``.
    """
    probs = gate_probs(x, w_gate)
    top_p, top_i = _iterative_topk(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_p, top_i.astype(jnp.int32)
