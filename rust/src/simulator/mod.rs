//! Discrete-event cluster simulator.
//!
//! Executes a [`crate::sched::Plan`] over the four exclusive DEP
//! resources with non-preemptive FIFO issue per resource, producing an
//! exact schedule (start/finish per task). This is the evaluation
//! substrate standing in for the paper's GPU testbeds: every throughput
//! number in the Tables 3-7 benches comes from here, with stage
//! durations supplied by the α-β performance models.
//!
//! The solver hot path uses [`simulate_into`] with a reusable
//! [`SimBuffers`] arena (zero allocations per candidate once warm);
//! [`simulate`] is the one-shot convenience wrapper over the same code.

pub mod engine;
pub mod trace;

pub use engine::{simulate, simulate_into, SimBuffers, SimError, SimResult};
pub use trace::{ScheduleTrace, TraceInterval};
