//! Device-memory occupancy model feeding Algorithm 1's
//! `getMaxR1(...)` (§4.3: "calculates the maximum allowable r1 based on
//! memory limits").
//!
//! AG devices hold the full replicated attention stack + shared experts
//! + the KV cache of every in-flight sample (`r1·m_a` of them) + a
//! working activation set. EG devices hold `E/eg` experts per layer plus
//! the per-part activation slab. The EG check is a feasibility gate
//! (weights either fit or the split is invalid); the AG check bounds
//! `r1·m_a`.

use crate::config::{Cluster, ExpertPlacement, GroupSplit, ModelConfig, Phase, Testbed};

/// Memory occupancy calculator for one (model, cluster, split, S,
/// phase). Capacity is accounted per pool: AG devices check against
/// the attention pool's memory, EG devices against the expert pool's —
/// on a single-pool cluster both are the same device size and the
/// model reduces to the original homogeneous accounting bit for bit.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    pub model: ModelConfig,
    /// Device memory per attention-pool GPU.
    pub ag_mem_bytes: usize,
    /// Device memory per expert-pool GPU.
    pub eg_mem_bytes: usize,
    pub split: GroupSplit,
    pub seq_len: usize,
    /// Serving phase: prefill holds `seq_len` KV entries plus the
    /// full-prompt activation slab per sample; decode holds its
    /// (grown) KV cache but only a one-token activation slab.
    pub phase: Phase,
    /// Fraction of device memory usable for model state (the rest is
    /// framework overhead / fragmentation slack).
    pub usable_frac: f64,
    /// Expert → shard assignment with replication. The uniform
    /// placement reproduces the legacy `⌈E/eg⌉`-experts-per-device
    /// accounting exactly; explicit placements charge the fullest
    /// shard's slots (replicas included) against expert-pool capacity.
    pub placement: ExpertPlacement,
}

impl MemoryModel {
    pub fn new(model: &ModelConfig, tb: &Testbed, split: GroupSplit, seq_len: usize) -> Self {
        Self::for_phase(model, tb, split, seq_len, Phase::Prefill)
    }

    pub fn for_phase(
        model: &ModelConfig,
        tb: &Testbed,
        split: GroupSplit,
        seq_len: usize,
        phase: Phase,
    ) -> Self {
        Self::for_cluster(model, &Cluster::single_pool(tb), split, seq_len, phase)
    }

    pub fn for_cluster(
        model: &ModelConfig,
        cl: &Cluster,
        split: GroupSplit,
        seq_len: usize,
        phase: Phase,
    ) -> Self {
        Self {
            model: model.clone(),
            ag_mem_bytes: cl.attn().gpu.mem_bytes,
            eg_mem_bytes: cl.expert().gpu.mem_bytes,
            split,
            seq_len,
            phase,
            usable_frac: 0.90,
            placement: ExpertPlacement::uniform(model.n_experts, split.eg),
        }
    }

    /// Account a concrete placement's replica weights instead of the
    /// uniform `⌈E/eg⌉` slots. The placement must match this model's
    /// expert count and split.
    pub fn with_placement(mut self, placement: ExpertPlacement) -> Self {
        assert_eq!(placement.n_experts(), self.model.n_experts, "placement/model mismatch");
        assert_eq!(placement.n_shards(), self.split.eg, "placement shards must match split.eg");
        self.placement = placement;
        self
    }

    fn usable_ag(&self) -> f64 {
        self.ag_mem_bytes as f64 * self.usable_frac
    }

    fn usable_eg(&self) -> f64 {
        self.eg_mem_bytes as f64 * self.usable_frac
    }

    /// Static weight bytes on each AG device: attention stack + shared
    /// experts for all layers (replicated across the AG, §2.2).
    pub fn ag_weight_bytes(&self) -> usize {
        let attn = self.model.n_layers * self.model.attn_param_bytes_per_layer();
        let shared = self.model.n_layers * self.model.n_shared * self.model.expert_param_bytes();
        attn + shared
    }

    /// Static weight bytes on each EG device: the fullest shard's
    /// expert slots (replicas included) per layer. Uniform placement:
    /// `⌈E/eg⌉` slots, the legacy accounting bit for bit.
    pub fn eg_weight_bytes(&self) -> usize {
        self.model.n_layers * self.placement.max_shard_slots() * self.model.expert_param_bytes()
    }

    /// Extra expert slots per expert-pool GPU beyond the uniform
    /// `⌈E/eg⌉` that still fit in usable memory — the replication
    /// budget ceiling for the placement search. (An upper bound for
    /// enumeration; each candidate placement is still gated by
    /// [`Self::eg_feasible`] on its actual fullest shard.)
    pub fn eg_slot_headroom(&self) -> usize {
        let per_slot = (self.model.n_layers * self.model.expert_param_bytes()) as f64;
        if per_slot <= 0.0 {
            return 0;
        }
        let cap = (self.usable_eg() / per_slot) as usize;
        cap.saturating_sub(self.model.n_experts.div_ceil(self.split.eg))
    }

    /// Per-sample dynamic bytes on an AG device: KV cache across all
    /// layers plus an activation working set (hidden states for one
    /// layer, double-buffered). Prefill writes `seq_len` KV entries and
    /// carries the full-prompt activation slab; a decode step holds its
    /// `kv_len` cached entries plus the one it writes, but activations
    /// for only the single generated token.
    pub fn ag_bytes_per_sample(&self) -> usize {
        let kv = self.model.kv_bytes_per_sample(self.phase.kv_resident(self.seq_len));
        let tokens = self.phase.tokens_per_sample(self.seq_len);
        let act = 2 * tokens * self.model.embed * self.model.bytes_per_elem;
        kv + act
    }

    /// Does the EG side fit at all with this split (checked against the
    /// expert pool's device memory)?
    pub fn eg_feasible(&self) -> bool {
        (self.eg_weight_bytes() as f64) < self.usable_eg()
    }

    /// Maximum total in-flight samples per AG GPU (`r1·m_a` bound,
    /// checked against the attention pool's device memory).
    pub fn max_samples_per_ag_gpu(&self) -> usize {
        let left = self.usable_ag() - self.ag_weight_bytes() as f64;
        if left <= 0.0 {
            return 0;
        }
        (left / self.ag_bytes_per_sample() as f64) as usize
    }

    /// Algorithm 1's `getMaxR1`: largest r1 such that `r1·m_a` fits,
    /// additionally clamped by the scheduler cap.
    pub fn get_max_r1(&self, m_a: usize, r1_cap: usize) -> usize {
        if m_a == 0 || !self.eg_feasible() {
            return 0;
        }
        (self.max_samples_per_ag_gpu() / m_a).min(r1_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mm(seq: usize) -> MemoryModel {
        MemoryModel::new(&ModelConfig::deepseek_v2(8), &Testbed::a(), GroupSplit::new(3, 5), seq)
    }

    #[test]
    fn weights_fit_on_paper_testbeds() {
        let m = mm(2048);
        assert!(m.eg_feasible());
        assert!((m.ag_weight_bytes() as f64) < m.usable_ag());
        assert!(m.max_samples_per_ag_gpu() > 0);
    }

    #[test]
    fn per_pool_capacity_is_accounted_per_role() {
        use crate::config::Cluster;
        let model = ModelConfig::deepseek_v2(8);
        // Single-pool reduction is the Testbed path bit for bit.
        let tb = Testbed::a();
        let hom = MemoryModel::new(&model, &tb, GroupSplit::new(3, 5), 2048);
        let cl = MemoryModel::for_cluster(
            &model,
            &Cluster::single_pool(&tb),
            GroupSplit::new(3, 5),
            2048,
            Phase::Prefill,
        );
        assert_eq!(hom.ag_mem_bytes, cl.ag_mem_bytes);
        assert_eq!(hom.eg_mem_bytes, cl.eg_mem_bytes);
        assert_eq!(hom.max_samples_per_ag_gpu(), cl.max_samples_per_ag_gpu());
        // A big attention pool + tiny expert pool: EG gates on the
        // expert pool's 24 GB, AG batches on the attention pool's 96 GB.
        let mut hetero = Cluster::reference_hetero();
        hetero.pools[1].gpu.mem_bytes = 24 << 30;
        let m =
            MemoryModel::for_cluster(&model, &hetero, GroupSplit::new(7, 1), 2048, Phase::Prefill);
        assert!(!m.eg_feasible(), "160 experts on one 24 GB device must not fit");
        let m =
            MemoryModel::for_cluster(&model, &hetero, GroupSplit::new(3, 5), 2048, Phase::Prefill);
        assert!(m.eg_feasible());
        let small_ag = MemoryModel::new(&model, &Testbed::b(), GroupSplit::new(3, 5), 2048);
        assert!(
            m.max_samples_per_ag_gpu() > small_ag.max_samples_per_ag_gpu(),
            "96 GB attention pool must batch more than a 24 GB one"
        );
    }

    #[test]
    fn replica_weights_charge_expert_pool_capacity() {
        use crate::config::{ExpertLoad, ExpertPlacement};
        let m = mm(2048);
        // Uniform placement is the legacy formula bit for bit.
        assert_eq!(m.eg_weight_bytes(), 8 * 32 * m.model.expert_param_bytes());
        // Replicated hot experts add slots on the fullest shard.
        let load = ExpertLoad::zipf(160, 1.5);
        let repl = mm(2048).with_placement(ExpertPlacement::replicate_hot(&load, 5, 10));
        assert!(repl.eg_weight_bytes() > m.eg_weight_bytes());
        assert!(repl.placement.max_shard_slots() >= 33);
        // Testbed A has headroom for replicas; the budget shrinks to
        // zero when every slot is spoken for.
        assert!(m.eg_slot_headroom() > 0);
        let mut tight = mm(2048);
        tight.eg_mem_bytes = m.eg_weight_bytes() + (1 << 20);
        assert_eq!(tight.eg_slot_headroom(), 0);
    }

    #[test]
    fn longer_sequences_fit_fewer_samples() {
        assert!(mm(8192).max_samples_per_ag_gpu() < mm(1024).max_samples_per_ag_gpu());
    }

    #[test]
    fn get_max_r1_inverse_in_m_a() {
        let m = mm(2048);
        let r1_at_1 = m.get_max_r1(1, 1_000_000);
        let r1_at_4 = m.get_max_r1(4, 1_000_000);
        assert!(r1_at_4 <= r1_at_1 / 4 + 1);
        assert_eq!(m.get_max_r1(0, 8), 0);
        assert_eq!(m.get_max_r1(1, 8), 8, "cap applies");
    }

    #[test]
    fn infeasible_when_experts_too_big() {
        // Squeeze all 160 experts onto 1 EG device of a 24 GB card:
        // 160·3·5120·1536·2B · 8 layers ≈ 60 GB — must be infeasible.
        let m = MemoryModel::new(
            &ModelConfig::deepseek_v2(8),
            &Testbed::b(),
            GroupSplit::new(7, 1),
            2048,
        );
        assert!(!m.eg_feasible());
        assert_eq!(m.get_max_r1(1, 8), 0);
    }

    fn mm_decode(kv: usize) -> MemoryModel {
        MemoryModel::for_phase(
            &ModelConfig::deepseek_v2(8),
            &Testbed::a(),
            GroupSplit::new(3, 5),
            1,
            Phase::Decode { kv_len: kv },
        )
    }

    #[test]
    fn decode_per_sample_bytes_pin_kv_growth() {
        // Decode at kv_len reads kv_len entries and writes 1, with a
        // one-token activation slab — the exact per-sample formula.
        let model = ModelConfig::deepseek_v2(8);
        let m = mm_decode(2048);
        assert_eq!(
            m.ag_bytes_per_sample(),
            model.kv_bytes_per_sample(2049) + 2 * model.embed * model.bytes_per_elem
        );
        // KV growth monotonically squeezes capacity, step by step.
        let samples = |kv: usize| mm_decode(kv).max_samples_per_ag_gpu();
        assert!(samples(2049) <= samples(2048));
        assert!(samples(8192) < samples(1024));
    }

    #[test]
    fn decode_fits_more_samples_than_prefill_at_equal_kv() {
        // Same resident KV, but no full-prompt activation slab: the
        // decode phase holds strictly more in-flight samples (the slab
        // dominates for MLA models whose latent KV is small).
        let pre = mm(2048);
        let dec = mm_decode(2047); // kv_resident = 2048, matching prefill
        assert!(dec.max_samples_per_ag_gpu() > 2 * pre.max_samples_per_ag_gpu());
        // And the r1 bound follows.
        assert!(dec.get_max_r1(4, 1_000_000) > pre.get_max_r1(4, 1_000_000));
    }

    #[test]
    fn mla_kv_much_smaller_than_mha() {
        let ds = ModelConfig::deepseek_v2(8); // MLA
        let mut mha = ds.clone();
        mha.attention = crate::config::AttentionKind::Mha;
        assert!(mha.kv_bytes_per_sample(2048) > 10 * ds.kv_bytes_per_sample(2048));
    }
}
