//! Statistics substrate: summary stats, percentiles, ordinary
//! least-squares linear regression with R² (used to fit the paper's α-β
//! performance models exactly as §5.2/Fig. 7 does), and integer ternary
//! search over convex objectives (Theorem 4 solver step).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Result of an ordinary least-squares fit `y ≈ alpha + beta * x`.
///
/// This is the α-β model of the paper (Eqs. 7-9): `alpha` captures fixed
/// launch/startup overhead, `beta` the per-unit cost, `r2` the fit quality
/// the paper reports in Fig. 7.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinFit {
    pub alpha: f64,
    pub beta: f64,
    pub r2: f64,
}

/// Why [`try_linear_fit`] refused to fit. Calibration inputs that would
/// produce meaningless or non-finite coefficients must fail loudly here
/// instead of poisoning a downstream solve (the lenient [`linear_fit`]
/// keeps its flat-model fallbacks for non-calibration callers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitError {
    /// Fewer than 2 samples: a line is not identifiable.
    TooFewSamples(usize),
    /// All workloads identical: the slope is not identifiable.
    ZeroVariance,
    /// A sample (or the resulting coefficient) is NaN/∞.
    NonFinite,
}

impl std::fmt::Display for FitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FitError::TooFewSamples(n) => {
                write!(f, "need at least 2 samples to fit a line, got {n}")
            }
            FitError::ZeroVariance => {
                write!(f, "all workloads are identical (zero variance); slope unidentifiable")
            }
            FitError::NonFinite => write!(f, "non-finite sample or coefficient"),
        }
    }
}

impl std::error::Error for FitError {}

/// Strict least-squares fit: errors on degenerate inputs (fewer than 2
/// samples, zero workload variance, non-finite values) instead of
/// returning the flat-model fallbacks [`linear_fit`] uses.
pub fn try_linear_fit(x: &[f64], y: &[f64]) -> Result<LinFit, FitError> {
    assert_eq!(x.len(), y.len(), "try_linear_fit: length mismatch");
    if x.len() < 2 {
        return Err(FitError::TooFewSamples(x.len()));
    }
    if x.iter().chain(y).any(|v| !v.is_finite()) {
        return Err(FitError::NonFinite);
    }
    let mx = mean(x);
    if x.iter().map(|xi| (xi - mx) * (xi - mx)).sum::<f64>() == 0.0 {
        return Err(FitError::ZeroVariance);
    }
    let fit = linear_fit(x, y);
    if !fit.alpha.is_finite() || !fit.beta.is_finite() || !fit.r2.is_finite() {
        return Err(FitError::NonFinite);
    }
    Ok(fit)
}

/// R² of an *explicit* line `y ≈ alpha + beta·x` against the data — not
/// necessarily the least-squares line, so the value can be negative
/// (worse than predicting the mean). Used to re-score a fit after its
/// coefficients were clamped into the valid cost cone. For zero-variance
/// `y`, returns 1.0 on zero residual and -∞ otherwise.
pub fn r_squared(x: &[f64], y: &[f64], alpha: f64, beta: f64) -> f64 {
    assert_eq!(x.len(), y.len(), "r_squared: length mismatch");
    if y.is_empty() {
        return 0.0;
    }
    let my = mean(y);
    let ss_tot: f64 = y.iter().map(|yi| (yi - my) * (yi - my)).sum();
    let ss_res: f64 = x.iter().zip(y).map(|(xi, yi)| (yi - (alpha + beta * xi)).powi(2)).sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            f64::NEG_INFINITY
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Least-squares fit of y = alpha + beta*x. Panics on len mismatch;
/// returns a flat model when x has no variance.
pub fn linear_fit(x: &[f64], y: &[f64]) -> LinFit {
    assert_eq!(x.len(), y.len(), "linear_fit: length mismatch");
    let n = x.len() as f64;
    if x.is_empty() {
        return LinFit { alpha: 0.0, beta: 0.0, r2: 0.0 };
    }
    let mx = mean(x);
    let my = mean(y);
    let sxx: f64 = x.iter().map(|xi| (xi - mx) * (xi - mx)).sum();
    let sxy: f64 = x.iter().zip(y).map(|(xi, yi)| (xi - mx) * (yi - my)).sum();
    if sxx == 0.0 {
        return LinFit { alpha: my, beta: 0.0, r2: 1.0 };
    }
    let beta = sxy / sxx;
    let alpha = my - beta * mx;
    let _ = n;
    // The least-squares line has zero residual whenever y is flat, so
    // r_squared's conventions coincide with the old inline computation.
    LinFit { alpha, beta, r2: r_squared(x, y, alpha, beta) }
}

/// Minimize a convex (or unimodal) function over the integer interval
/// [lo, hi] by ternary search; returns (argmin, min). O(log(hi-lo))
/// evaluations, with a final local sweep of ±2 to absorb flat plateaus
/// from `max(...)` kinks (the objective in Theorem 4 is piecewise linear,
/// so plateaus are real).
pub fn ternary_min_int<F: FnMut(i64) -> f64>(lo: i64, hi: i64, mut f: F) -> (i64, f64) {
    assert!(lo <= hi);
    let (mut lo, mut hi) = (lo, hi);
    while hi - lo > 4 {
        let m1 = lo + (hi - lo) / 3;
        let m2 = hi - (hi - lo) / 3;
        if f(m1) <= f(m2) {
            hi = m2 - 1;
        } else {
            lo = m1 + 1;
        }
    }
    let mut best = (lo, f(lo));
    for x in (lo + 1)..=hi {
        let v = f(x);
        if v < best.1 {
            best = (x, v);
        }
    }
    best
}

/// A tiny online throughput/latency accumulator.
#[derive(Debug, Default, Clone)]
pub struct Summary {
    pub samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn p(&self, p: f64) -> f64 {
        percentile(&self.samples, p)
    }

    pub fn std(&self) -> f64 {
        std_dev(&self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn exact_linear_fit() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = x.iter().map(|v| 0.5 + 2.0 * v).collect();
        let fit = linear_fit(&x, &y);
        assert!((fit.alpha - 0.5).abs() < 1e-12);
        assert!((fit.beta - 2.0).abs() < 1e-12);
        assert!((fit.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_fit_recovers_params() {
        let mut rng = crate::util::rng::Rng::new(42);
        let x: Vec<f64> = (1..200).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 0.7 * v + rng.normal() * 0.5).collect();
        let fit = linear_fit(&x, &y);
        assert!((fit.alpha - 3.0).abs() < 0.5, "alpha={}", fit.alpha);
        assert!((fit.beta - 0.7).abs() < 0.01, "beta={}", fit.beta);
        assert!(fit.r2 > 0.99);
    }

    #[test]
    fn degenerate_x_gives_flat_model() {
        let fit = linear_fit(&[2.0, 2.0, 2.0], &[1.0, 2.0, 3.0]);
        assert_eq!(fit.beta, 0.0);
        assert_eq!(fit.alpha, 2.0);
    }

    #[test]
    fn try_fit_rejects_degenerate_inputs() {
        assert_eq!(try_linear_fit(&[], &[]), Err(FitError::TooFewSamples(0)));
        assert_eq!(try_linear_fit(&[1.0], &[2.0]), Err(FitError::TooFewSamples(1)));
        assert_eq!(try_linear_fit(&[2.0, 2.0, 2.0], &[1.0, 2.0, 3.0]), Err(FitError::ZeroVariance));
        assert_eq!(try_linear_fit(&[1.0, f64::NAN], &[1.0, 2.0]), Err(FitError::NonFinite));
        assert_eq!(try_linear_fit(&[1.0, 2.0], &[1.0, f64::INFINITY]), Err(FitError::NonFinite));
    }

    #[test]
    fn try_fit_matches_lenient_fit_on_good_inputs() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = x.iter().map(|v| 0.5 + 2.0 * v).collect();
        assert_eq!(try_linear_fit(&x, &y).unwrap(), linear_fit(&x, &y));
    }

    #[test]
    fn r_squared_scores_explicit_lines() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = x.iter().map(|v| 0.5 + 2.0 * v).collect();
        // The true line explains everything; a wrong line can score
        // below zero (worse than the mean predictor).
        assert!((r_squared(&x, &y, 0.5, 2.0) - 1.0).abs() < 1e-12);
        assert!(r_squared(&x, &y, 100.0, -3.0) < 0.0);
        // Zero-variance y: exact flat line is perfect, anything else -∞.
        assert_eq!(r_squared(&[1.0, 2.0], &[5.0, 5.0], 5.0, 0.0), 1.0);
        assert_eq!(r_squared(&[1.0, 2.0], &[5.0, 5.0], 0.0, 0.0), f64::NEG_INFINITY);
    }

    #[test]
    fn ternary_finds_parabola_min() {
        let (x, v) = ternary_min_int(-100, 100, |x| ((x - 17) * (x - 17)) as f64);
        assert_eq!(x, 17);
        assert_eq!(v, 0.0);
    }

    #[test]
    fn ternary_handles_plateau() {
        // Piecewise linear with a flat bottom [5, 9].
        let f = |x: i64| ((x - 5).max(0) as f64) + ((5 - x).max(0) as f64) * 2.0
            - ((x - 5).max(0).min(4)) as f64;
        let (x, v) = ternary_min_int(0, 50, f);
        assert_eq!(v, 0.0, "argmin={x}");
        assert!((5..=9).contains(&x));
    }

    #[test]
    fn ternary_small_ranges() {
        let (x, _) = ternary_min_int(3, 3, |x| x as f64);
        assert_eq!(x, 3);
        let (x, _) = ternary_min_int(1, 4, |x| (x as f64 - 2.2).abs());
        assert_eq!(x, 2);
    }
}
