//! Property-based scheduling invariants over randomized α-β models:
//! Eq.-5 validity of every simulated plan, baseline dominance ordering,
//! and the monotonicity/convexity structure the solver exploits.

use findep::perfmodel::{LinearModel, StageModels};
use findep::sched::{Order, Plan, PlanConfig};
use findep::simulator::{simulate, ScheduleTrace};
use findep::util::proptest::{self, Config};
use findep::util::rng::Rng;

/// Random positive stage models (arbitrary hardware).
fn random_models(rng: &mut Rng) -> StageModels {
    StageModels {
        t_a: LinearModel::new(rng.range_f64(1e-6, 2e-3), rng.range_f64(1e-6, 2e-3)),
        t_s: LinearModel::new(rng.range_f64(0.0, 1e-3), rng.range_f64(0.0, 1e-3)),
        t_e: LinearModel::new(rng.range_f64(1e-6, 2e-3), rng.range_f64(1e-7, 1e-4)),
        t_a2e: LinearModel::new(rng.range_f64(1e-6, 2e-3), rng.range_f64(1e-7, 1e-4)),
        k_tokens: rng.range_f64(2.0, 400.0),
        has_shared: rng.bool(0.6),
    }
}

fn random_config(rng: &mut Rng, sm: &StageModels) -> PlanConfig {
    let m_a = 1 + rng.usize_below(6);
    let r1 = 1 + rng.usize_below(5);
    let r2 = 1 + rng.usize_below(8);
    let order = if rng.bool(0.5) { Order::Asas } else { Order::Aass };
    let mut cfg = PlanConfig::findep(m_a, r1, r2, sm.m_e(m_a as f64, r2), order);
    cfg.fuse_shared = rng.bool(0.2);
    cfg
}

#[test]
fn every_simulated_plan_satisfies_eq5() {
    proptest::check("eq5-validity", &Config::with_cases(150), |rng| {
        let sm = random_models(rng);
        let cfg = random_config(rng, &sm);
        let layers = 1 + rng.usize_below(6);
        let plan = Plan::build(&sm, cfg, layers, 1 + rng.usize_below(8), 1024);
        let sim = simulate(&plan);
        // Rules 1-5: resource exclusivity.
        let trace = ScheduleTrace::from_sim(&plan, &sim);
        trace.validate_exclusive().map_err(|e| format!("{e} for {cfg:?}"))?;
        // Rules 6-9: precedence.
        for i in 0..plan.n_tasks() {
            for &d in plan.deps(i) {
                proptest::ensure(
                    sim.start[i] >= sim.finish[d as usize] - 1e-12,
                    format!("precedence violated: {} before {}", i, d),
                )?;
            }
        }
        // Makespan sanity: at least the critical chain of one chunk.
        let m_e = cfg.m_e;
        let lower = sm.attn_time(cfg.m_a as f64)
            + 2.0 * sm.comm_time(m_e)
            + sm.expert_time(m_e);
        proptest::ensure(
            sim.makespan >= lower - 1e-12,
            format!("makespan {} below critical chain {lower}", sim.makespan),
        )
    });
}

#[test]
fn findep_dominates_pppipe_dominates_naive() {
    // With all (r1, r2, order) available, the best FinDEP schedule can
    // never lose to the best PPPipe schedule, which can never lose to
    // naive — search-space containment made measurable.
    proptest::check("dominance", &Config::with_cases(60), |rng| {
        let sm = random_models(rng);
        let layers = 1 + rng.usize_below(5);
        let ag = 1 + rng.usize_below(4);
        let total = 8usize; // total samples per GPU, fixed budget
        let eval = |cfg: PlanConfig| -> f64 {
            let plan = Plan::build(&sm, cfg, layers, ag, 1024);
            let sim = simulate(&plan);
            sim.throughput_tokens(&plan)
        };
        let naive = eval(PlanConfig::naive(total, sm.m_e(total as f64, 1)));
        let mut best_pp = 0.0f64;
        let mut best_fd = 0.0f64;
        for r1 in [1usize, 2, 4, 8] {
            let m_a = total / r1;
            best_pp = best_pp.max(eval(PlanConfig::pppipe(m_a, r1, sm.m_e(m_a as f64, 1))));
            for r2 in [1usize, 2, 4, 8] {
                for order in Order::both() {
                    best_fd = best_fd.max(eval(PlanConfig::findep(
                        m_a,
                        r1,
                        r2,
                        sm.m_e(m_a as f64, r2),
                        order,
                    )));
                    // FinDEP can also choose the fused arrangement.
                    let mut fused =
                        PlanConfig::findep(m_a, r1, r2, sm.m_e(m_a as f64, r2), order);
                    fused.fuse_shared = true;
                    best_fd = best_fd.max(eval(fused));
                }
            }
        }
        proptest::ensure(
            best_pp >= naive * (1.0 - 1e-9),
            format!("PPPipe {best_pp} < naive {naive}"),
        )?;
        proptest::ensure(
            best_fd >= best_pp * (1.0 - 1e-9),
            format!("FinDEP {best_fd} < PPPipe {best_pp}"),
        )
    });
}

#[test]
fn des_throughput_monotone_on_frontier() {
    // Theorems 1-3 verified through the DES (not just the closed form):
    // optimal-throughput is monotone in m_a (fixed r1) and in r1
    // (fixed m_a) when the rest is re-optimized — §5.3's experiment.
    proptest::check("des-monotonicity", &Config::with_cases(30), |rng| {
        let sm = random_models(rng);
        let layers = 2 + rng.usize_below(4);
        let ag = 1 + rng.usize_below(4);
        let best_at = |m_a: usize, r1: usize| -> f64 {
            let mut best = 0.0f64;
            for r2 in 1..=8 {
                for order in Order::both() {
                    let cfg =
                        PlanConfig::findep(m_a, r1, r2, sm.m_e(m_a as f64, r2), order);
                    let plan = Plan::build(&sm, cfg, layers, ag, 1024);
                    best = best.max(simulate(&plan).throughput_tokens(&plan));
                }
            }
            best
        };
        let mut prev = 0.0;
        for m_a in 1..=4 {
            let cur = best_at(m_a, 1);
            proptest::ensure(
                cur >= prev * (1.0 - 1e-9),
                format!("throughput not monotone in m_a at {m_a}"),
            )?;
            prev = cur;
        }
        let mut prev = 0.0;
        for r1 in 1..=4 {
            let cur = best_at(1, r1);
            proptest::ensure(
                cur >= prev * (1.0 - 1e-9),
                format!("throughput not monotone in r1 at {r1}"),
            )?;
            prev = cur;
        }
        Ok(())
    });
}

#[test]
fn non_overlapped_comm_bounded_by_total_comm() {
    proptest::check("comm-accounting", &Config::with_cases(80), |rng| {
        let sm = random_models(rng);
        let cfg = random_config(rng, &sm);
        let layers = 1 + rng.usize_below(5);
        let plan = Plan::build(&sm, cfg, layers, 2, 1024);
        let sim = simulate(&plan);
        let trace = ScheduleTrace::from_sim(&plan, &sim);
        let total_comm = trace.busy_time(findep::sched::Resource::A2ELink)
            + trace.busy_time(findep::sched::Resource::E2ALink);
        let exposed = trace.non_overlapped_comm();
        proptest::ensure(exposed >= -1e-12, "negative exposed comm")?;
        proptest::ensure(
            exposed <= total_comm + 1e-12,
            format!("exposed {exposed} exceeds total comm {total_comm}"),
        )
    });
}
