"""Model configurations shared between the Python compile path and the
Rust coordinator (mirrors ``rust/src/config/model.rs``).

Only the *tiny* configurations are AOT-compiled into runnable artifacts —
they execute for real on the PJRT CPU client. The paper-scale
DeepSeek-V2 / Qwen3-MoE shapes live in the Rust analytic layer and the
discrete-event simulator.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    embed: int          # M
    ffn_hidden: int     # H
    n_experts: int      # E (routed)
    top_k: int
    n_shared: int       # shared experts (0 = none)
    n_layers: int       # T
    n_heads: int        # n_h
    d_k: int
    d_v: int
    attention: str      # "mha" | "mla"
    bytes_per_elem: int

    def to_json_dict(self):
        return asdict(self)

    @property
    def head_dim_total(self) -> int:
        return self.n_heads * self.d_k


def tiny() -> ModelConfig:
    """Tiny DeepSeek-style config (shared expert present); f32 on CPU."""
    return ModelConfig(
        name="tiny",
        embed=64,
        ffn_hidden=128,
        n_experts=8,
        top_k=2,
        n_shared=1,
        n_layers=2,
        n_heads=4,
        d_k=16,
        d_v=16,
        attention="mha",
        bytes_per_elem=4,
    )


def tiny_noshared() -> ModelConfig:
    """Tiny Qwen-style config (no shared expert)."""
    c = tiny()
    return ModelConfig(**{**asdict(c), "name": "tiny-noshared", "n_shared": 0})


# AOT shape buckets: artifacts are compiled per static shape. The Rust
# coordinator routes work onto the smallest bucket that fits (padding).
SEQ_LEN = 16                      # real-exec sequence length
MA_BUCKETS = (1, 2, 4)            # samples per AG micro-batch
FFN_BUCKETS = (8, 16, 32, 64)     # token counts for FFN calls (shared + experts)
