//! The Testbed → Cluster refactor's correctness oracle: every Table-2
//! testbed, expressed as a one-pool [`Cluster`], must be bit-identical
//! to the legacy homogeneous path across every paper instance and both
//! serving phases — stage-model coefficients, Algorithm-1 solutions,
//! and split-search winners. The cluster code performs literally the
//! same f64 arithmetic when both DEP roles share one pool; these tests
//! pin that, so the heterogeneous generalization can never drift the
//! Table-2 reproductions.

use findep::config::{Cluster, GroupSplit, ModelConfig, Phase, Testbed};
use findep::perfmodel::StageModels;
use findep::solver::{
    self, enumerate_cluster_candidates, search_cluster, search_splits_serial, Instance,
    SearchParams, SolverParams, SplitSolution,
};

/// The 8 paper instances: every Table-2 testbed × both model families,
/// at the §5.4 layer counts the testbed's memory admits.
fn paper_instances() -> Vec<(ModelConfig, Testbed)> {
    let mut out = Vec::new();
    for tb in Testbed::all() {
        for deepseek in [true, false] {
            let layers = ModelConfig::paper_layers(deepseek, &tb.name[..2]);
            let model = if deepseek {
                ModelConfig::deepseek_v2(layers)
            } else {
                ModelConfig::qwen3_moe(layers)
            };
            out.push((model, tb.clone()));
        }
    }
    out
}

fn phases() -> [Phase; 2] {
    [Phase::Prefill, Phase::Decode { kv_len: 2048 }]
}

#[test]
fn stage_models_bit_identical_on_every_paper_instance() {
    // The Testbed-typed derivation (CompModels::from_testbed) against
    // the per-pool derivation (ClusterComps::from_cluster) on the
    // single-pool embedding: every α/β coefficient, k_tokens included,
    // must be equal — the solver stack consumes nothing else.
    for (model, tb) in paper_instances() {
        let cl = Cluster::single_pool(&tb);
        let split = GroupSplit::paper_default(&tb, model.has_shared_expert());
        for phase in phases() {
            let hand = StageModels::for_phase(&model, &tb, split, 2048, phase);
            let pool = StageModels::for_cluster(&model, &cl, split, 2048, phase);
            assert_eq!(hand, pool, "{} on {} {phase:?}", model.name, tb.name);
        }
    }
}

#[test]
fn solves_bit_identical_on_every_paper_instance_and_phase() {
    // End to end through Algorithm 1: the compat constructors
    // (Instance::new / Instance::decode) against explicit single-pool
    // cluster instances. Same winning config, same throughput and
    // makespan to the last bit, same feasibility verdicts.
    let params = SolverParams::default();
    for (model, tb) in paper_instances() {
        let cl = Cluster::single_pool(&tb);
        let split = GroupSplit::paper_default(&tb, model.has_shared_expert());
        for phase in phases() {
            let (legacy, cluster) = match phase {
                Phase::Prefill => (
                    Instance::new(model.clone(), tb.clone(), split, 2048),
                    Instance::on_cluster(model.clone(), cl.clone(), split, 2048),
                ),
                Phase::Decode { kv_len } => (
                    Instance::decode(model.clone(), tb.clone(), split, kv_len),
                    Instance::decode_on_cluster(model.clone(), cl.clone(), split, kv_len),
                ),
            };
            match (solver::solve(&legacy, &params), solver::solve(&cluster, &params)) {
                (Some(a), Some(b)) => {
                    let tag = format!("{} on {} {phase:?}", model.name, tb.name);
                    assert_eq!(a.config, b.config, "{tag}");
                    assert_eq!(
                        a.throughput_tokens.to_bits(),
                        b.throughput_tokens.to_bits(),
                        "{tag}"
                    );
                    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{tag}");
                }
                (None, None) => {}
                (a, b) => panic!(
                    "feasibility drift on {} / {} {phase:?}: legacy={} cluster={}",
                    model.name,
                    tb.name,
                    a.is_some(),
                    b.is_some()
                ),
            }
        }
    }
}

#[test]
fn prefill_search_winner_identical_to_testbed_search() {
    // The cluster placement search on a one-pool cluster must be the
    // testbed split search, winner for winner: same candidate space,
    // same canonical order, same strict-improvement reduction — via two
    // different code routes (serial cold sweep vs pruned incremental).
    let params = SearchParams::default();
    for (model, tb) in paper_instances() {
        let serial = search_splits_serial(&model, &tb, 2048, &params);
        let report = search_cluster(
            &model,
            &Cluster::single_pool(&tb),
            2048,
            Phase::Prefill,
            &params,
        );
        match (serial, report) {
            (Some(s), Some(r)) => {
                let tag = format!("{} on {}", model.name, tb.name);
                assert_eq!(s.candidate, r.best.candidate, "{tag}");
                assert_eq!(s.per_instance.config, r.best.per_instance.config, "{tag}");
                assert_eq!(
                    s.total_throughput.to_bits(),
                    r.best.total_throughput.to_bits(),
                    "{tag}"
                );
            }
            (None, None) => {}
            (s, r) => panic!(
                "search feasibility drift on {} / {}: serial={} cluster={}",
                model.name,
                tb.name,
                s.is_some(),
                r.is_some()
            ),
        }
    }
}

#[test]
fn decode_search_matches_exhaustive_reference_sweep() {
    // The legacy search layer never had a decode entry; oracle the
    // cluster search's decode phase against a hand-rolled exhaustive
    // sweep of the same candidate space with cold per-candidate solves.
    let params = SearchParams::default();
    let kv = 2048usize;
    for (model, tb) in [
        (ModelConfig::deepseek_v2(8), Testbed::a()),
        (ModelConfig::qwen3_moe(12), Testbed::c()),
    ] {
        let cl = Cluster::single_pool(&tb);
        let mut reference: Option<SplitSolution> = None;
        for candidate in enumerate_cluster_candidates(&cl, params.multi_replica) {
            let inst =
                Instance::decode_on_cluster(model.clone(), cl.clone(), candidate.split, kv);
            let Some(sol) = solver::solve(&inst, &params.solver) else { continue };
            let total = candidate.replicas as f64 * sol.throughput_tokens;
            if reference.as_ref().map_or(true, |b| total > b.total_throughput) {
                reference =
                    Some(SplitSolution { candidate, per_instance: sol, total_throughput: total });
            }
        }
        let reference = reference.expect("decode reference sweep must be feasible");
        let report = search_cluster(&model, &cl, 1, Phase::Decode { kv_len: kv }, &params)
            .expect("decode search must be feasible");
        let tag = format!("{} on {}", model.name, tb.name);
        assert_eq!(reference.candidate, report.best.candidate, "{tag}");
        assert_eq!(
            reference.per_instance.config, report.best.per_instance.config,
            "{tag}"
        );
        assert_eq!(
            reference.total_throughput.to_bits(),
            report.best.total_throughput.to_bits(),
            "{tag}"
        );
    }
}

#[test]
fn cluster_registry_reaches_every_table2_testbed() {
    // `Cluster::by_name` must expose each Table-2 letter as the same
    // single-pool cluster `Cluster::single_pool` constructs, identity
    // (fingerprint) included — the CLI's `--cluster A` and the legacy
    // `--testbed A` must be the same hardware.
    for tb in Testbed::all() {
        let letter = &tb.name[..1];
        let named = Cluster::by_name(letter).expect("registry must know every Table-2 letter");
        let direct = Cluster::single_pool(&tb);
        assert!(named.is_single_pool());
        assert_eq!(named.fingerprint(), direct.fingerprint(), "{}", tb.name);
        assert_eq!(named.n_gpus(), tb.n_gpus);
    }
}
