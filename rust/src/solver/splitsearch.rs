//! Split search: promote the (ag, eg) disaggregation ratio — and
//! multi-replica tilings of the cluster — from an ablation sweep to a
//! first-class solver layer.
//!
//! The paper's Algorithm 1 solves one fixed [`GroupSplit`]; §5's
//! deployments (and MegaScale-Infer's placement search) pick the split
//! itself. [`search`] enumerates every feasible split of a testbed,
//! plus placements that tile the cluster with `k` identical instances
//! of an `n/k`-GPU split, runs Algorithm 1 on each, and returns the
//! global argmax by total tokens/s. Three compounding optimisations
//! keep the enlarged space cheaper than a cold sweep:
//!
//! 1. **Branch-and-bound pruning.** Every candidate gets an optimistic
//!    throughput upper bound from the §4.2 closed forms alone (no DAG,
//!    no engine): the engine's makespan is at least the busiest
//!    resource's total occupancy, which per layer is at least
//!    `F = max(X, Y)` evaluated at `r2 = 1` and the largest
//!    memory-feasible `m_a` (the per-part launch overheads `r2·α` only
//!    grow with r2, and Theorem 1 makes the ratio `m_a / F(m_a)`
//!    non-decreasing). Candidates whose bound cannot beat the incumbent
//!    are skipped without ever building a model; best-bound-first
//!    ordering tightens the incumbent early.
//! 2. **Parallel search** across candidates on `std::thread::scope`
//!    workers (no new dependencies), with a shared atomic incumbent.
//!    The final winner is reduced deterministically — max total
//!    throughput, ties to the lowest candidate index — so the result is
//!    bit-identical to [`search_serial`]'s strict-improvement sweep at
//!    any thread count, and pruning can never change it: a pruned
//!    candidate is strictly below some evaluated throughput, hence
//!    strictly below the winner.
//! 3. **Topology reuse.** Each worker carries one [`Evaluator`] across
//!    candidates ([`solve_warm`]): candidate plans of different splits
//!    share task-DAG topologies and differ only in durations, so the
//!    engine serves them from its per-shape CSR cache
//!    (`sched::TopologyKey`) through the duration-only fast path.
//!
//! [`search_serial`] is the reference: the pre-existing behaviour of
//! `benches/ablations.rs` — a serial, cold, unpruned Algorithm-1 solve
//! per split — kept as the oracle for tests and the baseline
//! `benches/split_search.rs` measures against.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::config::{GroupSplit, ModelConfig, Testbed};
use crate::solver::algorithm1::{
    self, solve_warm, EvalMode, Evaluator, Instance, Solution, SolverParams, WarmStart,
};
use crate::solver::memory::MemoryModel;

/// One placement candidate: `replicas` identical instances, each owning
/// `split.ag + split.eg` GPUs of the testbed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitCandidate {
    pub replicas: usize,
    pub split: GroupSplit,
}

impl SplitCandidate {
    pub fn describe(&self) -> String {
        if self.replicas == 1 {
            format!("({},{})", self.split.ag, self.split.eg)
        } else {
            format!("{}x({},{})", self.replicas, self.split.ag, self.split.eg)
        }
    }
}

/// Split-search knobs on top of the inner Algorithm-1 parameters.
#[derive(Debug, Clone, Copy)]
pub struct SearchParams {
    pub solver: SolverParams,
    /// Worker threads; 0 = all available cores.
    pub threads: usize,
    /// Branch-and-bound pruning on the analytic throughput bound.
    pub prune: bool,
    /// Include multi-replica tilings of the cluster.
    pub multi_replica: bool,
}

impl Default for SearchParams {
    fn default() -> Self {
        Self { solver: SolverParams::default(), threads: 0, prune: true, multi_replica: true }
    }
}

/// One solved candidate.
#[derive(Debug, Clone)]
pub struct SplitSolution {
    pub candidate: SplitCandidate,
    /// Algorithm 1's solution for a single instance of the candidate.
    pub per_instance: Solution,
    /// Cluster-wide tokens/s: `replicas × per-instance throughput`.
    pub total_throughput: f64,
}

/// Search diagnostics.
#[derive(Debug, Clone, Copy, Default)]
pub struct SearchStats {
    /// Candidates enumerated.
    pub candidates: usize,
    /// Candidates skipped by the branch-and-bound test.
    pub pruned: usize,
    /// Candidates that were infeasible (bound 0 or Algorithm 1 `None`).
    pub infeasible: usize,
    /// Candidates actually solved to a feasible solution.
    pub solved: usize,
    /// Total Algorithm-1 probe evaluations across solved candidates.
    pub evals: usize,
    /// (m_a, r1) rows pruned *inside* Algorithm 1 across solved
    /// candidates (the incumbent-seeded inner bound, not the
    /// candidate-level bound counted in `pruned`).
    pub row_pruned: usize,
    /// Worker threads used.
    pub threads: usize,
    /// Wall time of the whole search.
    pub solve_seconds: f64,
}

/// Search output: the winner plus every solved candidate (in canonical
/// candidate order — the per-split table `benches/ablations.rs` prints).
#[derive(Debug, Clone)]
pub struct SearchReport {
    pub best: SplitSolution,
    pub evaluated: Vec<SplitSolution>,
    pub stats: SearchStats,
}

/// All placement candidates of an `n_gpus` testbed in canonical order:
/// replicas ascending (1 first), then ag ascending. `replicas` must
/// divide `n_gpus` and leave at least 2 GPUs per instance (both groups
/// non-empty).
pub fn enumerate_candidates(n_gpus: usize, multi_replica: bool) -> Vec<SplitCandidate> {
    let mut out = Vec::new();
    let max_r = if multi_replica { n_gpus / 2 } else { 1 };
    for replicas in 1..=max_r.max(1) {
        if n_gpus % replicas != 0 {
            continue;
        }
        let per = n_gpus / replicas;
        if per < 2 {
            continue;
        }
        for split in GroupSplit::enumerate(per) {
            out.push(SplitCandidate { replicas, split });
        }
    }
    out
}

/// The testbed one instance of a `replicas`-way tiling sees: same
/// per-GPU constants, `n_gpus / replicas` GPUs. (Conservative for
/// multi-node testbeds — a tile that fits inside one node would see
/// better links than the cluster-wide constants assume.)
fn instance_testbed(tb: &Testbed, replicas: usize) -> Testbed {
    let mut t = tb.clone();
    t.n_gpus = tb.n_gpus / replicas;
    t
}

/// Optimistic tokens/s upper bound for one *instance* of a split, from
/// the §4.2 closed forms only. Admissible: for every configuration
/// Algorithm 1 can evaluate, the engine's makespan over `T` layers is
/// at least `T · r1 · F(m_a, r2)` (each resource executes its tasks
/// non-preemptively), `F` at fixed `m_a` is minimized at `r2 = 1`
/// (the per-part launch overheads scale with r2 while the `β` terms are
/// conserved), and `m_a / F(m_a, 1)` is non-decreasing in `m_a`
/// (Theorem 1), so the bound evaluated at the largest memory-feasible
/// `m_a` dominates every candidate. Returns 0.0 for infeasible splits.
pub fn throughput_bound(
    model: &ModelConfig,
    tb: &Testbed,
    split: GroupSplit,
    seq_len: usize,
    params: &SolverParams,
) -> f64 {
    let mem = MemoryModel::new(model, tb, split, seq_len);
    if !mem.eg_feasible() {
        return 0.0;
    }
    let ma_max = mem.max_samples_per_ag_gpu().min(params.ma_cap);
    if ma_max == 0 {
        return 0.0;
    }
    let sm = crate::perfmodel::StageModels::new(model, tb, split, seq_len);
    // The shared §4.2 row bound ([`algorithm1::row_bound`]) evaluated
    // at the largest memory-feasible m_a: F = max(X, r2·Y) at r2 = 1 is
    // the per-layer pipeline period floor, and Theorem 1 makes
    // m_a / F(m_a, 1) non-decreasing, so this dominates every row. In
    // the AG-bound regime the bound is *tight* (an ASAS schedule
    // achieves makespan = T·r1·X exactly), and the engine computes that
    // makespan in a different summation order than the closed form —
    // within ~1e-14 relative (pinned by simulator_vs_analytic); the
    // bound's 1e-9 relative inflation keeps admissibility through
    // floating point, and candidates differ by far more, so no pruning
    // is lost.
    algorithm1::row_bound(&sm, ma_max, split.ag, seq_len, model.n_layers)
}

/// The serial reference sweep: cold Algorithm-1 solve per candidate,
/// strict-improvement argmax in canonical order — no pruning, no
/// parallelism, no cross-candidate arena reuse. This is what
/// `benches/ablations.rs` did before the solver layer existed; tests
/// use it as the oracle and `benches/split_search.rs` as the baseline.
pub fn search_serial(
    model: &ModelConfig,
    testbed: &Testbed,
    seq_len: usize,
    params: &SearchParams,
) -> Option<SplitSolution> {
    let mut best: Option<SplitSolution> = None;
    for candidate in enumerate_candidates(testbed.n_gpus, params.multi_replica) {
        let tb = instance_testbed(testbed, candidate.replicas);
        let inst = Instance::new(model.clone(), tb, candidate.split, seq_len);
        let Some(sol) = algorithm1::solve(&inst, &params.solver) else { continue };
        let total = candidate.replicas as f64 * sol.throughput_tokens;
        if best.as_ref().map_or(true, |b| total > b.total_throughput) {
            best = Some(SplitSolution { candidate, per_instance: sol, total_throughput: total });
        }
    }
    best
}

/// The optimised search: branch-and-bound pruned, parallel,
/// topology-reusing. Bit-identical winner to [`search_serial`] at any
/// thread count (see the module docs for why pruning and scheduling
/// races cannot change the argmax). Returns `None` when no candidate
/// is feasible.
pub fn search(
    model: &ModelConfig,
    testbed: &Testbed,
    seq_len: usize,
    params: &SearchParams,
) -> Option<SearchReport> {
    let t0 = Instant::now();
    let candidates = enumerate_candidates(testbed.n_gpus, params.multi_replica);
    let bounds: Vec<f64> = candidates
        .iter()
        .map(|c| {
            let tb = instance_testbed(testbed, c.replicas);
            c.replicas as f64 * throughput_bound(model, &tb, c.split, seq_len, &params.solver)
        })
        .collect();
    // Best-bound-first: the strongest candidates set the incumbent
    // early, so weaker ones prune without solving.
    let mut visit: Vec<usize> = (0..candidates.len()).collect();
    visit.sort_by(|&a, &b| bounds[b].total_cmp(&bounds[a]).then(a.cmp(&b)));

    let requested = if params.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        params.threads
    };
    let threads = requested.clamp(1, candidates.len().max(1));

    let cursor = AtomicUsize::new(0);
    // Incumbent total throughput as f64 bits — non-negative floats
    // order identically to their bit patterns, so fetch_max works.
    let incumbent = AtomicU64::new(0);
    let pruned = AtomicUsize::new(0);
    let infeasible = AtomicUsize::new(0);
    let evals = AtomicUsize::new(0);
    let row_pruned = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, SplitSolution)>> = Mutex::new(Vec::new());

    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut ev: Option<Evaluator> = None;
                loop {
                    let next = cursor.fetch_add(1, Ordering::Relaxed);
                    if next >= visit.len() {
                        break;
                    }
                    let idx = visit[next];
                    let candidate = candidates[idx];
                    let bound = bounds[idx];
                    if bound <= 0.0 {
                        infeasible.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    if params.prune {
                        let inc = f64::from_bits(incumbent.load(Ordering::Acquire));
                        if bound < inc {
                            pruned.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    }
                    let tb = instance_testbed(testbed, candidate.replicas);
                    let inst = Instance::new(model.clone(), tb, candidate.split, seq_len);
                    let ev = ev.get_or_insert_with(|| Evaluator::new(&inst));
                    // Reuse the incumbent *inside* Algorithm 1: a hard
                    // per-instance floor of incumbent/replicas lets the
                    // inner sweep bound-prune rows and screen final
                    // engine evaluations that cannot affect the global
                    // argmax. Losing candidates may come back degraded
                    // or `None`; the winner cannot (its best row sits
                    // at or above every floor any worker installs), so
                    // the deterministic reduction is unchanged.
                    let warm = if params.prune {
                        let inc = f64::from_bits(incumbent.load(Ordering::Acquire));
                        if inc > 0.0 {
                            Some(WarmStart::incumbent(inc / candidate.replicas as f64))
                        } else {
                            None
                        }
                    } else {
                        None
                    };
                    match solve_warm(&inst, &params.solver, EvalMode::Buffered, ev, warm.as_ref())
                    {
                        None => {
                            if warm.is_some() {
                                // Every row fell to the incumbent floor:
                                // skipped work, not infeasibility.
                                pruned.fetch_add(1, Ordering::Relaxed);
                            } else {
                                infeasible.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Some(sol) => {
                            evals.fetch_add(sol.evals, Ordering::Relaxed);
                            row_pruned.fetch_add(sol.pruned_rows, Ordering::Relaxed);
                            let total = candidate.replicas as f64 * sol.throughput_tokens;
                            incumbent.fetch_max(total.to_bits(), Ordering::AcqRel);
                            results.lock().unwrap().push((
                                idx,
                                SplitSolution {
                                    candidate,
                                    per_instance: sol,
                                    total_throughput: total,
                                },
                            ));
                        }
                    }
                }
            });
        }
    });

    let mut solved = results.into_inner().unwrap();
    solved.sort_by_key(|(idx, _)| *idx);
    // Deterministic reduction: canonical order + strict improvement —
    // exactly search_serial's rule, so ties break to the lowest index.
    let mut best: Option<SplitSolution> = None;
    for (_, s) in &solved {
        if best.as_ref().map_or(true, |b| s.total_throughput > b.total_throughput) {
            best = Some(s.clone());
        }
    }
    let stats = SearchStats {
        candidates: candidates.len(),
        pruned: pruned.into_inner(),
        infeasible: infeasible.into_inner(),
        solved: solved.len(),
        evals: evals.into_inner(),
        row_pruned: row_pruned.into_inner(),
        threads,
        solve_seconds: t0.elapsed().as_secs_f64(),
    };
    best.map(|best| SearchReport {
        best,
        evaluated: solved.into_iter().map(|(_, s)| s).collect(),
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case() -> (ModelConfig, Testbed) {
        (ModelConfig::deepseek_v2(4), Testbed::a())
    }

    #[test]
    fn enumeration_is_canonical() {
        let c = enumerate_candidates(8, true);
        // 7 single-instance splits + 3 of (2x4 GPUs) + 1 of (4x2 GPUs).
        assert_eq!(c.len(), 11);
        assert_eq!(c[0], SplitCandidate { replicas: 1, split: GroupSplit::new(1, 7) });
        assert_eq!(c[7], SplitCandidate { replicas: 2, split: GroupSplit::new(1, 3) });
        assert_eq!(c[10], SplitCandidate { replicas: 4, split: GroupSplit::new(1, 1) });
        assert_eq!(enumerate_candidates(8, false).len(), 7);
        // 32 GPUs: 31 + 15 + 7 + 3 + 1.
        assert_eq!(enumerate_candidates(32, true).len(), 57);
        // A 2-GPU cluster has exactly one placement.
        assert_eq!(enumerate_candidates(2, true).len(), 1);
    }

    #[test]
    fn search_finds_feasible_winner_with_stats() {
        let (model, tb) = case();
        let report = search(&model, &tb, 2048, &SearchParams::default()).expect("feasible");
        assert!(report.best.total_throughput > 0.0);
        assert_eq!(
            report.best.total_throughput,
            report.best.candidate.replicas as f64 * report.best.per_instance.throughput_tokens
        );
        assert_eq!(report.stats.candidates, 11);
        assert_eq!(
            report.stats.solved + report.stats.pruned + report.stats.infeasible,
            report.stats.candidates
        );
        assert_eq!(report.stats.solved, report.evaluated.len());
        // evaluated is in canonical candidate order.
        for w in report.evaluated.windows(2) {
            let key = |s: &SplitSolution| (s.candidate.replicas, s.candidate.split.ag);
            assert!(key(&w[0]) < key(&w[1]));
        }
    }

    #[test]
    fn bounds_dominate_solutions() {
        let (model, tb) = case();
        let params = SearchParams { prune: false, ..Default::default() };
        let report = search(&model, &tb, 2048, &params).unwrap();
        for s in &report.evaluated {
            let itb = instance_testbed(&tb, s.candidate.replicas);
            let b = s.candidate.replicas as f64
                * throughput_bound(&model, &itb, s.candidate.split, 2048, &params.solver);
            assert!(
                b >= s.total_throughput,
                "bound {b} < achieved {} on {}",
                s.total_throughput,
                s.candidate.describe()
            );
        }
    }

    #[test]
    fn fully_infeasible_model_returns_none() {
        // Experts far beyond every split's EG memory on 24 GB cards.
        let model = ModelConfig::deepseek_v2(64);
        let tb = Testbed::b();
        assert!(search(&model, &tb, 2048, &SearchParams::default()).is_none());
        assert!(search_serial(&model, &tb, 2048, &SearchParams::default()).is_none());
    }
}
