//! The PJRT execution engine: compile every HLO artifact once, expose
//! typed stage calls.
//!
//! Thread-safety: `xla`'s raw wrappers hold C pointers and are `!Send`
//! by default, but the underlying PJRT CPU client and loaded
//! executables are thread-safe objects (they carry internal
//! synchronization and are driven concurrently by JAX/TF in normal
//! use). We wrap the engine in an [`EngineHandle`] with an explicit
//! `unsafe impl Send + Sync` documented by that invariant; all
//! coordinator threads share one compiled engine.

use std::collections::BTreeMap;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::artifact::Manifest;
use crate::runtime::tensor::{Tensor, TensorI32};

/// Compiled artifact registry keyed by (stage, bucket).
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    exes: BTreeMap<(String, usize), xla::PjRtLoadedExecutable>,
    pub platform: String,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Engine({} artifacts on {})", self.exes.len(), self.platform)
    }
}

impl Engine {
    /// Compile all artifacts listed in the manifest on the PJRT CPU
    /// client. One-time cost at coordinator startup.
    pub fn compile(manifest: &Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        let platform = client.platform_name();
        let mut exes = BTreeMap::new();
        for a in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                a.path.to_str().context("artifact path utf-8")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", a.path.display()))?;
            exes.insert((a.stage.clone(), a.bucket), exe);
        }
        Ok(Engine { client, exes, platform })
    }

    fn exe(&self, stage: &str, bucket: usize) -> Result<&xla::PjRtLoadedExecutable> {
        self.exes
            .get(&(stage.to_string(), bucket))
            .with_context(|| format!("no artifact for stage={stage} bucket={bucket}"))
    }

    /// Smallest compiled bucket >= n for a stage.
    pub fn bucket_for(&self, stage: &str, n: usize) -> Result<usize> {
        self.exes
            .keys()
            .filter(|(s, b)| s == stage && *b >= n)
            .map(|(_, b)| *b)
            .min()
            .with_context(|| format!("no bucket >= {n} for stage {stage}"))
    }

    /// Execute an artifact whose output is a 1-tuple of one f32 array.
    pub fn run1(&self, stage: &str, bucket: usize, inputs: &[&Tensor]) -> Result<Tensor> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        self.run1_lits(stage, bucket, &refs)
    }

    /// Hot-path variant of [`Self::run1`]: callers pass pre-built
    /// literals (weights come from the [`crate::coordinator::moe`]
    /// weight-literal cache, so only activations are converted per
    /// call — the §Perf L3 optimization).
    pub fn run1_lits(&self, stage: &str, bucket: usize, inputs: &[&xla::Literal]) -> Result<Tensor> {
        let exe = self.exe(stage, bucket)?;
        let result = exe.execute::<&xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Tensor::from_literal(&out)
    }

    /// Execute the gate artifact: returns (probs f32 [n,k], idx i32 [n,k]).
    pub fn run_gate(&self, _bucket: usize, inputs: &[&Tensor]) -> Result<(Tensor, TensorI32)> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        self.run_gate_lits(&refs)
    }

    /// Hot-path gate execution on pre-built literals.
    pub fn run_gate_lits(&self, inputs: &[&xla::Literal]) -> Result<(Tensor, TensorI32)> {
        let n = inputs
            .first()
            .and_then(|l| l.array_shape().ok())
            .map(|s| s.dims().first().copied().unwrap_or(0) as usize)
            .unwrap_or(0);
        let bucket = self.bucket_for("gate", n)?;
        anyhow::ensure!(bucket == n, "gate literal must be pre-padded to a bucket");
        let exe = self.exe("gate", bucket)?;
        let result = exe.execute::<&xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let (probs, idx) = result.to_tuple2()?;
        Ok((Tensor::from_literal(&probs)?, TensorI32::from_literal(&idx)?))
    }

    pub fn n_artifacts(&self) -> usize {
        self.exes.len()
    }
}

/// Shared, thread-safe engine handle.
///
/// Safety: the PJRT CPU client/executables are internally synchronized;
/// all mutation happens at `compile` time before the handle is shared.
#[derive(Clone, Debug)]
pub struct EngineHandle(Arc<Engine>);

unsafe impl Send for EngineHandle {}
unsafe impl Sync for EngineHandle {}

impl EngineHandle {
    pub fn new(engine: Engine) -> Self {
        Self(Arc::new(engine))
    }
}

impl std::ops::Deref for EngineHandle {
    type Target = Engine;

    fn deref(&self) -> &Engine {
        &self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ArtifactSet;
    use crate::runtime::artifacts_dir;

    fn engine() -> Option<(ArtifactSet, Engine)> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let set = ArtifactSet::load(&dir).unwrap();
        let eng = Engine::compile(&set.manifest).unwrap();
        Some((set, eng))
    }

    #[test]
    fn compiles_all_artifacts() {
        let Some((set, eng)) = engine() else { return };
        assert_eq!(eng.n_artifacts(), set.manifest.artifacts.len());
        assert_eq!(eng.bucket_for("ffn", 9).unwrap(), 16);
        assert_eq!(eng.bucket_for("ffn", 8).unwrap(), 8);
        assert!(eng.bucket_for("ffn", 1000).is_err());
    }

    #[test]
    fn ffn_stage_executes_and_matches_weights_contract() {
        let Some((set, eng)) = engine() else { return };
        let n = 8;
        let x = Tensor::zeros(vec![n, set.manifest.model.embed]);
        let wg = set.weights.get("layer0.shared_gate").unwrap();
        let wu = set.weights.get("layer0.shared_up").unwrap();
        let wd = set.weights.get("layer0.shared_down").unwrap();
        let y = eng.run1("ffn", n, &[&x, wg, wu, wd]).unwrap();
        assert_eq!(y.shape, vec![n, set.manifest.model.embed]);
        // Zero input through SwiGLU must give zeros.
        assert!(y.data.iter().all(|v| v.abs() < 1e-6));
    }

    #[test]
    fn gate_stage_executes() {
        let Some((set, eng)) = engine() else { return };
        let n = 16;
        let mut x = Tensor::zeros(vec![n, set.manifest.model.embed]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i % 13) as f32 - 6.0) * 0.1;
        }
        let w = set.weights.get("layer0.gate_w").unwrap();
        let (probs, idx) = eng.run_gate(n, &[&x, w]).unwrap();
        assert_eq!(probs.shape, vec![n, set.manifest.model.top_k]);
        assert_eq!(idx.shape, vec![n, set.manifest.model.top_k]);
        for row in 0..n {
            let s: f32 = (0..set.manifest.model.top_k).map(|k| probs.data[row * 2 + k]).sum();
            assert!((s - 1.0).abs() < 1e-4, "row {row} probs sum {s}");
        }
        assert!(idx.data.iter().all(|&e| (0..8).contains(&e)));
    }
}
