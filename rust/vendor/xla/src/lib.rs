//! Vendored stand-in for the `xla` PJRT bindings.
//!
//! The build image has no network and no PJRT shared library, so this
//! crate reproduces the small slice of the `xla` API that FinDEP's L3
//! coordinator uses, in two tiers:
//!
//! * **Builder-constructed computations execute for real.** `XlaBuilder`
//!   graphs (parameter / matmul / dot_general / softmax) are interpreted
//!   on the host in f32, so the Fig.-7 calibration probes and the
//!   `findep calibrate` subcommand measure genuine compute on this
//!   machine — the same operations, interpreted rather than JIT-compiled.
//! * **HLO-text artifacts do not execute.** `HloModuleProto::from_text_file`
//!   returns an error naming the limitation; the artifact-driven serving
//!   path (`runtime::engine`, `coordinator::*`) degrades exactly like a
//!   missing-artifacts checkout, which every caller already handles.
//!
//! `Literal` is a complete host-side container (f32 / i32 arrays and
//! tuples), so tensor conversion round-trips are fully functional.

use std::fmt;
use std::sync::Arc;

/// Stub error type (message-only).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

/// Element types FinDEP's artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    I32,
}

/// Array shape of a literal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parameter/operand shape for builder computations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shape {
    dims: Vec<i64>,
}

impl Shape {
    /// `Shape::array::<f32>(dims)` — the element type parameter is kept
    /// for API compatibility; only f32 arrays are interpreted.
    pub fn array<T>(dims: Vec<i64>) -> Shape {
        Shape { dims }
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side literal: dims + typed data (or a tuple of literals).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Element types extractable from a [`Literal`] via `to_vec`.
pub trait FromLiteral: Sized {
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl FromLiteral for f32 {
    fn extract(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(err("literal is not f32")),
        }
    }
}

impl FromLiteral for i32 {
    fn extract(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(err("literal is not i32")),
        }
    }
}

fn numel(dims: &[i64]) -> usize {
    dims.iter().product::<i64>().max(0) as usize
}

impl Literal {
    /// 1-D f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: Data::F32(data.to_vec()) }
    }

    /// Reinterpret the shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n = match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => return Err(err("cannot reshape a tuple literal")),
        };
        if numel(dims) != n {
            return Err(err(format!("reshape {:?} -> {:?}: element count mismatch", self.dims, dims)));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Build from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        bytes: &[u8],
    ) -> Result<Literal> {
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        if bytes.len() != numel(&dims) * 4 {
            return Err(err(format!(
                "untyped data is {} bytes, shape {:?} needs {}",
                bytes.len(),
                dims,
                numel(&dims) * 4
            )));
        }
        let data = match ty {
            ElementType::F32 => Data::F32(
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            ElementType::I32 => Data::I32(
                bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
        };
        Ok(Literal { dims, data })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            Data::Tuple(_) => Err(err("tuple literal has no array shape")),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    pub fn to_vec<T: FromLiteral>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Unwrap a 1-tuple.
    pub fn to_tuple1(&self) -> Result<Literal> {
        match &self.data {
            Data::Tuple(v) if v.len() == 1 => Ok(v[0].clone()),
            Data::Tuple(v) => Err(err(format!("expected 1-tuple, got {}-tuple", v.len()))),
            _ => Err(err("literal is not a tuple")),
        }
    }

    /// Unwrap a 2-tuple.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        match &self.data {
            Data::Tuple(v) if v.len() == 2 => Ok((v[0].clone(), v[1].clone())),
            Data::Tuple(v) => Err(err(format!("expected 2-tuple, got {}-tuple", v.len()))),
            _ => Err(err("literal is not a tuple")),
        }
    }
}

/// Opaque parsed-HLO handle. Text parsing is not supported by the stub;
/// the constructor reports that clearly so artifact-driven paths degrade
/// into the missing-artifacts behaviour their callers already handle.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(err(format!(
            "HLO text execution ({path}) requires the real PJRT runtime; \
             the vendored xla stub only interprets builder-constructed computations"
        )))
    }
}

// ---------------------------------------------------------------------
// Builder graph + interpreter.
// ---------------------------------------------------------------------

#[derive(Debug)]
enum Node {
    Parameter { index: usize },
    MatMul { lhs: Arc<Node>, rhs: Arc<Node> },
    DotGeneral {
        lhs: Arc<Node>,
        rhs: Arc<Node>,
        lhs_contracting: Vec<i64>,
        rhs_contracting: Vec<i64>,
        lhs_batch: Vec<i64>,
        rhs_batch: Vec<i64>,
    },
    Softmax { input: Arc<Node>, axis: i64 },
}

fn max_param_index(node: &Node) -> usize {
    match node {
        Node::Parameter { index } => index + 1,
        Node::MatMul { lhs, rhs } => max_param_index(lhs).max(max_param_index(rhs)),
        Node::DotGeneral { lhs, rhs, .. } => max_param_index(lhs).max(max_param_index(rhs)),
        Node::Softmax { input, .. } => max_param_index(input),
    }
}

/// Row-major strides for a dims vector.
fn strides(dims: &[i64]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1] as usize;
    }
    s
}

type Evaluated = (Vec<i64>, Vec<f32>);

fn eval(node: &Node, args: &[&Literal]) -> Result<Evaluated> {
    match node {
        Node::Parameter { index } => {
            let lit = args
                .get(*index)
                .ok_or_else(|| err(format!("missing argument for parameter {index}")))?;
            match &lit.data {
                Data::F32(v) => Ok((lit.dims.clone(), v.clone())),
                _ => Err(err("interpreter only supports f32 parameters")),
            }
        }
        Node::MatMul { lhs, rhs } => {
            let (ad, av) = eval(lhs, args)?;
            let (bd, bv) = eval(rhs, args)?;
            if ad.len() != 2 || bd.len() != 2 || ad[1] != bd[0] {
                return Err(err(format!("matmul shape mismatch: {ad:?} x {bd:?}")));
            }
            let (m, k, n) = (ad[0] as usize, ad[1] as usize, bd[1] as usize);
            let mut out = vec![0.0f32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let a = av[i * k + kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &bv[kk * n..(kk + 1) * n];
                    let orow = &mut out[i * n..(i + 1) * n];
                    for (o, b) in orow.iter_mut().zip(brow) {
                        *o += a * b;
                    }
                }
            }
            Ok((vec![m as i64, n as i64], out))
        }
        Node::DotGeneral { lhs, rhs, lhs_contracting, rhs_contracting, lhs_batch, rhs_batch } => {
            let (ad, av) = eval(lhs, args)?;
            let (bd, bv) = eval(rhs, args)?;
            dot_general(&ad, &av, &bd, &bv, lhs_contracting, rhs_contracting, lhs_batch, rhs_batch)
        }
        Node::Softmax { input, axis } => {
            let (dims, v) = eval(input, args)?;
            let rank = dims.len() as i64;
            let ax = if *axis < 0 { rank + axis } else { *axis };
            if ax < 0 || ax >= rank {
                return Err(err(format!("softmax axis {axis} out of range for rank {rank}")));
            }
            let ax = ax as usize;
            let size = dims[ax] as usize;
            let inner: usize = dims[ax + 1..].iter().product::<i64>() as usize;
            let outer: usize = dims[..ax].iter().product::<i64>() as usize;
            let mut out = vec![0.0f32; v.len()];
            for o in 0..outer {
                for i in 0..inner.max(1) {
                    let base = o * size * inner.max(1) + i;
                    let step = inner.max(1);
                    let mut mx = f32::NEG_INFINITY;
                    for s in 0..size {
                        mx = mx.max(v[base + s * step]);
                    }
                    let mut sum = 0.0f32;
                    for s in 0..size {
                        let e = (v[base + s * step] - mx).exp();
                        out[base + s * step] = e;
                        sum += e;
                    }
                    for s in 0..size {
                        out[base + s * step] /= sum;
                    }
                }
            }
            Ok((dims, out))
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dot_general(
    ad: &[i64],
    av: &[f32],
    bd: &[i64],
    bv: &[f32],
    lc: &[i64],
    rc: &[i64],
    lb: &[i64],
    rb: &[i64],
) -> Result<Evaluated> {
    if lc.len() != rc.len() || lb.len() != rb.len() {
        return Err(err("dot_general: dimension-list length mismatch"));
    }
    let is_in = |set: &[i64], d: usize| set.iter().any(|&x| x as usize == d);
    let lfree: Vec<usize> =
        (0..ad.len()).filter(|&d| !is_in(lc, d) && !is_in(lb, d)).collect();
    let rfree: Vec<usize> =
        (0..bd.len()).filter(|&d| !is_in(rc, d) && !is_in(rb, d)).collect();
    for (i, (&l, &r)) in lb.iter().zip(rb).enumerate() {
        if ad[l as usize] != bd[r as usize] {
            return Err(err(format!("dot_general: batch dim {i} size mismatch")));
        }
    }
    for (i, (&l, &r)) in lc.iter().zip(rc).enumerate() {
        if ad[l as usize] != bd[r as usize] {
            return Err(err(format!("dot_general: contracting dim {i} size mismatch")));
        }
    }
    let astr = strides(ad);
    let bstr = strides(bd);

    let batch_sizes: Vec<usize> = lb.iter().map(|&d| ad[d as usize] as usize).collect();
    let lfree_sizes: Vec<usize> = lfree.iter().map(|&d| ad[d] as usize).collect();
    let rfree_sizes: Vec<usize> = rfree.iter().map(|&d| bd[d] as usize).collect();
    let contract_sizes: Vec<usize> = lc.iter().map(|&d| ad[d as usize] as usize).collect();

    let prod = |v: &[usize]| v.iter().product::<usize>().max(1);
    let (nb, nlf, nrf, nc) =
        (prod(&batch_sizes), prod(&lfree_sizes), prod(&rfree_sizes), prod(&contract_sizes));

    // Decompose a linear index over `sizes` into per-dim offsets dotted
    // with `dim_strides`.
    let offset = |mut idx: usize, sizes: &[usize], dims: &[usize], str_: &[usize]| -> usize {
        let mut off = 0usize;
        for k in (0..sizes.len()).rev() {
            let d = idx % sizes[k];
            idx /= sizes[k];
            off += d * str_[dims[k]];
        }
        off
    };
    let lb_usize: Vec<usize> = lb.iter().map(|&d| d as usize).collect();
    let rb_usize: Vec<usize> = rb.iter().map(|&d| d as usize).collect();
    let lc_usize: Vec<usize> = lc.iter().map(|&d| d as usize).collect();
    let rc_usize: Vec<usize> = rc.iter().map(|&d| d as usize).collect();

    let mut out = vec![0.0f32; nb * nlf * nrf];
    for b in 0..nb {
        let a_b = offset(b, &batch_sizes, &lb_usize, &astr);
        let b_b = offset(b, &batch_sizes, &rb_usize, &bstr);
        for i in 0..nlf {
            let a_i = offset(i, &lfree_sizes, &lfree, &astr);
            for j in 0..nrf {
                let b_j = offset(j, &rfree_sizes, &rfree, &bstr);
                let mut acc = 0.0f32;
                for c in 0..nc {
                    let a_c = offset(c, &contract_sizes, &lc_usize, &astr);
                    let b_c = offset(c, &contract_sizes, &rc_usize, &bstr);
                    acc += av[a_b + a_i + a_c] * bv[b_b + b_j + b_c];
                }
                out[(b * nlf + i) * nrf + j] = acc;
            }
        }
    }
    let mut out_dims: Vec<i64> = batch_sizes.iter().map(|&d| d as i64).collect();
    out_dims.extend(lfree_sizes.iter().map(|&d| d as i64));
    out_dims.extend(rfree_sizes.iter().map(|&d| d as i64));
    Ok((out_dims, out))
}

/// Computation builder (API-compatible subset).
#[derive(Debug)]
pub struct XlaBuilder {
    _name: String,
}

impl XlaBuilder {
    pub fn new(name: &str) -> XlaBuilder {
        XlaBuilder { _name: name.to_string() }
    }

    pub fn parameter_s(&self, index: i64, _shape: &Shape, _name: &str) -> Result<XlaOp> {
        if index < 0 {
            return Err(err("negative parameter index"));
        }
        Ok(XlaOp { node: Arc::new(Node::Parameter { index: index as usize }) })
    }
}

/// A node in a builder computation.
#[derive(Debug, Clone)]
pub struct XlaOp {
    node: Arc<Node>,
}

impl XlaOp {
    pub fn matmul(&self, rhs: &XlaOp) -> Result<XlaOp> {
        Ok(XlaOp { node: Arc::new(Node::MatMul { lhs: self.node.clone(), rhs: rhs.node.clone() }) })
    }

    pub fn dot_general(
        &self,
        rhs: &XlaOp,
        lhs_contracting: &[i64],
        rhs_contracting: &[i64],
        lhs_batch: &[i64],
        rhs_batch: &[i64],
    ) -> Result<XlaOp> {
        Ok(XlaOp {
            node: Arc::new(Node::DotGeneral {
                lhs: self.node.clone(),
                rhs: rhs.node.clone(),
                lhs_contracting: lhs_contracting.to_vec(),
                rhs_contracting: rhs_contracting.to_vec(),
                lhs_batch: lhs_batch.to_vec(),
                rhs_batch: rhs_batch.to_vec(),
            }),
        })
    }

    pub fn softmax(&self, axis: i64) -> Result<XlaOp> {
        Ok(XlaOp { node: Arc::new(Node::Softmax { input: self.node.clone(), axis }) })
    }

    pub fn build(&self) -> Result<XlaComputation> {
        Ok(XlaComputation { root: Some(self.node.clone()) })
    }
}

/// A built computation: interpretable when builder-constructed, opaque
/// (uncompilable) when created from an HLO proto.
#[derive(Debug)]
pub struct XlaComputation {
    root: Option<Arc<Node>>,
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { root: None }
    }
}

/// Host "PJRT" client. `cpu()` always succeeds — the interpreter needs
/// no runtime library.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "host-interpreter".to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match &comp.root {
            Some(root) => {
                let n_params = max_param_index(root);
                Ok(PjRtLoadedExecutable { root: root.clone(), n_params })
            }
            None => Err(err(
                "compiling HLO-proto computations requires the real PJRT runtime \
                 (vendored stub interprets builder graphs only)",
            )),
        }
    }
}

/// A compiled (interpretable) executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    root: Arc<Node>,
    n_params: usize,
}

impl PjRtLoadedExecutable {
    /// Execute with owned or borrowed literals; returns the usual
    /// per-device, per-output buffer nesting (`[0][0]` for our 1-device
    /// single-output computations).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        if args.len() < self.n_params {
            return Err(err(format!(
                "executable needs {} arguments, got {}",
                self.n_params,
                args.len()
            )));
        }
        let refs: Vec<&Literal> = args.iter().map(|l| l.borrow()).collect();
        let (dims, data) = eval(&self.root, &refs)?;
        Ok(vec![vec![PjRtBuffer(Literal { dims, data: Data::F32(data) })]])
    }
}

/// A device buffer (host literal in the stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer(Literal);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.0.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).reshape(&[2, 3]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn untyped_bytes_round_trip() {
        let vals = [1.5f32, -2.0, 0.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let l =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vals.to_vec());
        let ivals = [7i32, -9];
        let bytes: Vec<u8> = ivals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let l =
            Literal::create_from_shape_and_untyped_data(ElementType::I32, &[2], &bytes).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), ivals.to_vec());
    }

    #[test]
    fn matmul_interprets() {
        let b = XlaBuilder::new("t");
        let x = b.parameter_s(0, &Shape::array::<f32>(vec![2, 3]), "x").unwrap();
        let y = b.parameter_s(1, &Shape::array::<f32>(vec![3, 2]), "y").unwrap();
        let comp = x.matmul(&y).unwrap().build().unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&comp).unwrap();
        let xl = Literal::vec1(&[1., 2., 3., 4., 5., 6.]).reshape(&[2, 3]).unwrap();
        let yl = Literal::vec1(&[1., 0., 0., 1., 1., 1.]).reshape(&[3, 2]).unwrap();
        let out = exe.execute::<Literal>(&[xl, yl]).unwrap()[0][0].to_literal_sync().unwrap();
        assert_eq!(out.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![4., 5., 10., 11.]);
    }

    #[test]
    fn attention_shaped_dot_general_and_softmax() {
        // scores[b,i,j] = sum_d q[b,i,d] k[b,j,d]; probs = softmax(-1);
        // out[b,i,d] = sum_j probs[b,i,j] v[b,j,d].
        let b = XlaBuilder::new("attn");
        let shape = Shape::array::<f32>(vec![2, 3, 4]);
        let q = b.parameter_s(0, &shape, "q").unwrap();
        let k = b.parameter_s(1, &shape, "k").unwrap();
        let v = b.parameter_s(2, &shape, "v").unwrap();
        let scores = q.dot_general(&k, &[2], &[2], &[0], &[0]).unwrap();
        let probs = scores.softmax(-1).unwrap();
        let comp = probs.dot_general(&v, &[2], &[1], &[0], &[0]).unwrap().build().unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&comp).unwrap();
        let data: Vec<f32> = (0..24).map(|i| (i % 5) as f32 * 0.1).collect();
        let lit = Literal::vec1(&data).reshape(&[2, 3, 4]).unwrap();
        let out = exe
            .execute::<&Literal>(&[&lit, &lit, &lit])
            .unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        assert_eq!(out.array_shape().unwrap().dims(), &[2, 3, 4]);
        let vals = out.to_vec::<f32>().unwrap();
        assert!(vals.iter().all(|x| x.is_finite()));
        // Each output row is a convex combination of v rows, so it must
        // stay within the min/max of the v values.
        let (mn, mx) = data.iter().fold((f32::MAX, f32::MIN), |(a, b), &x| (a.min(x), b.max(x)));
        assert!(vals.iter().all(|&x| x >= mn - 1e-5 && x <= mx + 1e-5));
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let b = XlaBuilder::new("sm");
        let x = b.parameter_s(0, &Shape::array::<f32>(vec![3, 5]), "x").unwrap();
        let comp = x.softmax(-1).unwrap().build().unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&comp).unwrap();
        let data: Vec<f32> = (0..15).map(|i| i as f32 - 7.0).collect();
        let lit = Literal::vec1(&data).reshape(&[3, 5]).unwrap();
        let out = exe.execute::<Literal>(&[lit]).unwrap()[0][0].to_literal_sync().unwrap();
        let v = out.to_vec::<f32>().unwrap();
        for r in 0..3 {
            let s: f32 = v[r * 5..(r + 1) * 5].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn hlo_text_is_rejected_clearly() {
        let e = HloModuleProto::from_text_file("/tmp/nope.hlo").unwrap_err();
        assert!(format!("{e}").contains("PJRT"));
        let comp = XlaComputation { root: None };
        assert!(PjRtClient::cpu().unwrap().compile(&comp).is_err());
    }

    #[test]
    fn tuple_accessors() {
        let a = Literal::vec1(&[1.0]);
        let t = Literal { dims: vec![], data: Data::Tuple(vec![a.clone()]) };
        assert_eq!(t.to_tuple1().unwrap(), a);
        assert!(a.to_tuple1().is_err());
        let t2 = Literal { dims: vec![], data: Data::Tuple(vec![a.clone(), a.clone()]) };
        assert!(t2.to_tuple2().is_ok());
        assert!(t2.to_tuple1().is_err());
    }
}
