//! Continuous batching, event-driven: a pure planning state machine
//! ([`super::planner`]) behind one mutex, drained by condvar-parked
//! serving workers ([`super::executor`]) that lease pipeline replicas
//! from a shared pool (the EPS-MoE / MegaScale-Infer serving shape —
//! many in-flight micro-batches keep the disaggregated attention and
//! expert groups busy, with no polling cadence anywhere).
//!
//! ```text
//!   submit() ──▶ ┌─────────────────────────┐     worker 0 .. W-1
//!        │       │ Planner (one mutex)     │  (parked on the `work`
//!        │       │  bounded submit queue   │◀── condvar; window-full,
//!        │       │  decode lane (priority) │    linger-expiry, or
//!        │       │  linger window (FIFO)   │    shutdown-drain wakes
//!        │       └─────────────────────────┘    exactly one)
//!        │  decode steps ▲      │ Execute(batch)
//!        │  (KV-growing  │      ▼
//!        │   re-entry)   │   ReplicaPool lease ──▶ Server::serve_batch
//!        │               └──────┤ (shared Registry + PlanCache)
//!        ◀──── final responses ─┘
//! ```
//!
//! Invariants (unchanged from the retired thread-pool design, which
//! lives on as the measured baseline in [`super::threadpool`]):
//!
//! * **FIFO draining** — windows form strictly in arrival order; with
//!   one worker and no decode traffic, responses come back in
//!   submission order regardless of how the stream was cut into
//!   batches. Decode re-entries take priority over fresh submissions
//!   (finish what is in flight), so equal-output requests still
//!   complete in submission order.
//! * **Continuous decode batching** — a request submitted with
//!   `output_len > 0` re-enters the planner after its prefill as one
//!   decode step per output token, KV growing each step; each window
//!   may therefore mix phases, and the server schedules its prefill and
//!   decode chunks under separate phase-keyed cached plans. The client
//!   receives exactly one response, after the last step.
//! * **Backpressure** — the submit queue is bounded: `submit` parks on
//!   the `space` condvar while it is full, `try_submit` rejects (and
//!   counts `queue_rejected`). The decode re-entry lane is unbounded so
//!   workers can never deadlock against a full queue; its depth is
//!   bounded by the requests already admitted.
//! * **Event-driven idleness** — an idle batcher performs no wakeups:
//!   every worker parks until a submit, a decode re-entry, a linger
//!   expiry, or shutdown arrives (the baseline woke every 200µs to
//!   re-poll its decode lane).
//! * **Per-request latency** — each final response's `latency_s` is
//!   rewritten to the true submit→response time (prefill plus every
//!   decode step), and each queue pass's wait lands in the shared
//!   registry's `queue_wait` histogram.
//! * **Shared planning** — workers share one [`PlanCache`], so an
//!   Adaptive shape solved on any worker is a hit on all of them —
//!   prefill and decode shapes memoized separately, hits returned as
//!   `Arc<Solution>` without cloning plan bodies under a lock.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::Phase;
use crate::coordinator::executor::{run_worker, EventCore};
use crate::coordinator::links::LinkDelay;
use crate::coordinator::moe::ModelHandle;
use crate::coordinator::planner::{PlannerConfig, QueuedRequest};
use crate::coordinator::server::{EmbeddedRequest, Policy, ReplicaPool, Response, Server};
use crate::metrics::Registry;
use crate::solver::PlanCache;

/// Continuous-batcher knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// EG workers per pipeline replica.
    pub eg: usize,
    /// Optional α-β link delay per replica.
    pub link_delay: Option<LinkDelay>,
    /// Scheduling policy applied to every assembled batch.
    pub policy: Policy,
    /// Most requests per assembled batch (the size bucket cap).
    pub max_batch: usize,
    /// Bounded submit-queue depth (`submit` blocks beyond it).
    pub queue_depth: usize,
    /// Serving workers = pipeline replicas = in-flight batches.
    pub workers: usize,
    /// How long a window lingers to fill after the first request
    /// arrives.
    pub linger: Duration,
    /// Memoize Adaptive plans per shape (shared across workers).
    pub cache_plans: bool,
    /// Pick each replica's Adaptive planning split with the split-search
    /// solver layer at startup instead of the fixed `(1, eg)` view.
    pub auto_split: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            eg: 2,
            link_delay: None,
            policy: Policy::Adaptive,
            max_batch: 8,
            queue_depth: 64,
            workers: 2,
            linger: Duration::from_millis(1),
            cache_plans: true,
            auto_split: false,
        }
    }
}

/// The continuous batcher: owns the event core and the worker pool.
/// Dropping it drains in-flight work and joins every thread.
pub struct Batcher {
    core: Arc<EventCore>,
    resp_rx: Receiver<Response>,
    metrics: Arc<Registry>,
    plan_cache: Arc<PlanCache>,
    /// Expected `S·M` element count per request — malformed requests
    /// are rejected at submit time so they can never sink a whole
    /// assembled batch inside a worker.
    req_elems: usize,
    threads: Vec<JoinHandle<()>>,
}

impl Batcher {
    /// Spin up `cfg.workers` serving replicas over one loaded model,
    /// planning against the hand-written testbed constants.
    pub fn new(model: ModelHandle, cfg: BatcherConfig) -> Result<Batcher> {
        Self::with_profile(model, cfg, None)
    }

    /// [`Batcher::new`] with every replica's Adaptive planner driven by
    /// a calibration profile's measured constants. The profile is
    /// applied before the optional auto-split selection, so the split
    /// itself is chosen under the calibrated view; its fingerprint
    /// rides every plan-cache key, keeping calibrated and
    /// hand-constant plans in disjoint keyspaces of the shared cache.
    pub fn with_profile(
        model: ModelHandle,
        cfg: BatcherConfig,
        profile: Option<&crate::perfmodel::profile::CalibrationProfile>,
    ) -> Result<Batcher> {
        let metrics = Arc::new(Registry::new());
        let plan_cache = Arc::new(PlanCache::new());
        let workers = cfg.workers.max(1);
        let req_elems = model.seq_len * model.model.embed;
        let prompt_len = model.seq_len;

        let core = Arc::new(EventCore::new(PlannerConfig {
            max_batch: cfg.max_batch,
            linger: cfg.linger,
            queue_depth: cfg.queue_depth,
        }));

        // The split search is deterministic in (model, plan testbed,
        // seq), so run it on the first replica only and hand the chosen
        // split to the rest — re-running it per replica would also
        // re-clear the shared plan cache under earlier replicas.
        let mut replicas = Vec::with_capacity(workers);
        let mut chosen_split = None;
        for _ in 0..workers {
            let mut server = Server::with_shared(
                model.clone(),
                cfg.eg,
                cfg.link_delay,
                metrics.clone(),
                plan_cache.clone(),
            )?;
            server.cache_plans = cfg.cache_plans;
            if let Some(p) = profile {
                server.set_calibration_profile(p);
            }
            if cfg.auto_split {
                match chosen_split {
                    None => chosen_split = Some(server.select_plan_split()),
                    Some(split) => server.plan_split = split,
                }
            }
            replicas.push(server);
        }
        let pool = Arc::new(ReplicaPool::new(replicas));

        let (resp_tx, resp_rx) = channel::<Response>();
        let mut threads = Vec::with_capacity(workers);
        for w in 0..workers {
            // Register before spawning: a submit racing the spawn must
            // never observe an empty pool and refuse legal work.
            core.register_worker();
            let core = core.clone();
            let metrics = metrics.clone();
            let pool = pool.clone();
            let resp_tx = resp_tx.clone();
            let policy = cfg.policy;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("findep-serve{w}"))
                    .spawn(move || {
                        let c = core.clone();
                        let m = metrics.clone();
                        run_worker(&core, &metrics, move |batch| {
                            serve_assembled(&c, &pool, &m, &resp_tx, policy, prompt_len, batch)
                        })
                    })
                    .context("spawn serving worker")?,
            );
        }

        Ok(Batcher { core, resp_rx, metrics, plan_cache, req_elems, threads })
    }

    /// A malformed request must fail at the submission boundary — once
    /// assembled, `serve_batch` would reject the whole batch and every
    /// co-batched request would silently lose its response.
    fn validate(&self, req: &EmbeddedRequest) -> Result<()> {
        anyhow::ensure!(
            req.hidden.data.len() == self.req_elems,
            "request {} has {} elements, expected {} (S·M)",
            req.id,
            req.hidden.data.len(),
            self.req_elems
        );
        Ok(())
    }

    /// Enqueue a request, parking while the queue is full
    /// (backpressure). Errors on malformed requests or after shutdown.
    /// A request with `output_len > 0` re-enters the stream as that
    /// many KV-growing decode steps after its prefill completes; the
    /// single response arrives once the last step finishes.
    pub fn submit(&self, req: EmbeddedRequest) -> Result<()> {
        self.validate(&req)?;
        self.core.submit(req)?;
        self.metrics.inc("queued", 1);
        Ok(())
    }

    /// Non-blocking enqueue: `Ok(false)` when the queue is full (the
    /// request is rejected and counted).
    pub fn try_submit(&self, req: EmbeddedRequest) -> Result<bool> {
        self.validate(&req)?;
        if self.core.try_submit(req)? {
            self.metrics.inc("queued", 1);
            Ok(true)
        } else {
            self.metrics.inc("queue_rejected", 1);
            Ok(false)
        }
    }

    /// Next completed response, or `None` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.resp_rx.recv_timeout(timeout).ok()
    }

    /// Collect up to `n` responses, waiting at most `timeout` for each.
    pub fn drain(&self, n: usize, timeout: Duration) -> Vec<Response> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match self.recv_timeout(timeout) {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plan_cache
    }

    /// Requests anywhere in the system still owed a final response.
    pub fn open(&self) -> usize {
        self.core.open()
    }

    /// Total worker condvar wakeups since startup (an idle batcher
    /// accumulates none — the event-driven regression surface).
    pub fn wakeups(&self) -> u64 {
        self.core.wakeups()
    }

    /// Wakeups whose poll found nothing to execute.
    pub fn idle_wakeups(&self) -> u64 {
        self.core.idle_wakeups()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Close the planner: admitted submits and in-flight decode
        // loops drain (`open` reaches zero), then every worker exits.
        self.core.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Releases a batch's `open` slots when dropped — including during a
/// panic unwind, so a worker dying mid-batch can never strand the
/// shutdown drain waiting on slots nobody will release. Requests that
/// re-enter as decode steps re-add their slot explicitly before this
/// guard drops (transient over-count, never under-count — the drain
/// must not observe a spurious zero).
struct OpenSlots<'a> {
    core: &'a EventCore,
    n: usize,
}

impl Drop for OpenSlots<'_> {
    fn drop(&mut self) {
        self.core.release_open(self.n);
    }
}

/// Execute one assembled window on a leased replica, then per request
/// either re-enter the next KV-grown decode step (output remaining) or
/// emit the final response with its true submit→response latency.
fn serve_assembled(
    core: &EventCore,
    pool: &ReplicaPool,
    metrics: &Registry,
    resp_tx: &Sender<Response>,
    policy: Policy,
    prompt_len: usize,
    batch: Vec<QueuedRequest>,
) {
    let mut reqs = Vec::with_capacity(batch.len());
    let mut meta = Vec::with_capacity(batch.len());
    for q in batch {
        meta.push((q.submitted, q.req.phase, q.req.output_len));
        reqs.push(q.req);
    }
    let slots = OpenSlots { core, n: reqs.len() };
    // With workers == replicas the lease is immediate; the pool exists
    // so execution capacity is a handoff, not a thread's identity.
    let server = pool.lease();
    match server.serve_batch(&reqs, policy) {
        Ok((responses, _stats)) => {
            for (mut resp, (submitted, phase, output_len)) in responses.into_iter().zip(meta) {
                if output_len > 0 {
                    // Autoregressive re-entry: this pass's output is
                    // the next step's input, the KV cache grows by the
                    // entry this pass wrote. The re-entry keeps the
                    // request open: add its slot before the batch
                    // guard releases this pass's.
                    let next = EmbeddedRequest {
                        id: resp.id,
                        hidden: resp.hidden,
                        phase: Phase::Decode { kv_len: phase.next_kv_len(prompt_len) },
                        output_len: output_len - 1,
                    };
                    metrics.inc("decode_steps", 1);
                    core.add_open(1);
                    core.reenter_decode(QueuedRequest::reentry(next, submitted));
                    continue;
                }
                resp.latency_s = submitted.elapsed().as_secs_f64();
                metrics.observe("request_latency", resp.latency_s);
                // A gone receiver just means the client stopped
                // listening; the drain accounting still completes.
                let _ = resp_tx.send(resp);
            }
        }
        Err(e) => {
            // Drop the batch but keep the replica alive; callers see
            // the gap via the serve_errors counter. Every request of
            // the failed batch is done for (the guard releases their
            // slots).
            metrics.inc("serve_errors", 1);
            eprintln!("serving worker: batch failed: {e:#}");
        }
    }
    drop(server);
    drop(slots);
}
