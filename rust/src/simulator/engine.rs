//! The discrete-event engine.
//!
//! Semantics (matching §3.2's job-shop model):
//! * each resource executes its issue queue in order, non-preemptively;
//! * a task starts at the max of (a) its resource becoming free after the
//!   previous queued task and (b) all of its Eq.-5 dependencies
//!   finishing;
//! * zero-duration tasks (e.g. absent shared experts) still sequence
//!   correctly but occupy no time.
//!
//! The engine runs a Kahn-style ready propagation over the union of
//! dependency edges and resource-order edges, which yields the exact
//! fixed point of the recurrences in §4.2 in O(V + E).
//!
//! ## Hot-path contract
//!
//! [`simulate_into`] executes into a reusable [`SimBuffers`] arena —
//! CSR adjacency, indegrees, the ready stack, and the start/finish
//! vectors are all rewritten in place, so Algorithm 1's candidate loop
//! performs zero allocations per probe once the arena is warm.
//!
//! Plans with the same [`TopologyKey`] (same `(r1, r2, order,
//! shared-tasks, n_layers)` shape) share their dependency structure and
//! differ only in task durations, so the arena additionally memoizes
//! the built CSR adjacency / indegree / resource-predecessor arrays per
//! key: a repeat shape takes a duration-only fast path that skips both
//! CSR construction passes and runs Kahn propagation directly against
//! the cached topology. The fast path is bit-identical to a full
//! rebuild (pinned by tests); plans without a key (hand-built test
//! plans) always rebuild into scratch storage.
//!
//! [`simulate`] is the one-shot wrapper. Cyclic plans (impossible from
//! `Plan::build`, but reachable from hand-built or corrupted
//! `PlanConfig` search states) surface as a [`SimError`] naming the
//! stuck task and its resource queue instead of aborting the solver.

use std::collections::HashMap;

use crate::sched::{Plan, Resource, TopologyKey};

/// Execution schedule of one plan.
#[derive(Debug, Clone, Default)]
pub struct SimResult {
    /// Start time per task (seconds), same indexing as `plan.tasks`.
    pub start: Vec<f64>,
    /// Finish time per task.
    pub finish: Vec<f64>,
    pub makespan: f64,
}

impl SimResult {
    /// Tokens/s for the simulated forward pass. Degenerate plans whose
    /// makespan is zero or non-finite (e.g. an all-zero-duration plan
    /// from an S=0 / no-shared edge case) report 0.0 rather than
    /// `inf`/NaN, so they can never win Algorithm 1's argmax.
    pub fn throughput_tokens(&self, plan: &Plan) -> f64 {
        if !self.makespan.is_finite() || self.makespan <= 0.0 {
            return 0.0;
        }
        plan.total_tokens / self.makespan
    }
}

/// A plan that cannot execute: some task never became ready because the
/// union of dependency and resource-order edges contains a cycle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimError {
    /// Label of one task on the cycle (first stuck task by index).
    pub task: String,
    /// Name of the resource queue that task is issued on.
    pub resource: &'static str,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "plan contains a cycle: task {} on the {} queue never became ready",
            self.task, self.resource
        )
    }
}

impl std::error::Error for SimError {}

/// One built topology: the duration-independent half of a simulation —
/// resource-order predecessors, the CSR dependents adjacency (dep edges
/// + resource-order edges), and the pristine indegree vector Kahn
/// propagation starts from.
#[derive(Debug, Clone, Default)]
struct Topology {
    /// Resource-order predecessor per task (`u32::MAX` = none).
    res_pred: Vec<u32>,
    /// CSR offsets into `adj` (length n + 1).
    adj_off: Vec<u32>,
    /// CSR dependents adjacency.
    adj: Vec<u32>,
    /// Initial unmet-predecessor count per task (deps + resource
    /// order); copied into the working vector per simulation.
    indeg0: Vec<u32>,
}

impl Topology {
    fn size_u32s(&self) -> usize {
        self.res_pred.len() + self.adj_off.len() + self.adj.len() + self.indeg0.len()
    }
}

/// Total u32s the per-arena topology cache may hold (~16 MiB) before it
/// is dropped wholesale — a crude but deterministic bound that keeps a
/// long-lived search evaluator from accumulating every shape it ever
/// probed.
const TOPO_CACHE_BUDGET_U32S: usize = 4 << 20;

/// Reusable simulation arena: one warm `SimBuffers` makes every
/// subsequent [`simulate_into`] allocation-free, and the per-key
/// topology cache makes repeat shapes skip CSR construction entirely.
#[derive(Debug, Clone, Default)]
pub struct SimBuffers {
    result: SimResult,
    /// Working unmet-predecessor counts (consumed by Kahn propagation).
    indeg: Vec<u32>,
    /// Ready stack.
    ready: Vec<u32>,
    /// Fill cursor scratch for CSR construction.
    cursor: Vec<u32>,
    /// Rebuilt-per-call topology for plans without a key.
    scratch: Topology,
    /// Memoized topologies for canonical plans, keyed by shape.
    cache: HashMap<TopologyKey, Topology>,
    cached_u32s: usize,
    topo_hits: u64,
    topo_misses: u64,
}

impl SimBuffers {
    pub fn new() -> Self {
        Self::default()
    }

    /// The most recent *successful* result (empty before the first
    /// successful simulation, and reset to empty after a cyclic-plan
    /// error).
    pub fn result(&self) -> &SimResult {
        &self.result
    }

    /// Simulations that reused a cached topology (duration-only fast
    /// path, no CSR construction).
    pub fn topo_hits(&self) -> u64 {
        self.topo_hits
    }

    /// Simulations of a keyed plan that had to build its topology.
    pub fn topo_misses(&self) -> u64 {
        self.topo_misses
    }

    /// Number of memoized topologies currently held.
    pub fn cached_topologies(&self) -> usize {
        self.cache.len()
    }
}

const NO_PRED: u32 = u32::MAX;

/// Build `plan`'s duration-independent structure into `topo` (CSR in
/// two passes, exactly the seed's construction order so downstream
/// traversal — and therefore the schedule — is bit-identical).
fn build_topology(plan: &Plan, topo: &mut Topology, cursor: &mut Vec<u32>) {
    let n = plan.tasks.len();
    topo.indeg0.clear();
    topo.indeg0.extend((0..n).map(|i| plan.deps(i).len() as u32));
    topo.res_pred.clear();
    topo.res_pred.resize(n, NO_PRED);
    for q in &plan.issue_order {
        for w in q.windows(2) {
            topo.res_pred[w[1] as usize] = w[0];
            topo.indeg0[w[1] as usize] += 1;
        }
    }

    // Pass 1: out-degree per task.
    cursor.clear();
    cursor.resize(n, 0);
    for i in 0..n {
        for &d in plan.deps(i) {
            cursor[d as usize] += 1;
        }
    }
    for q in &plan.issue_order {
        for w in q.windows(2) {
            cursor[w[0] as usize] += 1;
        }
    }
    // Prefix sums -> offsets; cursor becomes the fill position.
    topo.adj_off.clear();
    topo.adj_off.reserve(n + 1);
    let mut acc = 0u32;
    topo.adj_off.push(0);
    for i in 0..n {
        acc += cursor[i];
        topo.adj_off.push(acc);
        cursor[i] = topo.adj_off[i];
    }
    // Pass 2: fill.
    topo.adj.clear();
    topo.adj.resize(acc as usize, 0);
    for i in 0..n {
        for &d in plan.deps(i) {
            let c = &mut cursor[d as usize];
            topo.adj[*c as usize] = i as u32;
            *c += 1;
        }
    }
    for q in &plan.issue_order {
        for w in q.windows(2) {
            let c = &mut cursor[w[0] as usize];
            topo.adj[*c as usize] = w[1];
            *c += 1;
        }
    }
}

/// Simulate a plan into a reusable arena. Returns a borrow of the
/// schedule, or a [`SimError`] naming the stuck task if the plan is
/// cyclic — callers in the solver treat that as a skipped candidate.
pub fn simulate_into<'a>(plan: &Plan, buf: &'a mut SimBuffers) -> Result<&'a SimResult, SimError> {
    let n = plan.tasks.len();
    let key = plan.topology_key();
    {
        let SimBuffers {
            result,
            indeg,
            ready,
            cursor,
            scratch,
            cache,
            cached_u32s,
            topo_hits,
            topo_misses,
        } = &mut *buf;

        // --- Topology: cached per shape, rebuilt only on a miss. ------
        let topo: &Topology = if let Some(k) = key {
            if cache.contains_key(&k) {
                *topo_hits += 1;
            } else {
                *topo_misses += 1;
                let mut t = Topology::default();
                build_topology(plan, &mut t, cursor);
                let sz = t.size_u32s();
                if *cached_u32s + sz > TOPO_CACHE_BUDGET_U32S {
                    cache.clear();
                    *cached_u32s = 0;
                }
                *cached_u32s += sz;
                cache.insert(k, t);
            }
            cache.get(&k).expect("topology just ensured")
        } else {
            build_topology(plan, scratch, cursor);
            scratch
        };
        debug_assert_eq!(topo.indeg0.len(), n, "cached topology does not match plan shape");

        // --- Kahn ready propagation (duration-dependent half). --------
        indeg.clear();
        indeg.extend_from_slice(&topo.indeg0);
        result.start.clear();
        result.start.resize(n, 0.0);
        result.finish.clear();
        result.finish.resize(n, 0.0);
        ready.clear();
        ready.extend((0..n as u32).filter(|&i| indeg[i as usize] == 0));
        let mut done = 0usize;
        while let Some(i) = ready.pop() {
            let i = i as usize;
            let mut s = 0.0f64;
            for &d in plan.deps(i) {
                s = s.max(result.finish[d as usize]);
            }
            let p = topo.res_pred[i];
            if p != NO_PRED {
                s = s.max(result.finish[p as usize]);
            }
            result.start[i] = s;
            result.finish[i] = s + plan.tasks[i].duration;
            done += 1;
            for k in topo.adj_off[i] as usize..topo.adj_off[i + 1] as usize {
                let nidx = topo.adj[k] as usize;
                indeg[nidx] -= 1;
                if indeg[nidx] == 0 {
                    ready.push(nidx as u32);
                }
            }
        }
        if done != n {
            let stuck = (0..n).find(|&i| indeg[i] > 0).unwrap_or(0);
            // Leave the arena's result in a consistent (empty) state
            // rather than a half-written schedule mixed with a stale
            // makespan.
            result.start.clear();
            result.finish.clear();
            result.makespan = 0.0;
            return Err(SimError {
                task: plan.tasks[stuck].label(),
                resource: plan.tasks[stuck].resource().name(),
            });
        }
        result.makespan = result.finish.iter().copied().fold(0.0f64, f64::max);
    }
    Ok(&buf.result)
}

/// Simulate a plan (one-shot allocation path). Panics on cyclic plans —
/// every plan produced by `Plan::build` is acyclic by construction and
/// this is enforced by tests; searcher-facing code uses
/// [`simulate_into`] and degrades cyclic candidates into skips.
pub fn simulate(plan: &Plan) -> SimResult {
    let mut buf = SimBuffers::new();
    if let Err(e) = simulate_into(plan, &mut buf) {
        panic!("{e}");
    }
    buf.result
}

/// Busy intervals of one resource, sorted by start time. Total order
/// (`f64::total_cmp`), so a NaN interval from a corrupted plan sorts
/// deterministically instead of panicking trace tooling.
pub fn resource_intervals(plan: &Plan, sim: &SimResult, res: Resource) -> Vec<(f64, f64)> {
    let mut iv: Vec<(f64, f64)> = plan.issue_order[res.index()]
        .iter()
        .map(|&t| (sim.start[t as usize], sim.finish[t as usize]))
        .filter(|(s, f)| f > s)
        .collect();
    iv.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    iv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};
    use crate::perfmodel::{LinearModel, StageModels};
    use crate::sched::{Order, PlanConfig, TaskKind};

    fn models() -> StageModels {
        StageModels::new(&ModelConfig::deepseek_v2(4), &Testbed::a(), GroupSplit::new(3, 5), 2048)
    }

    fn build(m_a: usize, r1: usize, r2: usize, order: Order, layers: usize) -> Plan {
        let sm = models();
        let m_e = sm.m_e(m_a as f64, r2);
        Plan::build(&sm, PlanConfig::findep(m_a, r1, r2, m_e, order), layers, 3, 2048)
    }

    #[test]
    fn sequential_naive_matches_hand_sum() {
        let sm = models();
        let m_e = sm.m_e(2.0, 1);
        let plan = Plan::build(&sm, PlanConfig::naive(2, m_e), 1, 3, 2048);
        let sim = simulate(&plan);
        // naive, 1 layer: attn(+shared fused) -> a2e -> expert -> e2a
        let expect = sm.attn_time(2.0)
            + sm.shared_time(2.0)
            + sm.comm_time(m_e)
            + sm.expert_time(m_e)
            + sm.comm_time(m_e);
        assert!((sim.makespan - expect).abs() < 1e-12, "{} vs {}", sim.makespan, expect);
    }

    #[test]
    fn dependencies_respected() {
        let plan = build(2, 2, 3, Order::Asas, 3);
        let sim = simulate(&plan);
        for i in 0..plan.n_tasks() {
            for &d in plan.deps(i) {
                assert!(
                    sim.start[i] >= sim.finish[d as usize] - 1e-12,
                    "task {} starts before dep {} finishes",
                    plan.tasks[i].label(),
                    plan.tasks[d as usize].label()
                );
            }
        }
    }

    #[test]
    fn resources_never_overlap() {
        for order in Order::both() {
            let plan = build(2, 3, 2, order, 4);
            let sim = simulate(&plan);
            for res in Resource::ALL {
                let iv = resource_intervals(&plan, &sim, res);
                for w in iv.windows(2) {
                    assert!(
                        w[1].0 >= w[0].1 - 1e-12,
                        "overlap on {:?}: {:?} then {:?}",
                        res,
                        w[0],
                        w[1]
                    );
                }
            }
        }
    }

    #[test]
    fn pipelining_beats_naive() {
        let sm = models();
        let m_e1 = sm.m_e(4.0, 1);
        let naive = Plan::build(&sm, PlanConfig::naive(4, m_e1), 4, 3, 2048);
        let pp = Plan::build(&sm, PlanConfig::pppipe(2, 2, sm.m_e(2.0, 1)), 4, 3, 2048);
        let t_naive = simulate(&naive).makespan;
        let t_pp = simulate(&pp).makespan;
        assert!(t_pp < t_naive, "pppipe {t_pp} !< naive {t_naive}");
    }

    #[test]
    fn fine_graining_can_help() {
        // Same (m_a, r1), FinDEP r2>1 must not be slower than r2=1 when
        // kernel-launch overhead is small relative to transfer time.
        let sm = models();
        let c1 = PlanConfig::findep(2, 2, 1, sm.m_e(2.0, 1), Order::Asas);
        let c4 = PlanConfig::findep(2, 2, 4, sm.m_e(2.0, 4), Order::Asas);
        let t1 = simulate(&Plan::build(&sm, c1, 4, 3, 2048)).makespan;
        let t4 = simulate(&Plan::build(&sm, c4, 4, 3, 2048)).makespan;
        assert!(t4 <= t1 * 1.02, "r2=4 {t4} much worse than r2=1 {t1}");
    }

    #[test]
    fn zero_duration_shared_tasks_are_free() {
        // Qwen-style (no shared): ASAS and AASS must coincide.
        let m = ModelConfig::qwen3_moe(4);
        let sm = StageModels::new(&m, &Testbed::a(), GroupSplit::new(4, 4), 2048);
        let m_e = sm.m_e(2.0, 2);
        let a =
            simulate(&Plan::build(&sm, PlanConfig::findep(2, 2, 2, m_e, Order::Asas), 4, 4, 2048));
        let b =
            simulate(&Plan::build(&sm, PlanConfig::findep(2, 2, 2, m_e, Order::Aass), 4, 4, 2048));
        assert!((a.makespan - b.makespan).abs() < 1e-12);
    }

    #[test]
    fn makespan_equals_last_finish() {
        let plan = build(1, 2, 2, Order::Aass, 2);
        let sim = simulate(&plan);
        let last = sim.finish.iter().copied().fold(0.0f64, f64::max);
        assert_eq!(sim.makespan, last);
        assert!(sim.throughput_tokens(&plan) > 0.0);
    }

    #[test]
    fn simulate_into_reuses_arena_and_matches_one_shot() {
        let mut buf = SimBuffers::new();
        // Warm the arena on the largest plan first.
        let warm = build(2, 3, 4, Order::Asas, 4);
        simulate_into(&warm, &mut buf).unwrap();
        let caps = (buf.result.start.capacity(), buf.indeg.capacity());
        for (r1, r2, order) in [(2, 2, Order::Aass), (3, 4, Order::Asas), (1, 1, Order::Asas)] {
            let plan = build(2, r1, r2, order, 4);
            let one_shot = simulate(&plan);
            let reused = simulate_into(&plan, &mut buf).unwrap();
            assert_eq!(reused.start, one_shot.start);
            assert_eq!(reused.finish, one_shot.finish);
            assert_eq!(reused.makespan, one_shot.makespan);
        }
        assert_eq!(
            caps,
            (buf.result.start.capacity(), buf.indeg.capacity()),
            "simulation arena reallocated"
        );
        // (3, 4, ASAS) repeated the warm plan's topology: exactly one
        // hit, one cached entry per distinct shape.
        assert_eq!(buf.topo_hits(), 1);
        assert_eq!(buf.topo_misses(), 3);
        assert_eq!(buf.cached_topologies(), 3);
    }

    #[test]
    fn duration_only_fast_path_is_bit_identical_to_full_rebuild() {
        // Same topology, different durations (different m_a / m_e /
        // stage models): the cached-topology fast path must produce the
        // exact same schedule — bit for bit — as a cold full rebuild.
        let sm_a = models();
        let sm_b = StageModels::new(
            &ModelConfig::deepseek_v2(4),
            &Testbed::b(),
            GroupSplit::new(3, 5),
            4096,
        );
        let mut warm = SimBuffers::new();
        for order in Order::both() {
            for (sm, m_a) in [(&sm_a, 1usize), (&sm_a, 2), (&sm_b, 2), (&sm_b, 4)] {
                let m_e = sm.m_e(m_a as f64, 3);
                let plan = Plan::build(
                    sm,
                    PlanConfig::findep(m_a, 2, 3, m_e, order),
                    4,
                    3,
                    2048,
                );
                // Cold arena per plan: always a topology miss (the
                // full-rebuild reference).
                let mut cold = SimBuffers::new();
                let full = simulate_into(&plan, &mut cold).unwrap().clone();
                assert_eq!(cold.topo_hits(), 0);
                // Warm arena: everything after the first per order is a
                // duration-only hit.
                let fast = simulate_into(&plan, &mut warm).unwrap();
                let ctx = format!("fast path drifted ({}, m_a={m_a})", order.name());
                assert_eq!(fast.start, full.start, "{ctx}");
                assert_eq!(fast.finish, full.finish);
                assert_eq!(fast.makespan, full.makespan);
            }
        }
        // 2 orders × 4 duration variants over one shape each: 2 misses,
        // 6 hits.
        assert_eq!(warm.topo_misses(), 2);
        assert_eq!(warm.topo_hits(), 6);
    }

    #[test]
    fn nan_durations_cannot_reach_or_break_interval_sorting() {
        // Defensive hardening, not a reachable panic: a NaN-duration
        // task yields a NaN interval, but `f > s` is false for NaN so
        // the filter drops it before the sort ever sees it (and
        // `f64::max` discards the NaN for successors). The switch to
        // `total_cmp` removes the residual `partial_cmp(..).unwrap()`
        // trap should a future caller feed unfiltered intervals.
        let plan = Plan::from_raw_parts(
            vec![
                (TaskKind::Expert, f64::NAN, vec![]),
                (TaskKind::Expert, 1.0, vec![]),
                (TaskKind::Expert, 2.0, vec![]),
            ],
            [Vec::new(), vec![0, 1, 2], Vec::new(), Vec::new()],
        );
        let sim = simulate(&plan);
        assert!(sim.finish[0].is_nan());
        let iv = resource_intervals(&plan, &sim, Resource::EgCompute);
        // The NaN interval was filtered; the finite ones stay sorted.
        assert_eq!(iv.len(), 2);
        assert!(iv.iter().all(|(s, f)| s.is_finite() && f.is_finite()));
        assert!(iv[0].0 <= iv[1].0);
        // The comparator itself is total: sorting adversarial NaN data
        // directly must not panic and must order NaN deterministically.
        let mut raw = vec![(f64::NAN, 1.0), (0.5, 2.0), (0.0, f64::NAN)];
        raw.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        assert_eq!(raw[0].0, 0.0);
        assert!(raw[0].1.is_nan());
        assert_eq!(raw[1], (0.5, 2.0));
        assert!(raw[2].0.is_nan());
    }

    #[test]
    fn cyclic_plan_reports_stuck_task_instead_of_aborting() {
        // Two expert tasks depending on each other: unexecutable.
        let plan = Plan::from_raw_parts(
            vec![
                (TaskKind::Expert, 1.0, vec![1]),
                (TaskKind::Expert, 1.0, vec![0]),
            ],
            [Vec::new(), vec![0, 1], Vec::new(), Vec::new()],
        );
        let mut buf = SimBuffers::new();
        // Warm the arena with a good plan first: the error must not
        // leave the previous schedule half-mixed into the result.
        let good = build(1, 1, 1, Order::Asas, 1);
        simulate_into(&good, &mut buf).unwrap();
        let err = simulate_into(&plan, &mut buf).unwrap_err();
        assert_eq!(err.resource, "EG");
        assert!(err.task.starts_with("expert"), "unexpected task label {}", err.task);
        assert!(format!("{err}").contains("cycle"));
        assert!(buf.result().start.is_empty() && buf.result().makespan == 0.0);
    }

    #[test]
    fn issue_order_cycle_against_deps_is_detected() {
        // Deps say 0 -> 1, issue order says 1 before 0 is fine (FIFO
        // waits), but issue order 1 -> 0 with dep 1 -> 0 both ways jams.
        let plan = Plan::from_raw_parts(
            vec![
                (TaskKind::A2E, 1.0, vec![]),
                (TaskKind::A2E, 1.0, vec![0]),
            ],
            // Queue order contradicts the dependency: task 1 first.
            [Vec::new(), Vec::new(), vec![1, 0], Vec::new()],
        );
        let mut buf = SimBuffers::new();
        let err = simulate_into(&plan, &mut buf).unwrap_err();
        assert_eq!(err.resource, "A2E");
    }

    #[test]
    fn degenerate_zero_duration_plan_reports_zero_throughput() {
        // All-zero α/β models: every task takes 0 s, makespan is 0, and
        // the throughput guard must clamp to 0 instead of inf/NaN.
        let sm = StageModels {
            t_a: LinearModel::new(0.0, 0.0),
            t_s: LinearModel::new(0.0, 0.0),
            t_e: LinearModel::new(0.0, 0.0),
            t_a2e: LinearModel::new(0.0, 0.0),
            k_tokens: 1.0,
            has_shared: false,
        };
        let plan =
            Plan::build(&sm, PlanConfig::findep(1, 1, 1, 1.0, Order::Asas), 1, 1, 128);
        let sim = simulate(&plan);
        assert_eq!(sim.makespan, 0.0);
        assert_eq!(sim.throughput_tokens(&plan), 0.0);
        assert!(sim.throughput_tokens(&plan).is_finite());
    }
}
