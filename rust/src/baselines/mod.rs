//! Baseline schedulers the paper compares against: naive sequential DEP
//! (Fig. 3a) and MegaScale-Infer's ping-pong pipeline, PPPipe (Fig. 3b),
//! each with its own best-configuration sweep so comparisons are against
//! the *optimally tuned* baseline, as in Table 5.

pub mod naive;
pub mod pppipe;

pub use naive::best_naive;
pub use pppipe::{best_pppipe, best_pppipe_deep};
