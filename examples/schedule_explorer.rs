//! Schedule explorer: visualize how naive DEP, PPPipe, and FinDEP
//! occupy the four DEP resources (Fig. 3 of the paper, regenerated from
//! our simulator), and dump a Chrome trace for the FinDEP schedule.
//!
//! Run: `cargo run --release --example schedule_explorer [testbed]`

use findep::baselines::{best_naive, best_pppipe};
use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::sched::{Order, Plan, PlanConfig};
use findep::simulator::{simulate, ScheduleTrace};
use findep::solver::{solve, Instance, SolverParams};

fn show(name: &str, inst: &Instance, cfg: PlanConfig, layers: usize) -> ScheduleTrace {
    let sm = inst.stage_models();
    let plan = Plan::build(&sm, cfg, layers, inst.split.ag, inst.seq_len);
    let sim = simulate(&plan);
    let trace = ScheduleTrace::from_sim(&plan, &sim);
    println!("\n== {name}: {} ==", cfg.describe());
    print!("{}", trace.ascii_gantt(110));
    trace
}

fn main() {
    let tb_name = std::env::args().nth(1).unwrap_or_else(|| "B".to_string());
    let testbed = Testbed::by_name(&tb_name).unwrap_or_else(Testbed::b);
    let model = ModelConfig::deepseek_v2(8);
    let split = GroupSplit::new(3, 5);
    let inst = Instance::new(model.clone(), testbed, split, 4096);
    let params = SolverParams::default();
    let layers = 2; // two layers are enough to see the steady-state beat

    println!(
        "Schedules for {} on {} (S={}, first {layers} layers)\n\
         legend: A attention | S shared expert | > A2E | E expert FFN | < E2A",
        model.name, inst.testbed.name, inst.seq_len
    );

    let naive = best_naive(&inst, params.ma_cap).expect("feasible");
    show("Naive DEP (Fig. 3a)", &inst, naive.config, layers);

    let pp = best_pppipe(&inst, &params).expect("feasible");
    show("PPPipe (Fig. 3b)", &inst, pp.config, layers);

    let fd = solve(&inst, &params).expect("feasible");
    let fd_trace = show("FinDEP (Fig. 3c/3d)", &inst, fd.config, layers);

    // The ASAS/AASS contrast of Fig. 4 at the FinDEP configuration.
    let mut alt = fd.config;
    alt.order = match fd.config.order {
        Order::Asas => Order::Aass,
        Order::Aass => Order::Asas,
    };
    show("FinDEP with the other AG order (Fig. 4)", &inst, alt, layers);

    // Chrome trace export for the winning schedule.
    let out = std::env::temp_dir().join("findep_schedule.json");
    std::fs::write(&out, findep::util::json::to_string(&fd_trace.to_chrome_trace()))
        .expect("write trace");
    println!(
        "\nChrome trace for the FinDEP schedule written to {} \
         (open in chrome://tracing or ui.perfetto.dev)",
        out.display()
    );
}
