//! Continuous batching: a bounded request queue in front of a pool of
//! pipelined serving workers (the EPS-MoE / MegaScale-Infer serving
//! shape — many in-flight micro-batches keep the disaggregated
//! attention/expert groups busy).
//!
//! ```text
//!   submit() ──▶ bounded queue ──▶ assembler (FIFO, linger window,
//!        │                         size-bucketed batches)
//!        │                              │ bounded work channel
//!        │                              ▼
//!        │                     worker 0 .. W-1  (one Server +
//!        │                     pipeline replica each; shared
//!        │                     Registry + PlanCache)
//!        │                              │
//!        ◀──────── responses ───────────┘
//! ```
//!
//! Invariants:
//!
//! * **FIFO draining** — the assembler forms batches strictly in
//!   arrival order; with one worker, responses come back in submission
//!   order regardless of how the stream was cut into batches.
//! * **Backpressure** — the submit queue is a bounded `sync_channel`:
//!   `submit` blocks when the queue is full, `try_submit` rejects (and
//!   counts `queue_rejected`).
//! * **Per-request latency** — each response's `latency_s` is rewritten
//!   to the true enqueue→response time, and the enqueue→dispatch wait
//!   lands in the shared registry's `queue_wait` histogram.
//! * **Shared planning** — workers share one [`PlanCache`], so an
//!   Adaptive shape solved on any worker is a hit on all of them.

use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
    TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::links::LinkDelay;
use crate::coordinator::moe::ModelHandle;
use crate::coordinator::server::{EmbeddedRequest, Policy, Response, Server};
use crate::metrics::Registry;
use crate::solver::PlanCache;

/// A request plus its enqueue timestamp (the latency reference).
struct QueuedRequest {
    req: EmbeddedRequest,
    enqueued: Instant,
}

/// Continuous-batcher knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// EG workers per pipeline replica.
    pub eg: usize,
    /// Optional α-β link delay per replica.
    pub link_delay: Option<LinkDelay>,
    /// Scheduling policy applied to every assembled batch.
    pub policy: Policy,
    /// Most requests per assembled batch (the size bucket cap).
    pub max_batch: usize,
    /// Bounded submit-queue depth (`submit` blocks beyond it).
    pub queue_depth: usize,
    /// Serving workers = pipeline replicas = in-flight batches.
    pub workers: usize,
    /// How long the assembler waits to fill a batch after the first
    /// request arrives.
    pub linger: Duration,
    /// Memoize Adaptive plans per shape (shared across workers).
    pub cache_plans: bool,
    /// Pick each replica's Adaptive planning split with the split-search
    /// solver layer at startup instead of the fixed `(1, eg)` view.
    pub auto_split: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            eg: 2,
            link_delay: None,
            policy: Policy::Adaptive,
            max_batch: 8,
            queue_depth: 64,
            workers: 2,
            linger: Duration::from_millis(1),
            cache_plans: true,
            auto_split: false,
        }
    }
}

/// The continuous batcher: owns the queue, the assembler, and the
/// worker pool. Dropping it drains in-flight work and joins every
/// thread.
pub struct Batcher {
    submit_tx: Option<SyncSender<QueuedRequest>>,
    resp_rx: Receiver<Response>,
    metrics: Arc<Registry>,
    plan_cache: Arc<PlanCache>,
    /// Expected `S·M` element count per request — malformed requests
    /// are rejected at submit time so they can never sink a whole
    /// assembled batch inside a worker.
    req_elems: usize,
    threads: Vec<JoinHandle<()>>,
}

impl Batcher {
    /// Spin up the assembler and `cfg.workers` serving replicas over
    /// one loaded model.
    pub fn new(model: ModelHandle, cfg: BatcherConfig) -> Result<Batcher> {
        let metrics = Arc::new(Registry::new());
        let plan_cache = Arc::new(PlanCache::new());
        let workers = cfg.workers.max(1);
        let max_batch = cfg.max_batch.max(1);
        let req_elems = model.seq_len * model.model.embed;

        let (submit_tx, submit_rx) = sync_channel::<QueuedRequest>(cfg.queue_depth.max(1));
        // Bounded work channel: the assembler runs at most `workers`
        // batches ahead of the slowest replica.
        let (work_tx, work_rx) = sync_channel::<Vec<QueuedRequest>>(workers);
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (resp_tx, resp_rx) = channel::<Response>();

        let mut threads = Vec::with_capacity(workers + 1);
        // The split search is deterministic in (model, plan testbed,
        // seq), so run it on the first replica only and hand the chosen
        // split to the rest — re-running it per worker would also
        // re-clear the shared plan cache under the earlier workers.
        let mut chosen_split = None;
        {
            let metrics = metrics.clone();
            let linger = cfg.linger;
            threads.push(
                std::thread::Builder::new()
                    .name("findep-batcher".into())
                    .spawn(move || assembler_loop(submit_rx, work_tx, max_batch, linger, metrics))
                    .context("spawn batch assembler")?,
            );
        }
        for w in 0..workers {
            let mut server = Server::with_shared(
                model.clone(),
                cfg.eg,
                cfg.link_delay,
                metrics.clone(),
                plan_cache.clone(),
            )?;
            server.cache_plans = cfg.cache_plans;
            if cfg.auto_split {
                match chosen_split {
                    None => chosen_split = Some(server.select_plan_split()),
                    Some(split) => server.plan_split = split,
                }
            }
            let work_rx = work_rx.clone();
            let resp_tx = resp_tx.clone();
            let policy = cfg.policy;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("findep-serve{w}"))
                    .spawn(move || worker_loop(server, policy, work_rx, resp_tx))
                    .context("spawn serving worker")?,
            );
        }

        Ok(Batcher {
            submit_tx: Some(submit_tx),
            resp_rx,
            metrics,
            plan_cache,
            req_elems,
            threads,
        })
    }

    /// A malformed request must fail at the submission boundary — once
    /// assembled, `serve_batch` would reject the whole batch and every
    /// co-batched request would silently lose its response.
    fn validate(&self, req: &EmbeddedRequest) -> Result<()> {
        anyhow::ensure!(
            req.hidden.data.len() == self.req_elems,
            "request {} has {} elements, expected {} (S·M)",
            req.id,
            req.hidden.data.len(),
            self.req_elems
        );
        Ok(())
    }

    /// Enqueue a request, blocking while the queue is full
    /// (backpressure). Errors on malformed requests or after shutdown.
    pub fn submit(&self, req: EmbeddedRequest) -> Result<()> {
        self.validate(&req)?;
        let tx = self.submit_tx.as_ref().context("batcher closed")?;
        tx.send(QueuedRequest { req, enqueued: Instant::now() })
            .ok()
            .context("batcher workers gone")?;
        self.metrics.inc("queued", 1);
        Ok(())
    }

    /// Non-blocking enqueue: `Ok(false)` when the queue is full (the
    /// request is rejected and counted).
    pub fn try_submit(&self, req: EmbeddedRequest) -> Result<bool> {
        self.validate(&req)?;
        let tx = self.submit_tx.as_ref().context("batcher closed")?;
        match tx.try_send(QueuedRequest { req, enqueued: Instant::now() }) {
            Ok(()) => {
                self.metrics.inc("queued", 1);
                Ok(true)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.inc("queue_rejected", 1);
                Ok(false)
            }
            Err(TrySendError::Disconnected(_)) => {
                anyhow::bail!("batcher workers gone")
            }
        }
    }

    /// Next completed response, or `None` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.resp_rx.recv_timeout(timeout).ok()
    }

    /// Collect up to `n` responses, waiting at most `timeout` for each.
    pub fn drain(&self, n: usize, timeout: Duration) -> Vec<Response> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match self.recv_timeout(timeout) {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plan_cache
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Close the queue: the assembler drains what's pending, then
        // the work channel closes and every worker exits.
        self.submit_tx.take();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// FIFO batch assembly with a linger window: take the first request
/// (blocking), then fill up to `max_batch` from whatever arrives within
/// `linger`, draining already-queued requests without waiting.
fn assembler_loop(
    rx: Receiver<QueuedRequest>,
    work_tx: SyncSender<Vec<QueuedRequest>>,
    max_batch: usize,
    linger: Duration,
    metrics: Arc<Registry>,
) {
    loop {
        let first = match rx.recv() {
            Ok(q) => q,
            Err(_) => return, // queue closed and drained
        };
        let mut batch = Vec::with_capacity(max_batch);
        batch.push(first);
        let deadline = Instant::now() + linger;
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(q) => {
                    batch.push(q);
                    continue;
                }
                Err(TryRecvError::Disconnected) => break,
                Err(TryRecvError::Empty) => {}
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            match rx.recv_timeout(remaining) {
                Ok(q) => batch.push(q),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        for q in &batch {
            metrics.observe("queue_wait", q.enqueued.elapsed().as_secs_f64());
        }
        metrics.inc("batches_assembled", 1);
        metrics.observe("batch_fill", batch.len() as f64);
        if work_tx.send(batch).is_err() {
            return; // all workers gone
        }
    }
}

/// One serving replica: pop the next assembled batch, serve it, rewrite
/// per-request latencies to enqueue→response, emit responses.
fn worker_loop(
    server: Server,
    policy: Policy,
    work_rx: Arc<Mutex<Receiver<Vec<QueuedRequest>>>>,
    resp_tx: Sender<Response>,
) {
    loop {
        // Hold the lock only for the pop; serving runs unlocked so the
        // other replicas pipeline their own batches meanwhile.
        let batch = {
            let rx = work_rx.lock().unwrap();
            rx.recv()
        };
        let Ok(batch) = batch else { return };
        let mut reqs = Vec::with_capacity(batch.len());
        let mut enqueued = Vec::with_capacity(batch.len());
        for q in batch {
            reqs.push(q.req);
            enqueued.push(q.enqueued);
        }
        match server.serve_batch(&reqs, policy) {
            Ok((responses, _stats)) => {
                for (mut resp, t) in responses.into_iter().zip(enqueued) {
                    resp.latency_s = t.elapsed().as_secs_f64();
                    server.metrics.observe("request_latency", resp.latency_s);
                    if resp_tx.send(resp).is_err() {
                        return;
                    }
                }
            }
            Err(e) => {
                // Drop the batch but keep the replica alive; callers
                // see the gap via the serve_errors counter.
                server.metrics.inc("serve_errors", 1);
                eprintln!("serving worker: batch failed: {e:#}");
            }
        }
    }
}
