//! Synthetic serving workloads.
//!
//! * Offline mode (Table 5): fixed-size batches at a fixed sequence
//!   length — the throughput-saturation regime.
//! * Online mode (Table 6 / §5.5): requests arrive with unpredictable
//!   prompt lengths; batches form per arrival window and the scheduler
//!   re-solves per batch. Scenarios are parameterized by the *mean
//!   arriving token count* (the paper uses 3072 and 6144).

use crate::util::rng::Rng;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Prompt sequence length.
    pub seq_len: usize,
    /// Arrival time, seconds from epoch start.
    pub arrival_s: f64,
}

impl Request {
    pub fn tokens(&self) -> usize {
        self.seq_len
    }
}

/// Offline batch generator: `count` requests of identical length.
pub fn offline_batch(count: usize, seq_len: usize) -> Vec<Request> {
    (0..count)
        .map(|i| Request { id: i as u64, seq_len, arrival_s: 0.0 })
        .collect()
}

/// Online arrival process: Poisson arrivals at `rate_per_s`, lognormal
/// prompt lengths with the given mean/std, truncated to
/// [min_len, max_len] and rounded to a multiple of `round_to` (shape
/// buckets).
#[derive(Debug, Clone)]
pub struct OnlineWorkload {
    pub rate_per_s: f64,
    pub mean_len: f64,
    pub std_len: f64,
    pub min_len: usize,
    pub max_len: usize,
    pub round_to: usize,
}

impl OnlineWorkload {
    /// The paper's Table-6 scenario: mean arriving tokens per request.
    pub fn paper_scenario(mean_tokens: usize) -> Self {
        Self {
            rate_per_s: 4.0,
            mean_len: mean_tokens as f64,
            std_len: mean_tokens as f64 * 0.4,
            min_len: 256,
            max_len: 4 * mean_tokens,
            round_to: 256,
        }
    }

    /// Generate `n` requests.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<Request> {
        let mut t = 0.0;
        (0..n)
            .map(|i| {
                t += rng.exponential(self.rate_per_s);
                let raw = rng.lognormal_mean_std(self.mean_len, self.std_len);
                let len = (raw as usize).clamp(self.min_len, self.max_len);
                let len = (len.div_ceil(self.round_to)) * self.round_to;
                Request { id: i as u64, seq_len: len, arrival_s: t }
            })
            .collect()
    }
}

/// Group online requests into serving batches: consecutive arrivals
/// within `window_s` of the batch head, up to `max_batch` requests,
/// bucketed by rounded sequence length so one AOT artifact shape serves
/// the whole batch.
pub fn window_batches(reqs: &[Request], window_s: f64, max_batch: usize) -> Vec<Vec<Request>> {
    let mut batches: Vec<Vec<Request>> = Vec::new();
    let mut current: Vec<Request> = Vec::new();
    let mut head_t = f64::NEG_INFINITY;
    for r in reqs {
        let fits_window = current.is_empty() || r.arrival_s - head_t <= window_s;
        if current.is_empty() {
            head_t = r.arrival_s;
        }
        if !fits_window || current.len() >= max_batch {
            batches.push(std::mem::take(&mut current));
            head_t = r.arrival_s;
        }
        current.push(r.clone());
    }
    if !current.is_empty() {
        batches.push(current);
    }
    batches
}

/// Representative sequence length for a batch: the max (padding model —
/// every sample is padded up to the bucket the artifact was compiled
/// for).
pub fn batch_seq_len(batch: &[Request]) -> usize {
    batch.iter().map(|r| r.seq_len).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offline_batches_are_uniform() {
        let b = offline_batch(16, 2048);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|r| r.seq_len == 2048 && r.arrival_s == 0.0));
        assert_eq!(b[3].tokens(), 2048);
    }

    #[test]
    fn online_lengths_bucketed_and_bounded() {
        let w = OnlineWorkload::paper_scenario(3072);
        let mut rng = Rng::new(1);
        let reqs = w.generate(500, &mut rng);
        assert_eq!(reqs.len(), 500);
        for r in &reqs {
            assert!(r.seq_len >= w.min_len);
            assert!(r.seq_len <= w.max_len + w.round_to);
            assert_eq!(r.seq_len % w.round_to, 0);
        }
        // Arrivals strictly increase.
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
        // Mean length lands near the target.
        let mean: f64 =
            reqs.iter().map(|r| r.seq_len as f64).sum::<f64>() / reqs.len() as f64;
        assert!((mean - 3072.0).abs() / 3072.0 < 0.2, "mean={mean}");
    }

    #[test]
    fn windows_respect_size_and_time() {
        let reqs: Vec<Request> = (0..10)
            .map(|i| Request { id: i, seq_len: 512, arrival_s: i as f64 * 0.1 })
            .collect();
        let batches = window_batches(&reqs, 0.25, 3);
        assert!(batches.iter().all(|b| b.len() <= 3));
        let total: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(total, 10);
        // A huge window with big max_batch puts everything together.
        let one = window_batches(&reqs, 100.0, 100);
        assert_eq!(one.len(), 1);
    }

    #[test]
    fn batch_seq_len_is_max() {
        let b = vec![
            Request { id: 0, seq_len: 512, arrival_s: 0.0 },
            Request { id: 1, seq_len: 1024, arrival_s: 0.1 },
        ];
        assert_eq!(batch_seq_len(&b), 1024);
        assert_eq!(batch_seq_len(&[]), 0);
    }
}
