//! Table 5 — the main result: FinDEP vs best-configured PPPipe across
//! two backbones (DeepSeek-V2 with shared experts, Qwen3-MoE without),
//! four testbeds, and sequence lengths 1024-8192.
//!
//! Layer counts per testbed follow §5.4 (DeepSeek 8/4/16/16, Qwen
//! 24/12/48/48); (ag, eg) follows §5.5 ((3,5) / (4,4) on 8-GPU
//! testbeds, (8,24) on D). PPPipe is swept to its optimal (m_a, r1)
//! exactly as the paper's bracketed speedups require.
//!
//! Run: `cargo bench --bench table5_main`

use findep::baselines::{best_pppipe, best_pppipe_deep};
use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{solve, Instance, SolverParams};
use findep::util::bench::Table;

fn main() {
    let params = SolverParams::default();
    let seqs = [1024usize, 2048, 4096, 8192];
    let paper_speedups: &[(&str, &str, &[f64])] = &[
        // paper Table 5 speedup columns per testbed for reference rows
        ("deepseek", "A", &[1.10, 1.09, 1.16, f64::NAN]),
        ("deepseek", "B", &[1.07, 1.06, 1.06, f64::NAN]),
        ("deepseek", "C", &[1.02, 1.03, 1.10, f64::NAN]),
        ("deepseek", "D", &[1.08, 1.12, 1.10, f64::NAN]),
        ("qwen", "A", &[1.13, 1.20, 1.13, 1.53]),
        ("qwen", "B", &[1.11, 1.08, 1.23, 1.61]),
        ("qwen", "C", &[1.03, 1.02, 1.07, 1.35]),
        ("qwen", "D", &[1.08, 1.08, 1.24, 1.22]),
    ];

    for (backbone, deepseek) in [("DeepSeek-V2", true), ("Qwen3-MoE", false)] {
        let mut table = Table::new(
            &format!("Table 5 ({backbone}): tokens/s, FinDEP speedup vs best PPPipe"),
            &["testbed", "S", "PPPipe", "FinDEP", "speedup", "paper", "vs deep-PP (ablation)"],
        );
        for tb in Testbed::all() {
            let layers = ModelConfig::paper_layers(deepseek, &tb.name[..2]);
            let model = if deepseek {
                ModelConfig::deepseek_v2(layers)
            } else {
                ModelConfig::qwen3_moe(layers)
            };
            let split = GroupSplit::paper_default(&tb, deepseek);
            for (si, &s) in seqs.iter().enumerate() {
                // The paper's DeepSeek rows stop at 4096.
                if deepseek && s == 8192 {
                    continue;
                }
                let inst = Instance::new(model.clone(), tb.clone(), split, s);
                let (pp, fd) = (best_pppipe(&inst, &params), solve(&inst, &params));
                let pp_deep = best_pppipe_deep(&inst, &params);
                let paper = paper_speedups
                    .iter()
                    .find(|(b, t, _)| {
                        *b == if deepseek { "deepseek" } else { "qwen" }
                            && tb.name.starts_with(&format!("{t} "))
                    })
                    .map(|(_, _, v)| v[si])
                    .unwrap_or(f64::NAN);
                match (pp, fd) {
                    (Some(pp), Some(fd)) => {
                        let sp = fd.throughput_tokens / pp.throughput_tokens;
                        let sp_deep = pp_deep
                            .map(|d| fd.throughput_tokens / d.throughput_tokens)
                            .unwrap_or(f64::NAN);
                        table.row(&[
                            tb.name.clone(),
                            s.to_string(),
                            format!("{:.0}", pp.throughput_tokens),
                            format!("{:.0}", fd.throughput_tokens),
                            format!("{sp:.3}x"),
                            if paper.is_nan() { "-".into() } else { format!("{paper:.2}x") },
                            format!("{sp_deep:.3}x"),
                        ]);
                        assert!(
                            sp >= 0.999,
                            "FinDEP lost to PPPipe on {} S={s}",
                            tb.name
                        );
                    }
                    _ => table.row(&[
                        tb.name.clone(),
                        s.to_string(),
                        "infeasible".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]),
                }
            }
        }
        table.print();
    }
    println!(
        "Shape check vs paper: FinDEP ≥ PPPipe everywhere; gains concentrate on comm-bound \
         testbeds (A/B/D) and shrink toward 1.0x on NVSwitch testbed C (Amdahl, §5.5).\n\
         PPPipe is ping-pong double buffering (r1 ≤ 2, Fig. 3b); the ablation column compares \
         FinDEP against an idealized depth-unlimited PPPipe, quantifying how much of the win \
         is pipeline depth vs fine-grained task scheduling — see EXPERIMENTS.md."
    );
}
