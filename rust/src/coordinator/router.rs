//! Token→expert routing: pack tokens per expert for A2E dispatch,
//! combine expert outputs (gate-weighted) on return — the data-plane
//! half of the MoE layer that the paper's EG confinement property
//! (§2.2) relies on.

use crate::config::ExpertLoad;
use crate::runtime::tensor::{Tensor, TensorI32};

/// Tokens routed to one expert.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertGroup {
    pub expert: usize,
    /// Row indices into the flattened token tensor.
    pub token_ids: Vec<u32>,
    /// Gate weight per routed token (aligned with `token_ids`).
    pub weights: Vec<f32>,
}

/// Routing decision for a token block: per-expert groups.
#[derive(Debug, Clone, PartialEq)]
pub struct Routing {
    pub groups: Vec<ExpertGroup>,
    pub n_tokens: usize,
    pub top_k: usize,
}

/// A gate emitted an expert index outside `[0, n_experts)` — a
/// corrupted or mis-configured gate output. Promoted from a
/// `debug_assert!` so release serving surfaces the fault as a typed
/// pipeline error instead of an out-of-bounds panic (or, worse,
/// silently mis-bucketed tokens).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExpertIndexError {
    /// Token row in the flattened gate output.
    pub token: usize,
    /// Top-k slot within the token's row.
    pub slot: usize,
    /// The offending raw index (may be negative).
    pub expert: i64,
    pub n_experts: usize,
}

impl std::fmt::Display for ExpertIndexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "gate routed token {} (slot {}) to expert {} but the model has {} experts",
            self.token, self.slot, self.expert, self.n_experts
        )
    }
}

impl std::error::Error for ExpertIndexError {}

/// Build per-expert token groups from gate outputs.
/// `probs`, `idx`: [N, top_k].
pub fn route(
    probs: &Tensor,
    idx: &TensorI32,
    n_experts: usize,
) -> Result<Routing, ExpertIndexError> {
    let n = probs.shape[0];
    let k = probs.shape[1];
    let mut groups: Vec<ExpertGroup> = (0..n_experts)
        .map(|e| ExpertGroup { expert: e, token_ids: Vec::new(), weights: Vec::new() })
        .collect();
    for t in 0..n {
        for j in 0..k {
            let raw = idx.data[t * k + j];
            if raw < 0 || raw as usize >= n_experts {
                return Err(ExpertIndexError {
                    token: t,
                    slot: j,
                    expert: raw as i64,
                    n_experts,
                });
            }
            let e = raw as usize;
            groups[e].token_ids.push(t as u32);
            groups[e].weights.push(probs.data[t * k + j]);
        }
    }
    groups.retain(|g| !g.token_ids.is_empty());
    Ok(Routing { groups, n_tokens: n, top_k: k })
}

impl Routing {
    /// Token count conservation: total routed assignments == N·top_k.
    pub fn total_assignments(&self) -> usize {
        self.groups.iter().map(|g| g.token_ids.len()).sum()
    }

    /// Split this routing into `parts` fine-grained parts along the
    /// token dimension (the r2 split of §2.3: "the expert part processes
    /// samples token by token ... we can further partition along the
    /// token dimension"). Tokens [0, N) are cut into contiguous ranges;
    /// each part keeps only the group slices whose tokens fall in its
    /// range, so parts are disjoint and their union is the original
    /// routing.
    /// Single pass over the assignments: each token lands in part
    /// `t / per` directly (`O(assignments + parts)` instead of the old
    /// per-part rescan of every group, `O(parts · assignments)`).
    /// Output is identical to the rescan — groups appear in original
    /// group order (first-occurrence order under an outer group loop),
    /// tokens keep their within-group order, empty groups are dropped,
    /// and tokens `>= n_tokens` fall in no part (the legacy ranges were
    /// all capped at `n_tokens`). Pinned by
    /// `split_parts_matches_quadratic_reference`.
    pub fn split_parts(&self, parts: usize) -> Vec<Routing> {
        let parts = parts.clamp(1, self.n_tokens.max(1));
        let per = self.n_tokens.div_ceil(parts);
        let mut out: Vec<Routing> = (0..parts)
            .map(|_| Routing { groups: Vec::new(), n_tokens: self.n_tokens, top_k: self.top_k })
            .collect();
        // Generation-stamped slot map: gen[p] names the last source
        // group that opened a destination group in part p, slot[p] its
        // position there — no per-group reset of either array.
        let mut gen: Vec<u32> = vec![u32::MAX; parts];
        let mut slot: Vec<u32> = vec![0; parts];
        for (gi, g) in self.groups.iter().enumerate() {
            for (i, &t) in g.token_ids.iter().enumerate() {
                let t_us = t as usize;
                if t_us >= self.n_tokens {
                    continue;
                }
                let p = t_us / per;
                if gen[p] != gi as u32 {
                    gen[p] = gi as u32;
                    out[p].groups.push(ExpertGroup {
                        expert: g.expert,
                        token_ids: Vec::new(),
                        weights: Vec::new(),
                    });
                    slot[p] = (out[p].groups.len() - 1) as u32;
                }
                let dst = &mut out[p].groups[slot[p] as usize];
                dst.token_ids.push(t);
                dst.weights.push(g.weights[i]);
            }
        }
        out
    }
}

/// Online EWMA of the per-expert share of routed assignments — the
/// observed counterpart of a workload's [`ExpertLoad`]. The serving
/// loop feeds every routed batch in; the coordinator compares the
/// observed load against the profile its current placement was solved
/// for and re-solves when the drift crosses a threshold.
#[derive(Debug, Clone)]
pub struct ExpertStats {
    /// EWMA of each expert's share of assignments (sums to ~1).
    ewma: Vec<f64>,
    /// Scratch counts, reused across batches (allocation-free observe).
    counts: Vec<f64>,
    alpha: f64,
    batches: u64,
}

impl ExpertStats {
    /// `alpha` is the EWMA weight of the newest batch (0 < alpha <= 1).
    pub fn new(n_experts: usize, alpha: f64) -> Self {
        assert!(n_experts > 0, "ExpertStats over zero experts");
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA alpha out of (0, 1]");
        Self { ewma: vec![0.0; n_experts], counts: vec![0.0; n_experts], alpha, batches: 0 }
    }

    /// Fold one routed batch into the histogram. The first batch seeds
    /// the EWMA directly; empty routings are ignored.
    pub fn observe(&mut self, routing: &Routing) {
        let total = routing.total_assignments();
        if total == 0 {
            return;
        }
        self.counts.iter_mut().for_each(|c| *c = 0.0);
        for g in &routing.groups {
            self.counts[g.expert] += g.token_ids.len() as f64;
        }
        let inv = 1.0 / total as f64;
        if self.batches == 0 {
            for (w, &c) in self.ewma.iter_mut().zip(&self.counts) {
                *w = c * inv;
            }
        } else {
            let a = self.alpha;
            for (w, &c) in self.ewma.iter_mut().zip(&self.counts) {
                *w = (1.0 - a) * *w + a * (c * inv);
            }
        }
        self.batches += 1;
    }

    /// Batches folded in so far.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Observed relative load (mean 1.0); uniform until the first
    /// batch has been observed.
    pub fn observed_load(&self) -> ExpertLoad {
        if self.batches == 0 {
            ExpertLoad::uniform(self.ewma.len())
        } else {
            ExpertLoad::from_weights(&self.ewma)
        }
    }

    /// Hottest expert's relative load — exactly 1.0 when balanced.
    pub fn skew(&self) -> f64 {
        self.observed_load().max_rel()
    }
}

/// Gather the input rows for one expert group.
pub fn pack(x: &Tensor, group: &ExpertGroup) -> Tensor {
    x.gather_rows(&group.token_ids.iter().map(|&t| t as usize).collect::<Vec<_>>())
}

/// Scatter-accumulate one expert's outputs into the combine buffer with
/// gate weighting: `acc[token] += w · y[row]`.
pub fn combine_into(acc: &mut Tensor, group: &ExpertGroup, y: &Tensor) {
    let m = acc.row_len();
    debug_assert_eq!(y.row_len(), m);
    debug_assert_eq!(y.dim0(), group.token_ids.len());
    for (row, (&t, &w)) in group.token_ids.iter().zip(&group.weights).enumerate() {
        let dst = &mut acc.data[t as usize * m..(t as usize + 1) * m];
        let src = &y.data[row * m..(row + 1) * m];
        for (d, s) in dst.iter_mut().zip(src) {
            *d += w * s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{self, Config};
    use crate::util::rng::Rng;

    fn mk_gate(rng: &mut Rng, n: usize, e: usize, k: usize) -> (Tensor, TensorI32) {
        let mut probs = Vec::new();
        let mut idx = Vec::new();
        for _ in 0..n {
            // Distinct experts per token, renormalized weights.
            let mut experts: Vec<i32> = (0..e as i32).collect();
            rng.shuffle(&mut experts);
            let raw: Vec<f64> = (0..k).map(|_| rng.range_f64(0.1, 1.0)).collect();
            let s: f64 = raw.iter().sum();
            for j in 0..k {
                probs.push((raw[j] / s) as f32);
                idx.push(experts[j]);
            }
        }
        (
            Tensor::new(vec![n, k], probs),
            TensorI32 { shape: vec![n, k], data: idx },
        )
    }

    /// The pre-optimization quadratic `split_parts` (verbatim), kept as
    /// the regression oracle for the single-pass rewrite.
    fn split_parts_reference(r: &Routing, parts: usize) -> Vec<Routing> {
        let parts = parts.clamp(1, r.n_tokens.max(1));
        let per = r.n_tokens.div_ceil(parts);
        (0..parts)
            .map(|p| {
                let lo = (p * per) as u32;
                let hi = (((p + 1) * per).min(r.n_tokens)) as u32;
                let groups: Vec<ExpertGroup> = r
                    .groups
                    .iter()
                    .filter_map(|g| {
                        let sel: Vec<usize> = g
                            .token_ids
                            .iter()
                            .enumerate()
                            .filter(|(_, &t)| t >= lo && t < hi)
                            .map(|(i, _)| i)
                            .collect();
                        if sel.is_empty() {
                            return None;
                        }
                        Some(ExpertGroup {
                            expert: g.expert,
                            token_ids: sel.iter().map(|&i| g.token_ids[i]).collect(),
                            weights: sel.iter().map(|&i| g.weights[i]).collect(),
                        })
                    })
                    .collect();
                Routing { groups, n_tokens: r.n_tokens, top_k: r.top_k }
            })
            .collect()
    }

    #[test]
    fn routing_conserves_assignments() {
        let mut rng = Rng::new(3);
        let (p, i) = mk_gate(&mut rng, 32, 8, 2);
        let r = route(&p, &i, 8).expect("valid gate");
        assert_eq!(r.total_assignments(), 32 * 2);
        for g in &r.groups {
            assert!(!g.token_ids.is_empty());
            assert_eq!(g.token_ids.len(), g.weights.len());
        }
    }

    #[test]
    fn split_parts_partition_tokens() {
        let mut rng = Rng::new(5);
        let (p, i) = mk_gate(&mut rng, 33, 8, 2);
        let r = route(&p, &i, 8).expect("valid gate");
        for parts in [1usize, 2, 3, 5] {
            let split = r.split_parts(parts);
            let total: usize = split.iter().map(|s| s.total_assignments()).sum();
            assert_eq!(total, r.total_assignments(), "parts={parts}");
            // Disjoint token ranges.
            for (a, b) in split.iter().zip(split.iter().skip(1)) {
                let max_a = a.groups.iter().flat_map(|g| &g.token_ids).max();
                let min_b = b.groups.iter().flat_map(|g| &g.token_ids).min();
                if let (Some(&ma), Some(&mb)) = (max_a, min_b) {
                    assert!(ma < mb);
                }
            }
        }
    }

    #[test]
    fn pack_combine_is_weighted_permutation_inverse() {
        // Property: routing with identity experts (y = x) and weights
        // summing to 1 per token reconstructs x exactly.
        proptest::check("pack-combine-inverse", &Config::with_cases(40), |rng| {
            let n = 1 + rng.usize_below(40);
            let e = 2 + rng.usize_below(8);
            let k = 1 + rng.usize_below(2.min(e));
            let m = 4;
            let (p, i) = mk_gate(rng, n, e, k);
            let x = Tensor::new(
                vec![n, m],
                (0..n * m).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect(),
            );
            let r = route(&p, &i, e).expect("valid gate");
            let mut acc = Tensor::zeros(vec![n, m]);
            for g in &r.groups {
                let xg = pack(&x, g);
                combine_into(&mut acc, g, &xg); // identity "expert"
            }
            proptest::ensure(
                acc.max_abs_diff(&x) < 2e-6,
                format!("reconstruction error {}", acc.max_abs_diff(&x)),
            )
        });
    }

    #[test]
    fn split_respects_part_count_bounds() {
        let mut rng = Rng::new(9);
        let (p, i) = mk_gate(&mut rng, 4, 4, 1);
        let r = route(&p, &i, 4).expect("valid gate");
        // More parts than tokens clamps to token count.
        let split = r.split_parts(100);
        assert!(split.len() <= 4);
    }

    #[test]
    fn split_parts_matches_quadratic_reference() {
        // Random routings from the real gate path.
        let mut rng = Rng::new(17);
        for _ in 0..20 {
            let n = 1 + rng.usize_below(64);
            let e = 2 + rng.usize_below(10);
            let k = 1 + rng.usize_below(2.min(e));
            let (p, i) = mk_gate(&mut rng, n, e, k);
            let r = route(&p, &i, e).expect("valid gate");
            for parts in [1usize, 2, 3, 7, n, n + 5] {
                assert_eq!(
                    r.split_parts(parts),
                    split_parts_reference(&r, parts),
                    "n={n} e={e} k={k} parts={parts}"
                );
            }
        }
        // Hand-built adversarial routing: non-ascending token ids,
        // duplicate tokens across groups, and a token >= n_tokens
        // (which the legacy capped ranges silently drop).
        let r = Routing {
            groups: vec![
                ExpertGroup {
                    expert: 3,
                    token_ids: vec![5, 1, 9, 1],
                    weights: vec![0.1, 0.2, 0.3, 0.4],
                },
                ExpertGroup { expert: 0, token_ids: vec![2, 8], weights: vec![0.5, 0.6] },
                ExpertGroup { expert: 7, token_ids: vec![12, 0], weights: vec![0.7, 0.8] },
            ],
            n_tokens: 10,
            top_k: 1,
        };
        for parts in [1usize, 2, 3, 4, 10] {
            assert_eq!(r.split_parts(parts), split_parts_reference(&r, parts), "parts={parts}");
        }
    }

    #[test]
    fn route_rejects_out_of_range_expert() {
        let probs = Tensor::new(vec![2, 2], vec![0.5, 0.5, 0.5, 0.5]);
        let idx = TensorI32 { shape: vec![2, 2], data: vec![0, 1, 3, 2] };
        // Index 3 with only 3 experts is fine; with 3 experts, 3 is out.
        let err = route(&probs, &idx, 3).expect_err("index 3 of 3 experts");
        assert_eq!(err, ExpertIndexError { token: 1, slot: 0, expert: 3, n_experts: 3 });
        assert!(err.to_string().contains("expert 3"));
        // Negative indices are rejected, not wrapped.
        let neg = TensorI32 { shape: vec![2, 2], data: vec![0, 1, -1, 2] };
        let err = route(&probs, &neg, 4).expect_err("negative index");
        assert_eq!(err.expert, -1);
        // A valid gate still routes.
        assert!(route(&probs, &idx, 4).is_ok());
    }

    #[test]
    fn expert_stats_track_observed_skew() {
        let mut stats = ExpertStats::new(4, 0.2);
        // Before any batch: uniform, skew exactly 1.
        assert!(stats.observed_load().is_uniform());
        assert_eq!(stats.skew(), 1.0);
        // A skewed routing: expert 0 takes 3 of 4 assignments.
        let hot = Routing {
            groups: vec![
                ExpertGroup {
                    expert: 0,
                    token_ids: vec![0, 1, 2],
                    weights: vec![1.0, 1.0, 1.0],
                },
                ExpertGroup { expert: 2, token_ids: vec![3], weights: vec![1.0] },
            ],
            n_tokens: 4,
            top_k: 1,
        };
        stats.observe(&hot);
        assert_eq!(stats.batches(), 1);
        // First batch seeds the EWMA directly: rel_0 = 0.75·4 = 3.
        let load = stats.observed_load();
        assert!((load.rel(0) - 3.0).abs() < 1e-12);
        assert!((stats.skew() - 3.0).abs() < 1e-12);
        // A balanced routing pulls the EWMA back toward uniform.
        let flat = Routing {
            groups: (0..4)
                .map(|e| ExpertGroup { expert: e, token_ids: vec![e as u32], weights: vec![1.0] })
                .collect(),
            n_tokens: 4,
            top_k: 1,
        };
        let before = stats.skew();
        for _ in 0..50 {
            stats.observe(&flat);
        }
        assert!(stats.skew() < before);
        assert!((stats.skew() - 1.0).abs() < 0.01, "skew {}", stats.skew());
        // Drift against the seeded load is measurable.
        assert!(ExpertLoad::uniform(4).linf_drift(&stats.observed_load()) < 0.05);
    }
}
