//! Anytime-solver semantics (PR 8 tentpole, integration level):
//!
//! 1. An infinite budget is a no-op: bit-identical plan AND identical
//!    evaluation count versus the unbudgeted solve.
//! 2. A zero budget with an exact warm seed returns the seed's plan
//!    unchanged, flagged non-exhaustive — the serving loop's "use what
//!    the cache already knows, refine later" contract.
//! 3. A background refinement publish never races a concurrent
//!    `PlanCache::clear()`: whatever the interleaving, a cleared cache
//!    never serves the stale refined plan (the token pins the old
//!    generation), and an uncleared cache always does.
//!
//! Run under both `RUST_TEST_THREADS=1` and `=8` in CI: the race test
//! in (3) must hold regardless of scheduler pressure.

use std::sync::Arc;
use std::time::Duration;

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{
    solve, solve_warm, EvalMode, Instance, PlanCache, ShapeKey, SolverParams, WarmStart,
};

fn instances() -> Vec<(&'static str, Instance)> {
    vec![
        (
            "deepseek/A",
            Instance::new(ModelConfig::deepseek_v2(8), Testbed::a(), GroupSplit::new(3, 5), 2048),
        ),
        (
            "qwen/C",
            Instance::new(
                ModelConfig::qwen3_moe(48),
                Testbed::c(),
                GroupSplit::new(4, 4),
                2048,
            ),
        ),
    ]
}

/// Caps wide enough that every instance has a multi-row sweep, so the
/// budget actually has something to cut.
fn params() -> SolverParams {
    SolverParams { ma_cap: 8, r1_cap: 8, r2_cap: 64, ..Default::default() }
}

#[test]
fn infinite_budget_is_bit_identical_to_unbudgeted() {
    for (label, inst) in instances() {
        let base = params();
        let plain = solve(&inst, &base).expect("feasible");
        let budgeted_params = SolverParams { budget: Some(Duration::MAX), ..base };
        let budgeted = solve(&inst, &budgeted_params).expect("feasible");
        assert_eq!(budgeted.config, plain.config, "plan drifted under Duration::MAX on {label}");
        assert_eq!(
            budgeted.throughput_tokens.to_bits(),
            plain.throughput_tokens.to_bits(),
            "throughput drifted under Duration::MAX on {label}"
        );
        assert_eq!(
            budgeted.evals, plain.evals,
            "an unreachable deadline must not change the sweep on {label}"
        );
        assert!(budgeted.exhaustive, "an unreachable deadline never truncates ({label})");
    }
}

#[test]
fn zero_budget_returns_the_warm_seed_unchanged() {
    for (label, inst) in instances() {
        let base = params();
        let cold = solve(&inst, &base).expect("feasible");
        let seed = WarmStart::from_solution(&cold);
        let zero = SolverParams { budget: Some(Duration::ZERO), ..base };
        let out =
            solve_warm(&inst, &zero, EvalMode::Buffered, &mut inst.evaluator(), Some(&seed))
                .expect("the seed itself keeps a zero-budget solve feasible");
        assert_eq!(out.config, cold.config, "zero budget must hand back the seed plan ({label})");
        assert_eq!(
            out.throughput_tokens.to_bits(),
            cold.throughput_tokens.to_bits(),
            "seed throughput must survive re-evaluation bit for bit ({label})"
        );
        assert!(out.warm_seeded, "{label}");
        assert!(!out.exhaustive, "a zero-budget sweep cannot claim exhaustiveness ({label})");
    }
}

#[test]
fn refinement_publish_never_races_clear() {
    let (_, inst) = instances().pop().expect("instances");
    let base = params();
    let truncated = solve(&inst, &SolverParams { budget: Some(Duration::ZERO), ..base })
        .expect("feasible");
    assert!(!truncated.exhaustive, "zero budget must truncate this multi-row instance");
    let full = solve(&inst, &base).expect("feasible");
    assert!(full.exhaustive);

    let cache = Arc::new(PlanCache::new());
    let key = ShapeKey::prefill(2048, 32);
    for i in 0..200 {
        let (seeded, token) =
            cache.get_or_solve_refinable(key, || Some(truncated.clone()));
        assert!(
            !seeded.expect("closure returned Some").exhaustive,
            "the cache must initially hold the truncated incumbent"
        );

        let do_clear = i % 2 == 1;
        let publisher = {
            let cache = Arc::clone(&cache);
            let refined = Arc::new(full.clone());
            std::thread::spawn(move || cache.publish_refined(&token, key, refined))
        };
        if do_clear {
            cache.clear();
        }
        let published_live = publisher.join().expect("publisher thread");

        if do_clear {
            // Whether the publish landed before or after the swap, the
            // fresh generation must never show the old entry: a
            // cleared cache serving a stale refined plan would pin a
            // dead topology.
            assert!(
                cache.peek(key).is_none(),
                "refined plan leaked across a clear (iteration {i})"
            );
        } else {
            assert!(published_live, "publish into the live generation must succeed");
            let live = cache.peek(key).expect("present").expect("solved");
            assert!(live.exhaustive, "the cache must serve the refined plan");
            assert_eq!(live.config, full.config);
            cache.clear();
        }
    }
}
