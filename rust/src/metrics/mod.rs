//! Serving metrics: counters, latency histograms, and throughput meters
//! used by the coordinator and the bench harnesses.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{Json, JsonObj};
use crate::util::stats;

/// Latency histogram with fixed log-spaced buckets (1 µs .. ~100 s).
#[derive(Debug)]
pub struct Histogram {
    /// Bucket upper bounds in seconds.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    /// Raw samples kept for exact percentiles (bounded reservoir).
    samples: Vec<f64>,
    max_samples: usize,
    total: u64,
    sum: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let mut bounds = Vec::new();
        let mut b = 1e-6;
        while b < 100.0 {
            bounds.push(b);
            b *= 2.0;
        }
        let n = bounds.len();
        Self { bounds, counts: vec![0; n + 1], samples: Vec::new(), max_samples: 65_536, total: 0, sum: 0.0 }
    }

    pub fn record(&mut self, seconds: f64) {
        let idx = self.bounds.partition_point(|&b| b < seconds);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += seconds;
        if self.samples.len() < self.max_samples {
            self.samples.push(seconds);
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        stats::percentile(&self.samples, p)
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("count", Json::Num(self.total as f64));
        o.insert("mean_s", Json::Num(self.mean()));
        o.insert("p50_s", Json::Num(self.percentile(50.0)));
        o.insert("p95_s", Json::Num(self.percentile(95.0)));
        o.insert("p99_s", Json::Num(self.percentile(99.0)));
        Json::Obj(o)
    }
}

/// Tokens/s meter over a wall-clock window.
#[derive(Debug)]
pub struct ThroughputMeter {
    started: Instant,
    tokens: u64,
    requests: u64,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        Self { started: Instant::now(), tokens: 0, requests: 0 }
    }

    pub fn add(&mut self, tokens: u64) {
        self.tokens += tokens;
        self.requests += 1;
    }

    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn tokens_per_s(&self) -> f64 {
        let e = self.elapsed_s();
        if e > 0.0 {
            self.tokens as f64 / e
        } else {
            0.0
        }
    }
}

/// Thread-safe metrics registry shared across coordinator components.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn observe(&self, name: &str, seconds: f64) {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .record(seconds);
    }

    pub fn histogram_json(&self, name: &str) -> Option<Json> {
        self.histograms.lock().unwrap().get(name).map(|h| h.to_json())
    }

    /// Sample count of a histogram (0 when it was never observed).
    pub fn histogram_count(&self, name: &str) -> u64 {
        self.histograms.lock().unwrap().get(name).map(|h| h.count()).unwrap_or(0)
    }

    /// Mean of a histogram, or `None` when no histogram of that name
    /// was ever observed — distinguishable from a true zero mean (the
    /// old 0.0 sentinel was not).
    pub fn histogram_mean(&self, name: &str) -> Option<f64> {
        self.histograms.lock().unwrap().get(name).map(|h| h.mean())
    }

    pub fn snapshot_json(&self) -> Json {
        let mut o = JsonObj::new();
        let mut counters = JsonObj::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters.insert(k.clone(), Json::Num(*v as f64));
        }
        o.insert("counters", Json::Obj(counters));
        let mut hists = JsonObj::new();
        for (k, h) in self.histograms.lock().unwrap().iter() {
            hists.insert(k.clone(), h.to_json());
        }
        o.insert("histograms", Json::Obj(hists));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.0505).abs() < 1e-6);
        assert!((h.percentile(50.0) - 0.0505).abs() < 2e-3);
        assert!(h.percentile(99.0) > 0.09);
    }

    #[test]
    fn meter_accumulates() {
        let mut m = ThroughputMeter::new();
        m.add(100);
        m.add(200);
        assert_eq!(m.tokens(), 300);
        assert_eq!(m.requests(), 2);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(m.tokens_per_s() > 0.0);
    }

    #[test]
    fn registry_is_shared_safely() {
        let r = std::sync::Arc::new(Registry::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    r.inc("reqs", 1);
                    r.observe("lat", 0.001);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("reqs"), 400);
        let j = r.snapshot_json();
        assert_eq!(j.get("counters").get("reqs").as_f64(), Some(400.0));
        assert!(r.histogram_json("lat").is_some());
        assert!(r.histogram_json("missing").is_none());
        assert_eq!(r.histogram_count("lat"), 400);
        assert!((r.histogram_mean("lat").unwrap() - 0.001).abs() < 1e-9);
        assert_eq!(r.histogram_count("missing"), 0);
        // An unknown histogram is None, not a fake zero mean; a real
        // all-zero histogram still reads back as Some(0.0).
        assert_eq!(r.histogram_mean("missing"), None);
        r.observe("zero", 0.0);
        assert_eq!(r.histogram_mean("zero"), Some(0.0));
    }
}
