//! Schedule traces: interval analytics (Table 7's non-overlapped
//! communication time), Eq.-5 validity checking, ASCII Gantt rendering,
//! and Chrome `about:tracing` JSON export.

use crate::sched::{Plan, Resource};
use crate::simulator::engine::SimResult;
use crate::util::json::{Json, JsonObj};

/// One executed task interval.
#[derive(Debug, Clone)]
pub struct TraceInterval {
    pub label: String,
    pub resource: Resource,
    pub start: f64,
    pub finish: f64,
}

/// A fully-executed schedule with analysis helpers.
#[derive(Debug, Clone)]
pub struct ScheduleTrace {
    pub intervals: Vec<TraceInterval>,
    pub makespan: f64,
}

impl ScheduleTrace {
    pub fn from_sim(plan: &Plan, sim: &SimResult) -> Self {
        let intervals = plan
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| TraceInterval {
                label: t.label(),
                resource: t.resource(),
                start: sim.start[i],
                finish: sim.finish[i],
            })
            .collect();
        Self { intervals, makespan: sim.makespan }
    }

    /// Busy intervals of a resource, merged and sorted.
    pub fn busy(&self, res: Resource) -> Vec<(f64, f64)> {
        let mut iv: Vec<(f64, f64)> = self
            .intervals
            .iter()
            .filter(|t| t.resource == res && t.finish > t.start)
            .map(|t| (t.start, t.finish))
            .collect();
        iv.sort_by(|a, b| a.partial_cmp(b).unwrap());
        merge(&iv)
    }

    /// Total busy time of a resource.
    pub fn busy_time(&self, res: Resource) -> f64 {
        self.busy(res).iter().map(|(s, f)| f - s).sum()
    }

    /// **Non-overlapped communication time** (Table 7): the portion of
    /// wall time where at least one link (A2E or E2A) is transferring
    /// while *both* compute resources are idle — i.e. communication that
    /// the schedule failed to hide behind computation.
    pub fn non_overlapped_comm(&self) -> f64 {
        let comm = union(&self.busy(Resource::A2ELink), &self.busy(Resource::E2ALink));
        let compute = union(&self.busy(Resource::AgCompute), &self.busy(Resource::EgCompute));
        subtract_len(&comm, &compute)
    }

    /// Idle time of a compute resource inside the makespan window.
    pub fn idle_time(&self, res: Resource) -> f64 {
        self.makespan - self.busy_time(res)
    }

    /// Chrome `about:tracing` / Perfetto-compatible JSON.
    pub fn to_chrome_trace(&self) -> Json {
        let mut events = Vec::new();
        for t in &self.intervals {
            let mut o = JsonObj::new();
            o.insert("name", Json::Str(t.label.clone()));
            o.insert("cat", Json::Str(t.resource.name().into()));
            o.insert("ph", Json::Str("X".into()));
            // Microsecond timestamps, as Chrome expects.
            o.insert("ts", Json::Num(t.start * 1e6));
            o.insert("dur", Json::Num((t.finish - t.start) * 1e6));
            o.insert("pid", Json::Num(1.0));
            o.insert("tid", Json::Num(t.resource.index() as f64 + 1.0));
            events.push(Json::Obj(o));
        }
        let mut root = JsonObj::new();
        root.insert("traceEvents", Json::Arr(events));
        root.insert("displayTimeUnit", Json::Str("ms".into()));
        Json::Obj(root)
    }

    /// ASCII Gantt chart (one row per resource), `width` columns wide.
    pub fn ascii_gantt(&self, width: usize) -> String {
        let mut out = String::new();
        let scale = width as f64 / self.makespan.max(1e-12);
        for res in Resource::ALL {
            let mut row = vec![b'.'; width];
            for t in self.intervals.iter().filter(|t| t.resource == res) {
                let a = ((t.start * scale) as usize).min(width.saturating_sub(1));
                let b = ((t.finish * scale).ceil() as usize).clamp(a + 1, width);
                let ch = match t.label.as_bytes().first() {
                    Some(b'a') if t.label.starts_with("attn") => b'A',
                    Some(b's') => b'S',
                    Some(b'a') => b'>', // a2e
                    Some(b'e') if t.label.starts_with("expert") => b'E',
                    _ => b'<', // e2a
                };
                for c in &mut row[a..b] {
                    *c = ch;
                }
            }
            out.push_str(&format!("{:>4} |{}|\n", res.name(), String::from_utf8(row).unwrap()));
        }
        out.push_str(&format!(
            "      makespan {:.3} ms, non-overlapped comm {:.3} ms\n",
            self.makespan * 1e3,
            self.non_overlapped_comm() * 1e3
        ));
        out
    }

    /// Validate the Eq.-5 exclusivity constraints on this trace: no two
    /// tasks of one resource overlap. Returns a violation description.
    pub fn validate_exclusive(&self) -> Result<(), String> {
        for res in Resource::ALL {
            let mut iv: Vec<(f64, f64, &str)> = self
                .intervals
                .iter()
                .filter(|t| t.resource == res && t.finish > t.start)
                .map(|t| (t.start, t.finish, t.label.as_str()))
                .collect();
            iv.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for w in iv.windows(2) {
                if w[1].0 < w[0].1 - 1e-12 {
                    return Err(format!(
                        "resource {} overlap: {} [{:.6},{:.6}) vs {} [{:.6},{:.6})",
                        res.name(),
                        w[0].2,
                        w[0].0,
                        w[0].1,
                        w[1].2,
                        w[1].0,
                        w[1].1
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Merge overlapping sorted intervals.
fn merge(iv: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut out: Vec<(f64, f64)> = Vec::new();
    for &(s, f) in iv {
        if let Some(last) = out.last_mut() {
            if s <= last.1 + 1e-15 {
                last.1 = last.1.max(f);
                continue;
            }
        }
        out.push((s, f));
    }
    out
}

/// Union of two merged interval lists.
fn union(a: &[(f64, f64)], b: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut all: Vec<(f64, f64)> = a.iter().chain(b.iter()).copied().collect();
    all.sort_by(|x, y| x.partial_cmp(y).unwrap());
    merge(&all)
}

/// Total length of `a \ b` (both merged + sorted).
fn subtract_len(a: &[(f64, f64)], b: &[(f64, f64)]) -> f64 {
    let mut total = 0.0;
    for &(s, f) in a {
        let mut cur = s;
        for &(bs, bf) in b {
            if bf <= cur {
                continue;
            }
            if bs >= f {
                break;
            }
            if bs > cur {
                total += bs - cur;
            }
            cur = cur.max(bf);
            if cur >= f {
                break;
            }
        }
        if cur < f {
            total += f - cur;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};
    use crate::perfmodel::StageModels;
    use crate::sched::{Order, PlanConfig};
    use crate::simulator::engine::simulate;

    fn trace(r1: usize, r2: usize) -> (Plan, ScheduleTrace) {
        let sm = StageModels::new(
            &ModelConfig::deepseek_v2(4),
            &Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        );
        let m_e = sm.m_e(2.0, r2);
        let plan =
            Plan::build(&sm, PlanConfig::findep(2, r1, r2, m_e, Order::Asas), 4, 3, 2048);
        let sim = simulate(&plan);
        let tr = ScheduleTrace::from_sim(&plan, &sim);
        (plan, tr)
    }

    #[test]
    fn interval_helpers() {
        assert_eq!(merge(&[(0.0, 1.0), (0.5, 2.0), (3.0, 4.0)]), vec![(0.0, 2.0), (3.0, 4.0)]);
        assert_eq!(
            union(&[(0.0, 1.0)], &[(0.5, 2.0), (5.0, 6.0)]),
            vec![(0.0, 2.0), (5.0, 6.0)]
        );
        let len = subtract_len(&[(0.0, 10.0)], &[(2.0, 3.0), (5.0, 7.0)]);
        assert!((len - 7.0).abs() < 1e-12);
        // Subtraction with nothing to subtract.
        assert!((subtract_len(&[(1.0, 4.0)], &[]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn validates_and_measures() {
        let (_plan, tr) = trace(2, 3);
        tr.validate_exclusive().unwrap();
        assert!(tr.busy_time(Resource::AgCompute) > 0.0);
        assert!(tr.busy_time(Resource::EgCompute) > 0.0);
        assert!(tr.non_overlapped_comm() >= 0.0);
        assert!(tr.non_overlapped_comm() <= tr.makespan);
        assert!(tr.idle_time(Resource::EgCompute) >= 0.0);
    }

    #[test]
    fn finer_pipeline_hides_more_comm() {
        // More r2 parts should not increase non-overlapped comm (with the
        // cheap kernel-launch constants of testbed A at this size).
        let (_p1, t1) = trace(2, 1);
        let (_p2, t2) = trace(2, 4);
        assert!(
            t2.non_overlapped_comm() <= t1.non_overlapped_comm() + 1e-9,
            "r2=4 exposed {} vs r2=1 {}",
            t2.non_overlapped_comm(),
            t1.non_overlapped_comm()
        );
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let (_plan, tr) = trace(2, 2);
        let j = tr.to_chrome_trace();
        let text = crate::util::json::to_string(&j);
        let back = crate::util::json::parse(&text).unwrap();
        let events = back.get("traceEvents").as_arr().unwrap();
        assert_eq!(events.len(), tr.intervals.len());
        assert_eq!(events[0].get("ph").as_str(), Some("X"));
    }

    #[test]
    fn gantt_renders_all_rows() {
        let (_plan, tr) = trace(2, 2);
        let g = tr.ascii_gantt(60);
        for name in ["AG", "EG", "A2E", "E2A"] {
            assert!(g.contains(name), "missing row {name}:\n{g}");
        }
        assert!(g.contains("makespan"));
    }
}
