//! Self-contained substrates (the image's crate registry is offline, so
//! FinDEP vendors its own JSON, RNG, CLI, stats, bench-harness,
//! property-test, and logging layers).

pub mod args;
pub mod bench;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
