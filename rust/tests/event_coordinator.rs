//! Event-driven coordinator tests: idle parking (near-zero wakeups),
//! concurrent shutdown drain (no response lost or duplicated, `open`
//! reaches zero), backpressure release, and — artifact-gated — the
//! full `Batcher` against the serial oracle (bit-identical responses,
//! FIFO order) plus the polling baseline's idle cost.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use findep::coordinator::batcher::{Batcher, BatcherConfig};
use findep::coordinator::executor::{run_worker, EventCore};
use findep::coordinator::moe::ModelHandle;
use findep::coordinator::planner::{PlannerConfig, QueuedRequest};
use findep::coordinator::server::{EmbeddedRequest, Policy, Server};
use findep::coordinator::threadpool::ThreadPoolBatcher;
use findep::metrics::Registry;
use findep::runtime::artifacts_dir;

fn cfg(max_batch: usize, linger: Duration, queue_depth: usize) -> PlannerConfig {
    PlannerConfig { max_batch, linger, queue_depth }
}

/// Spawn `n` workers whose executor emulates the batcher's serving
/// step without a model: requests with `output_len > 0` re-enter the
/// decode lane (one step per output token), finished requests send
/// their id to `done`. Open-slot accounting mirrors the real batcher.
fn spawn_sim_workers(
    core: &Arc<EventCore>,
    metrics: &Arc<Registry>,
    n: usize,
    done: Sender<u64>,
) -> Vec<std::thread::JoinHandle<()>> {
    let mut handles = Vec::new();
    for _ in 0..n {
        core.register_worker();
        let core = core.clone();
        let metrics = metrics.clone();
        let done = done.clone();
        handles.push(std::thread::spawn(move || {
            let c = core.clone();
            run_worker(&core, &metrics, move |batch| {
                let n = batch.len();
                for q in batch {
                    if q.req.output_len > 0 {
                        let mut next = q.req;
                        next.output_len -= 1;
                        c.add_open(1);
                        c.reenter_decode(QueuedRequest::reentry(next, q.submitted));
                    } else {
                        let _ = done.send(q.req.id);
                    }
                }
                c.release_open(n);
            });
        }));
    }
    handles
}

// ---- idle parking (the DECODE_POLL regression) -------------------------

#[test]
fn idle_event_core_performs_near_zero_wakeups() {
    // The retired design woke its assembler every 200µs while idle
    // (≈1250 wakeups over this window). The event core must park: no
    // submit, no re-entry, no linger window ⇒ no wakeups beyond the
    // occasional spurious condvar return.
    let core = Arc::new(EventCore::new(cfg(8, Duration::from_millis(1), 64)));
    let metrics = Arc::new(Registry::new());
    let (done_tx, _done_rx) = channel();
    let handles = spawn_sim_workers(&core, &metrics, 4, done_tx);
    std::thread::sleep(Duration::from_millis(250));
    let idle = core.wakeups();
    assert!(idle <= 8, "idle workers woke {idle} times; they must park, not poll");
    // The core still works after the idle stretch, and shuts down clean.
    core.submit(EmbeddedRequest::synthetic(7, 2, 2)).unwrap();
    core.close();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(core.open(), 0);
}

#[test]
fn lingering_window_wakes_at_deadline_not_at_poll_cadence() {
    // One request into an 8-wide window: nothing fills it, so the
    // worker must sleep through the linger and execute at the deadline
    // — a bounded handful of wakeups, not a 200µs cadence.
    let linger = Duration::from_millis(50);
    let core = Arc::new(EventCore::new(cfg(8, linger, 64)));
    let metrics = Arc::new(Registry::new());
    let (done_tx, done_rx) = channel();
    let handles = spawn_sim_workers(&core, &metrics, 2, done_tx);
    let t0 = Instant::now();
    core.submit(EmbeddedRequest::synthetic(0, 2, 2)).unwrap();
    let id = done_rx.recv_timeout(Duration::from_secs(10)).expect("lingering window sealed");
    let waited = t0.elapsed();
    assert_eq!(id, 0);
    assert!(
        waited >= Duration::from_millis(30),
        "partial window sealed after {waited:?}, before the linger deadline"
    );
    assert!(
        core.wakeups() <= 16,
        "linger served by {} wakeups; the baseline cadence would need ~250",
        core.wakeups()
    );
    core.close();
    for h in handles {
        h.join().unwrap();
    }
}

// ---- concurrent shutdown drain -----------------------------------------

#[test]
fn concurrent_shutdown_drains_every_decode_loop() {
    // Many workers, deep decode loops, queued submits from several
    // threads, then shutdown: every admitted request must produce
    // exactly one completion (no loss, no duplication) and `open`
    // must reach zero before the workers exit.
    let core = Arc::new(EventCore::new(cfg(4, Duration::from_micros(200), 8)));
    let metrics = Arc::new(Registry::new());
    let (done_tx, done_rx) = channel();
    let workers = spawn_sim_workers(&core, &metrics, 8, done_tx);

    let submitters: Vec<_> = (0..4u64)
        .map(|t| {
            let core = core.clone();
            std::thread::spawn(move || {
                for i in 0..16u64 {
                    // Blocking submits against depth 8: backpressure is
                    // exercised while workers drain concurrently.
                    core.submit(EmbeddedRequest::synthetic_autoregressive(t * 16 + i, 2, 2, 3))
                        .unwrap();
                }
            })
        })
        .collect();
    for s in submitters {
        s.join().unwrap();
    }
    // Close while decode loops are still in flight: the drain must
    // finish all 64 requests' 3-step loops regardless.
    core.close();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(core.open(), 0, "drain finished with open slots outstanding");
    let mut got: Vec<u64> = done_rx.try_iter().collect();
    got.sort_unstable();
    assert_eq!(got, (0..64).collect::<Vec<_>>(), "responses lost or duplicated in the drain");
    // Every pass (1 prefill + 3 decode steps per request) crossed the
    // window exactly once.
    assert_eq!(metrics.histogram_count("queue_wait"), 64 * 4);
    // Submits after shutdown are rejected, decode lane drained clean.
    assert!(core.submit(EmbeddedRequest::synthetic(999, 2, 2)).is_err());
}

#[test]
fn single_worker_completion_order_matches_serial_oracle() {
    // With one worker the event loop must preserve the serial order:
    // equal-length decode loops submitted in order complete in order
    // (the decode lane outranks fresh submits, so nobody leapfrogs).
    let core = Arc::new(EventCore::new(cfg(4, Duration::from_micros(200), 64)));
    let metrics = Arc::new(Registry::new());
    let (done_tx, done_rx) = channel();
    let workers = spawn_sim_workers(&core, &metrics, 1, done_tx);
    for i in 0..12u64 {
        core.submit(EmbeddedRequest::synthetic_autoregressive(i, 2, 2, 2)).unwrap();
    }
    let mut got = Vec::new();
    for _ in 0..12 {
        got.push(done_rx.recv_timeout(Duration::from_secs(10)).expect("request completed"));
    }
    assert_eq!(got, (0..12).collect::<Vec<_>>(), "single worker must complete FIFO");
    core.close();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(core.open(), 0);
}

// ---- backpressure ------------------------------------------------------

#[test]
fn backpressure_rejects_at_depth_and_releases_on_drain() {
    let core = Arc::new(EventCore::new(cfg(1, Duration::ZERO, 1)));
    let metrics = Arc::new(Registry::new());
    // One worker whose executor blocks until told to proceed.
    let (gate_tx, gate_rx) = channel::<()>();
    let gate_rx = Arc::new(std::sync::Mutex::new(gate_rx));
    let (done_tx, done_rx) = channel();
    core.register_worker();
    let handle = {
        let core = core.clone();
        let metrics = metrics.clone();
        let gate_rx = gate_rx.clone();
        std::thread::spawn(move || {
            let c = core.clone();
            run_worker(&core, &metrics, move |batch| {
                gate_rx.lock().unwrap().recv().ok();
                let n = batch.len();
                for q in batch {
                    let _ = done_tx.send(q.req.id);
                }
                c.release_open(n);
            });
        })
    };
    // r0 is picked up by the worker (blocked in exec); r1 occupies the
    // single bounded slot; r2 must be rejected.
    assert!(core.try_submit(EmbeddedRequest::synthetic(0, 2, 2)).unwrap());
    // Wait until the worker has pulled r0 out of the queue.
    let t0 = Instant::now();
    loop {
        if core.try_submit(EmbeddedRequest::synthetic(1, 2, 2)).unwrap() {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never ingested r0");
        std::thread::yield_now();
    }
    assert!(
        !core.try_submit(EmbeddedRequest::synthetic(2, 2, 2)).unwrap(),
        "queue depth 1 must reject a second queued submit"
    );
    // Release the worker: the queue drains and a slot frees up.
    gate_tx.send(()).unwrap();
    gate_tx.send(()).unwrap();
    let t0 = Instant::now();
    loop {
        if core.try_submit(EmbeddedRequest::synthetic(2, 2, 2)).unwrap() {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "drain never freed the bounded slot");
        std::thread::yield_now();
    }
    gate_tx.send(()).unwrap();
    core.close();
    handle.join().unwrap();
    let mut got: Vec<u64> = done_rx.try_iter().collect();
    got.sort_unstable();
    assert_eq!(got, vec![0, 1, 2]);
    assert_eq!(core.open(), 0);
}

#[test]
fn blocked_submitters_unblock_on_close() {
    // A submitter parked on a full queue must error out (not hang)
    // when the batcher shuts down underneath it.
    let core = Arc::new(EventCore::new(cfg(1, Duration::ZERO, 1)));
    let metrics = Arc::new(Registry::new());
    let (gate_tx, gate_rx) = channel::<()>();
    let gate_rx = Arc::new(std::sync::Mutex::new(gate_rx));
    let (done_tx, done_rx) = channel();
    core.register_worker();
    let worker = {
        let core = core.clone();
        let metrics = metrics.clone();
        let gate_rx = gate_rx.clone();
        std::thread::spawn(move || {
            let c = core.clone();
            run_worker(&core, &metrics, move |batch| {
                // Blocks until signalled; a dropped gate means "run free".
                gate_rx.lock().unwrap().recv().ok();
                let n = batch.len();
                for q in batch {
                    let _ = done_tx.send(q.req.id);
                }
                c.release_open(n);
            });
        })
    };
    // r0 is pulled into the (gated) worker; r1 then occupies the single
    // bounded slot; the blocking submit of r9 parks on the space condvar.
    core.submit(EmbeddedRequest::synthetic(0, 2, 2)).unwrap();
    let t0 = Instant::now();
    loop {
        if core.try_submit(EmbeddedRequest::synthetic(1, 2, 2)).unwrap() {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never ingested r0");
        std::thread::yield_now();
    }
    let blocked = {
        let core = core.clone();
        std::thread::spawn(move || core.submit(EmbeddedRequest::synthetic(9, 2, 2)))
    };
    std::thread::sleep(Duration::from_millis(20));
    core.close();
    let res = blocked.join().unwrap();
    assert!(res.is_err(), "submitter blocked on a closed batcher must error, not hang");
    // Release the gate: the shutdown drain finishes r0 and r1.
    drop(gate_tx);
    worker.join().unwrap();
    let mut got: Vec<u64> = done_rx.try_iter().collect();
    got.sort_unstable();
    assert_eq!(got, vec![0, 1], "admitted requests must survive the drain");
    assert_eq!(core.open(), 0, "close drained with open slots outstanding");
}

// ---- worker-death robustness -------------------------------------------

#[test]
fn panicking_worker_releases_its_slots_and_submits_fail_cleanly() {
    let core = Arc::new(EventCore::new(cfg(1, Duration::ZERO, 8)));
    let metrics = Arc::new(Registry::new());
    let panics = Arc::new(AtomicUsize::new(0));
    core.register_worker();
    let handle = {
        let core = core.clone();
        let metrics = metrics.clone();
        let panics = panics.clone();
        std::thread::spawn(move || {
            let c = core.clone();
            run_worker(&core, &metrics, move |batch| {
                // The open-slot guard lives in the batcher's executor;
                // emulate it with a drop guard so a panic still
                // releases the batch's slots.
                struct Guard<'a>(&'a EventCore, usize);
                impl Drop for Guard<'_> {
                    fn drop(&mut self) {
                        self.0.release_open(self.1);
                    }
                }
                let _g = Guard(&c, batch.len());
                panics.fetch_add(1, Ordering::SeqCst);
                panic!("worker dies mid-batch");
            });
        })
    };
    core.submit(EmbeddedRequest::synthetic(0, 2, 2)).unwrap();
    assert!(handle.join().is_err(), "worker must have panicked");
    assert_eq!(panics.load(Ordering::SeqCst), 1);
    assert_eq!(core.open(), 0, "panicked batch leaked open slots");
    assert_eq!(core.live_workers(), 0);
    // With every worker dead, submits fail instead of queueing forever.
    assert!(core.submit(EmbeddedRequest::synthetic(1, 2, 2)).is_err());
}

// ---- artifact-gated: the real Batcher ----------------------------------

fn skip() -> bool {
    let missing = !artifacts_dir().join("manifest.json").exists();
    if missing {
        eprintln!("skipping: run `make artifacts` first");
    }
    missing
}

#[test]
fn idle_batcher_parks_while_baseline_polls() {
    if skip() {
        return;
    }
    let model = ModelHandle::load(&artifacts_dir(), true).unwrap();
    let idle_for = Duration::from_millis(300);

    let event = Batcher::new(model.clone(), BatcherConfig::default()).unwrap();
    std::thread::sleep(idle_for);
    let event_wakeups = event.wakeups();

    let baseline = ThreadPoolBatcher::new(model, BatcherConfig::default()).unwrap();
    std::thread::sleep(idle_for);
    let baseline_polls = baseline.poll_wakeups();

    assert!(
        event_wakeups <= 8,
        "idle event batcher woke {event_wakeups} times; workers must park"
    );
    assert!(
        baseline_polls > 100,
        "baseline should be polling at the 200µs cadence, saw {baseline_polls}"
    );
}

#[test]
fn event_batcher_is_bit_identical_to_serial_oracle() {
    if skip() {
        return;
    }
    let model = ModelHandle::load(&artifacts_dir(), true).unwrap();
    let (s, m) = (model.seq_len, model.model.embed);
    let batch: Vec<EmbeddedRequest> =
        (0..10u64).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();

    // Serial oracle: one request at a time, directly on a server.
    let direct = Server::new(model.clone(), 2, None).unwrap();
    let mut want = Vec::new();
    for r in &batch {
        let (mut resp, _) = direct.serve_batch(std::slice::from_ref(r), Policy::Adaptive).unwrap();
        want.push(resp.remove(0));
    }

    // max_batch 1 + zero linger pins the batch composition to one
    // request per window — identical float reduction order to the
    // oracle, so responses must be bit-identical, in FIFO order.
    let cfg = BatcherConfig {
        workers: 1,
        max_batch: 1,
        linger: Duration::ZERO,
        policy: Policy::Adaptive,
        ..Default::default()
    };
    let batcher = Batcher::new(model, cfg).unwrap();
    for r in &batch {
        batcher.submit(r.clone()).unwrap();
    }
    let got = batcher.drain(10, Duration::from_secs(60));
    assert_eq!(got.len(), 10, "batcher lost responses");
    for (i, (w, g)) in want.iter().zip(&got).enumerate() {
        assert_eq!(g.id, i as u64, "event batcher broke FIFO order");
        assert_eq!(w.id, g.id);
        assert_eq!(
            w.hidden.data, g.hidden.data,
            "response {i} is not bit-identical to the serial oracle"
        );
    }
}

#[test]
fn batcher_concurrent_shutdown_completes_all_responses() {
    if skip() {
        return;
    }
    let model = ModelHandle::load(&artifacts_dir(), true).unwrap();
    let (s, m) = (model.seq_len, model.model.embed);
    let cfg = BatcherConfig {
        workers: 4,
        max_batch: 4,
        queue_depth: 8,
        policy: Policy::Adaptive,
        linger: Duration::from_micros(200),
        ..Default::default()
    };
    let batcher = Arc::new(Batcher::new(model, cfg).unwrap());
    let n = 24u64;
    let out_len = 2usize;
    let submitters: Vec<_> = (0..3u64)
        .map(|t| {
            let batcher = batcher.clone();
            std::thread::spawn(move || {
                for i in 0..n / 3 {
                    batcher
                        .submit(EmbeddedRequest::synthetic_autoregressive(
                            t * (n / 3) + i,
                            s,
                            m,
                            out_len,
                        ))
                        .unwrap();
                }
            })
        })
        .collect();
    for st in submitters {
        st.join().unwrap();
    }
    let resps = batcher.drain(n as usize, Duration::from_secs(60));
    assert_eq!(resps.len(), n as usize, "autoregressive requests lost responses");
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>(), "responses missing or duplicated");
    assert_eq!(batcher.metrics().counter("decode_steps"), n * out_len as u64);
    // All final responses are out; the open counter drains to zero as
    // the last batches' slot guards drop.
    let t0 = Instant::now();
    while batcher.open() != 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "open counter stuck at {}", batcher.open());
        std::thread::yield_now();
    }
    // Drop with everything drained: must join cleanly (no hang).
    drop(batcher);
}

#[test]
fn dropping_batcher_with_undrained_work_does_not_hang() {
    if skip() {
        return;
    }
    let model = ModelHandle::load(&artifacts_dir(), true).unwrap();
    let (s, m) = (model.seq_len, model.model.embed);
    let cfg = BatcherConfig { workers: 2, max_batch: 4, ..Default::default() };
    let batcher = Batcher::new(model, cfg).unwrap();
    for i in 0..6u64 {
        batcher.submit(EmbeddedRequest::synthetic_autoregressive(i, s, m, 2)).unwrap();
    }
    // Take only part of the output, then drop: the drain must complete
    // the in-flight decode loops and join every worker regardless.
    let _partial = batcher.drain(2, Duration::from_secs(60));
    let t0 = Instant::now();
    drop(batcher);
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "drop-with-undrained-work stalled the shutdown drain"
    );
}
