//! The **execution** half of the event-driven coordinator: an
//! [`EventCore`] wraps the pure [`Planner`] state machine in one mutex
//! plus two condvars, and [`run_worker`] is the work-stealing worker
//! loop that drains it.
//!
//! ```text
//!   submit()/try_submit() ──▶ ┌──────────────────────────┐
//!     (blocked submitters     │  Mutex<Planner>          │
//!      park on `space`)       │   submit queue (bounded) │
//!                             │   decode lane (priority) │
//!   reenter_decode() ───────▶ │   linger window          │──▶ Step
//!     (prefill-done unlocks   └──────────────────────────┘
//!      the decode step)                 ▲
//!                 notify_one            │ poll under the lock
//!   workers ◀───────────────────────────┘
//!   (parked on the `work` condvar — Park = indefinitely,
//!    ParkUntil = until the open window's linger deadline;
//!    an idle core performs no wakeups at all)
//! ```
//!
//! Every state transition is event-driven: a submit, a decode
//! re-entry, a linger expiry, or shutdown notifies exactly the waiters
//! that can make progress. There is no polling cadence anywhere — the
//! regression tests assert a fully idle core stays at (near) zero
//! wakeups, where the retired thread-pool design woke its assembler
//! every 200µs to re-check the decode lane
//! ([`super::threadpool`] keeps that design as the measured baseline).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::coordinator::planner::{
    Planner, PlannerConfig, Poll, QueuedRequest, Step, SubmitError, SubmitOutcome,
};
use crate::coordinator::server::EmbeddedRequest;
use crate::metrics::Registry;

/// Shared event state: the planner behind a mutex, the two wait sets,
/// and the system-wide accounting the planner's drain logic needs.
pub struct EventCore {
    planner: Mutex<Planner>,
    /// Workers park here (notified on submit, decode re-entry,
    /// batch completion during shutdown, and close).
    work: Condvar,
    /// Backpressured submitters park here (notified when a poll frees
    /// bounded-queue slots, on close, and on worker death).
    space: Condvar,
    /// Requests anywhere in the system that still owe a final
    /// response; shutdown drains until this reaches zero so pending
    /// decode loops are never dropped.
    open: AtomicUsize,
    /// Workers currently registered (spawned and not yet exited); a
    /// submit against a dead pool errors instead of queueing forever.
    live_workers: AtomicUsize,
    /// Times any worker returned from a condvar wait. An idle core
    /// must not accumulate these — pinned by the idle-parking
    /// regression test.
    wakeups: AtomicU64,
    /// Wakeups whose next poll found nothing to execute (spurious or
    /// linger-herd wakeups).
    idle_wakeups: AtomicU64,
}

impl EventCore {
    pub fn new(cfg: PlannerConfig) -> Self {
        Self {
            planner: Mutex::new(Planner::new(cfg)),
            work: Condvar::new(),
            space: Condvar::new(),
            open: AtomicUsize::new(0),
            live_workers: AtomicUsize::new(0),
            wakeups: AtomicU64::new(0),
            idle_wakeups: AtomicU64::new(0),
        }
    }

    /// Recover the planner even if a worker panicked while holding the
    /// lock: planner state is a set of queues that stays structurally
    /// valid mid-mutation, and a batch lost to a panicking worker is
    /// routed to retry-or-fail by its attempt's drop guard
    /// ([`crate::coordinator::batcher::run_attempt`]).
    fn lock(&self) -> MutexGuard<'_, Planner> {
        self.planner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Requests still owed a final response.
    pub fn open(&self) -> usize {
        self.open.load(Ordering::SeqCst)
    }

    /// Take `n` open slots (requests entering the system or decode
    /// steps re-entering it).
    pub fn add_open(&self, n: usize) {
        self.open.fetch_add(n, Ordering::SeqCst);
    }

    /// Release `n` open slots (final responses emitted, or requests
    /// abandoned by a failed batch).
    pub fn release_open(&self, n: usize) {
        self.open.fetch_sub(n, Ordering::SeqCst);
    }

    pub fn wakeups(&self) -> u64 {
        self.wakeups.load(Ordering::SeqCst)
    }

    pub fn idle_wakeups(&self) -> u64 {
        self.idle_wakeups.load(Ordering::SeqCst)
    }

    pub fn live_workers(&self) -> usize {
        self.live_workers.load(Ordering::SeqCst)
    }

    /// Register a worker **before** spawning its thread, so a submit
    /// racing the spawn never observes an empty pool.
    pub fn register_worker(&self) {
        self.live_workers.fetch_add(1, Ordering::SeqCst);
    }

    /// Fresh submissions waiting in the bounded queue right now (the
    /// admission-control wait estimate reads this).
    pub fn queued(&self) -> usize {
        self.lock().queued()
    }

    /// Enqueue a fresh request, parking while the bounded queue is
    /// full (backpressure). Errors after close
    /// ([`SubmitError::Closed`]) or when every worker has died
    /// ([`SubmitError::WorkersGone`]).
    pub fn submit(&self, req: EmbeddedRequest) -> Result<(), SubmitError> {
        let mut p = self.lock();
        loop {
            if p.is_closed() {
                return Err(SubmitError::Closed);
            }
            if self.live_workers() == 0 {
                return Err(SubmitError::WorkersGone);
            }
            if p.has_space() {
                break;
            }
            p = self.space.wait(p).unwrap_or_else(PoisonError::into_inner);
        }
        self.add_open(1);
        let outcome = p.offer_submit(QueuedRequest::fresh(req));
        debug_assert_eq!(outcome, SubmitOutcome::Accepted);
        drop(p);
        self.work.notify_one();
        Ok(())
    }

    /// Non-blocking enqueue: `Ok(false)` when the bounded queue is
    /// full.
    pub fn try_submit(&self, req: EmbeddedRequest) -> Result<bool, SubmitError> {
        let mut p = self.lock();
        if p.is_closed() {
            return Err(SubmitError::Closed);
        }
        if self.live_workers() == 0 {
            return Err(SubmitError::WorkersGone);
        }
        if !p.has_space() {
            return Ok(false);
        }
        self.add_open(1);
        let outcome = p.offer_submit(QueuedRequest::fresh(req));
        debug_assert_eq!(outcome, SubmitOutcome::Accepted);
        drop(p);
        self.work.notify_one();
        Ok(true)
    }

    /// Re-enter a decode step whose prefill (or previous step) just
    /// completed. The caller must already hold the request's open slot
    /// (`add_open`); the decode lane is unbounded so this never blocks
    /// — a worker re-entering its own output must not deadlock against
    /// a full queue.
    pub fn reenter_decode(&self, q: QueuedRequest) {
        self.lock().push_decode(q);
        self.work.notify_one();
    }

    /// Re-enqueue a request whose replica failed mid-serve into the
    /// front-priority retry lane. The caller keeps holding the
    /// request's open slot (the failed batch never released it), so
    /// the shutdown drain still waits for it.
    pub fn reenter_retry(&self, q: QueuedRequest) {
        self.lock().push_retry(q);
        self.work.notify_one();
    }

    /// Begin shutdown: admitted work drains, then workers exit.
    pub fn close(&self) {
        self.lock().close();
        self.work.notify_all();
        self.space.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.lock().is_closed()
    }
}

/// The work-stealing worker loop: poll the planner under the lock,
/// execute ready batches outside it, park on the `work` condvar when
/// nothing is ready (until the linger deadline when a window is open,
/// indefinitely otherwise).
///
/// `exec` runs one assembled batch end to end and owns the response /
/// decode-re-entry / open-slot bookkeeping (the batcher passes a
/// closure over its replica pool; tests pass no-op executors). The
/// loop itself records the assembly metrics every batch crosses —
/// `queue_wait` per request, `batches_assembled`, `batch_fill` — so
/// the planner stays clock-free.
pub fn run_worker<E>(core: &EventCore, metrics: &Registry, mut exec: E)
where
    E: FnMut(Vec<QueuedRequest>),
{
    /// Deregisters on exit — including panic unwinds — and wakes both
    /// wait sets so blocked submitters can observe a dead pool and
    /// parked peers can re-evaluate the exit condition.
    struct LiveGuard<'a>(&'a EventCore);
    impl Drop for LiveGuard<'_> {
        fn drop(&mut self) {
            self.0.live_workers.fetch_sub(1, Ordering::SeqCst);
            self.0.work.notify_all();
            self.0.space.notify_all();
        }
    }
    let guard = LiveGuard(core);
    let mut p = core.lock();
    // Whether the previous iteration woke from a park (to classify the
    // wakeup as productive or idle once the next poll answers).
    let mut woke = false;
    loop {
        let Poll { step, freed } = p.poll(Instant::now(), core.open());
        if freed > 0 {
            core.space.notify_all();
        }
        match step {
            Step::Execute(batch) => {
                woke = false;
                drop(p);
                let now = Instant::now();
                for q in &batch {
                    metrics.observe("queue_wait", now.duration_since(q.enqueued).as_secs_f64());
                }
                metrics.inc("batches_assembled", 1);
                metrics.observe("batch_fill", batch.len() as f64);
                exec(batch);
                p = core.lock();
                // A completed batch may have released the last open
                // slots (or re-entered decode steps): during shutdown
                // the parked peers must re-evaluate Exit.
                if p.is_closed() {
                    core.work.notify_all();
                }
            }
            Step::Park => {
                if woke {
                    core.idle_wakeups.fetch_add(1, Ordering::SeqCst);
                }
                p = core.work.wait(p).unwrap_or_else(PoisonError::into_inner);
                core.wakeups.fetch_add(1, Ordering::SeqCst);
                woke = true;
            }
            Step::ParkUntil(deadline) => {
                if woke {
                    core.idle_wakeups.fetch_add(1, Ordering::SeqCst);
                }
                let timeout = deadline.saturating_duration_since(Instant::now());
                let (g, _) = core
                    .work
                    .wait_timeout(p, timeout)
                    .unwrap_or_else(PoisonError::into_inner);
                p = g;
                core.wakeups.fetch_add(1, Ordering::SeqCst);
                woke = true;
            }
            Step::Exit => {
                drop(p);
                drop(guard); // notifies peers: they re-poll and exit too
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;
    use std::time::Duration;

    fn cfg(max_batch: usize, linger_us: u64, depth: usize) -> PlannerConfig {
        PlannerConfig {
            max_batch,
            linger: Duration::from_micros(linger_us),
            queue_depth: depth,
        }
    }

    fn spawn_noop_workers(
        core: &Arc<EventCore>,
        metrics: &Arc<Registry>,
        n: usize,
    ) -> (Vec<std::thread::JoinHandle<()>>, std::sync::mpsc::Receiver<u64>) {
        let (done_tx, done_rx) = channel::<u64>();
        let mut handles = Vec::new();
        for _ in 0..n {
            core.register_worker();
            let core = core.clone();
            let metrics = metrics.clone();
            let done_tx = done_tx.clone();
            handles.push(std::thread::spawn(move || {
                let c = core.clone();
                run_worker(&core, &metrics, move |batch| {
                    let n = batch.len();
                    for q in batch {
                        let _ = done_tx.send(q.req.id);
                    }
                    c.release_open(n);
                });
            }));
        }
        (handles, done_rx)
    }

    #[test]
    fn submits_flow_through_workers_and_drain_on_close() {
        let core = Arc::new(EventCore::new(cfg(4, 200, 16)));
        let metrics = Arc::new(Registry::new());
        let (handles, done_rx) = spawn_noop_workers(&core, &metrics, 3);
        for i in 0..20u64 {
            core.submit(EmbeddedRequest::synthetic(i, 2, 2)).unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..20 {
            got.push(done_rx.recv_timeout(Duration::from_secs(10)).expect("request completed"));
        }
        got.sort_unstable();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
        core.close();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(core.open(), 0);
        assert_eq!(core.live_workers(), 0);
        assert_eq!(metrics.histogram_count("queue_wait"), 20);
        assert!(core.submit(EmbeddedRequest::synthetic(99, 2, 2)).is_err());
    }

    #[test]
    fn submit_errors_are_typed() {
        let core = EventCore::new(cfg(4, 200, 2));
        // No workers registered: the queue would never drain.
        assert_eq!(
            core.submit(EmbeddedRequest::synthetic(0, 2, 2)),
            Err(SubmitError::WorkersGone)
        );
        core.register_worker();
        core.close();
        assert_eq!(core.submit(EmbeddedRequest::synthetic(1, 2, 2)), Err(SubmitError::Closed));
        assert_eq!(core.try_submit(EmbeddedRequest::synthetic(2, 2, 2)), Err(SubmitError::Closed));
        assert_eq!(core.open(), 0);
    }

    #[test]
    fn try_submit_backpressures_at_queue_depth() {
        // No workers: nothing drains the queue, so the bound is exact.
        let core = EventCore::new(cfg(4, 200, 2));
        core.register_worker(); // pretend one exists so submits are legal
        assert!(core.try_submit(EmbeddedRequest::synthetic(0, 2, 2)).unwrap());
        assert!(core.try_submit(EmbeddedRequest::synthetic(1, 2, 2)).unwrap());
        assert!(!core.try_submit(EmbeddedRequest::synthetic(2, 2, 2)).unwrap());
        assert_eq!(core.open(), 2, "rejected submissions must not hold open slots");
    }
}
