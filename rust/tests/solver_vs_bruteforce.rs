//! Integration: Algorithm 1 vs exhaustive search — the Pareto-frontier
//! walk plus convex r2 search must recover (within numerical noise) the
//! brute-force optimum on every instance (the "near-optimal" claim of
//! §4.3).

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{algorithm1, bruteforce, Instance, SolverParams};

fn instances() -> Vec<Instance> {
    let mut out = Vec::new();
    for tb in Testbed::all() {
        for s in [1024usize, 4096] {
            out.push(Instance::new(
                ModelConfig::deepseek_v2(8),
                tb.clone(),
                GroupSplit::paper_default(&tb, true),
                s,
            ));
            out.push(Instance::new(
                ModelConfig::qwen3_moe(12),
                tb.clone(),
                GroupSplit::paper_default(&tb, false),
                s,
            ));
        }
    }
    out
}

#[test]
fn algorithm1_matches_bruteforce_optimum() {
    let params = SolverParams { ma_cap: 4, r1_cap: 4, r2_cap: 16, ..Default::default() };
    for inst in instances() {
        let brute = bruteforce::exhaustive(&inst, params.ma_cap, params.r1_cap, params.r2_cap);
        let solved = algorithm1::solve(&inst, &params);
        match (brute, solved) {
            (Some((bcfg, _, btput)), Some(sol)) => {
                assert!(
                    sol.throughput_tokens >= btput * 0.999,
                    "Algorithm 1 {:.2} < brute-force {:.2} on {} {} S={} \
                     (alg1 {:?} vs brute {:?})",
                    sol.throughput_tokens,
                    btput,
                    inst.model.name,
                    inst.cluster.name,
                    inst.seq_len,
                    sol.config,
                    bcfg
                );
            }
            (None, None) => {} // consistently infeasible
            (b, s) => panic!(
                "feasibility disagreement on {} {}: brute={} alg1={}",
                inst.model.name,
                inst.cluster.name,
                b.is_some(),
                s.is_some()
            ),
        }
    }
}

#[test]
fn solver_is_subsecond_everywhere() {
    // The paper's headline solver claim: < 1 s per instance.
    let params = SolverParams::default();
    for inst in instances() {
        if let Some(sol) = algorithm1::solve(&inst, &params) {
            assert!(
                sol.solve_seconds < 1.0,
                "solver took {:.3}s on {} {}",
                sol.solve_seconds,
                inst.model.name,
                inst.cluster.name
            );
        }
    }
}

#[test]
fn online_solver_matches_online_bruteforce() {
    let params = SolverParams { ma_cap: 8, r1_cap: 4, r2_cap: 16, ..Default::default() };
    for inst in instances().into_iter().take(6) {
        let batch = 8usize;
        let Some(sol) = algorithm1::solve_online(&inst, batch, &params) else {
            continue;
        };
        // Exhaustive over the same constrained space.
        let mut best = 0.0f64;
        for r1 in 1..=params.r1_cap.min(batch) {
            if batch % r1 != 0 {
                continue;
            }
            let m_a = batch / r1;
            let (_, _, tput) = bruteforce::best_for_fixed_ma_r1(&inst, m_a, r1, params.r2_cap);
            best = best.max(tput);
        }
        assert!(
            sol.throughput_tokens >= best * 0.999,
            "online solver {:.2} < exhaustive {:.2} on {} {}",
            sol.throughput_tokens,
            best,
            inst.model.name,
            inst.cluster.name
        );
        assert_eq!(sol.config.m_a * sol.config.r1, batch);
    }
}
