"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
with hypothesis sweeps over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import expert_ffn as ffn_k
from compile.kernels import gating as gate_k
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, shape, dtype=np.float32, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Expert FFN kernel.
# ---------------------------------------------------------------------------

class TestExpertFfn:
    @settings(**SETTINGS)
    @given(
        n=st.integers(1, 96),
        m=st.sampled_from([16, 64, 128]),
        h=st.sampled_from([32, 128]),
        block=st.sampled_from([8, 32, 128]),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref_across_shapes(self, n, m, h, block, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, (n, m))
        wg, wu = rand(rng, (h, m), scale=0.2), rand(rng, (h, m), scale=0.2)
        wd = rand(rng, (m, h), scale=0.2)
        got = ffn_k.expert_ffn(x, wg, wu, wd, block_n=block)
        want = ref.ref_ffn(x, wg, wu, wd)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    def test_uneven_n_is_padded_correctly(self):
        rng = np.random.default_rng(1)
        x = rand(rng, (13, 64))
        wg, wu = rand(rng, (128, 64), scale=0.2), rand(rng, (128, 64), scale=0.2)
        wd = rand(rng, (64, 128), scale=0.2)
        got = ffn_k.expert_ffn(x, wg, wu, wd, block_n=8)
        assert got.shape == (13, 64)
        np.testing.assert_allclose(got, ref.ref_ffn(x, wg, wu, wd), atol=1e-5)

    def test_bf16_path(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rand(rng, (32, 64)), dtype=jnp.bfloat16)
        wg = jnp.asarray(rand(rng, (128, 64), scale=0.2), dtype=jnp.bfloat16)
        wu = jnp.asarray(rand(rng, (128, 64), scale=0.2), dtype=jnp.bfloat16)
        wd = jnp.asarray(rand(rng, (64, 128), scale=0.2), dtype=jnp.bfloat16)
        got = ffn_k.expert_ffn(x, wg, wu, wd, block_n=16)
        want = ref.ref_ffn(
            x.astype(jnp.float32), wg.astype(jnp.float32),
            wu.astype(jnp.float32), wd.astype(jnp.float32))
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32), np.asarray(want), atol=0.15)

    def test_zero_input_gives_zero(self):
        x = np.zeros((8, 64), np.float32)
        rng = np.random.default_rng(3)
        wg, wu = rand(rng, (128, 64)), rand(rng, (128, 64))
        wd = rand(rng, (64, 128))
        got = ffn_k.expert_ffn(x, wg, wu, wd)
        np.testing.assert_allclose(got, np.zeros((8, 64)), atol=1e-7)

    def test_vmem_estimator_monotone(self):
        assert ffn_k.vmem_bytes(256, 5120, 1536) > ffn_k.vmem_bytes(128, 5120, 1536)
        # MXU utilization perfect for 128-aligned tiles.
        assert ffn_k.mxu_utilization_estimate(128, 5120, 1536) == 1.0
        assert ffn_k.mxu_utilization_estimate(100, 5120, 1536) < 1.0


# ---------------------------------------------------------------------------
# Attention kernel.
# ---------------------------------------------------------------------------

class TestAttention:
    @settings(**SETTINGS)
    @given(
        b=st.integers(1, 3),
        nh=st.sampled_from([1, 2, 4]),
        s=st.sampled_from([16, 32, 64]),
        d=st.sampled_from([8, 16]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref(self, b, nh, s, d, causal, seed):
        rng = np.random.default_rng(seed)
        q, k = rand(rng, (b, nh, s, d)), rand(rng, (b, nh, s, d))
        v = rand(rng, (b, nh, s, d))
        got = attn_k.attention(q, k, v, causal=causal, block_q=16, block_k=16)
        want = ref.ref_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)

    def test_dv_differs_from_dk(self):
        rng = np.random.default_rng(11)
        q, k = rand(rng, (1, 2, 32, 16)), rand(rng, (1, 2, 32, 16))
        v = rand(rng, (1, 2, 32, 8))
        got = attn_k.attention(q, k, v, causal=True, block_q=16, block_k=16)
        want = ref.ref_attention(q, k, v, causal=True)
        assert got.shape == (1, 2, 32, 8)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)

    def test_causal_mask_blocks_future(self):
        # With causal attention, output at position 0 must not depend on
        # later keys/values.
        rng = np.random.default_rng(5)
        q, k, v = (rand(rng, (1, 1, 16, 8)) for _ in range(3))
        out1 = attn_k.attention(q, k, v, causal=True, block_q=8, block_k=8)
        k2, v2 = k.copy(), v.copy()
        k2[:, :, 8:], v2[:, :, 8:] = 99.0, -99.0
        out2 = attn_k.attention(q, k2, v2, causal=True, block_q=8, block_k=8)
        np.testing.assert_allclose(out1[:, :, :8], out2[:, :, :8], atol=1e-6)

    def test_softmax_rows_are_convex_combos(self):
        # Non-causal attention output must lie within [min(v), max(v)].
        rng = np.random.default_rng(6)
        q, k, v = (rand(rng, (1, 1, 32, 8)) for _ in range(3))
        out = np.asarray(attn_k.attention(q, k, v, causal=False, block_q=16, block_k=16))
        assert out.max() <= v.max() + 1e-5
        assert out.min() >= v.min() - 1e-5


# ---------------------------------------------------------------------------
# Gate kernel.
# ---------------------------------------------------------------------------

class TestGate:
    @settings(**SETTINGS)
    @given(
        n=st.integers(1, 80),
        e=st.sampled_from([4, 8, 16]),
        k=st.integers(1, 4),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref(self, n, e, k, seed):
        if k > e:
            k = e
        rng = np.random.default_rng(seed)
        x, w = rand(rng, (n, 64)), rand(rng, (e, 64), scale=0.3)
        p1, i1 = gate_k.gate_topk(x, w, k)
        p2, i2 = ref.ref_gate(x, w, k)
        np.testing.assert_allclose(p1, p2, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    def test_probs_normalized_and_sorted(self):
        rng = np.random.default_rng(9)
        x, w = rand(rng, (40, 64)), rand(rng, (8, 64), scale=0.3)
        p, i = gate_k.gate_topk(x, w, 2)
        p = np.asarray(p)
        np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)
        assert (p[:, 0] >= p[:, 1]).all(), "top-k must be sorted"
        assert np.asarray(i).max() < 8 and np.asarray(i).min() >= 0

    def test_full_probs_sum_to_one(self):
        rng = np.random.default_rng(10)
        x, w = rand(rng, (24, 64)), rand(rng, (8, 64))
        probs = np.asarray(gate_k.gate_probs(x, w, block_n=8))
        np.testing.assert_allclose(probs.sum(-1), 1.0, atol=1e-5)
