//! The DEP serving coordinator — the L3 system of the paper, running
//! for real on PJRT-CPU.
//!
//! Topology mirrors §2.2 / Fig. 2: one AG worker executes attention +
//! gate + shared-expert artifacts (AG weights are replicated, so one
//! worker faithfully represents per-GPU behaviour and whole-AG
//! throughput is `ag ×` its rate); `eg` EG workers each own
//! `E/eg` experts and execute the expert-FFN artifact per routed token
//! group. A2E and E2A are channel links with optional α-β delay
//! injection so schedule differences remain observable on a host without
//! real interconnect.
//!
//! The pipeline executor consumes a [`crate::sched::PlanConfig`]
//! (produced by Algorithm 1, PPPipe, or naive) and issues fine-grained
//! tasks in the planned order — the same vocabulary the simulator
//! executes analytically.
//!
//! [`batcher`] stacks continuous batching on top: a bounded request
//! queue drains into size-bucketed batches pipelined across a pool of
//! server replicas that share one metrics registry and one memoized
//! plan cache.

pub mod batcher;
pub mod links;
pub mod moe;
pub mod pipeline;
pub mod router;
pub mod server;
