//! Solver-speed claim (§4.3 / §5.4): "the solver completes in under
//! 1 second" and its complexity is O(C·d(M)) — fast enough for
//! per-request online adaptation.
//!
//! Benchmarks Algorithm 1 wall time across every (model, testbed, S)
//! instance of the evaluation plus the online variant, and scales the
//! search caps to show the growth is benign.
//!
//! Run: `cargo bench --bench solver_speed`

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{solve, solve_online, Instance, SolverParams};
use findep::util::bench::{Bencher, Table};

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let params = SolverParams::default();

    let mut table = Table::new(
        "Algorithm 1 solve time (must stay << 1 s)",
        &["instance", "mean", "p50", "evals", "throughput (tok/s)"],
    );
    for tb in Testbed::all() {
        for (deepseek, name) in [(true, "deepseek"), (false, "qwen")] {
            let layers = ModelConfig::paper_layers(deepseek, &tb.name[..2]);
            let model = if deepseek {
                ModelConfig::deepseek_v2(layers)
            } else {
                ModelConfig::qwen3_moe(layers)
            };
            let split = GroupSplit::paper_default(&tb, deepseek);
            let inst = Instance::new(model, tb.clone(), split, 4096);
            let Some(sol) = solve(&inst, &params) else { continue };
            let r = bencher.run(&format!("{name}/{}", tb.name), || {
                let _ = solve(&inst, &params);
            });
            assert!(
                r.mean_s() < 1.0,
                "solver exceeded 1 s on {name}/{}",
                tb.name
            );
            table.row(&[
                format!("{name} on {}", tb.name),
                findep::util::bench::fmt_duration(r.mean_s()),
                findep::util::bench::fmt_duration(r.p50_s()),
                sol.evals.to_string(),
                format!("{:.0}", sol.throughput_tokens),
            ]);
        }
    }
    table.print();

    // Online variant (the per-batch re-solve of Table 6).
    let inst = Instance::new(
        ModelConfig::deepseek_v2(8),
        Testbed::a(),
        GroupSplit::new(3, 5),
        3072,
    );
    let r = bencher.run("solve_online(batch=4/gpu)", || {
        let _ = solve_online(&inst, 4, &params);
    });
    println!("online re-solve: {}", r.report());
    assert!(r.mean_s() < 1.0);

    // Cap scaling: the Pareto-frontier walk keeps growth benign.
    let mut table = Table::new("solve time vs search caps", &["ma_cap", "r1_cap", "r2_cap", "mean"]);
    for (ma, r1, r2) in [(4usize, 4usize, 16usize), (8, 8, 32), (16, 8, 64), (32, 8, 128)] {
        let p = SolverParams { ma_cap: ma, r1_cap: r1, r2_cap: r2 };
        let r = bencher.run(&format!("caps {ma}/{r1}/{r2}"), || {
            let _ = solve(&inst, &p);
        });
        table.row(&[
            ma.to_string(),
            r1.to_string(),
            r2.to_string(),
            findep::util::bench::fmt_duration(r.mean_s()),
        ]);
        assert!(r.mean_s() < 1.0, "solver exceeded 1 s at caps {ma}/{r1}/{r2}");
    }
    table.print();
    println!("paper claim: solver < 1 s on every instance — holds with large margin here.");
}
