//! Integration: the discrete-event engine must reproduce the §4.2
//! closed-form timestamps exactly on ASAS plans — the paper's algebra
//! and our task-DAG semantics are the same object.

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::perfmodel::StageModels;
use findep::sched::{analytic::Analytic, Order, Plan, PlanConfig, TaskKind};
use findep::simulator::simulate;

fn cases() -> Vec<(ModelConfig, GroupSplit)> {
    vec![
        (ModelConfig::deepseek_v2(8), GroupSplit::new(3, 5)),
        (ModelConfig::qwen3_moe(12), GroupSplit::new(4, 4)),
    ]
}

#[test]
fn makespan_matches_closed_form_across_grid() {
    for tb in Testbed::all() {
        for (model, split) in cases() {
            for s in [1024usize, 2048, 4096] {
                let sm = StageModels::new(&model, &tb, split, s);
                for m_a in [1usize, 2, 4] {
                    for r1 in [1usize, 2, 3, 4] {
                        for r2 in [1usize, 2, 4, 8] {
                            let a = Analytic::new(&sm, m_a as f64, r1, r2);
                            let cfg = PlanConfig::findep(m_a, r1, r2, a.m_e, Order::Asas);
                            let plan = Plan::build(&sm, cfg, model.n_layers, split.ag, s);
                            let des = simulate(&plan).makespan;
                            let an = a.makespan(model.n_layers);
                            assert!(
                                (des - an).abs() <= 1e-9 * an.max(1e-9),
                                "DES {des} != analytic {an} \
                                 (tb={} model={} S={s} m_a={m_a} r1={r1} r2={r2})",
                                tb.name,
                                model.name
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn layer0_timestamps_match_closed_forms() {
    let model = ModelConfig::deepseek_v2(4);
    let split = GroupSplit::new(3, 5);
    let sm = StageModels::new(&model, &Testbed::a(), split, 2048);
    let (m_a, r1, r2) = (2usize, 3usize, 2usize);
    let a = Analytic::new(&sm, m_a as f64, r1, r2);
    let plan = Plan::build(
        &sm,
        PlanConfig::findep(m_a, r1, r2, a.m_e, Order::Asas),
        model.n_layers,
        split.ag,
        2048,
    );
    let sim = simulate(&plan);
    for i in 0..r1 {
        let at = plan.find(TaskKind::Attention, 0, i as u32, 0).unwrap();
        assert!(
            (sim.start[at] - a.tau_a(i)).abs() < 1e-12,
            "tau_a({i}): {} vs {}",
            sim.start[at],
            a.tau_a(i)
        );
        let sh = plan.find(TaskKind::SharedExpert, 0, i as u32, 0).unwrap();
        assert!((sim.start[sh] - a.tau_s(i)).abs() < 1e-12, "tau_s({i})");
        for j in 0..r2 {
            let a2e = plan.find(TaskKind::A2E, 0, i as u32, j as u32).unwrap();
            assert!(
                (sim.start[a2e] - a.tau_a2e(i, j)).abs() < 1e-12,
                "tau_a2e({i},{j}): {} vs {}",
                sim.start[a2e],
                a.tau_a2e(i, j)
            );
            let e = plan.find(TaskKind::Expert, 0, i as u32, j as u32).unwrap();
            assert!((sim.start[e] - a.tau_e(i, j)).abs() < 1e-12, "tau_e({i},{j})");
            let e2a = plan.find(TaskKind::E2A, 0, i as u32, j as u32).unwrap();
            assert!((sim.start[e2a] - a.tau_e2a(i, j)).abs() < 1e-12, "tau_e2a({i},{j})");
        }
    }
}

#[test]
fn objective_agrees_with_des_throughput() {
    let model = ModelConfig::qwen3_moe(12);
    let split = GroupSplit::new(4, 4);
    let sm = StageModels::new(&model, &Testbed::b(), split, 2048);
    for (m_a, r1, r2) in [(1usize, 1usize, 1usize), (2, 2, 2), (4, 2, 4)] {
        let a = Analytic::new(&sm, m_a as f64, r1, r2);
        let plan = Plan::build(
            &sm,
            PlanConfig::findep(m_a, r1, r2, a.m_e, Order::Asas),
            model.n_layers,
            split.ag,
            2048,
        );
        let sim = simulate(&plan);
        let des_tput = sim.throughput_tokens(&plan);
        let an_tput = a.throughput_tokens(model.n_layers, split.ag, 2048);
        assert!(
            ((des_tput - an_tput) / an_tput).abs() < 1e-9,
            "throughput mismatch: {des_tput} vs {an_tput}"
        );
    }
}

#[test]
fn skew_sampled_part_loads_price_through_the_simulator() {
    use findep::config::{ExpertLoad, ExpertPlacement};
    use findep::util::rng::Rng;
    let model = ModelConfig::deepseek_v2(4);
    let split = GroupSplit::new(3, 5);
    let sm = StageModels::new(&model, &Testbed::a(), split, 2048);
    let (m_a, r1, r2) = (2usize, 3usize, 4usize);
    let a = Analytic::new(&sm, m_a as f64, r1, r2);
    let cfg = PlanConfig::findep(m_a, r1, r2, a.m_e, Order::Asas);
    let base = Plan::build(&sm, cfg, model.n_layers, split.ag, 2048);
    // Unit factors are the identity: the simulated makespan is
    // bit-identical to the homogeneous plan.
    let ones = Plan::build_loaded(&sm, cfg, model.n_layers, split.ag, 2048, &[1.0; 4]);
    assert_eq!(simulate(&ones).makespan.to_bits(), simulate(&base).makespan.to_bits());
    // Zipf-sampled per-part factors (Monte-Carlo routing through the
    // uniform placement): deterministic under a fixed seed, and the
    // simulated makespan covers the slowest realized expert part.
    let load = ExpertLoad::zipf(model.n_experts, 1.2);
    let placement = ExpertPlacement::uniform(model.n_experts, split.eg);
    let factors = load.sample_part_factors(&placement, 256, r2, &mut Rng::new(41));
    assert_eq!(factors.len(), r2);
    assert!(factors.iter().all(|f| f.is_finite() && *f > 0.0), "{factors:?}");
    let loaded = Plan::build_loaded(&sm, cfg, model.n_layers, split.ag, 2048, &factors);
    let sim = simulate(&loaded);
    let max_f = factors.iter().fold(0.0f64, |m, &f| m.max(f));
    assert!(
        sim.makespan >= sm.expert_time(cfg.m_e * max_f),
        "makespan {} cannot undercut its slowest expert part {}",
        sim.makespan,
        sm.expert_time(cfg.m_e * max_f)
    );
    let again = load.sample_part_factors(&placement, 256, r2, &mut Rng::new(41));
    let replay = Plan::build_loaded(&sm, cfg, model.n_layers, split.ag, 2048, &again);
    assert_eq!(simulate(&replay).makespan.to_bits(), sim.makespan.to_bits());
}
