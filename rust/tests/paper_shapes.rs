//! Qualitative paper-shape checks: the *directions* and *regimes* of the
//! paper's evaluation must hold on our calibrated simulator —
//! who wins, where speedups grow, where they vanish (§5.4-§5.5
//! Discussion). Absolute numbers are testbed-specific and not asserted.

use findep::baselines::{best_naive, best_pppipe};
use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::sched::Plan;
use findep::simulator::{simulate, ScheduleTrace};
use findep::solver::{solve, Instance, SolverParams};

fn speedup(inst: &Instance, params: &SolverParams) -> Option<f64> {
    let pp = best_pppipe(inst, params)?;
    let fd = solve(inst, params)?;
    Some(fd.throughput_tokens / pp.throughput_tokens)
}

#[test]
fn findep_never_loses_to_best_pppipe_anywhere() {
    // Table 5's universal claim across 2 backbones x 4 testbeds x S.
    let params = SolverParams::default();
    for tb in Testbed::all() {
        for (model, shared) in
            [(ModelConfig::deepseek_v2(8), true), (ModelConfig::qwen3_moe(12), false)]
        {
            for s in [1024usize, 2048, 4096, 8192] {
                let inst = Instance::new(
                    model.clone(),
                    tb.clone(),
                    GroupSplit::paper_default(&tb, shared),
                    s,
                );
                if let Some(sp) = speedup(&inst, &params) {
                    assert!(
                        sp >= 0.999,
                        "FinDEP slower than PPPipe: {sp:.3}x on {} {} S={s}",
                        model.name,
                        tb.name
                    );
                }
            }
        }
    }
}

#[test]
fn speedup_grows_with_sequence_length() {
    // Table 5's bold numbers: the S=8192 column shows the largest
    // speedups (communication becomes the bottleneck). Check the
    // comm-bound testbed B with the Qwen backbone (1.61x in the paper).
    let params = SolverParams::default();
    let tb = Testbed::b();
    let model = ModelConfig::qwen3_moe(12);
    let split = GroupSplit::paper_default(&tb, false);
    let sp_short = speedup(&Instance::new(model.clone(), tb.clone(), split, 1024), &params)
        .expect("feasible");
    let sp_long = speedup(&Instance::new(model.clone(), tb.clone(), split, 8192), &params)
        .expect("feasible");
    assert!(
        sp_long >= sp_short - 0.02,
        "speedup should grow (or hold) with S: S=1024 {sp_short:.3}x vs S=8192 {sp_long:.3}x"
    );
    assert!(sp_long > 1.0, "long sequences must show a real win, got {sp_long:.3}x");
}

#[test]
fn comm_cheap_testbed_shows_smaller_gains() {
    // §5.5 Discussion: on testbed C (fat NVLink) FinDEP's advantage
    // shrinks toward 1.0x (Amdahl); on comm-bound B it is larger.
    let params = SolverParams::default();
    let model = ModelConfig::qwen3_moe(12);
    let sp_b = speedup(&Instance::new(
        model.clone(),
        Testbed::b(),
        GroupSplit::new(4, 4),
        4096,
    ), &params)
    .expect("B feasible");
    let sp_c = speedup(&Instance::new(
        model.clone(),
        Testbed::c(),
        GroupSplit::new(4, 4),
        4096,
    ), &params)
    .expect("C feasible");
    assert!(
        sp_b >= sp_c - 0.02,
        "comm-bound B ({sp_b:.3}x) should benefit at least as much as comm-cheap C ({sp_c:.3}x)"
    );
}

#[test]
fn non_overlap_ordering_matches_table7() {
    // Table 7: naive > PPPipe > FinDEP in exposed communication time
    // (DeepSeek on testbed A).
    let params = SolverParams::default();
    let tb = Testbed::a();
    let model = ModelConfig::deepseek_v2(8);
    let split = GroupSplit::new(3, 5);
    for s in [1024usize, 2048, 4096] {
        let inst = Instance::new(model.clone(), tb.clone(), split, s);
        let sm = inst.stage_models();
        let exposed = |cfg: findep::sched::PlanConfig| -> f64 {
            let plan = Plan::build(&sm, cfg, model.n_layers, split.ag, s);
            let sim = simulate(&plan);
            ScheduleTrace::from_sim(&plan, &sim).non_overlapped_comm()
        };
        let nv = best_naive(&inst, params.ma_cap).unwrap();
        let pp = best_pppipe(&inst, &params).unwrap();
        let fd = solve(&inst, &params).unwrap();
        let (e_nv, e_pp, e_fd) =
            (exposed(nv.config), exposed(pp.config), exposed(fd.config));
        assert!(
            e_nv >= e_pp - 1e-9,
            "S={s}: naive exposed {e_nv:.5} < pppipe {e_pp:.5}"
        );
        assert!(
            e_pp >= e_fd - 1e-9,
            "S={s}: pppipe exposed {e_pp:.5} < findep {e_fd:.5}"
        );
    }
}

#[test]
fn testbed_d_scales_beyond_testbed_c() {
    // Table 5: the 32-GPU system serves more aggregate tokens/s than
    // the 8-GPU system (more AG GPUs commit more samples per pass).
    let params = SolverParams::default();
    let model = ModelConfig::deepseek_v2(16);
    let c = solve(
        &Instance::new(model.clone(), Testbed::c(), GroupSplit::new(3, 5), 2048),
        &params,
    )
    .expect("C feasible");
    let d = solve(
        &Instance::new(model.clone(), Testbed::d(), GroupSplit::new(8, 24), 2048),
        &params,
    )
    .expect("D feasible");
    assert!(
        d.throughput_tokens > c.throughput_tokens,
        "32-GPU D ({:.0} tok/s) should outscale 8-GPU C ({:.0} tok/s)",
        d.throughput_tokens,
        c.throughput_tokens
    );
}

#[test]
fn shared_expert_scheduling_matters_for_deepseek() {
    // §2.3 motivation: FinDEP's separate shared-expert task (overlapping
    // A2E) must beat forcing the shared expert inline (PPPipe fusion) at
    // the same (m_a, r1), on at least the comm-heavy testbeds.
    let tb = Testbed::b();
    let model = ModelConfig::deepseek_v2(8);
    let split = GroupSplit::new(3, 5);
    let inst = Instance::new(model.clone(), tb, split, 4096);
    let sm = inst.stage_models();
    let (m_a, r1) = (2usize, 2usize);
    let fused = inst.evaluate(findep::sched::PlanConfig::pppipe(m_a, r1, sm.m_e(m_a as f64, 1)));
    let separate = inst.evaluate(findep::sched::PlanConfig::findep(
        m_a,
        r1,
        1,
        sm.m_e(m_a as f64, 1),
        findep::sched::Order::Asas,
    ));
    assert!(
        separate.1 >= fused.1 * 0.999,
        "separate shared scheduling {:.1} should not lose to fused {:.1}",
        separate.1,
        fused.1
    );
}
