//! End-to-end serving driver — the full three-layer system on a real
//! workload.
//!
//! Loads the tiny MoE's AOT artifacts (Pallas kernels → JAX stages →
//! HLO text), compiles them on the PJRT CPU client, spins up the DEP
//! coordinator (1 AG worker + 2 EG workers + A2E/E2A links), validates
//! numerics against the Python golden output, then serves a stream of
//! batched requests under naive / PPPipe / FinDEP / adaptive policies,
//! reporting latency and throughput per policy.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example serve_e2e`
//!
//! Results recorded in EXPERIMENTS.md §End-to-end.

use findep::coordinator::links::LinkDelay;
use findep::coordinator::moe::ModelHandle;
use findep::coordinator::pipeline::{ExecConfig, Pipeline};
use findep::coordinator::server::{EmbeddedRequest, Policy, Server};
use findep::runtime::artifact::{Golden, Manifest};
use findep::runtime::artifacts_dir;
use findep::sched::Order;
use findep::util::bench::Table;
use findep::util::stats;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }

    // --- Load + compile (the one-time startup cost). -------------------
    let t0 = std::time::Instant::now();
    let model = ModelHandle::load(&dir, true)?;
    println!(
        "loaded {} artifacts on {} in {:.2}s (model '{}': {} layers, {} experts, top-{}, \
         {} shared)",
        model.engine.n_artifacts(),
        model.engine.platform,
        t0.elapsed().as_secs_f64(),
        model.model.name,
        model.model.n_layers,
        model.model.n_experts,
        model.model.top_k,
        model.model.n_shared,
    );

    // --- Golden validation: rust pipeline vs python forward. ------------
    let manifest = Manifest::load(&dir)?;
    let golden = Golden::load(&manifest.golden)?;
    {
        let pipeline = Pipeline::new(model.clone(), 2, None)?;
        let (out, _) = pipeline.forward(&golden.input, ExecConfig::findep(2, 2, Order::Asas))?;
        let diff = out.max_abs_diff(&golden.output);
        anyhow::ensure!(diff <= golden.atol, "golden mismatch: {diff}");
        println!(
            "golden check  : rust DEP pipeline == python forward (max |Δ| = {diff:.2e}, \
             atol {:.0e})",
            golden.atol
        );
    }

    // --- Serve under each policy. ---------------------------------------
    // Mild bandwidth-shaped link delay keeps the schedule differences
    // visible on a host whose real interconnect is a memcpy.
    let delay = Some(LinkDelay { alpha_s: 3e-5, beta_s_per_byte: 2e-7 });
    let srv = Server::new(model, 2, delay)?;
    let s = srv.pipeline.model().seq_len;
    let m = srv.pipeline.model().model.embed;

    let policies: Vec<(&str, Policy)> = vec![
        ("naive-DEP", Policy::Naive),
        ("PPPipe(r1=2)", Policy::PpPipe { r1: 2 }),
        ("FinDEP(2,2,ASAS)", Policy::FinDep { r1: 2, r2: 2, order: Order::Asas }),
        ("FinDEP adaptive", Policy::Adaptive),
    ];

    let n_batches = 12usize;
    let batch_size = 4usize;
    let mut table = Table::new(
        &format!("Real serving: {n_batches} batches x {batch_size} requests (S={s}, M={m})"),
        &["policy", "tokens/s", "p50 batch ms", "p95 batch ms", "AG wait ms (mean)"],
    );

    for (name, policy) in policies {
        // Warmup.
        let reqs: Vec<EmbeddedRequest> =
            (0..batch_size as u64).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
        let _ = srv.serve_batch(&reqs, policy)?;

        let mut lat = Vec::new();
        let mut waits = Vec::new();
        let mut tokens = 0usize;
        let t0 = std::time::Instant::now();
        for b in 0..n_batches as u64 {
            let reqs: Vec<EmbeddedRequest> = (0..batch_size as u64)
                .map(|i| EmbeddedRequest::synthetic(b * batch_size as u64 + i, s, m))
                .collect();
            let (resp, stats_fwd) = srv.serve_batch(&reqs, policy)?;
            tokens += resp.len() * s;
            lat.push(stats_fwd.total * 1e3);
            waits.push(stats_fwd.wait * 1e3);
        }
        let dt = t0.elapsed().as_secs_f64();
        table.row(&[
            name.to_string(),
            format!("{:.0}", tokens as f64 / dt),
            format!("{:.2}", stats::percentile(&lat, 50.0)),
            format!("{:.2}", stats::percentile(&lat, 95.0)),
            format!("{:.2}", stats::mean(&waits)),
        ]);
    }
    table.print();
    println!("metrics snapshot:\n{}", findep::util::json::to_string_pretty(&srv.metrics.snapshot_json()));
    Ok(())
}
