//! `findep` CLI — leader entrypoint.
//!
//! Subcommands:
//! * `solve`    — run Algorithm 1 for a (model, testbed, split, S) and
//!   print the chosen configuration + predicted throughput.
//! * `search-splits` — search the (ag, eg) split itself (plus
//!   multi-replica tilings) with the pruned parallel split-search
//!   solver layer; print the per-candidate table and the winner.
//!   `--cluster` searches a heterogeneous pool layout, `--ttft-ms`
//!   optimizes goodput under a makespan cap, and `--carve` partitions
//!   a cluster into prefill and decode sub-clusters for a traffic mix.
//! * `compare`  — naive vs PPPipe vs FinDEP on the simulator, with an
//!   ASCII Gantt of each schedule.
//! * `serve`    — real execution: load AOT artifacts, serve synthetic
//!   batches through the DEP pipeline, report tokens/s and latency.
//!   `--ttft-ms`/`--tpot-ms` arm an SLO: plans are capped at the
//!   targets and the run is graded on percentile attainment + goodput.
//! * `calibrate`— Fig.-7-style micro-benchmarks on this host (PJRT GEMM
//!   / attention probes + link probe), printing fitted α-β models + R².

use findep::baselines;
use findep::config::{Cluster, GroupSplit, ModelConfig, Phase, Testbed};
use findep::coordinator::batcher::{Batcher, BatcherConfig, ResilienceConfig};
use findep::coordinator::faults::FaultPlan;
use findep::coordinator::links::LinkDelay;
use findep::coordinator::moe::ModelHandle;
use findep::coordinator::server::{EmbeddedRequest, Policy, Server};
use findep::coordinator::slo::SloPolicy;
use findep::perfmodel::{calibrate, profile, CalibrationProfile, ComponentFit, ProfileThresholds};
use findep::runtime::{artifacts_dir, probe};
use findep::sched::{Order, Plan};
use findep::simulator::{simulate, ScheduleTrace};
use findep::solver::{self, Instance, SolverParams};
use findep::util::args::Spec;
use findep::util::bench::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { vec![] } else { args[1..].to_vec() };
    let code = match cmd {
        "solve" => cmd_solve(&rest),
        "search-splits" => cmd_search_splits(&rest),
        "compare" => cmd_compare(&rest),
        "serve" => cmd_serve(&rest),
        "calibrate" => cmd_calibrate(&rest),
        _ => {
            eprintln!(
                "findep — fine-grained scheduling for disaggregated expert parallelism\n\n\
                 usage: findep <solve|search-splits|compare|serve|calibrate> [--help]"
            );
            if cmd == "help" {
                0
            } else {
                2
            }
        }
    };
    std::process::exit(code);
}

/// Load, gate, and announce a command's `--profile` argument ("" =
/// hand constants); `Err` carries the process exit code. The
/// validation layer runs here, at the use boundary: a profile that
/// fails the R²/degeneracy gate never reaches a solver.
fn profile_for(
    p: &findep::util::args::Parsed,
    doing: &str,
) -> Result<Option<CalibrationProfile>, i32> {
    let path = p.get("profile");
    if path.is_empty() {
        return Ok(None);
    }
    let loaded = CalibrationProfile::load(std::path::Path::new(path))
        .map_err(|e| format!("--profile {path}: {e}"))
        .and_then(|prof| {
            prof.validate(&ProfileThresholds::default())
                .map_err(|e| format!("--profile {path} rejected: {e}"))
                .map(|()| prof)
        });
    match loaded {
        Ok(prof) => {
            println!(
                "{doing} under calibration profile {} (fingerprint {:016x})",
                prof.host,
                prof.fingerprint().0
            );
            Ok(Some(prof))
        }
        Err(e) => {
            eprintln!("{e}");
            Err(2)
        }
    }
}

fn instance_from(p: &findep::util::args::Parsed) -> Option<Instance> {
    let testbed = Testbed::by_name(p.get("testbed"))?;
    let model = ModelConfig::paper_preset(p.get("model"), p.get("testbed"))?;
    let split = GroupSplit::paper_default(&testbed, model.has_shared_expert());
    Some(Instance::new(model, testbed, split, p.get_usize("seq")))
}

fn cmd_solve(args: &[String]) -> i32 {
    let spec = Spec::new("findep solve", "run Algorithm 1 and print the best configuration")
        .opt("model", "deepseek-v2", "model preset (deepseek-v2|qwen3-moe|tiny)")
        .opt("testbed", "A", "testbed A|B|C|D")
        .opt_uint("seq", "2048", "sequence length S")
        .opt("phase", "prefill", "serving phase: prefill|decode")
        .opt_uint("kv", "0", "decode KV length per sample (0 = --seq)")
        .opt_uint("budget-us", "0", "anytime solve budget in µs (0 = exhaustive)")
        .opt("profile", "", "calibration profile JSON (from `calibrate --out`)");
    let p = match spec.parse(args) {
        Ok(p) => p,
        Err(e) => return usage(e),
    };
    let Some(testbed) = Testbed::by_name(p.get("testbed")) else {
        eprintln!("unknown testbed");
        return 2;
    };
    let Some(model) = ModelConfig::paper_preset(p.get("model"), p.get("testbed")) else {
        eprintln!("unknown model");
        return 2;
    };
    let split = GroupSplit::paper_default(&testbed, model.has_shared_expert());
    let seq = p.get_usize("seq");
    let mut inst = match p.get("phase") {
        "prefill" => Instance::new(model, testbed.clone(), split, seq),
        "decode" => {
            let kv = match p.get_usize("kv") {
                0 => seq,
                kv => kv,
            };
            Instance::decode(model, testbed.clone(), split, kv)
        }
        other => {
            eprintln!("unknown phase '{other}' (prefill|decode)");
            return 2;
        }
    };
    match profile_for(&p, "solving") {
        Err(code) => return code,
        Ok(Some(prof)) => {
            let mut t = Table::new(
                "calibrated vs Table-2 stage times (at m_a = 1, r2 = 1)",
                &["stage", "Table-2", "calibrated", "delta"],
            );
            let deltas = profile::stage_deltas(
                &inst.model,
                &testbed,
                &prof,
                inst.split,
                inst.seq_len,
                inst.phase,
            );
            for d in deltas {
                t.row(&[
                    d.stage.to_string(),
                    format!("{:.4} ms", d.hand_s * 1e3),
                    format!("{:.4} ms", d.calibrated_s * 1e3),
                    format!("{:+.1}%", d.delta_pct()),
                ]);
            }
            t.print();
            inst.cluster = Cluster::from_profile(&inst.cluster, &prof);
        }
        Ok(None) => {}
    }
    let budget = match p.get_u64("budget-us") {
        0 => None,
        us => Some(std::time::Duration::from_micros(us)),
    };
    let params = SolverParams { budget, ..SolverParams::default() };
    match solver::solve(&inst, &params) {
        Some(sol) => {
            let phase_note = match inst.phase {
                Phase::Prefill => format!("S={}", inst.seq_len),
                Phase::Decode { kv_len } => format!("decode kv={kv_len}"),
            };
            println!("instance: {} on {} {}", inst.model.name, inst.cluster.name, phase_note);
            println!("best config: {}", sol.config.describe());
            println!("makespan: {:.3} ms", sol.makespan * 1e3);
            let unit = if inst.phase.is_decode() { "decoded tokens/s" } else { "tokens/s" };
            println!("throughput: {:.2} {unit}", sol.throughput_tokens);
            println!(
                "solver: {:.1} ms, {} evaluations, {} rows bound-pruned{}{}",
                sol.solve_seconds * 1e3,
                sol.evals,
                sol.pruned_rows,
                if sol.warm_seeded { ", warm-seeded" } else { "" },
                if sol.exhaustive { "" } else { " — budget expired, plan is the best incumbent" },
            );
            0
        }
        None => {
            eprintln!("instance infeasible (experts do not fit the EG)");
            1
        }
    }
}

/// Shared per-candidate table + stats footer for both the legacy
/// single-testbed search and the cluster-aware search.
fn print_search_report(title: &str, report: &solver::SearchReport, params: &solver::SearchParams) {
    let mut table =
        Table::new(title, &["placement", "per-instance config", "total tokens/s", "note"]);
    let mut rows: Vec<&solver::SplitSolution> = report.evaluated.iter().collect();
    rows.sort_by(|a, b| b.total_throughput.total_cmp(&a.total_throughput));
    for s in rows {
        table.row(&[
            s.candidate.describe(),
            s.per_instance.config.describe(),
            format!("{:.0}", s.total_throughput),
            if s.candidate == report.best.candidate { "best".into() } else { String::new() },
        ]);
    }
    table.print();
    let st = &report.stats;
    println!(
        "{} candidates: {} solved, {} pruned by bound, {} infeasible — {:.1} ms on {} threads \
         ({} Algorithm-1 probes)",
        st.candidates,
        st.solved,
        st.pruned,
        st.infeasible,
        st.solve_seconds * 1e3,
        st.threads,
        st.evals,
    );
    if params.prune && st.pruned > 0 {
        println!(
            "note: the winner and stats are deterministic, but which non-winning candidates \
             get solved before the bound prunes them depends on thread timing — pass \
             --no-prune for the full (and stable) per-split table."
        );
    }
}

fn cmd_search_splits(args: &[String]) -> i32 {
    let spec = Spec::new(
        "findep search-splits",
        "search (ag, eg) splits and replica tilings on top of Algorithm 1",
    )
    .opt("model", "deepseek-v2", "model preset (deepseek-v2|qwen3-moe|tiny)")
    .opt("testbed", "A", "testbed A|B|C|D (single-pool cluster)")
    .opt("cluster", "", "heterogeneous cluster: hetero | A|B|C|D (overrides --testbed)")
    .opt_uint("seq", "2048", "sequence length S")
    .opt_uint("threads", "0", "worker threads (0 = all cores)")
    .opt_float("ttft-ms", "0", "cap per-batch makespan at this TTFT SLO in ms (0 = none)")
    .opt("profile", "", "calibration profile JSON (from `calibrate --out`)")
    .flag("no-prune", "disable the analytic branch-and-bound pruning")
    .flag("no-replicas", "single-instance splits only (no cluster tilings)")
    .flag("serial", "also run the serial cold sweep and report its wall time")
    .flag("carve", "partition the cluster into prefill + decode sub-clusters for a traffic mix")
    .opt_float("prefill-frac", "0.5", "carve: fraction of token demand that is prefill")
    .opt_uint("decode-kv", "0", "carve: decode KV length (0 = --seq)");
    let p = match spec.parse(args) {
        Ok(p) => p,
        Err(e) => return usage(e),
    };
    let Some(testbed) = Testbed::by_name(p.get("testbed")) else {
        eprintln!("unknown testbed");
        return 2;
    };
    let Some(model) = ModelConfig::paper_preset(p.get("model"), p.get("testbed")) else {
        eprintln!("unknown model");
        return 2;
    };
    let seq = p.get_usize("seq");
    let ttft_ms = p.get_f64("ttft-ms");
    if ttft_ms < 0.0 {
        return usage("--ttft-ms must be ≥ 0".into());
    }
    let max_makespan = (ttft_ms > 0.0).then(|| ttft_ms * 1e-3);
    let params = solver::SearchParams {
        solver: SolverParams { max_makespan, ..SolverParams::default() },
        threads: p.get_usize("threads"),
        prune: !p.has_flag("no-prune"),
        multi_replica: !p.has_flag("no-replicas"),
    };

    // Cluster route: an explicit pool layout, a makespan cap (goodput
    // mode), or a carve request all go through the cluster-aware
    // search. The bare-testbed route below stays bit-identical to the
    // pre-cluster CLI.
    let cluster_arg = p.get("cluster").to_string();
    if !cluster_arg.is_empty() || max_makespan.is_some() || p.has_flag("carve") {
        let base = if cluster_arg.is_empty() {
            Cluster::single_pool(&testbed)
        } else {
            match Cluster::by_name(&cluster_arg) {
                Some(c) => c,
                None => {
                    eprintln!("unknown cluster '{cluster_arg}' (hetero | A|B|C|D)");
                    return 2;
                }
            }
        };
        let cluster = match profile_for(&p, "searching") {
            Err(code) => return code,
            Ok(Some(prof)) => Cluster::from_profile(&base, &prof),
            Ok(None) => base,
        };
        if p.has_flag("carve") {
            let frac = p.get_f64("prefill-frac");
            if !(0.0..=1.0).contains(&frac) {
                return usage("--prefill-frac must be in [0, 1]".into());
            }
            let mix = solver::TrafficMix {
                prefill_seq: seq,
                decode_kv: match p.get_usize("decode-kv") {
                    0 => seq,
                    kv => kv,
                },
                prefill_frac: frac,
            };
            let Some(plan) = solver::carve(&model, &cluster, &mix, &params) else {
                eprintln!("no feasible carve: neither side of any partition fits the model");
                return 1;
            };
            println!(
                "carve: {} on {} (prefill S={}, decode kv={}, prefill frac {:.2})",
                model.name, cluster.name, mix.prefill_seq, mix.decode_kv, mix.prefill_frac
            );
            println!(
                "  prefill GPUs per pool {:?}: {} — {} at {:.0} tokens/s",
                plan.prefill_gpus,
                plan.prefill.candidate.describe(),
                plan.prefill.per_instance.config.describe(),
                plan.prefill.total_throughput,
            );
            println!(
                "  decode  GPUs per pool {:?}: {} — {} at {:.0} tokens/s",
                plan.decode_gpus,
                plan.decode.candidate.describe(),
                plan.decode.per_instance.config.describe(),
                plan.decode.total_throughput,
            );
            println!(
                "  sustainable goodput at the mix: {:.0} tokens/s ({} partitions searched)",
                plan.goodput, plan.partitions
            );
            return 0;
        }
        let Some(report) = solver::search_cluster(&model, &cluster, seq, Phase::Prefill, &params)
        else {
            eprintln!(
                "no feasible (ag, eg) split on this cluster{}",
                if max_makespan.is_some() { " under the --ttft-ms cap" } else { "" }
            );
            return 1;
        };
        let objective = if max_makespan.is_some() { "goodput" } else { "throughput" };
        print_search_report(
            &format!("split search ({objective}): {} on {} S={seq}", model.name, cluster.name),
            &report,
            &params,
        );
        if let Some(cap) = max_makespan {
            println!(
                "SLO cap: every listed plan fits a {:.2} ms per-batch makespan (winner: {:.2} ms)",
                cap * 1e3,
                report.best.per_instance.makespan * 1e3,
            );
        }
        return 0;
    }

    let testbed = match profile_for(&p, "searching") {
        Err(code) => return code,
        Ok(Some(prof)) => Testbed::from_profile(&testbed, &prof),
        Ok(None) => testbed,
    };
    let Some(report) = solver::search_splits(&model, &testbed, seq, &params) else {
        eprintln!("no feasible (ag, eg) split on this testbed");
        return 1;
    };
    print_search_report(
        &format!("split search: {} on {} S={seq}", model.name, testbed.name),
        &report,
        &params,
    );
    let st = &report.stats;
    if p.has_flag("serial") {
        let t0 = std::time::Instant::now();
        let serial = solver::search_splits_serial(&model, &testbed, seq, &params);
        let dt = t0.elapsed().as_secs_f64();
        match serial {
            Some(s) => println!(
                "serial cold sweep: {:.1} ms ({:.2}x slower), same winner: {}",
                dt * 1e3,
                dt / st.solve_seconds.max(1e-12),
                s.candidate == report.best.candidate
                    && s.total_throughput == report.best.total_throughput,
            ),
            None => println!("serial cold sweep: infeasible (disagrees with search!)"),
        }
    }
    0
}

fn cmd_compare(args: &[String]) -> i32 {
    let spec = Spec::new("findep compare", "naive vs PPPipe vs FinDEP on the simulator")
        .opt("model", "deepseek-v2", "model preset")
        .opt("testbed", "A", "testbed A|B|C|D")
        .opt_uint("seq", "2048", "sequence length S")
        .opt("profile", "", "calibration profile JSON (from `calibrate --out`)")
        .flag("gantt", "print ASCII Gantt charts");
    let p = match spec.parse(args) {
        Ok(p) => p,
        Err(e) => return usage(e),
    };
    let Some(mut inst) = instance_from(&p) else {
        eprintln!("unknown model or testbed");
        return 2;
    };
    match profile_for(&p, "comparing") {
        Err(code) => return code,
        Ok(Some(prof)) => inst.cluster = Cluster::from_profile(&inst.cluster, &prof),
        Ok(None) => {}
    }
    let params = SolverParams::default();
    let naive = baselines::best_naive(&inst, params.ma_cap);
    let pp = baselines::best_pppipe(&inst, &params);
    let fd = solver::solve(&inst, &params);
    let mut table = Table::new(
        &format!("{} on {} (S={})", inst.model.name, inst.cluster.name, inst.seq_len),
        &["scheduler", "config", "tokens/s", "speedup vs naive"],
    );
    let base = naive.as_ref().map(|s| s.throughput_tokens).unwrap_or(0.0);
    for (name, sol) in [("Naive-DEP", &naive), ("PPPipe", &pp), ("FinDEP", &fd)] {
        match sol {
            Some(s) => table.row(&[
                name.to_string(),
                s.config.describe(),
                format!("{:.2}", s.throughput_tokens),
                format!("{:.2}x", s.throughput_tokens / base),
            ]),
            None => table.row(&[name.to_string(), "infeasible".into(), "-".into(), "-".into()]),
        }
    }
    table.print();
    if p.has_flag("gantt") {
        let sm = inst.stage_models();
        for (name, sol) in [("naive", &naive), ("pppipe", &pp), ("findep", &fd)] {
            if let Some(s) = sol {
                let plan = Plan::build(
                    &sm,
                    s.config,
                    inst.model.n_layers.min(2),
                    inst.split.ag,
                    inst.seq_len,
                );
                let sim = simulate(&plan);
                println!("\n{name} (first 2 layers):");
                print!("{}", ScheduleTrace::from_sim(&plan, &sim).ascii_gantt(100));
            }
        }
    }
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let spec = Spec::new("findep serve", "real-execution serving on the PJRT CPU runtime")
        .opt_uint("eg", "2", "number of EG workers")
        .opt_uint("batches", "8", "number of batches to serve")
        .opt_uint("batch-size", "4", "requests per batch")
        .opt("policy", "findep", "naive|pppipe|findep|adaptive")
        .opt_float("link-alpha-us", "0", "injected link startup latency (µs)")
        .opt_float("link-gbps", "0", "injected link bandwidth (GB/s, 0 = none)")
        .opt_uint("queue-depth", "0", "bounded request queue depth (0 = direct batch loop)")
        .opt_uint("workers", "2", "serving replicas / in-flight batches (queue mode)")
        .opt_uint("max-batch", "8", "max requests per assembled batch (queue mode)")
        .opt_uint("linger-us", "500", "batch-fill window in µs (queue mode)")
        .opt_uint("requests", "0", "total requests in queue mode (0 = batches × batch-size)")
        .opt_uint("decode-steps", "0", "decode steps per request after prefill (KV-growing)")
        .opt("profile", "", "calibration profile JSON driving the adaptive planner")
        .opt("cluster", "", "planner cluster: hetero | A|B|C|D (default: artifact testbed)")
        .opt_float("ttft-ms", "0", "TTFT SLO target in ms (0 = none): caps prefill plans")
        .opt_float("tpot-ms", "0", "TPOT SLO target in ms (0 = none): caps decode plans")
        .opt_float("slo-pct", "99", "percentile the SLO targets are graded at")
        .opt("fault-plan", "", "faults: reference | random:<seed> | <replica>=<kind>[@<n>],...")
        .opt_uint("deadline-ms", "0", "per-request deadline in ms (0 = none; queue mode)")
        .opt_uint("max-retries", "2", "serve attempts per request after a replica failure")
        .opt_uint("solve-budget-us", "0", "anytime budget per solve in µs (0 = exhaustive)")
        .flag("no-refine", "do not refine budget-truncated plans in the background")
        .flag("no-plan-cache", "re-solve the adaptive plan on every batch")
        .flag("auto-split", "pick the adaptive planning (ag, eg) split via split search")
        .flag("noshared", "serve the tiny-noshared (Qwen-style) variant");
    let p = match spec.parse(args) {
        Ok(p) => p,
        Err(e) => return usage(e),
    };

    // Validate the argument combination up front, before touching
    // artifacts: a bad invocation should fail in microseconds with a
    // message naming the offending flag.
    let queue_depth = p.get_usize("queue-depth");
    if p.was_set("queue-depth") && queue_depth == 0 {
        return usage("--queue-depth must be > 0 (omit it for the direct batch loop)".into());
    }
    let deadline_ms = p.get_u64("deadline-ms");
    let fault_spec = p.get("fault-plan").to_string();
    if queue_depth == 0 {
        if !fault_spec.is_empty()
            || deadline_ms > 0
            || p.was_set("max-retries")
            || p.was_set("workers")
            || p.was_set("max-batch")
        {
            return usage(
                "--fault-plan/--deadline-ms/--max-retries/--workers/--max-batch \
                 require queue mode (--queue-depth > 0)"
                    .into(),
            );
        }
    } else {
        if p.get_usize("workers") == 0 {
            return usage("--workers must be > 0 in queue mode".into());
        }
        if p.get_usize("max-batch") == 0 {
            return usage("--max-batch must be > 0 in queue mode".into());
        }
        if deadline_ms > 0 && deadline_ms.saturating_mul(1000) <= p.get_u64("linger-us") {
            return usage(format!(
                "--deadline-ms {deadline_ms} is shorter than the batch-fill window \
                 (--linger-us {}): every request would expire in the queue",
                p.get_u64("linger-us")
            ));
        }
    }
    let fault_plan = match FaultPlan::parse(&fault_spec, p.get_usize("workers")) {
        Ok(plan) => plan,
        Err(e) => return usage(format!("--fault-plan: {e}")),
    };
    let slo = {
        let ttft_ms = p.get_f64("ttft-ms");
        let tpot_ms = p.get_f64("tpot-ms");
        let pct = p.get_f64("slo-pct");
        if ttft_ms < 0.0 || tpot_ms < 0.0 {
            return usage("--ttft-ms and --tpot-ms must be ≥ 0".into());
        }
        if !(pct > 0.0 && pct <= 100.0) {
            return usage("--slo-pct must be in (0, 100]".into());
        }
        let ttft = (ttft_ms > 0.0).then(|| ttft_ms * 1e-3);
        let tpot = (tpot_ms > 0.0).then(|| tpot_ms * 1e-3);
        (ttft.is_some() || tpot.is_some()).then(|| SloPolicy::new(ttft, tpot, pct))
    };
    let plan_cluster = match p.get("cluster") {
        "" => None,
        name => match Cluster::by_name(name) {
            Some(c) => Some(c),
            None => {
                eprintln!("unknown cluster '{name}' (hetero | A|B|C|D)");
                return 2;
            }
        },
    };

    let prof = match profile_for(&p, "adaptive planning") {
        Ok(prof) => prof,
        Err(code) => return code,
    };
    let dir = artifacts_dir();
    let model = match ModelHandle::load(&dir, !p.has_flag("noshared")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!(
                "failed to load artifacts from {}: {e:#}\nrun `make artifacts` first",
                dir.display()
            );
            return 1;
        }
    };
    let delay = if p.get_f64("link-alpha-us") > 0.0 || p.get_f64("link-gbps") > 0.0 {
        Some(LinkDelay {
            alpha_s: p.get_f64("link-alpha-us") * 1e-6,
            beta_s_per_byte: if p.get_f64("link-gbps") > 0.0 {
                1.0 / (p.get_f64("link-gbps") * 1e9)
            } else {
                0.0
            },
        })
    } else {
        None
    };
    let s = model.seq_len;
    let m = model.model.embed;
    let policy = match p.get("policy") {
        "naive" => Policy::Naive,
        "pppipe" => Policy::PpPipe { r1: 2 },
        "adaptive" => Policy::Adaptive,
        _ => Policy::FinDep { r1: 2, r2: 2, order: Order::Asas },
    };
    let n_batches = p.get_usize("batches");
    let batch_size = p.get_usize("batch-size");
    let decode_steps = p.get_usize("decode-steps");
    let solve_budget = match p.get_u64("solve-budget-us") {
        0 => None,
        us => Some(std::time::Duration::from_micros(us)),
    };

    // Queue mode: the continuous batcher pipelines in-flight batches
    // through a pool of serving replicas.
    if queue_depth > 0 {
        let cfg = BatcherConfig {
            eg: p.get_usize("eg"),
            link_delay: delay,
            policy,
            max_batch: p.get_usize("max-batch"),
            queue_depth,
            workers: p.get_usize("workers"),
            linger: std::time::Duration::from_micros(p.get_u64("linger-us")),
            cache_plans: !p.has_flag("no-plan-cache"),
            auto_split: p.has_flag("auto-split"),
            solve_budget,
            refine_plans: !p.has_flag("no-refine"),
            slo,
        };
        let resilience = ResilienceConfig {
            fault_plan,
            max_retries: p.get_u64("max-retries") as u32,
            ..Default::default()
        };
        let total = match p.get_usize("requests") {
            0 => n_batches * batch_size,
            r => r,
        };
        let batcher = match Batcher::with_planner(
            model,
            cfg,
            prof.as_ref(),
            resilience,
            plan_cluster.as_ref(),
        ) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("failed to start batcher: {e:#}");
                return 1;
            }
        };
        let deadline = (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms));
        let t0 = std::time::Instant::now();
        let mut shed = 0usize;
        for i in 0..total {
            let mut req = EmbeddedRequest::synthetic_autoregressive(i as u64, s, m, decode_steps);
            if let Some(d) = deadline {
                req = req.with_deadline(std::time::Instant::now() + d);
            }
            match batcher.submit(req) {
                Ok(()) => {}
                Err(e @ findep::coordinator::batcher::SubmitError::Shed { .. }) => {
                    eprintln!("request {i} {e}");
                    shed += 1;
                }
                Err(e) => {
                    eprintln!("submit failed ({e:?}): {e}");
                    return 1;
                }
            }
        }
        let accepted = total - shed;
        let (resps, failures) =
            batcher.drain_outcomes(accepted, std::time::Duration::from_secs(60));
        let dt = t0.elapsed().as_secs_f64();
        for f in &failures {
            eprintln!("request {} failed after {:.1} ms: {}", f.id, f.latency_s * 1e3, f.error);
        }
        let total = accepted - failures.len();
        if resps.len() != total {
            eprintln!("timed out: {} of {total} responses", resps.len());
            return 1;
        }
        let tokens = total * (s + decode_steps);
        println!(
            "served {total} requests ({tokens} tokens, {} decoded) in {:.2}s -> {:.1} req/s, \
             {:.1} tokens/s ({:?}, {} workers, max batch {})",
            total * decode_steps,
            dt,
            total as f64 / dt,
            tokens as f64 / dt,
            policy,
            cfg.workers,
            cfg.max_batch,
        );
        let cache = batcher.plan_cache();
        println!(
            "plan cache: {} hits / {} misses ({} shapes); queue wait mean {:.3} ms over {} passes",
            cache.hits(),
            cache.misses(),
            cache.len(),
            batcher.metrics().histogram_mean("queue_wait").unwrap_or(0.0) * 1e3,
            batcher.metrics().histogram_count("queue_wait"),
        );
        if let Some(slo) = slo {
            let report = slo.evaluate(batcher.metrics());
            let dim = |name: &str, target: Option<f64>, observed: Option<f64>, met: Option<bool>| {
                let Some(t) = target else { return };
                match (observed, met) {
                    (Some(o), Some(ok)) => println!(
                        "  {name} p{:.0}: {:.2} ms observed vs {:.2} ms target — {}",
                        slo.percentile,
                        o * 1e3,
                        t * 1e3,
                        if ok { "met" } else { "MISSED" },
                    ),
                    _ => println!(
                        "  {name} p{:.0}: no samples recorded vs {:.2} ms target",
                        slo.percentile,
                        t * 1e3,
                    ),
                }
            };
            println!("SLO report:");
            dim("TTFT", slo.ttft_s, report.ttft_observed, report.ttft_met);
            dim("TPOT", slo.tpot_s, report.tpot_observed, report.tpot_met);
            println!(
                "  attainment {:.1}% -> goodput {:.1} tokens/s (raw {:.1})",
                report.attainment(batcher.metrics()) * 100.0,
                report.goodput(tokens as f64 / dt, batcher.metrics()),
                tokens as f64 / dt,
            );
        }
        println!("{}", findep::util::json::to_string_pretty(&batcher.metrics().snapshot_json()));
        return 0;
    }

    let mut srv = Server::new(model, p.get_usize("eg"), delay).expect("server");
    srv.cache_plans = !p.has_flag("no-plan-cache");
    srv.solve_budget = solve_budget;
    srv.refine_plans = !p.has_flag("no-refine");
    if let Some(cl) = plan_cluster {
        println!("adaptive planner targets cluster: {}", cl.name);
        srv.set_cluster(cl);
    }
    if let Some(pr) = &prof {
        srv.set_calibration_profile(pr);
    }
    if let Some(slo) = slo {
        // Direct mode records no per-request latency histograms, so
        // the SLO shapes planning (makespan-capped plans) but the run
        // is not graded — use queue mode for the attainment report.
        println!(
            "SLO-capped planning: prefill ≤ {}, decode ≤ {} per batch",
            slo.ttft_s.map(|t| format!("{:.2} ms", t * 1e3)).unwrap_or_else(|| "∞".into()),
            slo.tpot_s.map(|t| format!("{:.2} ms", t * 1e3)).unwrap_or_else(|| "∞".into()),
        );
        srv.set_slo(Some(slo));
    }
    if p.has_flag("auto-split") {
        let split = srv.select_plan_split();
        println!("auto-split: adaptive plans target (ag={}, eg={})", split.ag, split.eg);
    }
    let t0 = std::time::Instant::now();
    let mut tokens = 0usize;
    for b in 0..n_batches {
        let reqs: Vec<EmbeddedRequest> = (0..batch_size)
            .map(|i| EmbeddedRequest::synthetic((b * batch_size + i) as u64, s, m))
            .collect();
        match srv.serve_batch(&reqs, policy) {
            Ok((resp, stats)) => {
                tokens += resp.len() * s;
                println!(
                    "batch {b}: {} reqs in {:.2} ms (attn {:.2} gate {:.2} shared {:.2} \
                     wait {:.2})",
                    resp.len(),
                    stats.total * 1e3,
                    stats.attention * 1e3,
                    stats.gate * 1e3,
                    stats.shared * 1e3,
                    stats.wait * 1e3
                );
                // Autoregressive tail: each response feeds the next
                // KV-grown decode step, scheduled under the decode plan.
                let mut hidden: Vec<_> = resp.into_iter().map(|r| (r.id, r.hidden)).collect();
                for step in 0..decode_steps {
                    let dreqs: Vec<EmbeddedRequest> = hidden
                        .drain(..)
                        .map(|(id, h)| EmbeddedRequest {
                            id,
                            hidden: h,
                            phase: findep::config::Phase::Decode { kv_len: s + step },
                            output_len: 0,
                            deadline: None,
                        })
                        .collect();
                    match srv.serve_batch(&dreqs, policy) {
                        Ok((dresp, _)) => {
                            tokens += dresp.len();
                            hidden = dresp.into_iter().map(|r| (r.id, r.hidden)).collect();
                        }
                        Err(e) => {
                            eprintln!("batch {b} decode step {step} failed: {e:#}");
                            return 1;
                        }
                    }
                }
            }
            Err(e) => {
                eprintln!("batch {b} failed: {e:#}");
                return 1;
            }
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nserved {n_batches} batches, {tokens} tokens in {:.2}s -> {:.1} tokens/s ({:?})",
        dt,
        tokens as f64 / dt,
        policy
    );
    println!("{}", findep::util::json::to_string_pretty(&srv.metrics.snapshot_json()));
    0
}

fn cmd_calibrate(args: &[String]) -> i32 {
    let spec = Spec::new(
        "findep calibrate",
        "fit α-β models on this host (Fig. 7) and optionally persist them as a profile",
    )
    .opt_uint("trials", "9", "timed trials per point")
    .opt_uint("warmup", "3", "warmup runs per point")
    .opt("out", "", "write the fitted calibration profile JSON here")
    .opt("host", "", "host tag recorded in the profile (default $HOSTNAME)")
    .flag("quick", "CI smoke mode: fewer probe points, caps trials at 3 and warmup at 1");
    let p = match spec.parse(args) {
        Ok(p) => p,
        Err(e) => return usage(e),
    };
    let quick = p.has_flag("quick");
    let trials = if quick { p.get_usize("trials").min(3) } else { p.get_usize("trials") };
    let warmup = if quick { p.get_usize("warmup").min(1) } else { p.get_usize("warmup") };
    let client = xla::PjRtClient::cpu().expect("PJRT CPU client");

    let gemm_shapes: &[(usize, usize, usize)] = if quick {
        &[(32, 64, 64), (64, 64, 128), (128, 128, 128)]
    } else {
        &[(32, 64, 64), (64, 64, 128), (128, 128, 128), (256, 128, 256), (256, 256, 512)]
    };
    let mut gemm_samples = Vec::new();
    for &(m, k, n) in gemm_shapes {
        let s = probe::gemm_sample(&client, m, k, n, warmup, trials).expect("gemm probe");
        println!("gemm {m}x{k}x{n}: {:.3} ms", s.seconds * 1e3);
        gemm_samples.push(s);
    }
    let (gm, r2g) = match calibrate::fit(&gemm_samples) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("gemm: {e}");
            return 1;
        }
    };
    println!("t_gm(x) = {:.3e} + {:.3e}·x  (R² = {:.6})", gm.alpha, gm.beta, r2g);

    let attn_shapes: &[(usize, usize, usize)] = if quick {
        &[(4, 16, 16), (8, 32, 16), (8, 64, 16)]
    } else {
        &[(4, 16, 16), (8, 32, 16), (8, 64, 16), (16, 64, 32)]
    };
    let mut attn_samples = Vec::new();
    for &(hb, s, d) in attn_shapes {
        let smp = probe::attention_sample(&client, hb, s, d, warmup, trials).expect("attn probe");
        println!("attn hb={hb} S={s} d={d}: {:.3} ms", smp.seconds * 1e3);
        attn_samples.push(smp);
    }
    let (am, r2a) = match calibrate::fit(&attn_samples) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("attention: {e}");
            return 1;
        }
    };
    println!("t_attn(y) = {:.3e} + {:.3e}·y  (R² = {:.6})", am.alpha, am.beta, r2a);

    let comm_sizes: &[usize] = if quick {
        &[1 << 14, 1 << 16, 1 << 18]
    } else {
        &[1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22]
    };
    let comm = calibrate::calibrate_copy_link(comm_sizes, warmup, trials);
    let (cm, r2c, comm_samples) = match comm {
        Ok(v) => v,
        Err(e) => {
            eprintln!("transfer: {e}");
            return 1;
        }
    };
    println!("t_c(z) = {:.3e} + {:.3e}·z  (R² = {:.6})", cm.alpha, cm.beta, r2c);

    let hbm_sizes: &[usize] = if quick {
        &[1 << 18, 1 << 20, 1 << 22]
    } else {
        &[1 << 20, 1 << 22, 1 << 24, 1 << 25]
    };
    let hbm_samples: Vec<calibrate::Sample> =
        hbm_sizes.iter().map(|&n| probe::hbm_stream_sample(n, warmup, trials)).collect();
    let (hm, r2h) = match calibrate::fit(&hbm_samples) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("hbm: {e}");
            return 1;
        }
    };
    println!("t_hbm(z) = {:.3e} + {:.3e}·z  (R² = {:.6})", hm.alpha, hm.beta, r2h);

    let host = match p.get("host") {
        "" => std::env::var("HOSTNAME").unwrap_or_else(|_| "unknown-host".into()),
        h => h.to_string(),
    };
    let created_unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let built = build_profile(
        host,
        created_unix_s,
        trials,
        (gm, r2g, gemm_samples),
        (am, r2a, attn_samples),
        (cm, r2c, comm_samples),
        (hm, r2h, hbm_samples),
    );
    let prof = match built {
        Ok(prof) => prof,
        Err(e) => {
            eprintln!("refusing to build a profile from a degenerate fit — {e}");
            return 1;
        }
    };
    let th = ProfileThresholds::default();
    // A smoke run on a noisy host may legitimately miss the R² bar, so
    // this is a warning, not a failure: rejection is enforced where it
    // matters, at every `--profile` load.
    let valid = match prof.validate(&th) {
        Ok(()) => {
            println!("profile valid: every component clears R² ≥ {}", th.min_r2);
            true
        }
        Err(e) => {
            println!("WARNING: {e} — `--profile` loads will reject this calibration");
            false
        }
    };
    let out = p.get("out");
    if !out.is_empty() {
        if let Err(e) = prof.save(std::path::Path::new(out)) {
            eprintln!("{e}");
            return 1;
        }
        let note = if valid { "" } else { " — fails validation, kept for diagnosis only" };
        println!("wrote {out} (fingerprint {:016x}){note}", prof.fingerprint().0);
    }
    0
}

/// One fitted component as `calibrate` produces it: (model, R², samples).
type Fit = (findep::perfmodel::LinearModel, f64, Vec<calibrate::Sample>);

/// Assemble the persisted profile from the four component fits; a
/// degenerate component (e.g. a slope clamped to zero) surfaces as an
/// error naming it.
fn build_profile(
    host: String,
    created_unix_s: u64,
    trials: usize,
    gemm: Fit,
    attn: Fit,
    comm: Fit,
    hbm: Fit,
) -> Result<CalibrationProfile, String> {
    let mk = |name: &str, (m, r2, samples): Fit| {
        ComponentFit::from_fit(m, r2, samples).map_err(|e| format!("{name}: {e}"))
    };
    Ok(CalibrationProfile {
        version: profile::PROFILE_VERSION,
        host,
        created_unix_s,
        trials,
        gemm: mk("gemm", gemm)?,
        attn: mk("attention", attn)?,
        comm: mk("transfer", comm)?,
        hbm: mk("hbm", hbm)?,
    })
}

fn usage(msg: String) -> i32 {
    eprintln!("{msg}");
    2
}
