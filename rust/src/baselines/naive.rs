//! Naive DEP (Fig. 3a): strict sequential handoff — the whole mini-batch
//! moves AG → A2E → EG → E2A each layer with no pipelining at all
//! (r1 = r2 = 1, shared expert processed inline with attention).

use crate::sched::PlanConfig;
use crate::solver::algorithm1::{Instance, Solution};

/// Best naive configuration: the largest memory-feasible m_a (throughput
/// is monotone in batch size here too — amortizing fixed overheads is
/// all naive DEP can do).
pub fn best_naive(inst: &Instance, ma_cap: usize) -> Option<Solution> {
    let mem = inst.memory();
    let mut ev = inst.evaluator();
    let sm = ev.stage_models().clone();
    let cap = mem.max_samples_per_ag_gpu().min(ma_cap);
    if cap == 0 || !mem.eg_feasible() {
        return None;
    }
    let mut best: Option<Solution> = None;
    for m_a in 1..=cap {
        let cfg = PlanConfig::naive(m_a, sm.m_e(m_a as f64, 1));
        let (makespan, tput) = ev.evaluate(cfg);
        if best.as_ref().map_or(true, |b| tput > b.throughput_tokens) {
            best = Some(Solution {
                config: cfg,
                makespan,
                throughput_tokens: tput,
                solve_seconds: 0.0,
                evals: m_a,
                pruned_rows: 0,
                warm_seeded: false,
                exhaustive: true,
            });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};

    #[test]
    fn naive_is_sequential() {
        let inst = Instance::new(
            ModelConfig::deepseek_v2(4),
            Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        );
        let sol = best_naive(&inst, 8).unwrap();
        assert_eq!(sol.config.r1, 1);
        assert_eq!(sol.config.r2, 1);
        assert!(sol.config.fuse_shared);
        assert!(sol.throughput_tokens > 0.0);
    }

    #[test]
    fn infeasible_returns_none() {
        let inst = Instance::new(
            ModelConfig::deepseek_v2(8),
            Testbed::b(),
            GroupSplit::new(7, 1),
            2048,
        );
        assert!(best_naive(&inst, 8).is_none());
    }
}
