"""L1 Pallas kernel: tiled multi-head attention with online softmax.

The paper implements attention with FlashInfer on CUDA; the TPU
adaptation (DESIGN.md §Hardware-Adaptation) replaces the
threadblock-per-query-tile decomposition with a Pallas grid over
(batch·heads, query blocks) and an **online-softmax scan over KV blocks**
inside the kernel, so the S×S score matrix never materializes in HBM:

* Q tile [block_q, d] and one K/V tile [block_k, d] live in VMEM;
  running max / normalizer / accumulator are carried through the KV scan
  (the flash-attention recurrence).
* Both GEMMs (Q·Kᵀ and P·V) are MXU passes with fp32 accumulation.
* Causal masking is applied per-tile from the absolute row/col indices.

``interpret=True`` for CPU-PJRT executability (see expert_ffn.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, seq_len, causal, scale):
    """One (batch·head, q-block) grid step: scan KV blocks with online
    softmax."""
    q = q_ref[...]  # [block_q, d_k]
    block_q = q.shape[0]
    d_v = v_ref.shape[-1]
    q_offset = pl.program_id(1) * block_q

    def body(start, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[pl.dslice(start * block_k, block_k), :]
        v = v_ref[pl.dslice(start * block_k, block_k), :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_offset + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = start * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        correction = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * correction + jnp.sum(p, axis=-1)
        acc = acc * correction[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        return acc, m_cur, l_cur

    n_kv = seq_len // block_k
    acc0 = jnp.zeros((block_q, d_v), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _m, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def attention(q, k, v, causal=True, block_q=16, block_k=16):
    """Tiled attention: q, k: [B, n_h, S, d_k], v: [B, n_h, S, d_v].

    S must be divisible by block_q and block_k (AOT shape buckets
    guarantee this; tests sweep uneven shapes via padding at the caller).
    """
    b, nh, s, d_k = q.shape
    d_v = v.shape[-1]
    assert s % block_q == 0 and s % block_k == 0, "S must tile evenly"
    scale = 1.0 / (d_k ** 0.5)

    qf = q.reshape(b * nh, s, d_k)
    kf = k.reshape(b * nh, s, d_k)
    vf = v.reshape(b * nh, s, d_v)

    kernel = functools.partial(
        _attn_kernel, block_k=block_k, seq_len=s, causal=causal, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * nh, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d_k), lambda g, i: (g, i, 0)),
            pl.BlockSpec((None, s, d_k), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((None, s, d_v), lambda g, i: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d_v), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * nh, s, d_v), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, nh, s, d_v)
