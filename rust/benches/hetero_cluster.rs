//! Heterogeneous-cluster placement and SLO-goodput gates for the
//! Testbed → [`findep::config::Cluster`] refactor.
//!
//! Two acceptance gates, asserted before any timing:
//!
//! 1. **Heterogeneity pays.** On the two-pool reference cluster
//!    (compute-rich attention pool + bandwidth-rich expert pool),
//!    [`search_cluster`] must strictly beat the best plan a
//!    homogeneous-assumption search can produce. The baseline pretends
//!    the whole cluster is uniform — once per pool spec — runs the
//!    legacy testbed [`search_splits`], then maps its winning placement
//!    onto the real inventory (clamping each role to its pool,
//!    discarding placements the pools cannot tile) and re-solves that
//!    shape on the real cluster. Mapped plans live inside the cluster
//!    search's own candidate space, so the hetero winner can never lose;
//!    the gate asserts it strictly *wins* — the enlarged, pool-aware
//!    space finds a placement no uniform pretense reaches.
//! 2. **Goodput ≠ throughput under an SLO.** With a per-batch latency
//!    cap between the fastest evaluated plan and the throughput winner
//!    (a tight TTFT target), the goodput-optimal plan must differ from
//!    the throughput-optimal one, meet the cap, and give up peak
//!    tokens/s. The cap is derived from the uncapped report itself, so
//!    the gate is self-tuning across model shapes.
//!
//! Emits a `BENCH_hetero.json` trajectory file.
//!
//! Run: `cargo bench --bench hetero_cluster`

use findep::config::{Cluster, GroupSplit, ModelConfig, Phase, Testbed};
use findep::solver::{
    search_cluster, search_splits, Instance, SearchParams, SearchReport, SolverParams,
    SplitCandidate,
};
use findep::util::bench::{fmt_duration, Bencher, Table};
use findep::util::json::{to_string_pretty, Json, JsonObj};

/// The uniform-hardware fiction a homogeneous-assumption planner
/// operates under: every GPU in the cluster is `pool_idx`'s spec, and
/// every link runs at the real cross-pool M2N constants (the fairest
/// uniform reading of the wiring — the transfer model is the one thing
/// the pretense keeps honest).
fn pretend_uniform(cl: &Cluster, pool_idx: usize) -> Testbed {
    let p = &cl.pools[pool_idx];
    let m2n = cl.m2n();
    Testbed {
        name: format!("pretend-uniform {}", p.gpu.name),
        n_gpus: cl.n_gpus(),
        mem_bytes: p.gpu.mem_bytes,
        gemm_flops: p.gpu.gemm_flops,
        attn_flops: p.gpu.attn_flops,
        alpha_comp_s: p.gpu.alpha_comp_s,
        alpha_attn_s: p.gpu.alpha_attn_s,
        link_bw: m2n.bw,
        alpha_comm_s: m2n.alpha_s,
        hbm_bw: p.gpu.hbm_bw,
        nvlink: cl.nvlink,
        multi_node: cl.multi_node,
    }
}

/// Deploy a homogeneous-assumption winner on the real cluster: clamp
/// each role to its pool's per-replica share (a 16-uniform-GPU plan may
/// ask for more attention GPUs than the attention pool owns), drop
/// placements whose replica count cannot tile both pools, and re-solve
/// the surviving shape on the real per-pool models. Returns the
/// cluster-wide tokens/s the mapped plan actually achieves (0.0 when
/// the placement cannot deploy at all).
fn map_onto_cluster(
    model: &ModelConfig,
    cl: &Cluster,
    winner: &SplitCandidate,
    seq_len: usize,
) -> (f64, Option<SplitCandidate>) {
    let (na, ne) = (cl.attn().n_gpus, cl.expert().n_gpus);
    let r = winner.replicas;
    if na % r != 0 || ne % r != 0 {
        return (0.0, None);
    }
    let ag = winner.split.ag.min(na / r);
    let eg = winner.split.eg.min(ne / r);
    if ag < 1 || eg < 1 {
        return (0.0, None);
    }
    let mapped = SplitCandidate { replicas: r, split: GroupSplit::new(ag, eg) };
    let inst = Instance::on_cluster(model.clone(), cl.clone(), mapped.split, seq_len);
    match findep::solver::solve(&inst, &SolverParams::default()) {
        Some(sol) => (r as f64 * sol.throughput_tokens, Some(mapped)),
        None => (0.0, Some(mapped)),
    }
}

/// Strict-improvement margin gate 1 must clear: far above the ~1e-9
/// engine/closed-form agreement, far below the ≥ 0.4% margins the
/// analytic model predicts for the reference cluster.
const MARGIN: f64 = 1e-5;

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let params = SearchParams::default();
    let cl = Cluster::reference_hetero();
    let seq = 2048usize;

    let mut report = JsonObj::new();
    report.insert("bench", Json::Str("hetero_cluster".into()));
    report.insert("quick", Json::Bool(quick));
    report.insert("cluster", cl.to_json());
    report.insert("seq_len", Json::Num(seq as f64));

    let mut table = Table::new(
        "Heterogeneous placement + SLO goodput (two-pool reference cluster)",
        &["model", "hetero winner", "tok/s", "homog. baseline", "gain", "SLO cap", "goodput plan"],
    );
    let mut entries: Vec<Json> = Vec::new();

    for model in [ModelConfig::deepseek_v2(8), ModelConfig::qwen3_moe(12)] {
        // ---- Gate 1: heterogeneity-aware search beats every uniform
        // pretense, strictly. ----
        let het: SearchReport = search_cluster(&model, &cl, seq, Phase::Prefill, &params)
            .unwrap_or_else(|| panic!("{}: hetero search found no feasible plan", model.name));
        let mut baseline = 0.0f64;
        let mut baseline_specs: Vec<Json> = Vec::new();
        for pool_idx in 0..cl.pools.len() {
            let tb = pretend_uniform(&cl, pool_idx);
            let mut spec = JsonObj::new();
            spec.insert("pretend_spec", Json::Str(tb.name.clone()));
            match search_splits(&model, &tb, seq, &params) {
                None => {
                    spec.insert("feasible", Json::Bool(false));
                }
                Some(rep) => {
                    let (mapped_tput, mapped) =
                        map_onto_cluster(&model, &cl, &rep.best.candidate, seq);
                    spec.insert("feasible", Json::Bool(true));
                    spec.insert("winner", Json::Str(rep.best.candidate.describe()));
                    spec.insert("pretend_total_tokens_per_s", Json::Num(rep.best.total_throughput));
                    spec.insert(
                        "mapped",
                        mapped.map_or(Json::Null, |m| Json::Str(m.describe())),
                    );
                    spec.insert("mapped_total_tokens_per_s", Json::Num(mapped_tput));
                    baseline = baseline.max(mapped_tput);
                }
            }
            baseline_specs.push(Json::Obj(spec));
        }
        assert!(baseline > 0.0, "{}: no uniform pretense deployed at all", model.name);
        assert!(
            het.best.total_throughput > baseline * (1.0 + MARGIN),
            "{}: hetero-aware search ({:.1} tok/s) must strictly beat the best \
             homogeneous-assumption plan mapped onto the cluster ({:.1} tok/s)",
            model.name,
            het.best.total_throughput,
            baseline
        );
        let gain = het.best.total_throughput / baseline;

        // ---- Gate 2: a tight TTFT cap moves the optimum. ----
        // Cap halfway between the fastest evaluated plan's batch
        // makespan and the throughput winner's: tight enough to exclude
        // the winner, loose enough that something qualifies.
        let uncapped_ms = het.best.per_instance.makespan;
        let min_ms = het
            .evaluated
            .iter()
            .map(|s| s.per_instance.makespan)
            .fold(f64::INFINITY, f64::min);
        assert!(
            min_ms < uncapped_ms,
            "{}: no evaluated plan is faster than the throughput winner \
             (min {min_ms} vs winner {uncapped_ms}) — cannot derive a discriminating cap",
            model.name
        );
        let cap = 0.5 * (min_ms + uncapped_ms);
        let capped_params = SearchParams {
            solver: SolverParams { max_makespan: Some(cap), ..SolverParams::default() },
            ..params
        };
        let capped: SearchReport = search_cluster(&model, &cl, seq, Phase::Prefill, &capped_params)
            .unwrap_or_else(|| {
                panic!("{}: no plan meets the {:.2} ms cap", model.name, cap * 1e3)
            });
        // The throughput winner exceeds the cap by construction, so the
        // goodput optimum must be a different (placement, config) plan
        // that meets the cap and concedes peak tokens/s.
        assert!(
            capped.best.candidate != het.best.candidate
                || capped.best.per_instance.config != het.best.per_instance.config,
            "{}: goodput-optimal plan must differ from the throughput-optimal one",
            model.name
        );
        assert!(
            capped.best.per_instance.makespan <= cap,
            "{}: goodput winner violates its own cap ({} > {cap})",
            model.name,
            capped.best.per_instance.makespan
        );
        assert!(
            uncapped_ms > cap,
            "{}: throughput winner unexpectedly fits the cap",
            model.name
        );
        assert!(
            capped.best.total_throughput <= het.best.total_throughput,
            "{}: goodput under a cap cannot exceed unconstrained throughput",
            model.name
        );

        // ---- Timing (the gates above ran cold, untimed). ----
        let r_het = bencher.run(&format!("{}/search_cluster", model.name), || {
            let _ = search_cluster(&model, &cl, seq, Phase::Prefill, &params);
        });
        let r_cap = bencher.run(&format!("{}/search_cluster_slo", model.name), || {
            let _ = search_cluster(&model, &cl, seq, Phase::Prefill, &capped_params);
        });

        table.row(&[
            model.name.clone(),
            format!(
                "{} {}",
                het.best.candidate.describe(),
                het.best.per_instance.config.describe()
            ),
            format!("{:.0}", het.best.total_throughput),
            format!("{baseline:.0}"),
            format!("{:.2}%", (gain - 1.0) * 100.0),
            format!("{:.1} ms", cap * 1e3),
            format!(
                "{} {} ({:.0} tok/s, {:.1} ms)",
                capped.best.candidate.describe(),
                capped.best.per_instance.config.describe(),
                capped.best.total_throughput,
                capped.best.per_instance.makespan * 1e3
            ),
        ]);

        let mut e = JsonObj::new();
        e.insert("model", Json::Str(model.name.clone()));
        e.insert("hetero_winner", Json::Str(het.best.candidate.describe()));
        e.insert("hetero_config", Json::Str(het.best.per_instance.config.describe()));
        e.insert("hetero_total_tokens_per_s", Json::Num(het.best.total_throughput));
        e.insert("hetero_makespan_s", Json::Num(uncapped_ms));
        e.insert("candidates", Json::Num(het.stats.candidates as f64));
        e.insert("solved", Json::Num(het.stats.solved as f64));
        e.insert("pruned", Json::Num(het.stats.pruned as f64));
        e.insert("baselines", Json::Arr(baseline_specs));
        e.insert("homogeneous_baseline_tokens_per_s", Json::Num(baseline));
        e.insert("hetero_gain", Json::Num(gain));
        e.insert("slo_cap_s", Json::Num(cap));
        e.insert("goodput_winner", Json::Str(capped.best.candidate.describe()));
        e.insert("goodput_config", Json::Str(capped.best.per_instance.config.describe()));
        e.insert("goodput_total_tokens_per_s", Json::Num(capped.best.total_throughput));
        e.insert("goodput_makespan_s", Json::Num(capped.best.per_instance.makespan));
        e.insert(
            "throughput_given_up",
            Json::Num(1.0 - capped.best.total_throughput / het.best.total_throughput),
        );
        e.insert("search_mean_s", Json::Num(r_het.mean_s()));
        e.insert("search_slo_mean_s", Json::Num(r_cap.mean_s()));
        entries.push(Json::Obj(e));

        println!(
            "{}: hetero search {} / SLO search {}",
            model.name,
            fmt_duration(r_het.mean_s()),
            fmt_duration(r_cap.mean_s())
        );
    }

    table.print();
    report.insert("instances", Json::Arr(entries));
    std::fs::write("BENCH_hetero.json", to_string_pretty(&Json::Obj(report)))
        .expect("write BENCH_hetero.json");
    println!("wrote BENCH_hetero.json");
}
