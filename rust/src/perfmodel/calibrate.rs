//! Micro-benchmark calibration (§5.2 / Fig. 7).
//!
//! The paper runs ~2 minutes of GEMM / attention / transfer
//! micro-benchmarks, fits α-β models by least squares, and reports R².
//! This module does the same against *this* machine: the GEMM and
//! attention probes execute real HLO through the PJRT CPU client (see
//! `runtime::probe`), the transfer probe measures memcpy-through-channel
//! time. The resulting `CompModels` drive the real-execution coordinator;
//! the simulator's testbed models use the analytic constants in
//! `config::cluster` instead.

use std::time::Instant;

use crate::perfmodel::{CompModels, LinearModel};
use crate::util::stats;

/// A single calibration observation: workload and measured seconds.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub workload: f64,
    pub seconds: f64,
}

/// Fit an α-β model from samples, returning (model, R²).
pub fn fit(samples: &[Sample]) -> (LinearModel, f64) {
    let x: Vec<f64> = samples.iter().map(|s| s.workload).collect();
    let y: Vec<f64> = samples.iter().map(|s| s.seconds).collect();
    LinearModel::fit(&x, &y)
}

/// Measure `f` with `warmup` throwaway runs and `trials` timed runs,
/// returning the median time — the paper uses 10 warmup + 20 stats runs
/// per point (§5.2); callers pick their own counts.
pub fn measure<F: FnMut()>(warmup: usize, trials: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(trials);
    for _ in 0..trials {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    stats::percentile(&times, 50.0)
}

/// Calibrate a host-side "transfer" model by timing buffer copies of
/// increasing size through a channel (our A2E/E2A link substrate).
/// Returns (model, R², samples).
pub fn calibrate_copy_link(sizes: &[usize]) -> (LinearModel, f64, Vec<Sample>) {
    use std::sync::mpsc;
    let samples: Vec<Sample> = sizes
        .iter()
        .map(|&n| {
            let src = vec![1.0f32; n / 4];
            let seconds = measure(3, 9, || {
                let (tx, rx) = mpsc::channel::<Vec<f32>>();
                tx.send(src.clone()).unwrap();
                let got = rx.recv().unwrap();
                assert_eq!(got.len(), src.len());
            });
            Sample { workload: n as f64, seconds }
        })
        .collect();
    let (m, r2) = fit(&samples);
    (m, r2, samples)
}

/// Build component models from three fitted pieces.
pub fn comp_models(gemm: LinearModel, attn: LinearModel, comm: LinearModel) -> CompModels {
    CompModels { gemm, attn, comm }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_synthetic_alpha_beta() {
        let samples: Vec<Sample> = (1..40)
            .map(|i| {
                let w = i as f64 * 1e6;
                Sample { workload: w, seconds: 2e-5 + 1e-12 * w }
            })
            .collect();
        let (m, r2) = fit(&samples);
        assert!((m.alpha - 2e-5).abs() < 1e-9);
        assert!((m.beta - 1e-12).abs() < 1e-16);
        assert!(r2 > 0.999999, "r2={r2}");
    }

    #[test]
    fn measure_returns_positive_median() {
        let mut x = 0u64;
        let t = measure(2, 5, || {
            for i in 0..10_000u64 {
                x = x.wrapping_add(i);
            }
        });
        assert!(t > 0.0);
        assert!(x > 0);
    }

    #[test]
    fn copy_link_calibration_is_monotone_enough() {
        // Small sizes to stay fast; we only check the fit is usable.
        let (m, _r2, samples) = calibrate_copy_link(&[1 << 12, 1 << 14, 1 << 16, 1 << 18]);
        assert_eq!(samples.len(), 4);
        assert!(m.beta >= 0.0);
        assert!(m.alpha >= 0.0);
    }
}
