//! Calibration-profile integration tests: fit → persist → solve.
//!
//! Pins the tentpole contract end to end: a profile round-trips through
//! JSON bit-exactly, the validation layer rejects what it must, a
//! Table-2-equivalent profile reproduces the hand-constant solve bit
//! for bit on every paper instance, and plans solved under distinct
//! profiles occupy disjoint plan-cache keyspaces.

use findep::config::{GroupSplit, ModelConfig, Phase, Testbed};
use findep::perfmodel::{profile, CalibrationProfile, CompModels, ProfileId, ProfileThresholds};
use findep::solver::{self, Instance, PlanCache, ShapeKey, SolverParams};
use findep::util::json;

fn paper_instances() -> Vec<(ModelConfig, Testbed)> {
    let mut out = Vec::new();
    for tb in Testbed::all() {
        out.push((ModelConfig::deepseek_v2(8), tb.clone()));
        out.push((ModelConfig::qwen3_moe(12), tb));
    }
    out
}

#[test]
fn profile_file_round_trip_preserves_comp_models_bitwise() {
    let tb = Testbed::a();
    let prof = CalibrationProfile::from_testbed(&tb);
    let path = std::env::temp_dir().join("findep_profile_roundtrip_test.json");
    prof.save(&path).unwrap();
    let loaded = CalibrationProfile::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded, prof, "write → read must be lossless");
    assert_eq!(loaded.fingerprint(), prof.fingerprint());
    loaded.validate(&ProfileThresholds::default()).unwrap();
    // The derived component models — the interface the whole solver
    // stack consumes — are bit-identical across the round trip and
    // equal to the hand-constant derivation.
    for split in [GroupSplit::new(3, 5), GroupSplit::new(4, 4), GroupSplit::new(6, 2)] {
        let hand = CompModels::from_testbed(&tb, split);
        let from_loaded = CompModels::from_profile(&loaded, &tb, split);
        assert_eq!(hand, from_loaded, "split {split:?}");
    }
}

#[test]
fn load_rejects_malformed_profiles() {
    let dir = std::env::temp_dir();
    let garbage = dir.join("findep_profile_garbage_test.json");
    std::fs::write(&garbage, "{not json").unwrap();
    assert!(CalibrationProfile::load(&garbage).is_err());
    std::fs::write(&garbage, r#"{"version": 1, "host": "x"}"#).unwrap();
    let err = CalibrationProfile::load(&garbage).unwrap_err().to_string();
    assert!(err.contains("gemm"), "missing component named: {err}");
    std::fs::remove_file(&garbage).ok();
    assert!(CalibrationProfile::load(&dir.join("findep_no_such_profile.json")).is_err());
}

#[test]
fn validation_gates_r2_and_degenerate_fits() {
    let th = ProfileThresholds::default();
    let mut prof = CalibrationProfile::from_testbed(&Testbed::b());
    prof.validate(&th).unwrap();
    prof.gemm.r2 = th.min_r2 - 1e-6;
    let err = prof.validate(&th).unwrap_err().to_string();
    assert!(err.contains("gemm") && err.contains("R²"), "{err}");
    // A stricter bar rejects what the default accepts.
    let mut prof = CalibrationProfile::from_testbed(&Testbed::b());
    prof.attn.r2 = 0.95;
    prof.validate(&th).unwrap();
    assert!(prof.validate(&ProfileThresholds { min_r2: 0.999, ..th }).is_err());
    // Degenerate constants never pass, whatever the thresholds.
    let mut prof = CalibrationProfile::from_testbed(&Testbed::b());
    prof.hbm.unit_per_s = 0.0;
    assert!(prof.validate(&th).is_err());
}

#[test]
fn table2_equivalent_profile_solves_bit_identically_everywhere() {
    let params = SolverParams::default();
    for (model, tb) in paper_instances() {
        let split = GroupSplit::paper_default(&tb, model.has_shared_expert());
        let prof = CalibrationProfile::from_testbed(&tb);
        // Route the profile through its serialized form, exactly as a
        // `calibrate --out` → `solve --profile` workflow would.
        let text = json::to_string_pretty(&prof.to_json());
        let prof = CalibrationProfile::from_json(&json::parse(&text).unwrap()).unwrap();
        let cal_tb = Testbed::from_profile(&tb, &prof);

        for inst in [
            Instance::new(model.clone(), tb.clone(), split, 2048),
            Instance::decode(model.clone(), tb.clone(), split, 2048),
        ] {
            let cal_inst = match inst.phase {
                Phase::Prefill => Instance::new(model.clone(), cal_tb.clone(), split, inst.seq_len),
                Phase::Decode { kv_len } => {
                    Instance::decode(model.clone(), cal_tb.clone(), split, kv_len)
                }
            };
            let hand = solver::solve(&inst, &params);
            let cal = solver::solve(&cal_inst, &params);
            match (hand, cal) {
                (Some(h), Some(c)) => {
                    assert_eq!(
                        h.config,
                        c.config,
                        "{} on {} {:?}",
                        model.name,
                        tb.name,
                        inst.phase
                    );
                    assert_eq!(
                        h.throughput_tokens.to_bits(),
                        c.throughput_tokens.to_bits(),
                        "{} on {} {:?}",
                        model.name,
                        tb.name,
                        inst.phase
                    );
                    assert_eq!(h.makespan.to_bits(), c.makespan.to_bits());
                }
                (None, None) => {}
                (h, c) => panic!(
                    "feasibility must agree: hand={} cal={} ({} on {})",
                    h.is_some(),
                    c.is_some(),
                    model.name,
                    tb.name
                ),
            }
        }
        // And the stage-delta report confirms zero movement, in both
        // phase derivations.
        for phase in [Phase::Prefill, Phase::Decode { kv_len: 2048 }] {
            for d in profile::stage_deltas(&model, &tb, &prof, split, 2048, phase) {
                assert_eq!(d.hand_s.to_bits(), d.calibrated_s.to_bits(), "{}", d.stage);
            }
        }
    }
}

#[test]
fn distinct_profiles_never_alias_cached_plans() {
    let model = ModelConfig::deepseek_v2(8);
    let tb = Testbed::a();
    let split = GroupSplit::new(3, 5);
    let params = SolverParams::default();

    let table2 = CalibrationProfile::from_testbed(&tb);
    let mut slower = CalibrationProfile::from_testbed(&tb);
    slower.gemm.unit_per_s *= 0.5; // half the measured GEMM throughput
    assert_ne!(table2.fingerprint(), slower.fingerprint());

    let cache = PlanCache::new();
    let solve_under = |prof: &CalibrationProfile| {
        let inst = Instance::new(model.clone(), Testbed::from_profile(&tb, prof), split, 2048);
        cache
            .get_or_solve(ShapeKey::prefill(2048, 8).with_profile(prof.fingerprint()), || {
                solver::solve_online(&inst, 8, &params)
            })
            .expect("paper instance is feasible")
    };
    let a = solve_under(&table2);
    assert_eq!((cache.hits(), cache.misses()), (0, 1));
    let b = solve_under(&slower);
    assert_eq!((cache.hits(), cache.misses()), (0, 2), "second profile must not hit the first");
    assert_eq!(cache.len(), 2);
    assert_ne!(
        a.throughput_tokens.to_bits(),
        b.throughput_tokens.to_bits(),
        "halved GEMM throughput must move the solve"
    );
    // Re-query both keyspaces: each hit returns its own plan.
    let a2 = solve_under(&table2);
    let b2 = solve_under(&slower);
    assert_eq!((cache.hits(), cache.misses()), (2, 2));
    assert_eq!(a.config, a2.config);
    assert_eq!(b.config, b2.config);
    assert_eq!(a.throughput_tokens.to_bits(), a2.throughput_tokens.to_bits());
    assert_eq!(b.throughput_tokens.to_bits(), b2.throughput_tokens.to_bits());
    // The hand keyspace is a third, independent one.
    let inst = Instance::new(model.clone(), tb.clone(), split, 2048);
    let hand = cache
        .get_or_solve(ShapeKey::prefill(2048, 8), || solver::solve_online(&inst, 8, &params))
        .unwrap();
    assert_eq!(cache.misses(), 3);
    assert_eq!(cache.len(), 3);
    assert_eq!(ShapeKey::prefill(2048, 8).profile, ProfileId::HAND);
    assert_eq!(hand.throughput_tokens.to_bits(), a.throughput_tokens.to_bits());
}

/// Artifact-gated: the serving stack keys Adaptive plans by the
/// server's active profile, so switching a replica onto calibrated
/// constants re-solves instead of reusing hand-constant plans.
#[test]
fn server_rekeys_plans_after_profile_switch() {
    use findep::coordinator::moe::ModelHandle;
    use findep::coordinator::server::Server;
    use findep::runtime::artifacts_dir;

    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let model = ModelHandle::load(&dir, true).unwrap();
    let mut srv = Server::new(model, 2, None).unwrap();
    assert_eq!(srv.plan_profile(), ProfileId::HAND);
    let (ma_hand, r1_hand, _) = srv.plan_adaptive(3);
    let after_hand = srv.plan_cache().len();
    assert!(after_hand >= 1);

    let prof = CalibrationProfile::from_testbed(srv.plan_testbed());
    srv.set_calibration_profile(&prof);
    assert_eq!(srv.plan_profile(), prof.fingerprint());
    let (ma_cal, r1_cal, _) = srv.plan_adaptive(3);
    assert_eq!(
        srv.plan_cache().len(),
        after_hand + 1,
        "calibrated plan must occupy its own cache entry"
    );
    // Constants are Table-2-equivalent, so the plan itself agrees even
    // though the cache entries are disjoint.
    assert_eq!((ma_hand, r1_hand), (ma_cal, r1_cal));
}
