//! A2E / E2A links: serialized message channels with optional α-β delay
//! injection.
//!
//! Each direction is one exclusive resource (§3.2). A link is a
//! dedicated forwarding thread: messages queue in FIFO order and occupy
//! the link for `α + β·bytes` (when a delay model is set), which is
//! exactly the t_c model of Eq. 9 — this keeps schedule differences
//! observable on a host whose real interconnect is a memcpy.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

/// α-β transfer-time model for delay injection.
#[derive(Debug, Clone, Copy)]
pub struct LinkDelay {
    pub alpha_s: f64,
    pub beta_s_per_byte: f64,
}

impl LinkDelay {
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.alpha_s + self.beta_s_per_byte * bytes as f64
    }
}

/// A message that knows its wire size.
pub trait Payload: Send + 'static {
    fn wire_bytes(&self) -> usize;
}

/// One direction of the inter-group interconnect.
pub struct Link<T: Payload> {
    tx: Sender<T>,
    handle: Option<JoinHandle<()>>,
}

impl<T: Payload> Link<T> {
    /// Create a link delivering into `out_tx`. With `delay = None`
    /// messages forward immediately (still FIFO-serialized).
    pub fn new(out_tx: Sender<T>, delay: Option<LinkDelay>) -> Self {
        let (tx, rx): (Sender<T>, Receiver<T>) = channel();
        let handle = std::thread::Builder::new()
            .name("findep-link".into())
            .spawn(move || {
                while let Ok(msg) = rx.recv() {
                    if let Some(d) = delay {
                        let t = d.transfer_time(msg.wire_bytes());
                        if t > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(t));
                        }
                    }
                    if out_tx.send(msg).is_err() {
                        break; // receiver gone: drain and exit
                    }
                }
            })
            .expect("spawn link thread");
        Self { tx, handle: Some(handle) }
    }

    pub fn send(&self, msg: T) -> Result<(), std::sync::mpsc::SendError<T>> {
        self.tx.send(msg)
    }
}

impl<T: Payload> Drop for Link<T> {
    fn drop(&mut self) {
        // Dropping tx closes the channel; the thread drains and exits.
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    struct Msg(usize);

    impl Payload for Msg {
        fn wire_bytes(&self) -> usize {
            self.0
        }
    }

    #[test]
    fn forwards_in_fifo_order() {
        let (out_tx, out_rx) = channel();
        let link = Link::new(out_tx, None);
        for i in 0..10 {
            link.send(Msg(i)).unwrap();
        }
        for i in 0..10 {
            assert_eq!(out_rx.recv().unwrap().0, i);
        }
    }

    #[test]
    fn delay_model_injects_latency() {
        let (out_tx, out_rx) = channel();
        let delay = LinkDelay { alpha_s: 5e-3, beta_s_per_byte: 0.0 };
        let link = Link::new(out_tx, Some(delay));
        let t0 = Instant::now();
        link.send(Msg(0)).unwrap();
        out_rx.recv().unwrap();
        assert!(t0.elapsed().as_secs_f64() >= 4e-3, "delay not applied");
    }

    #[test]
    fn transfer_time_is_affine() {
        let d = LinkDelay { alpha_s: 1e-3, beta_s_per_byte: 1e-6 };
        assert!((d.transfer_time(1000) - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn drop_joins_cleanly() {
        let (out_tx, _out_rx) = channel();
        let link = Link::new(out_tx, None);
        link.send(Msg(1)).unwrap();
        drop(link); // must not hang
    }
}
