"""AOT lowering: JAX stage functions -> HLO *text* artifacts + weights +
golden outputs.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust
side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py there).

Artifacts (per shape bucket, static shapes):

* ``attn_ma{m}.hlo.txt``   — attention stage, h [m, S, M] + 4 projections
* ``gate_n{n}.hlo.txt``    — router, x [n, M] -> (top-k probs, indices)
* ``ffn_n{n}.hlo.txt``     — SwiGLU FFN, x [n, M] (shared AND routed
  experts share this artifact: identical compute shape, §3.1)

plus ``weights.bin`` (flat f32, little-endian), ``manifest.json`` (tensor
table + artifact table + model config), and ``golden.json`` /
``golden_noshared.json`` (full-model input/output pairs for the Rust
integration tests).

Run via ``make artifacts``; Python never runs at serving time.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import configs, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_attention(cfg: configs.ModelConfig, m_a: int, seq: int) -> str:
    m = cfg.embed
    nh, dk, dv = cfg.n_heads, cfg.d_k, cfg.d_v
    f = functools.partial(
        model.attention_stage, n_heads=nh, d_k=dk, d_v=dv, causal=True
    )
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(lambda h, wq, wk, wv, wo: (f(h, wq, wk, wv, wo),)).lower(
        spec((m_a, seq, m), jnp.float32),
        spec((nh * dk, m), jnp.float32),
        spec((nh * dk, m), jnp.float32),
        spec((nh * dv, m), jnp.float32),
        spec((m, nh * dv), jnp.float32),
    )
    return to_hlo_text(lowered)


def lower_gate(cfg: configs.ModelConfig, n: int) -> str:
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(
        lambda x, w: model.gate_stage(x, w, top_k=cfg.top_k)
    ).lower(
        spec((n, cfg.embed), jnp.float32),
        spec((cfg.n_experts, cfg.embed), jnp.float32),
    )
    return to_hlo_text(lowered)


def lower_ffn(cfg: configs.ModelConfig, n: int) -> str:
    m, h = cfg.embed, cfg.ffn_hidden
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(
        lambda x, wg, wu, wd: (model.ffn_stage(x, wg, wu, wd),)
    ).lower(
        spec((n, m), jnp.float32),
        spec((h, m), jnp.float32),
        spec((h, m), jnp.float32),
        spec((m, h), jnp.float32),
    )
    return to_hlo_text(lowered)


WEIGHT_KEYS = [
    # (manifest name, per-layer dict key) — stacked expert tensors are
    # stored whole; the Rust loader slices per expert.
    ("wq", "wq"), ("wk", "wk"), ("wv", "wv"), ("wo", "wo"),
    ("gate_w", "gate_w"),
    ("exp_gate", "exp_gate"), ("exp_up", "exp_up"), ("exp_down", "exp_down"),
    ("shared_gate", "shared_gate"), ("shared_up", "shared_up"),
    ("shared_down", "shared_down"),
]


def pack_weights(weights):
    """Flatten all layer weights into one f32 buffer + tensor table."""
    blobs, table, offset = [], [], 0
    for li, lw in enumerate(weights):
        for name, key in WEIGHT_KEYS:
            if key not in lw:
                continue
            arr = np.asarray(lw[key], dtype=np.float32)
            table.append({
                "name": f"layer{li}.{name}",
                "shape": list(arr.shape),
                "offset": offset,       # in f32 elements
            })
            blobs.append(arr.ravel())
            offset += arr.size
    return np.concatenate(blobs), table


def golden_case(cfg, weights, batch, seq, seed):
    rng = np.random.default_rng(seed)
    h = (rng.standard_normal((batch, seq, cfg.embed)) * 0.5).astype(np.float32)
    out = model.model_forward(jnp.asarray(h), weights, cfg.top_k)
    ref_out = model.reference_forward(jnp.asarray(h), weights, cfg.top_k)
    kernel_vs_ref = float(jnp.max(jnp.abs(out - ref_out)))
    assert kernel_vs_ref < 1e-3, f"kernel path diverged from oracle: {kernel_vs_ref}"
    return {
        "batch": batch,
        "seq": seq,
        "embed": cfg.embed,
        "input": [float(v) for v in h.ravel()],
        "output": [float(v) for v in np.asarray(out).ravel()],
        "atol": 2e-3,
        "kernel_vs_ref_maxdiff": kernel_vs_ref,
    }


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cfg = configs.tiny()
    cfg_ns = configs.tiny_noshared()
    seq = configs.SEQ_LEN

    artifacts = []

    for m_a in configs.MA_BUCKETS:
        path = f"attn_ma{m_a}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(lower_attention(cfg, m_a, seq))
        artifacts.append({
            "stage": "attention", "bucket": m_a, "path": path,
            "inputs": [
                {"name": "h", "shape": [m_a, seq, cfg.embed]},
                {"name": "wq", "shape": [cfg.n_heads * cfg.d_k, cfg.embed]},
                {"name": "wk", "shape": [cfg.n_heads * cfg.d_k, cfg.embed]},
                {"name": "wv", "shape": [cfg.n_heads * cfg.d_v, cfg.embed]},
                {"name": "wo", "shape": [cfg.embed, cfg.n_heads * cfg.d_v]},
            ],
            "outputs": [{"name": "h", "shape": [m_a, seq, cfg.embed]}],
        })

    gate_buckets = sorted({m_a * seq for m_a in configs.MA_BUCKETS})
    for n in gate_buckets:
        path = f"gate_n{n}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(lower_gate(cfg, n))
        artifacts.append({
            "stage": "gate", "bucket": n, "path": path,
            "inputs": [
                {"name": "x", "shape": [n, cfg.embed]},
                {"name": "gate_w", "shape": [cfg.n_experts, cfg.embed]},
            ],
            "outputs": [
                {"name": "probs", "shape": [n, cfg.top_k]},
                {"name": "idx", "shape": [n, cfg.top_k], "dtype": "s32"},
            ],
        })

    for n in configs.FFN_BUCKETS:
        path = f"ffn_n{n}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(lower_ffn(cfg, n))
        artifacts.append({
            "stage": "ffn", "bucket": n, "path": path,
            "inputs": [
                {"name": "x", "shape": [n, cfg.embed]},
                {"name": "w_gate", "shape": [cfg.ffn_hidden, cfg.embed]},
                {"name": "w_up", "shape": [cfg.ffn_hidden, cfg.embed]},
                {"name": "w_down", "shape": [cfg.embed, cfg.ffn_hidden]},
            ],
            "outputs": [{"name": "y", "shape": [n, cfg.embed]}],
        })

    # Weights (shared between both tiny variants; the no-shared variant
    # simply never reads the shared tensors).
    weights = model.init_weights(cfg, seed=0)
    flat, table = pack_weights(weights)
    flat.tofile(os.path.join(out_dir, "weights.bin"))

    # Golden end-to-end cases.
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden_case(cfg, weights, batch=2, seq=seq, seed=7), f)
    weights_ns = [
        {k: v for k, v in lw.items() if not k.startswith("shared_")}
        for lw in weights
    ]
    with open(os.path.join(out_dir, "golden_noshared.json"), "w") as f:
        json.dump(golden_case(cfg_ns, weights_ns, batch=2, seq=seq, seed=7), f)

    manifest = {
        "model": cfg.to_json_dict(),
        "model_noshared": cfg_ns.to_json_dict(),
        "seq_len": seq,
        "ma_buckets": list(configs.MA_BUCKETS),
        "ffn_buckets": list(configs.FFN_BUCKETS),
        "weights": {"file": "weights.bin", "tensors": table},
        "artifacts": artifacts,
        "golden": "golden.json",
        "golden_noshared": "golden_noshared.json",
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(artifacts)} HLO artifacts + weights + goldens to {out_dir}")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    args = p.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
