//! Table 3 — throughput is monotone in m_a (r1 = 1), DeepSeek-V2 on
//! testbeds C and D, S ∈ {2048, 4096}.
//!
//! Exactly the paper's §5.3 protocol: a 2-MoE-layer DeepSeek-V2 variant,
//! (ag,eg) = (3,5) on C and (8,24) on D; for each (m_a, r1) point a
//! brute-force search over all (m_e, r2) and both computation orders
//! picks the optimum, then m_a sweeps {1, 2, 4} at r1 = 1.
//!
//! Run: `cargo bench --bench table3_ma_monotone`

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{bruteforce, Instance};
use findep::util::bench::Table;

fn main() {
    // "a smaller variant of DeepSeek-V2 236B ... employing only two MoE
    // layers" (§5.3).
    let model = ModelConfig::deepseek_v2(2);
    let cases = [
        (Testbed::c(), GroupSplit::new(3, 5)),
        (Testbed::d(), GroupSplit::new(8, 24)),
    ];
    let mut table = Table::new(
        "Table 3: throughput (tokens/s) vs m_a (r1=1), DeepSeek-V2, 2 layers",
        &["testbed", "S", "m_a=1", "m_a=2", "m_a=4", "monotone?"],
    );
    for (tb, split) in cases {
        for s in [2048usize, 4096] {
            let inst = Instance::new(model.clone(), tb.clone(), split, s);
            let mut row = vec![tb.name.clone(), s.to_string()];
            let mut vals = Vec::new();
            for m_a in [1usize, 2, 4] {
                let (_, _, tput) = bruteforce::best_for_fixed_ma_r1(&inst, m_a, 1, 32);
                vals.push(tput);
                row.push(format!("{tput:.2}"));
            }
            let monotone = vals.windows(2).all(|w| w[1] >= w[0] * (1.0 - 1e-9));
            row.push(if monotone { "yes".into() } else { "NO — VIOLATION".into() });
            table.row(&row);
        }
    }
    table.print();
    println!(
        "paper Table 3 (C, S=2048): 202.67 / 245.33 / 284.00 — rising in m_a; ours must rise too \
         (absolute scale differs: simulator constants, not H20 silicon)."
    );
}
