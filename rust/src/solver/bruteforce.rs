//! Exhaustive configuration search — the validation reference for
//! Algorithm 1 (§5.3 uses exactly this: "for each (m_a, r1) pair, we
//! performed a brute-force search over all (m_e, r2) values and
//! computation orders").
//!
//! Every probe goes through the discrete-event engine (never the
//! closed forms): the reference must stay independent of the solver's
//! analytic fast path. The engine still runs on a reusable
//! [`Evaluator`] arena, so the full grid sweep is allocation-free after
//! the first candidate.

use crate::sched::{Order, PlanConfig};
use crate::solver::algorithm1::{Evaluator, Instance};

/// Best (r2, order) for a fixed (m_a, r1) by exhaustive scan, reusing a
/// caller-held evaluator arena. Returns (config, makespan, tokens/s).
pub fn best_for_fixed_ma_r1_with(
    ev: &mut Evaluator,
    m_a: usize,
    r1: usize,
    r2_cap: usize,
) -> (PlanConfig, f64, f64) {
    // Borrow the models' scalars instead of cloning per (m_a, r1) visit.
    let k_tokens = ev.stage_models().k_tokens;
    let has_shared = ev.stage_models().has_shared;
    let m_e_for = |r2: usize| k_tokens * m_a as f64 / r2 as f64;
    let max_r2 = (m_e_for(1).floor() as usize).clamp(1, r2_cap);
    let mut best: Option<(PlanConfig, f64, f64)> = None;
    for order in Order::both() {
        if !has_shared && order == Order::Aass {
            continue;
        }
        for r2 in 1..=max_r2 {
            let cfg = PlanConfig::findep(m_a, r1, r2, m_e_for(r2), order);
            let (ms, tput) = ev.evaluate(cfg);
            if best.as_ref().map_or(true, |b| tput > b.2) {
                best = Some((cfg, ms, tput));
            }
        }
    }
    best.expect("r2 range is non-empty")
}

/// Best (r2, order) for a fixed (m_a, r1) by exhaustive scan (one-shot
/// arena). Returns (config, makespan, tokens/s).
pub fn best_for_fixed_ma_r1(
    inst: &Instance,
    m_a: usize,
    r1: usize,
    r2_cap: usize,
) -> (PlanConfig, f64, f64) {
    best_for_fixed_ma_r1_with(&mut inst.evaluator(), m_a, r1, r2_cap)
}

/// Full exhaustive search over the (m_a, r1) grid (memory-feasible
/// points only). Returns the best (config, makespan, tokens/s).
pub fn exhaustive(
    inst: &Instance,
    ma_cap: usize,
    r1_cap: usize,
    r2_cap: usize,
) -> Option<(PlanConfig, f64, f64)> {
    let mem = inst.memory();
    let mut ev = inst.evaluator();
    let mut best: Option<(PlanConfig, f64, f64)> = None;
    for m_a in 1..=ma_cap {
        let max_r1 = mem.get_max_r1(m_a, r1_cap);
        for r1 in 1..=max_r1 {
            let cand = best_for_fixed_ma_r1_with(&mut ev, m_a, r1, r2_cap);
            if best.as_ref().map_or(true, |b| cand.2 > b.2) {
                best = Some(cand);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};

    #[test]
    fn fixed_point_search_returns_positive_throughput() {
        let inst = Instance::new(
            ModelConfig::deepseek_v2(4),
            Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        );
        let (cfg, ms, tput) = best_for_fixed_ma_r1(&inst, 2, 2, 16);
        assert_eq!((cfg.m_a, cfg.r1), (2, 2));
        assert!(ms > 0.0 && tput > 0.0);
    }

    #[test]
    fn exhaustive_small_grid() {
        let inst = Instance::new(
            ModelConfig::qwen3_moe(4),
            Testbed::b(),
            GroupSplit::new(4, 4),
            1024,
        );
        let best = exhaustive(&inst, 2, 2, 8).unwrap();
        assert!(best.2 > 0.0);
    }

    #[test]
    fn arena_reuse_matches_one_shot() {
        let inst = Instance::new(
            ModelConfig::deepseek_v2(4),
            Testbed::b(),
            GroupSplit::new(3, 5),
            2048,
        );
        let mut ev = inst.evaluator();
        for (m_a, r1) in [(1usize, 1usize), (2, 2), (4, 1)] {
            let a = best_for_fixed_ma_r1(&inst, m_a, r1, 8);
            let b = best_for_fixed_ma_r1_with(&mut ev, m_a, r1, 8);
            assert_eq!(a.0, b.0);
            assert_eq!(a.2, b.2);
        }
    }
}
