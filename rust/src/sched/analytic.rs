//! Closed-form timestamp algebra of §4.2 (ASAS schedule).
//!
//! Building blocks (all per-layer, at a fixed configuration):
//!
//! * `X(m_a)      = t_a(m_a) + t_s(m_a)` — AG occupancy of one chunk
//! * `Y(m_e)      = max(t_e(m_e), t_a2e(m_e))` — fine-pipe beat
//! * `F(m_a,m_e)  = max(X, r2·Y)` — per-chunk pipeline period
//! * `G(m_a,m_e)  = t_a + 2·t_a2e + t_e + (r2−1)·Y` (Eq. 12) — the
//!   chunk-0 round-trip latency through AG → A2E → EG → E2A
//!
//! and the layer-0 start-time formulas plus the per-layer offset
//! `max(G, r1·F)`. The throughput objective (Eq. 13) divides the total
//! sample count by the resulting makespan. These forms are the fast path
//! of Algorithm 1; the discrete-event simulator re-derives the same
//! schedule from the task DAG, and `rust/tests/simulator_vs_analytic.rs`
//! pins them together.

use crate::perfmodel::StageModels;
use crate::sched::{Order, PlanConfig};

/// All §4.2 quantities evaluated at one configuration.
#[derive(Debug, Clone, Copy)]
pub struct Analytic {
    pub t_a: f64,
    pub t_s: f64,
    pub t_e: f64,
    pub t_c: f64,
    pub x: f64,
    pub y: f64,
    pub f: f64,
    pub g: f64,
    pub r1: usize,
    pub r2: usize,
    pub m_a: f64,
    pub m_e: f64,
}

impl Analytic {
    pub fn new(models: &StageModels, m_a: f64, r1: usize, r2: usize) -> Self {
        assert!(r1 >= 1 && r2 >= 1);
        let m_e = models.m_e(m_a, r2);
        let t_a = models.attn_time(m_a);
        let t_s = models.shared_time(m_a);
        let t_e = models.expert_time(m_e);
        let t_c = models.comm_time(m_e);
        let x = t_a + t_s;
        let y = t_e.max(t_c);
        let f = x.max(r2 as f64 * y);
        let g = t_a + 2.0 * t_c + t_e + (r2 as f64 - 1.0) * y;
        Self { t_a, t_s, t_e, t_c, x, y, f, g, r1, r2, m_a, m_e }
    }

    /// The closed forms for a concrete [`PlanConfig`], when they apply.
    ///
    /// Returns `Some` exactly for the configurations the §4.2 algebra
    /// covers — ASAS order, shared expert scheduled separately (not
    /// fused), and an `m_e` consistent with token conservation — which
    /// is precisely the candidate shape Algorithm 1's inner r2 probes
    /// generate. On those plans the closed form and the discrete-event
    /// engine agree exactly (`rust/tests/simulator_vs_analytic.rs`), so
    /// the solver uses this as its allocation-free probe fast path and
    /// falls back to the simulator for AASS / fused candidates.
    pub fn from_config(models: &StageModels, cfg: &PlanConfig) -> Option<Analytic> {
        if cfg.order != Order::Asas || cfg.fuse_shared {
            return None;
        }
        let m_e = models.m_e(cfg.m_a as f64, cfg.r2);
        let consistent = (cfg.m_e - m_e).abs() <= 1e-12 * m_e.abs().max(1.0);
        if !consistent {
            return None;
        }
        Some(Analytic::new(models, cfg.m_a as f64, cfg.r1, cfg.r2))
    }

    /// Per-layer start-time offset: `max(G, r1·F)` (§4.2).
    pub fn layer_offset(&self) -> f64 {
        self.g.max(self.r1 as f64 * self.f)
    }

    /// Layer-0 timestamps (the boxed formulas of §4.2).
    pub fn tau_a(&self, i: usize) -> f64 {
        i as f64 * self.x
    }

    pub fn tau_s(&self, i: usize) -> f64 {
        i as f64 * self.x + self.t_a
    }

    pub fn tau_a2e(&self, i: usize, j: usize) -> f64 {
        self.t_a + i as f64 * self.f + j as f64 * self.t_c
    }

    pub fn tau_e(&self, i: usize, j: usize) -> f64 {
        self.t_a + self.t_c + i as f64 * self.f + j as f64 * self.y
    }

    pub fn tau_e2a(&self, i: usize, j: usize) -> f64 {
        self.t_a + self.t_c + self.t_e + i as f64 * self.f + j as f64 * self.y
    }

    /// Makespan of a `t_layers`-layer forward pass: last E2A completion
    /// vs last shared-expert completion (the two terminal paths of
    /// Eq. 6's max).
    pub fn makespan(&self, t_layers: usize) -> f64 {
        assert!(t_layers >= 1);
        let shift = (t_layers as f64 - 1.0) * self.layer_offset();
        let eg_path = shift
            + self.tau_e2a(self.r1 - 1, self.r2 - 1)
            + self.t_c;
        let ag_path = shift + self.tau_s(self.r1 - 1) + self.t_s;
        eg_path.max(ag_path)
    }

    /// The denominator exactly as printed in Eq. 13 (kept for
    /// reference / regression against the paper's algebra; `makespan`
    /// above is the form the solver and simulator agree on — Eq. 13's
    /// printed form double-counts `(r2−1)·Y` relative to Eq. 12's G).
    pub fn eq13_denominator(&self, t_layers: usize) -> f64 {
        (t_layers as f64 - 1.0) * self.layer_offset()
            + self.x.max(self.g)
            + (self.r2 as f64 - 1.0) * self.y
            + (self.r1 as f64 - 1.0) * self.f
    }

    /// Throughput objective (Eq. 6/13), in *samples per second per AG
    /// GPU group-slot*; multiply by `ag·S / 1` for tokens/s.
    pub fn objective(&self, t_layers: usize) -> f64 {
        self.r1 as f64 * self.m_a / self.makespan(t_layers)
    }

    /// Tokens/s for a whole AG of `ag` GPUs at sequence length `s`.
    pub fn throughput_tokens(&self, t_layers: usize, ag: usize, s: usize) -> f64 {
        self.r1 as f64 * self.m_a * ag as f64 * s as f64 / self.makespan(t_layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};
    use crate::util::proptest::{self, Config};

    fn models() -> StageModels {
        StageModels::new(&ModelConfig::deepseek_v2(8), &Testbed::a(), GroupSplit::new(3, 5), 2048)
    }

    #[test]
    fn building_blocks_consistent() {
        let a = Analytic::new(&models(), 2.0, 2, 3);
        assert!((a.x - (a.t_a + a.t_s)).abs() < 1e-15);
        assert!((a.y - a.t_e.max(a.t_c)).abs() < 1e-15);
        assert!(a.f >= a.x && a.f >= a.r2 as f64 * a.y);
        assert!(a.g >= a.t_a + 2.0 * a.t_c + a.t_e);
    }

    #[test]
    fn naive_single_layer_makespan_is_sequential_sum() {
        // r1 = r2 = 1, one layer: makespan = t_a + t_s vs round trip.
        let sm = models();
        let a = Analytic::new(&sm, 2.0, 1, 1);
        let expect = (a.t_a + a.t_c + a.t_e + a.t_c).max(a.t_a + a.t_s);
        assert!((a.makespan(1) - expect).abs() < 1e-12);
    }

    #[test]
    fn makespan_grows_linearly_in_layers() {
        let a = Analytic::new(&models(), 2.0, 2, 2);
        let d1 = a.makespan(2) - a.makespan(1);
        let d2 = a.makespan(3) - a.makespan(2);
        assert!((d1 - d2).abs() < 1e-12);
        assert!((d1 - a.layer_offset()).abs() < 1e-12);
    }

    #[test]
    fn theorem1_monotone_in_m_a() {
        // Objective increases with m_a at fixed (r1, r2).
        let sm = models();
        for &(r1, r2) in &[(1usize, 1usize), (2, 2), (4, 3), (2, 8)] {
            let mut prev = 0.0;
            for m_a in 1..=32 {
                let obj = Analytic::new(&sm, m_a as f64, r1, r2).objective(8);
                assert!(
                    obj >= prev - 1e-12,
                    "objective not monotone at m_a={m_a} r1={r1} r2={r2}"
                );
                prev = obj;
            }
        }
    }

    #[test]
    fn theorem3_nondecreasing_in_r1() {
        let sm = models();
        for &(m_a, r2) in &[(1.0, 1usize), (2.0, 2), (4.0, 4)] {
            let mut prev = 0.0;
            for r1 in 1..=16 {
                let obj = Analytic::new(&sm, m_a, r1, r2).objective(8);
                assert!(obj >= prev - 1e-9, "objective decreasing at r1={r1}");
                prev = obj;
            }
        }
    }

    #[test]
    fn theorem1_and_3_property_random_models() {
        // Random positive α/β stage models must preserve the paper's
        // monotonicity theorems (they only rely on positivity+linearity).
        proptest::check("thm1-thm3", &Config::with_cases(60), |rng| {
            use crate::perfmodel::LinearModel;
            let sm = StageModels {
                t_a: LinearModel::new(rng.range_f64(1e-6, 1e-3), rng.range_f64(1e-7, 1e-3)),
                t_s: LinearModel::new(rng.range_f64(0.0, 1e-3), rng.range_f64(0.0, 1e-3)),
                t_e: LinearModel::new(rng.range_f64(1e-6, 1e-3), rng.range_f64(1e-7, 1e-3)),
                t_a2e: LinearModel::new(rng.range_f64(1e-6, 1e-3), rng.range_f64(1e-7, 1e-3)),
                k_tokens: rng.range_f64(1.0, 500.0),
                has_shared: rng.bool(0.5),
            };
            let t_layers = 1 + rng.usize_below(12);
            let r2 = 1 + rng.usize_below(8);
            // Theorem 1: m_a monotone.
            let r1 = 1 + rng.usize_below(6);
            let mut prev = 0.0;
            for m_a in 1..=16 {
                let obj = Analytic::new(&sm, m_a as f64, r1, r2).objective(t_layers);
                proptest::ensure(obj >= prev - 1e-12, format!("thm1 violated at m_a={m_a}"))?;
                prev = obj;
            }
            // Theorem 3: r1 non-decreasing.
            let m_a = 1.0 + rng.usize_below(8) as f64;
            let mut prev = 0.0;
            for r1 in 1..=12 {
                let obj = Analytic::new(&sm, m_a, r1, r2).objective(t_layers);
                proptest::ensure(obj >= prev - 1e-9, format!("thm3 violated at r1={r1}"))?;
                prev = obj;
            }
            Ok(())
        });
    }

    #[test]
    fn theorem4_unimodal_in_r2() {
        // Makespan as a function of r2 (others fixed) should be unimodal
        // (convex in 1/r2 per Theorem 4): ternary search must find the
        // global min found by exhaustive scan.
        let sm = models();
        for m_a in [1usize, 2, 4] {
            for r1 in [1usize, 2, 4] {
                let eval = |r2: i64| Analytic::new(&sm, m_a as f64, r1, r2 as usize).makespan(8);
                let exhaustive = (1..=64).map(|r2| (r2, eval(r2)))
                    .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .unwrap();
                let (_, tern_val) = crate::util::stats::ternary_min_int(1, 64, eval);
                assert!(
                    tern_val <= exhaustive.1 * (1.0 + 1e-9),
                    "ternary missed optimum: {} vs {} (m_a={m_a}, r1={r1})",
                    tern_val,
                    exhaustive.1
                );
            }
        }
    }

    #[test]
    fn from_config_gates_on_closed_form_applicability() {
        let sm = models();
        let m_e = sm.m_e(2.0, 3);
        let asas = crate::sched::PlanConfig::findep(2, 2, 3, m_e, crate::sched::Order::Asas);
        let a = Analytic::from_config(&sm, &asas).expect("ASAS candidate is covered");
        assert!((a.makespan(8) - Analytic::new(&sm, 2.0, 2, 3).makespan(8)).abs() < 1e-15);
        // AASS, fused, and inconsistent-m_e candidates are not covered.
        let aass = crate::sched::PlanConfig::findep(2, 2, 3, m_e, crate::sched::Order::Aass);
        assert!(Analytic::from_config(&sm, &aass).is_none());
        let mut fused = asas;
        fused.fuse_shared = true;
        assert!(Analytic::from_config(&sm, &fused).is_none());
        let mut skewed = asas;
        skewed.m_e = m_e * 1.5;
        assert!(Analytic::from_config(&sm, &skewed).is_none());
    }

    #[test]
    fn eq13_denominator_close_to_makespan() {
        // The printed Eq. 13 and our exact makespan may differ by the
        // double-counted (r2-1)Y term; they must stay within that bound.
        let sm = models();
        let a = Analytic::new(&sm, 2.0, 2, 4);
        let diff = (a.eq13_denominator(8) - a.makespan(8)).abs();
        assert!(diff <= (a.r2 as f64 - 1.0) * a.y + a.x + 1e-9, "diff={diff}");
    }
}
