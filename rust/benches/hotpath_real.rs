//! Real-execution hot path: the tiny MoE served end-to-end through the
//! AOT artifacts on PJRT-CPU under each scheduling policy, plus
//! stage-level micro-benchmarks of the runtime (the §Perf targets for
//! L3 live here).
//!
//! Not a paper table — this validates that the three layers compose and
//! measures the coordinator's own overheads (dispatch, routing,
//! combine) so the perf pass has a baseline. On a 1-core host the
//! parallel speedups are not observable; scheduling overhead and
//! correctness-under-load are.
//!
//! Run: `cargo bench --bench hotpath_real` (requires `make artifacts`)

use findep::coordinator::moe::ModelHandle;
use findep::coordinator::pipeline::{ExecConfig, Pipeline};
use findep::coordinator::server::{EmbeddedRequest, Policy, Server};
use findep::runtime::artifacts_dir;
use findep::runtime::tensor::Tensor;
use findep::sched::Order;
use findep::util::bench::{fmt_duration, Bencher, Table};

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping hotpath_real: run `make artifacts` first");
        return Ok(());
    }
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };

    let model = ModelHandle::load(&dir, true)?;
    let (s, m) = (model.seq_len, model.model.embed);

    // --- Stage micro-benchmarks (L3 hot-path pieces). -------------------
    let mut table = Table::new("runtime stage micro-benchmarks (tiny model)", &["stage", "mean", "p50"]);
    let mut h = Tensor::zeros(vec![2, s, m]);
    for (i, v) in h.data.iter_mut().enumerate() {
        *v = ((i % 23) as f32 - 11.0) * 0.02;
    }
    let r = bencher.run("attention(m_a=2)", || {
        let _ = model.attention(0, &h).unwrap();
    });
    table.row(&["attention m_a=2".into(), fmt_duration(r.mean_s()), fmt_duration(r.p50_s())]);
    let x = h.reshaped(vec![2 * s, m]);
    let r = bencher.run("gate(n=32)", || {
        let _ = model.gate(0, &x).unwrap();
    });
    table.row(&["gate n=32".into(), fmt_duration(r.mean_s()), fmt_duration(r.p50_s())]);
    let r = bencher.run("shared_ffn(n=32)", || {
        let _ = model.shared_expert(0, &x).unwrap();
    });
    table.row(&["shared FFN n=32".into(), fmt_duration(r.mean_s()), fmt_duration(r.p50_s())]);
    let x8 = x.truncate_rows(8);
    let r = bencher.run("expert_ffn(n=8)", || {
        let _ = model.expert(0, 3, &x8).unwrap();
    });
    table.row(&["expert FFN n=8".into(), fmt_duration(r.mean_s()), fmt_duration(r.p50_s())]);
    table.print();

    // --- Whole forward pass per schedule. --------------------------------
    let pipeline = Pipeline::new(model.clone(), 2, None)?;
    let mut batch = Tensor::zeros(vec![4, s, m]);
    for (i, v) in batch.data.iter_mut().enumerate() {
        *v = ((i % 31) as f32 - 15.0) * 0.01;
    }
    let mut table = Table::new(
        "forward pass (4 samples x 16 tokens, 2 layers, real PJRT execution)",
        &["schedule", "mean", "p50", "tokens/s"],
    );
    for (name, cfg) in [
        ("naive (r1=1,r2=1)", ExecConfig::naive()),
        ("pppipe (r1=2)", ExecConfig::pppipe(2)),
        ("findep (r1=2,r2=2,ASAS)", ExecConfig::findep(2, 2, Order::Asas)),
        ("findep (r1=4,r2=2,ASAS)", ExecConfig::findep(4, 2, Order::Asas)),
        ("findep (r1=2,r2=4,AASS)", ExecConfig::findep(2, 4, Order::Aass)),
    ] {
        let r = bencher.run(name, || {
            let _ = pipeline.forward(&batch, cfg).unwrap();
        });
        table.row(&[
            name.into(),
            fmt_duration(r.mean_s()),
            fmt_duration(r.p50_s()),
            format!("{:.0}", 4.0 * s as f64 / r.mean_s()),
        ]);
    }
    table.print();

    // --- Server path including batching + routing + metrics. -------------
    let srv = Server::new(model, 2, None)?;
    let reqs: Vec<EmbeddedRequest> =
        (0..4).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
    let mut table = Table::new("server serve_batch (4 requests)", &["policy", "mean", "tokens/s"]);
    for (name, policy) in [
        ("naive", Policy::Naive),
        ("pppipe", Policy::PpPipe { r1: 2 }),
        ("findep", Policy::FinDep { r1: 2, r2: 2, order: Order::Asas }),
        ("adaptive (incl. re-solve)", Policy::Adaptive),
    ] {
        let r = bencher.run(name, || {
            let _ = srv.serve_batch(&reqs, policy).unwrap();
        });
        table.row(&[
            name.into(),
            fmt_duration(r.mean_s()),
            format!("{:.0}", 4.0 * s as f64 / r.mean_s()),
        ]);
    }
    table.print();
    println!("(record before/after numbers in EXPERIMENTS.md §Perf when optimizing)");
    Ok(())
}
