//! Memoized online planning (§5.5 at serving rate).
//!
//! The online-adaptive mode re-solves the schedule per batch, but a
//! serving stream repeats a small set of shapes: the same sequence
//! bucket and padded batch size arrive over and over. [`PlanCache`]
//! memoizes [`Solution`]s per `(seq-len bucket, batch-size bucket)`
//! key, so the solver runs once per *shape* instead of once per
//! *batch* — a cache hit is a map lookup, three-plus orders of
//! magnitude cheaper than even the sub-millisecond re-solve.
//!
//! Infeasible shapes are cached too (as `None`): a batch the testbed
//! cannot hold would otherwise re-run the whole feasibility walk on
//! every arrival.
//!
//! The cache is shared across serving workers (`Arc<PlanCache>`); the
//! map lock is held across a miss's solve on purpose, so concurrent
//! workers hitting the same cold shape wait for one solve instead of
//! duplicating it.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::solver::Solution;

/// Round up to the next power of two — the shape-bucketing used for
/// arbitrary online shapes (a 2-approximation keyspace keeps the cache
/// small under lognormal prompt lengths).
pub fn bucket_up(x: usize) -> usize {
    x.max(1).next_power_of_two()
}

/// Cache key for an arbitrary `(seq_len, batch)` online shape. Serving
/// paths with exact padded capacities (the coordinator pads to
/// `r1 · m_a`) should key on those directly instead.
pub fn shape_key(seq_len: usize, batch: usize) -> (usize, usize) {
    (bucket_up(seq_len), bucket_up(batch))
}

/// Memoized `(seq bucket, batch bucket) -> Solution` store.
#[derive(Debug, Default)]
pub struct PlanCache {
    map: Mutex<BTreeMap<(usize, usize), Option<Solution>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Return the memoized solution for `key`, running `solve` exactly
    /// once per key on a miss (a `None` result is memoized as
    /// infeasible).
    pub fn get_or_solve(
        &self,
        key: (usize, usize),
        solve: impl FnOnce() -> Option<Solution>,
    ) -> Option<Solution> {
        let mut map = self.map.lock().unwrap();
        if let Some(cached) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return cached.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let solved = solve();
        map.insert(key, solved.clone());
        solved
    }

    /// Cached solution without solving (`None` = never solved; a cached
    /// infeasible shape reads back as `Some(None)`).
    pub fn peek(&self, key: (usize, usize)) -> Option<Option<Solution>> {
        self.map.lock().unwrap().get(&key).cloned()
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of memoized shapes (feasible and infeasible).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every memoized shape (testbed constants changed).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};
    use crate::solver::{solve_online, Instance, SolverParams};

    fn paper_instance() -> Instance {
        Instance::new(ModelConfig::deepseek_v2(8), Testbed::a(), GroupSplit::new(3, 5), 2048)
    }

    #[test]
    fn bucketing_rounds_up_to_powers_of_two() {
        assert_eq!(bucket_up(0), 1);
        assert_eq!(bucket_up(1), 1);
        assert_eq!(bucket_up(5), 8);
        assert_eq!(bucket_up(8), 8);
        assert_eq!(shape_key(3000, 6), (4096, 8));
    }

    #[test]
    fn solves_once_per_shape() {
        let cache = PlanCache::new();
        let mut solves = 0usize;
        for _ in 0..5 {
            let sol = cache.get_or_solve((2048, 8), || {
                solves += 1;
                solve_online(&paper_instance(), 8, &SolverParams::default())
            });
            assert!(sol.is_some());
        }
        assert_eq!(solves, 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 4);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cached_solution_matches_fresh_solve() {
        let cache = PlanCache::new();
        let inst = paper_instance();
        let params = SolverParams::default();
        let fresh = solve_online(&inst, 8, &params).unwrap();
        let cached = cache
            .get_or_solve((2048, 8), || solve_online(&inst, 8, &params))
            .unwrap();
        let hit = cache
            .get_or_solve((2048, 8), || panic!("must not re-solve"))
            .unwrap();
        assert_eq!(fresh.config, cached.config);
        assert_eq!(fresh.config, hit.config);
        assert_eq!(fresh.throughput_tokens, hit.throughput_tokens);
    }

    #[test]
    fn infeasible_shapes_are_memoized() {
        let cache = PlanCache::new();
        let inst = paper_instance();
        let params = SolverParams::default();
        let mut solves = 0usize;
        for _ in 0..3 {
            let sol = cache.get_or_solve(shape_key(2048, 10_000_000), || {
                solves += 1;
                solve_online(&inst, 10_000_000, &params)
            });
            assert!(sol.is_none());
        }
        assert_eq!(solves, 1);
        assert_eq!(cache.peek(shape_key(2048, 10_000_000)), Some(None));
        cache.clear();
        assert!(cache.is_empty());
        assert!(cache.peek(shape_key(2048, 10_000_000)).is_none());
    }
}
