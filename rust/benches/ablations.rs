//! Design-choice ablations called out in DESIGN.md:
//!
//! 1. **Group split (ag, eg)** — the disaggregation ratio itself: sweep
//!    every split of an 8-GPU testbed and show where the paper's chosen
//!    (3,5)/(4,4) splits sit.
//! 2. **AG execution order** — ASAS vs AASS at the solved configuration
//!    across regimes (Fig. 4's trade-off, measured).
//! 3. **r2 sensitivity** — throughput vs r2 at fixed (m_a, r1): the
//!    §2.3 launch-overhead trade-off that motivates an adaptive solver
//!    (expect a maximum at moderate r2, not at the extremes).
//!
//! Run: `cargo bench --bench ablations`

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::sched::{Order, PlanConfig};
use findep::solver::{search_splits, solve, Evaluator, Instance, SearchParams, SolverParams};
use findep::util::bench::Table;

fn main() {
    let params = SolverParams::default();

    // --- 1. Group-split ablation (delegated to the split-search solver
    //     layer: an unpruned single-replica search returns every
    //     feasible split's solved throughput in one call). -------------
    let mut table = Table::new(
        "Ablation 1: disaggregation split (ag, eg) on testbed A, S=4096",
        &["model", "split", "FinDEP tokens/s", "note"],
    );
    for (model, label) in [
        (ModelConfig::deepseek_v2(8), "deepseek"),
        (ModelConfig::qwen3_moe(24), "qwen"),
    ] {
        let sp = SearchParams {
            solver: params,
            prune: false,
            multi_replica: false,
            ..Default::default()
        };
        let report = search_splits(&model, &Testbed::a(), 4096, &sp);
        let best = report.as_ref().map(|r| r.best.candidate.split);
        for split in GroupSplit::enumerate(8) {
            let tput = report.as_ref().and_then(|r| {
                r.evaluated
                    .iter()
                    .find(|s| s.candidate.split == split)
                    .map(|s| s.total_throughput)
            });
            let paper_pick = (model.has_shared_expert() && (split.ag, split.eg) == (3, 5))
                || (!model.has_shared_expert() && (split.ag, split.eg) == (4, 4));
            let is_best = best.map_or(false, |b| b == split);
            table.row(&[
                label.into(),
                format!("({},{})", split.ag, split.eg),
                tput.map(|t| format!("{t:.0}")).unwrap_or_else(|| "infeasible".into()),
                match (paper_pick, is_best) {
                    (true, true) => "paper's pick = best".into(),
                    (true, false) => "paper's pick".into(),
                    (false, true) => "best".into(),
                    _ => String::new(),
                },
            ]);
        }
    }
    table.print();

    // --- 2. Order ablation. ----------------------------------------------
    let mut table = Table::new(
        "Ablation 2: ASAS vs AASS at the solved configuration (DeepSeek-V2)",
        &["testbed", "S", "ASAS tokens/s", "AASS tokens/s", "winner"],
    );
    for tb in Testbed::all() {
        let layers = ModelConfig::paper_layers(true, &tb.name[..2]);
        let model = ModelConfig::deepseek_v2(layers);
        let split = GroupSplit::paper_default(&tb, true);
        for s in [1024usize, 4096] {
            let inst = Instance::new(model.clone(), tb.clone(), split, s);
            let Some(sol) = solve(&inst, &params) else { continue };
            let mut ev = inst.evaluator();
            let mut eval_order = |order: Order| {
                let mut cfg: PlanConfig = sol.config;
                cfg.order = order;
                ev.evaluate(cfg).1
            };
            let (asas, aass) = (eval_order(Order::Asas), eval_order(Order::Aass));
            table.row(&[
                tb.name.clone(),
                s.to_string(),
                format!("{asas:.0}"),
                format!("{aass:.0}"),
                if (asas - aass).abs() < 1e-6 * asas {
                    "tie".into()
                } else if asas > aass {
                    "ASAS".into()
                } else {
                    "AASS".into()
                },
            ]);
        }
    }
    table.print();

    // --- 3. r2 sensitivity. ----------------------------------------------
    let mut table = Table::new(
        "Ablation 3: throughput vs r2 at fixed (m_a=2, r1=2) — the §2.3 trade-off",
        &["instance", "r2=1", "r2=2", "r2=4", "r2=8", "r2=16", "r2=32", "best r2"],
    );
    for (tb, model, split, s) in [
        (Testbed::b(), ModelConfig::qwen3_moe(12), GroupSplit::new(4, 4), 8192usize),
        (Testbed::a(), ModelConfig::deepseek_v2(8), GroupSplit::new(3, 5), 4096),
        (Testbed::c(), ModelConfig::deepseek_v2(16), GroupSplit::new(3, 5), 2048),
    ] {
        let inst = Instance::new(model.clone(), tb.clone(), split, s);
        let mut ev: Evaluator = inst.evaluator();
        let sm = ev.stage_models().clone();
        let mut row = vec![format!("{} on {} S={s}", model.name, tb.name)];
        let mut best = (1usize, 0.0f64);
        for r2 in [1usize, 2, 4, 8, 16, 32] {
            let cfg = PlanConfig::findep(2, 2, r2, sm.m_e(2.0, r2), Order::Asas);
            let (_, tput) = ev.evaluate(cfg);
            if tput > best.1 {
                best = (r2, tput);
            }
            row.push(format!("{tput:.0}"));
        }
        row.push(best.0.to_string());
        table.row(&row);
    }
    table.print();
    println!(
        "Expected shapes: (1) the paper's splits sit at/near the sweep optimum; (2) order \
         choice is regime-dependent (that is why Algorithm 1 evaluates both); (3) r2 has an \
         interior optimum — more parts overlap more until launch α dominates (§2.3)."
    );
}
