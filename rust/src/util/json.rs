//! Minimal JSON parser / serializer.
//!
//! The build image's crate registry is offline and `serde_json` is not in
//! the vendored set, so FinDEP carries its own small JSON substrate. It
//! supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) and preserves object insertion order, which we
//! rely on for stable manifest round-trips.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: insertion-ordered key list plus map for O(log n) lookup.
    Obj(JsonObj),
}

/// Insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.keys.iter()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

/// Error produced by [`parse`].
#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["k"]`-style access that tolerates missing keys (returns Null).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Array index access; Null when out of range / not an array.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    pub fn from_strs<I: IntoIterator<Item = S>, S: Into<String>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(|s| Json::Str(s.into())).collect())
    }

    pub fn from_f64s<I: IntoIterator<Item = f64>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(Json::Num).collect())
    }
}

/// Parse a JSON document. Rejects trailing garbage.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid code point"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid code point"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid utf-8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

/// Compact serialization.
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

/// Pretty serialization (2-space indent) for human-readable manifests.
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_pretty(v, 0, &mut out);
    out
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => escape_into(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(e, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, e)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_value(e, out);
            }
            out.push('}');
        }
    }
}

fn write_pretty(v: &Json, depth: usize, out: &mut String) {
    let pad = "  ".repeat(depth);
    let pad_in = "  ".repeat(depth + 1);
    match v {
        Json::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad_in);
                write_pretty(e, depth + 1, out);
            }
            out.push('\n');
            out.push_str(&pad);
            out.push(']');
        }
        Json::Obj(o) if !o.is_empty() => {
            out.push_str("{\n");
            for (i, (k, e)) in o.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad_in);
                escape_into(k, out);
                out.push_str(": ");
                write_pretty(e, depth + 1, out);
            }
            out.push('\n');
            out.push_str(&pad);
            out.push('}');
        }
        other => write_value(other, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn escapes_round_trip() {
        let s = "line\nquote\"back\\slash\ttab";
        let v = Json::Str(s.into());
        let parsed = parse(&to_string(&v)).unwrap();
        assert_eq!(parsed.as_str(), Some(s));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
        // Surrogate pair: 𝄞 (U+1D11E)
        assert_eq!(parse(r#""𝄞""#).unwrap().as_str(), Some("𝄞"));
        // Raw multibyte UTF-8 pass-through.
        assert_eq!(parse("\"héllo\"").unwrap().as_str(), Some("héllo"));
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
        assert_eq!(to_string(&v), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("\"\\x\"").is_err());
    }

    #[test]
    fn round_trips_pretty() {
        let src = r#"{"model":{"dims":[64,128],"shared":true},"ver":1.25}"#;
        let v = parse(src).unwrap();
        let pretty = to_string_pretty(&v);
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(to_string(&Json::Num(3.0)), "3");
        assert_eq!(to_string(&Json::Num(3.5)), "3.5");
        assert_eq!(to_string(&Json::Num(-0.125)), "-0.125");
    }

    #[test]
    fn accessors_tolerate_wrong_types() {
        let v = parse("[1]").unwrap();
        assert_eq!(v.get("missing"), &Json::Null);
        assert_eq!(v.idx(5), &Json::Null);
        assert_eq!(Json::Null.as_f64(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
    }
}
