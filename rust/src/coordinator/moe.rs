//! Typed MoE stage operations over the compiled engine: the bridge
//! between the coordinator's scheduling vocabulary and the AOT HLO
//! artifacts.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::ModelConfig;
use crate::runtime::artifact::ArtifactSet;
use crate::runtime::engine::{Engine, EngineHandle};
use crate::runtime::tensor::{Tensor, TensorI32};

/// Pre-built weight literals, keyed by manifest tensor name (expert
/// slices as `layer{t}.exp_gate[e]`). Built once at load; the serving
/// hot path then converts only activations per call (§Perf L3: weight
/// re-conversion was ~2/3 of per-stage overhead before this cache).
///
/// Safety of `Send + Sync`: literals are immutable after construction
/// and only read concurrently (PJRT copies them into device buffers on
/// execute).
pub struct WeightLiterals(BTreeMap<String, xla::Literal>);

unsafe impl Send for WeightLiterals {}
unsafe impl Sync for WeightLiterals {}

impl std::fmt::Debug for WeightLiterals {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WeightLiterals({} tensors)", self.0.len())
    }
}

impl WeightLiterals {
    fn get(&self, name: &str) -> Result<&xla::Literal> {
        self.0.get(name).with_context(|| format!("missing weight literal '{name}'"))
    }
}

/// A loaded, compiled model: weights + engine + config.
#[derive(Debug, Clone)]
pub struct ModelHandle {
    pub engine: EngineHandle,
    pub artifacts: Arc<ArtifactSet>,
    pub model: ModelConfig,
    pub seq_len: usize,
    weight_lits: Arc<WeightLiterals>,
}

impl ModelHandle {
    /// Load artifacts + weights and compile every stage executable.
    /// `shared` selects the tiny (DeepSeek-style) vs tiny-noshared
    /// (Qwen-style) model semantics over the same artifact set.
    pub fn load(dir: &std::path::Path, shared: bool) -> Result<ModelHandle> {
        let artifacts = Arc::new(ArtifactSet::load(dir)?);
        let engine = EngineHandle::new(Engine::compile(&artifacts.manifest)?);
        let model = if shared {
            artifacts.manifest.model.clone()
        } else {
            artifacts.manifest.model_noshared.clone()
        };
        let seq_len = artifacts.manifest.seq_len;

        // Pre-build every weight literal (plus per-expert slices of the
        // stacked tensors) so the hot path never converts weights.
        let mut lits = BTreeMap::new();
        for (name, _, _) in &artifacts.manifest.tensor_table {
            let t = artifacts.weights.get(name)?;
            lits.insert(name.clone(), t.to_literal()?);
            if name.contains(".exp_") {
                let n_experts = t.shape[0];
                for e in 0..n_experts {
                    let slice = artifacts.weights.expert_slice(name, e)?;
                    lits.insert(format!("{name}[{e}]"), slice.to_literal()?);
                }
            }
        }

        Ok(ModelHandle {
            engine,
            artifacts,
            model,
            seq_len,
            weight_lits: Arc::new(WeightLiterals(lits)),
        })
    }

    fn wl(&self, layer: usize, name: &str) -> Result<&xla::Literal> {
        self.weight_lits.get(&format!("layer{layer}.{name}"))
    }

    /// Attention stage on a micro-batch `h [m_a, S, M]` (residual
    /// included in the artifact).
    pub fn attention(&self, layer: usize, h: &Tensor) -> Result<Tensor> {
        let m_a = h.shape[0];
        let bucket = self.engine.bucket_for("attention", m_a)?;
        anyhow::ensure!(bucket == m_a, "attention m_a {m_a} must hit an exact bucket");
        let h_lit = h.to_literal()?;
        self.engine.run1_lits(
            "attention",
            bucket,
            &[
                &h_lit,
                self.wl(layer, "wq")?,
                self.wl(layer, "wk")?,
                self.wl(layer, "wv")?,
                self.wl(layer, "wo")?,
            ],
        )
    }

    /// Gate stage on flattened tokens `x [N, M]`.
    pub fn gate(&self, layer: usize, x: &Tensor) -> Result<(Tensor, TensorI32)> {
        let n = x.dim0();
        let bucket = self.engine.bucket_for("gate", n)?;
        let xp;
        let x_lit = if bucket == n {
            x.to_literal()?
        } else {
            xp = x.pad_rows_to(bucket);
            xp.to_literal()?
        };
        let (probs, idx) =
            self.engine.run_gate_lits(&[&x_lit, self.wl(layer, "gate_w")?])?;
        Ok((
            probs.truncate_rows(n),
            TensorI32 {
                shape: vec![n, idx.shape[1]],
                data: idx.data[..n * idx.shape[1]].to_vec(),
            },
        ))
    }

    /// Shared-expert FFN on `x [N, M]`.
    pub fn shared_expert(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        anyhow::ensure!(self.model.n_shared > 0, "model has no shared expert");
        self.ffn(
            x,
            self.wl(layer, "shared_gate")?,
            self.wl(layer, "shared_up")?,
            self.wl(layer, "shared_down")?,
        )
    }

    /// Routed-expert FFN: expert `e` of `layer` on its token group.
    pub fn expert(&self, layer: usize, e: usize, x: &Tensor) -> Result<Tensor> {
        self.ffn(
            x,
            self.weight_lits.get(&format!("layer{layer}.exp_gate[{e}]"))?,
            self.weight_lits.get(&format!("layer{layer}.exp_up[{e}]"))?,
            self.weight_lits.get(&format!("layer{layer}.exp_down[{e}]"))?,
        )
    }

    fn ffn(
        &self,
        x: &Tensor,
        wg: &xla::Literal,
        wu: &xla::Literal,
        wd: &xla::Literal,
    ) -> Result<Tensor> {
        let n = x.dim0();
        if n == 0 {
            return Ok(Tensor::zeros(vec![0, self.model.embed]));
        }
        let bucket = self
            .engine
            .bucket_for("ffn", n)
            .with_context(|| format!("ffn bucket for {n} tokens"))?;
        let xp;
        let x_lit = if bucket == n {
            x.to_literal()?
        } else {
            xp = x.pad_rows_to(bucket);
            xp.to_literal()?
        };
        let y = self.engine.run1_lits("ffn", bucket, &[&x_lit, wg, wu, wd])?;
        Ok(y.truncate_rows(n))
    }

    /// Experts owned by EG worker `w` of `eg` workers (contiguous
    /// partition, §2.2: an activated expert's computation is confined to
    /// a single device).
    pub fn experts_of_worker(&self, w: usize, eg: usize) -> std::ops::Range<usize> {
        let per = self.model.n_experts.div_ceil(eg);
        let lo = (w * per).min(self.model.n_experts);
        let hi = ((w + 1) * per).min(self.model.n_experts);
        lo..hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    fn handle() -> Option<ModelHandle> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(ModelHandle::load(&dir, true).unwrap())
    }

    #[test]
    fn expert_partition_covers_all_experts() {
        let Some(h) = handle() else { return };
        for eg in [1usize, 2, 3, 4, 8] {
            let mut covered = vec![false; h.model.n_experts];
            for w in 0..eg {
                for e in h.experts_of_worker(w, eg) {
                    assert!(!covered[e], "expert {e} owned twice (eg={eg})");
                    covered[e] = true;
                }
            }
            assert!(covered.iter().all(|&c| c), "eg={eg} left experts unowned");
        }
    }

    #[test]
    fn stages_execute_with_consistent_shapes() {
        let Some(h) = handle() else { return };
        let m = h.model.embed;
        let s = h.seq_len;
        let mut hin = Tensor::zeros(vec![1, s, m]);
        for (i, v) in hin.data.iter_mut().enumerate() {
            *v = ((i % 17) as f32 - 8.0) * 0.05;
        }
        let hout = h.attention(0, &hin).unwrap();
        assert_eq!(hout.shape, vec![1, s, m]);
        // Attention includes a residual: output differs from input.
        assert!(hout.max_abs_diff(&hin) > 1e-6);

        let x = hout.reshaped(vec![s, m]);
        let (probs, idx) = h.gate(0, &x).unwrap();
        assert_eq!(probs.shape, vec![s, h.model.top_k]);
        assert_eq!(idx.shape, vec![s, h.model.top_k]);

        let sh = h.shared_expert(0, &x).unwrap();
        assert_eq!(sh.shape, vec![s, m]);

        // Uneven token count exercises pad/truncate (bucket 8 for n=5).
        let x5 = x.truncate_rows(5);
        let y5 = h.expert(0, 3, &x5).unwrap();
        assert_eq!(y5.shape, vec![5, m]);
        // Padding must not change the first 5 rows: compare vs bucket-
        // exact call on 8 rows.
        let x8 = x.truncate_rows(8);
        let y8 = h.expert(0, 3, &x8).unwrap();
        for i in 0..5 * m {
            assert!((y5.data[i] - y8.data[i]).abs() < 1e-5);
        }
        // Empty token group short-circuits.
        let y0 = h.expert(0, 1, &Tensor::zeros(vec![0, m])).unwrap();
        assert_eq!(y0.dim0(), 0);
    }
}
