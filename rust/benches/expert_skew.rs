//! Skew-aware expert placement gates for the [`ExpertPlacement`]
//! refactor: replication must pay exactly when traffic says it should.
//!
//! Two acceptance gates, asserted before any timing:
//!
//! 1. **Skew pays.** Under a Zipf(s = 1.5) expert-popularity profile
//!    the hottest expert carries far more than the `E/eg` mean shard,
//!    so [`search_replication`] must spend a strictly positive replica
//!    budget and strictly beat the honest unreplicated baseline
//!    (`replicate_hot(load, eg, 0)` priced by the same Algorithm 1
//!    under the same load) in simulated tokens/s.
//! 2. **Uniform traffic is an exact tie.** Under the exactly-uniform
//!    load the search's baseline candidate is the canonical
//!    [`ExpertPlacement::uniform`], which sits at the perfect-balance
//!    floor — the search must return it with a zero budget, the
//!    [`PlacementId::UNIFORM`] fingerprint, and a solution bit-identical
//!    (`f64::to_bits`) to the legacy [`solver::solve`] on the bare
//!    instance. Replication never taxes balanced traffic.
//!
//! Emits a `BENCH_skew.json` trajectory file.
//!
//! Run: `cargo bench --bench expert_skew`

use findep::config::{
    Cluster, ExpertLoad, ExpertPlacement, GroupSplit, ModelConfig, Phase, PlacementId, Testbed,
};
use findep::solver::{self, Instance, SearchParams};
use findep::util::bench::{fmt_duration, Bencher, Table};
use findep::util::json::{to_string_pretty, Json, JsonObj};

/// Strict-improvement margin gate 1 must clear: far above the ~1e-9
/// engine/closed-form agreement, far below the tens-of-percent gains
/// the analytic model predicts for Zipf(1.5) hot-expert replication.
const MARGIN: f64 = 1e-5;

/// The skew the paper's serving traces motivate: a heavy-tailed gate
/// where the hottest expert draws several mean-shards' worth of tokens.
const ZIPF_S: f64 = 1.5;

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let params = SearchParams::default();
    let tb = Testbed::a();
    let cl = Cluster::single_pool(&tb);
    let seq = 2048usize;

    let mut report = JsonObj::new();
    report.insert("bench", Json::Str("expert_skew".into()));
    report.insert("quick", Json::Bool(quick));
    report.insert("testbed", Json::Str(tb.name.clone()));
    report.insert("seq_len", Json::Num(seq as f64));
    report.insert("zipf_s", Json::Num(ZIPF_S));

    let mut table = Table::new(
        "Skew-aware expert replication (Zipf gate vs uniform tie)",
        &["model", "split", "skew max_rel", "uniform tok/s", "replicated tok/s", "gain",
          "+slots", "placement"],
    );
    let mut entries: Vec<Json> = Vec::new();

    for model in [ModelConfig::deepseek_v2(8), ModelConfig::qwen3_moe(12)] {
        let split = GroupSplit::paper_default(&tb, model.has_shared_expert());
        let eg = split.eg;
        let base = Instance::on_cluster(model.clone(), cl.clone(), split, seq);

        // ---- Gate 1: Zipf skew — replication strictly beats the
        // honest unreplicated placement. ----
        let skew = ExpertLoad::zipf(model.n_experts, ZIPF_S);
        let unreplicated = base
            .clone()
            .with_placement(ExpertPlacement::replicate_hot(&skew, eg, 0), skew.clone());
        let baseline = solver::solve(&unreplicated, &params.solver).unwrap_or_else(|| {
            panic!("{}: unreplicated skewed instance is infeasible", model.name)
        });
        let rep = solver::search_replication(&base, &skew, &params)
            .unwrap_or_else(|| panic!("{}: replication search found no plan", model.name));
        assert!(
            rep.best.extra_slots > 0,
            "{}: Zipf({ZIPF_S}) skew (max_rel {:.1} vs floor {:.1}) must buy replicas",
            model.name,
            skew.max_rel(),
            model.n_experts as f64 / eg as f64
        );
        assert!(
            rep.best.solution.throughput_tokens
                > baseline.throughput_tokens * (1.0 + MARGIN),
            "{}: replicated plan ({:.1} tok/s) must strictly beat the unreplicated \
             placement under the same skewed load ({:.1} tok/s)",
            model.name,
            rep.best.solution.throughput_tokens,
            baseline.throughput_tokens
        );
        let gain = rep.best.solution.throughput_tokens / baseline.throughput_tokens;

        // ---- Gate 2: uniform traffic — exact tie with the legacy
        // uniform plan, bit for bit. ----
        let flat = ExpertLoad::uniform(model.n_experts);
        let legacy = solver::solve(&base, &params.solver)
            .unwrap_or_else(|| panic!("{}: legacy uniform solve infeasible", model.name));
        let tie = solver::search_replication(&base, &flat, &params)
            .unwrap_or_else(|| panic!("{}: uniform replication search infeasible", model.name));
        assert_eq!(tie.best.extra_slots, 0, "{}: uniform traffic must buy nothing", model.name);
        assert!(tie.best.placement.is_uniform(), "{}", model.name);
        assert_eq!(tie.best.placement.fingerprint(), PlacementId::UNIFORM, "{}", model.name);
        assert_eq!(tie.best.solution.config, legacy.config, "{}", model.name);
        assert_eq!(
            tie.best.solution.throughput_tokens.to_bits(),
            legacy.throughput_tokens.to_bits(),
            "{}: uniform-traffic throughput must tie the legacy plan exactly",
            model.name
        );
        assert_eq!(
            tie.best.solution.makespan.to_bits(),
            legacy.makespan.to_bits(),
            "{}: uniform-traffic makespan must tie the legacy plan exactly",
            model.name
        );

        // ---- Timing (the gates above ran cold, untimed). ----
        let r_skew = bencher.run(&format!("{}/search_replication", model.name), || {
            let _ = solver::search_replication(&base, &skew, &params);
        });
        let r_flat = bencher.run(&format!("{}/search_replication_uniform", model.name), || {
            let _ = solver::search_replication(&base, &flat, &params);
        });

        table.row(&[
            model.name.clone(),
            format!("({},{})", split.ag, eg),
            format!("{:.1}", skew.max_rel()),
            format!("{:.0}", baseline.throughput_tokens),
            format!("{:.0}", rep.best.solution.throughput_tokens),
            format!("{:.2}%", (gain - 1.0) * 100.0),
            format!("{}", rep.best.extra_slots),
            rep.best.placement.describe(),
        ]);

        let mut e = JsonObj::new();
        e.insert("model", Json::Str(model.name.clone()));
        e.insert("split", Json::Str(format!("({},{})", split.ag, eg)));
        e.insert("n_experts", Json::Num(model.n_experts as f64));
        e.insert("skew_max_rel", Json::Num(skew.max_rel()));
        e.insert("balance_floor", Json::Num(model.n_experts as f64 / eg as f64));
        e.insert("unreplicated_tokens_per_s", Json::Num(baseline.throughput_tokens));
        e.insert("replicated_tokens_per_s", Json::Num(rep.best.solution.throughput_tokens));
        e.insert("replication_gain", Json::Num(gain));
        e.insert("extra_slots", Json::Num(rep.best.extra_slots as f64));
        e.insert("placement", Json::Str(rep.best.placement.describe()));
        e.insert("config", Json::Str(rep.best.solution.config.describe()));
        e.insert("candidates", Json::Num(rep.stats.candidates as f64));
        e.insert("solved", Json::Num(rep.stats.solved as f64));
        e.insert("bound_pruned", Json::Num(rep.stats.bound_pruned as f64));
        e.insert("dominated", Json::Num(rep.stats.dominated as f64));
        e.insert("max_extra", Json::Num(rep.stats.max_extra as f64));
        e.insert("uniform_tie_tokens_per_s", Json::Num(legacy.throughput_tokens));
        e.insert("uniform_tie_exact", Json::Bool(true));
        e.insert("search_mean_s", Json::Num(r_skew.mean_s()));
        e.insert("search_uniform_mean_s", Json::Num(r_flat.mean_s()));
        entries.push(Json::Obj(e));

        println!(
            "{}: skewed search {} / uniform search {}",
            model.name,
            fmt_duration(r_skew.mean_s()),
            fmt_duration(r_flat.mean_s())
        );
    }

    table.print();
    report.insert("instances", Json::Arr(entries));
    std::fs::write("BENCH_skew.json", to_string_pretty(&Json::Obj(report)))
        .expect("write BENCH_skew.json");
    println!("wrote BENCH_skew.json");
}
