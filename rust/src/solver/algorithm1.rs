//! Algorithm 1: FinDEP configuration search (§4.3).
//!
//! ```text
//! for m_a = MA_max downto 1:
//!     r1 = getMaxR1(...)            # memory-constrained
//!     if r1 == 0 or r1 == prev r1: continue   # Pareto-dominated
//!     for order in {ASAS, AASS}:
//!         r2*, tps = argmin_{r2} makespan(...)  # convex in 1/r2 (Thm 4)
//!         m_e = m_a·ag·top_k·S / (r2*·E)
//!         keep the best
//! ```
//!
//! ## Candidate evaluation (the hot path)
//!
//! All candidate probes run through a reusable [`Evaluator`]: the stage
//! models are derived once per solve, the task DAG is rebuilt into a
//! [`PlanBuffers`] arena, and the discrete-event engine executes into a
//! [`SimBuffers`] arena — zero allocations per `(m_a, order, r2)` probe
//! once the arenas are warm. ASAS probes additionally shortcut through
//! the §4.2 closed forms ([`Analytic::from_config`]), which coincide
//! with the engine exactly on those plans (pinned by
//! `rust/tests/simulator_vs_analytic.rs`); AASS and fused candidates go
//! through the engine, which evaluates them exactly instead of by
//! approximation. The inner r2 ternary search memoizes its probes (the
//! search revisits midpoints), engine-probed winners skip the final
//! re-simulation (the probe already was exact), and repeat plan shapes
//! ride the engine's cached-topology duration-only fast path. The final
//! winner of an analytic probe run is still re-evaluated on the engine.
//! [`EvalMode::AllocPerCandidate`] preserves the original
//! allocate-per-probe behaviour so `benches/solver_speed.rs` can
//! measure both paths against each other. [`solve_with`] lets an outer
//! search (solver::splitsearch) share one evaluator — and with it the
//! arenas and topology cache — across many instances.
//!
//! Cyclic or degenerate candidates (a corrupted `PlanConfig` from an
//! outer searcher) degrade into skipped candidates: the engine reports
//! a [`crate::simulator::SimError`] instead of panicking, and the
//! throughput guard keeps `inf`/NaN out of the argmax.

use std::time::Instant;

use crate::config::{GroupSplit, ModelConfig, Phase, Testbed};
use crate::perfmodel::StageModels;
use crate::sched::analytic::Analytic;
use crate::sched::{Order, Plan, PlanBuffers, PlanConfig};
use crate::simulator::engine::{simulate_into, SimBuffers};
use crate::solver::memory::MemoryModel;
use crate::util::stats::ternary_min_int;

/// A solver problem instance.
///
/// `seq_len` is the tokens each sample contributes to one forward pass:
/// the prompt length for prefill instances, 1 for decode instances
/// (whose KV length lives in `phase`) — so `throughput_tokens` counts
/// prompt tokens/s for prefill and generated tokens/s for decode.
#[derive(Debug, Clone)]
pub struct Instance {
    pub model: ModelConfig,
    pub testbed: Testbed,
    pub split: GroupSplit,
    pub seq_len: usize,
    pub phase: Phase,
}

impl Instance {
    pub fn new(model: ModelConfig, testbed: Testbed, split: GroupSplit, seq_len: usize) -> Self {
        // The solve boundary: an empty batch shape (S = 0, e.g. from an
        // empty serving window) must fail loudly here, not surface as a
        // degenerate all-zero-duration plan winning the argmax.
        assert!(seq_len >= 1, "zero-length sequence reached the solver");
        Self { model, testbed, split, seq_len, phase: Phase::Prefill }
    }

    /// A decode-phase instance: every sample generates one token per
    /// forward pass against `kv_len` cached KV entries.
    pub fn decode(model: ModelConfig, testbed: Testbed, split: GroupSplit, kv_len: usize) -> Self {
        let mut inst = Self::new(model, testbed, split, 1);
        inst.phase = Phase::Decode { kv_len };
        inst
    }

    pub fn stage_models(&self) -> StageModels {
        StageModels::for_phase(&self.model, &self.testbed, self.split, self.seq_len, self.phase)
    }

    pub fn memory(&self) -> MemoryModel {
        MemoryModel::for_phase(&self.model, &self.testbed, self.split, self.seq_len, self.phase)
    }

    /// Build the reusable candidate evaluator for this instance.
    pub fn evaluator(&self) -> Evaluator {
        Evaluator::new(self)
    }

    /// Evaluate one concrete configuration end-to-end (build plan +
    /// simulate), returning (makespan seconds, tokens/s). One-shot
    /// convenience path: allocates fresh stage models and arenas per
    /// call — searchers should hold an [`Evaluator`] instead.
    pub fn evaluate(&self, cfg: PlanConfig) -> (f64, f64) {
        self.evaluator().evaluate(cfg)
    }
}

/// Reusable candidate evaluator: stage models derived once, plan and
/// simulation arenas rewritten in place per candidate.
#[derive(Debug, Clone)]
pub struct Evaluator {
    sm: StageModels,
    n_layers: usize,
    ag: usize,
    seq_len: usize,
    plan_buf: PlanBuffers,
    sim_buf: SimBuffers,
    /// Scratch for `best_r2`'s per-call probe memo (capacity persists
    /// across calls so the memo costs no steady-state allocation).
    r2_memo: Vec<f64>,
}

impl Evaluator {
    pub fn new(inst: &Instance) -> Evaluator {
        Evaluator {
            sm: inst.stage_models(),
            n_layers: inst.model.n_layers,
            ag: inst.split.ag,
            seq_len: inst.seq_len,
            plan_buf: PlanBuffers::new(),
            sim_buf: SimBuffers::new(),
            r2_memo: Vec::new(),
        }
    }

    /// Re-target the evaluator at another instance while keeping the
    /// plan/simulation arenas (and the engine's per-shape topology
    /// cache) warm — the split search re-solves many instances whose
    /// candidate plans share topologies and differ only in durations.
    pub fn reset(&mut self, inst: &Instance) {
        self.sm = inst.stage_models();
        self.n_layers = inst.model.n_layers;
        self.ag = inst.split.ag;
        self.seq_len = inst.seq_len;
    }

    /// The instance's stage models (shared with every probe).
    pub fn stage_models(&self) -> &StageModels {
        &self.sm
    }

    /// Would [`Evaluator::probe_makespan`] answer `cfg` from the §4.2
    /// closed forms (true) or from the discrete-event engine (false)?
    pub fn probe_is_analytic(&self, cfg: &PlanConfig) -> bool {
        Analytic::from_config(&self.sm, cfg).is_some()
    }

    /// Duration-only simulations served from the engine's topology
    /// cache so far (diagnostic; see `SimBuffers::topo_hits`).
    pub fn topo_hits(&self) -> u64 {
        self.sim_buf.topo_hits()
    }

    /// Tokens/s for a candidate whose exact engine makespan is already
    /// known — bit-identical to `SimResult::throughput_tokens` on the
    /// plan the engine would rebuild (same `PlanConfig::total_tokens`
    /// numerator, same degenerate-makespan guard), without
    /// re-simulating it.
    fn throughput_for(&self, cfg: &PlanConfig, makespan: f64) -> f64 {
        if !makespan.is_finite() || makespan <= 0.0 {
            return 0.0;
        }
        cfg.total_tokens(self.ag, self.seq_len) / makespan
    }

    /// Exact evaluation on the discrete-event engine, allocation-free
    /// once the arenas are warm. Returns (makespan, tokens/s); a
    /// degenerate/cyclic candidate reports `(inf, 0.0)` and thus can
    /// never win an argmax.
    pub fn evaluate(&mut self, cfg: PlanConfig) -> (f64, f64) {
        let plan = Plan::build_into(
            &mut self.plan_buf,
            &self.sm,
            cfg,
            self.n_layers,
            self.ag,
            self.seq_len,
        );
        match simulate_into(plan, &mut self.sim_buf) {
            Ok(sim) => (sim.makespan, sim.throughput_tokens(plan)),
            Err(_) => (f64::INFINITY, 0.0),
        }
    }

    /// Makespan-only probe for the inner r2 search: ASAS non-fused
    /// candidates go through the §4.2 closed forms (no DAG at all),
    /// everything else through the engine arenas.
    pub fn probe_makespan(&mut self, cfg: PlanConfig) -> f64 {
        if let Some(a) = Analytic::from_config(&self.sm, &cfg) {
            return a.makespan(self.n_layers);
        }
        self.evaluate(cfg).0
    }
}

/// How candidate probes are evaluated — see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalMode {
    /// Original bring-up behaviour: fresh stage models + fresh task DAG
    /// + fresh simulation vectors per candidate. Kept as the measured
    /// baseline for `benches/solver_speed.rs`.
    AllocPerCandidate,
    /// Arena-reuse + closed-form ASAS probes (the default).
    Buffered,
}

/// Search-space caps. `ma_cap` mirrors the paper's small per-GPU
/// micro-batch regime (Tables 3/4 sweep 1..4); `r1_cap`/`r2_cap` bound
/// the pipeline degrees (launch overhead makes extreme degrees useless,
/// §2.3).
#[derive(Debug, Clone, Copy)]
pub struct SolverParams {
    pub ma_cap: usize,
    pub r1_cap: usize,
    pub r2_cap: usize,
}

impl Default for SolverParams {
    fn default() -> Self {
        // The paper's experimental regime sweeps m_a and r1 over 1..4
        // (Tables 3/4); activation working sets and latency SLOs bound
        // in-flight samples well before raw KV memory does.
        Self { ma_cap: 4, r1_cap: 4, r2_cap: 64 }
    }
}

/// Solver output.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    pub config: PlanConfig,
    pub makespan: f64,
    pub throughput_tokens: f64,
    /// Wall time the solver itself took (the paper's <1 s claim).
    pub solve_seconds: f64,
    /// Number of (m_a, r1, order, r2) evaluations performed.
    pub evals: usize,
}

/// One candidate probe, dispatched per [`EvalMode`].
fn probe(inst: &Instance, ev: &mut Evaluator, mode: EvalMode, cfg: PlanConfig) -> f64 {
    match mode {
        // The seed's exact per-candidate path: Instance::evaluate
        // re-derives StageModels and allocates a fresh DAG + SimResult.
        EvalMode::AllocPerCandidate => inst.evaluate(cfg).0,
        EvalMode::Buffered => ev.probe_makespan(cfg),
    }
}

/// Final (winner) evaluation: always exact on the engine.
fn final_eval(inst: &Instance, ev: &mut Evaluator, mode: EvalMode, cfg: PlanConfig) -> (f64, f64) {
    match mode {
        EvalMode::AllocPerCandidate => inst.evaluate(cfg),
        EvalMode::Buffered => ev.evaluate(cfg),
    }
}

/// Optimal r2 (and its makespan) for fixed (m_a, r1, order) via ternary
/// search over the convex-in-1/r2 objective. Returns (r2, m_e, makespan,
/// evals, engine_exact) — `engine_exact` is true when the winning probe
/// already ran on the discrete-event engine, so the caller can skip the
/// final re-simulation of the identical configuration.
///
/// In [`EvalMode::Buffered`] the integer ternary search memoizes probe
/// values per r2 (the search revisits midpoints, and its final ±2
/// plateau sweep re-walks points the narrowing loop already paid for);
/// `evals` counts only real probe evaluations, so
/// `benches/solver_speed.rs` can assert the memo drops the probe count
/// against the allocate-per-candidate baseline, which keeps the
/// original re-evaluating behaviour.
#[allow(clippy::too_many_arguments)]
fn best_r2(
    inst: &Instance,
    ev: &mut Evaluator,
    mode: EvalMode,
    m_a: usize,
    r1: usize,
    order: Order,
    fuse_shared: bool,
    r2_cap: usize,
) -> (usize, f64, f64, usize, bool) {
    let mut evals = 0usize;
    // Borrow, don't clone: token conservation only needs k (the last
    // per-candidate-group allocation in the solve loop — StageModels is
    // small but this path runs per (m_a, r1, order) visit).
    let k_tokens = ev.stage_models().k_tokens;
    let m_e_for = |r2: usize| k_tokens * m_a as f64 / r2 as f64;
    // m_e below one token per expert per part is degenerate; bound r2 so
    // that m_e >= 1.
    let max_r2 = (m_e_for(1).floor() as usize).clamp(1, r2_cap);
    let memoize = mode == EvalMode::Buffered;
    // Borrow the evaluator's scratch (capacity persists across calls)
    // instead of allocating a memo per (m_a, r1, order) visit; taken
    // out so the probe closure can still borrow `ev` mutably.
    let mut memo = std::mem::take(&mut ev.r2_memo);
    memo.clear();
    if memoize {
        memo.resize(max_r2 + 1, f64::NAN);
    }
    let mut eval = |r2: i64| -> f64 {
        let r2 = r2 as usize;
        if memoize && !memo[r2].is_nan() {
            return memo[r2];
        }
        evals += 1;
        let mut cfg = PlanConfig::findep(m_a, r1, r2, m_e_for(r2), order);
        cfg.fuse_shared = fuse_shared;
        let ms = probe(inst, ev, mode, cfg);
        if memoize {
            memo[r2] = ms;
        }
        ms
    };
    let (r2, makespan) = ternary_min_int(1, max_r2 as i64, &mut eval);
    ev.r2_memo = memo;
    let r2 = r2 as usize;
    let mut win = PlanConfig::findep(m_a, r1, r2, m_e_for(r2), order);
    win.fuse_shared = fuse_shared;
    let engine_exact = memoize && !ev.probe_is_analytic(&win);
    (r2, win.m_e, makespan, evals, engine_exact)
}

/// Accept a candidate only if it beats the incumbent with a real,
/// finite throughput — degenerate probes (0.0 or non-finite) never win.
fn improves(best: &Option<Solution>, tput: f64) -> bool {
    tput.is_finite()
        && tput > 0.0
        && best.as_ref().map_or(true, |b| tput > b.throughput_tokens)
}

/// Algorithm 1 (offline mode): maximize throughput over
/// (m_a, r1, r2, m_e, order) subject to memory. Buffered hot path.
pub fn solve(inst: &Instance, params: &SolverParams) -> Option<Solution> {
    solve_mode(inst, params, EvalMode::Buffered)
}

/// Algorithm 1 with an explicit evaluation mode (the
/// `AllocPerCandidate` baseline exists for the solver-speed bench).
pub fn solve_mode(inst: &Instance, params: &SolverParams, mode: EvalMode) -> Option<Solution> {
    solve_with(inst, params, mode, &mut inst.evaluator())
}

/// Algorithm 1 with a caller-held evaluator: the split search re-solves
/// one instance per (ag, eg) candidate, and passing one evaluator
/// across those solves keeps the plan/simulation arenas and the
/// engine's topology cache warm (candidate plans of different splits
/// share topologies and differ only in durations). The evaluator is
/// re-targeted at `inst` on entry, so any evaluator of the same model
/// family works.
pub fn solve_with(
    inst: &Instance,
    params: &SolverParams,
    mode: EvalMode,
    ev: &mut Evaluator,
) -> Option<Solution> {
    let t0 = Instant::now();
    ev.reset(inst);
    let mem = inst.memory();
    let mut best: Option<Solution> = None;
    let mut evals = 0usize;
    let mut prev_r1 = usize::MAX;

    for m_a in (1..=params.ma_cap).rev() {
        let r1 = mem.get_max_r1(m_a, params.r1_cap);
        if r1 == 0 || r1 == prev_r1 {
            // Pareto-dominated: same r1 at a smaller m_a loses by Thm 1.
            continue;
        }
        prev_r1 = r1;
        for order in Order::both() {
            // With no shared expert both orders coincide; skip AASS.
            if !ev.stage_models().has_shared && order == Order::Aass {
                continue;
            }
            let (r2, m_e, ms, e, engine_exact) =
                best_r2(inst, ev, mode, m_a, r1, order, false, params.r2_cap);
            evals += e;
            let cfg = PlanConfig::findep(m_a, r1, r2, m_e, order);
            // Engine-probed winners are already exact: reuse the probe's
            // makespan instead of re-simulating the identical cfg.
            let (makespan, tput) = if engine_exact {
                (ms, ev.throughput_for(&cfg, ms))
            } else {
                evals += 1;
                final_eval(inst, ev, mode, cfg)
            };
            if improves(&best, tput) {
                best = Some(Solution {
                    config: cfg,
                    makespan,
                    throughput_tokens: tput,
                    solve_seconds: 0.0,
                    evals: 0,
                });
            }
        }
    }
    best.map(|mut b| {
        b.solve_seconds = t0.elapsed().as_secs_f64();
        b.evals = evals;
        b
    })
}

/// Online mode (§5.5): the batch is fixed by what arrived (total
/// `samples_per_gpu` samples per AG GPU); adapt `r1` (divisors of the
/// per-GPU batch), `r2`, and the order, with (ag, eg) pinned.
pub fn solve_online(
    inst: &Instance,
    samples_per_gpu: usize,
    params: &SolverParams,
) -> Option<Solution> {
    solve_online_mode(inst, samples_per_gpu, params, EvalMode::Buffered)
}

/// Online mode with an explicit evaluation mode.
pub fn solve_online_mode(
    inst: &Instance,
    samples_per_gpu: usize,
    params: &SolverParams,
    mode: EvalMode,
) -> Option<Solution> {
    solve_online_impl(inst, samples_per_gpu, params, mode, &[])
}

/// Online entry for the serving loop: like [`solve_online`], but `m_a`
/// restricted to `allowed_ma` — the coordinator's compiled attention
/// buckets, since the real executor can only launch bucket-exact
/// micro-batches. An empty slice places no restriction.
pub fn solve_online_bucketed(
    inst: &Instance,
    samples_per_gpu: usize,
    params: &SolverParams,
    allowed_ma: &[usize],
) -> Option<Solution> {
    solve_online_impl(inst, samples_per_gpu, params, EvalMode::Buffered, allowed_ma)
}

fn solve_online_impl(
    inst: &Instance,
    samples_per_gpu: usize,
    params: &SolverParams,
    mode: EvalMode,
    allowed_ma: &[usize],
) -> Option<Solution> {
    let t0 = Instant::now();
    let mut ev = inst.evaluator();
    let mem = inst.memory();
    if samples_per_gpu == 0 || mem.max_samples_per_ag_gpu() < samples_per_gpu {
        return None;
    }
    let mut best: Option<Solution> = None;
    let mut evals = 0usize;
    for r1 in 1..=params.r1_cap.min(samples_per_gpu) {
        if samples_per_gpu % r1 != 0 {
            continue;
        }
        let m_a = samples_per_gpu / r1;
        if !allowed_ma.is_empty() && !allowed_ma.contains(&m_a) {
            continue;
        }
        for order in Order::both() {
            if !ev.stage_models().has_shared && order == Order::Aass {
                continue;
            }
            let (r2, m_e, ms, e, engine_exact) =
                best_r2(inst, &mut ev, mode, m_a, r1, order, false, params.r2_cap);
            evals += e;
            let cfg = PlanConfig::findep(m_a, r1, r2, m_e, order);
            let (makespan, tput) = if engine_exact {
                (ms, ev.throughput_for(&cfg, ms))
            } else {
                evals += 1;
                final_eval(inst, &mut ev, mode, cfg)
            };
            if improves(&best, tput) {
                best = Some(Solution {
                    config: cfg,
                    makespan,
                    throughput_tokens: tput,
                    solve_seconds: 0.0,
                    evals: 0,
                });
            }
        }
    }
    best.map(|mut b| {
        b.solve_seconds = t0.elapsed().as_secs_f64();
        b.evals = evals;
        b
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst_deepseek(tb: Testbed) -> Instance {
        Instance::new(ModelConfig::deepseek_v2(8), tb, GroupSplit::new(3, 5), 2048)
    }

    fn inst_qwen(tb: Testbed) -> Instance {
        Instance::new(ModelConfig::qwen3_moe(12), tb, GroupSplit::new(4, 4), 2048)
    }

    #[test]
    fn solves_all_testbeds_quickly() {
        for tb in Testbed::all() {
            let inst = inst_deepseek(tb.clone());
            let sol = solve(&inst, &SolverParams::default()).expect("feasible");
            assert!(sol.throughput_tokens > 0.0);
            assert!(sol.solve_seconds < 1.0, "solver too slow: {}s", sol.solve_seconds);
            assert!(sol.config.r1 >= 1 && sol.config.r2 >= 1);
        }
    }

    #[test]
    fn qwen_without_shared_solves() {
        let sol = solve(&inst_qwen(Testbed::b()), &SolverParams::default()).unwrap();
        assert!(!sol.config.fuse_shared);
        assert!(sol.throughput_tokens > 0.0);
    }

    #[test]
    fn solution_beats_naive_and_trivial_configs() {
        let inst = inst_deepseek(Testbed::a());
        let sol = solve(&inst, &SolverParams::default()).unwrap();
        let sm = inst.stage_models();
        let naive = inst.evaluate(PlanConfig::naive(1, sm.m_e(1.0, 1))).1;
        assert!(
            sol.throughput_tokens >= naive,
            "solver {} < naive {}",
            sol.throughput_tokens,
            naive
        );
    }

    #[test]
    fn online_respects_batch() {
        let inst = inst_deepseek(Testbed::a());
        let sol = solve_online(&inst, 8, &SolverParams::default()).unwrap();
        assert_eq!(sol.config.m_a * sol.config.r1, 8);
        // Huge batches that don't fit must be rejected.
        assert!(solve_online(&inst, 10_000_000, &SolverParams::default()).is_none());
    }

    #[test]
    fn online_bucketed_restricts_ma() {
        let inst = inst_deepseek(Testbed::a());
        let params = SolverParams::default();
        // Restricting to a single bucket pins m_a.
        let sol = solve_online_bucketed(&inst, 8, &params, &[2]).unwrap();
        assert_eq!(sol.config.m_a, 2);
        assert_eq!(sol.config.r1, 4);
        // The unrestricted entry agrees with solve_online exactly.
        let a = solve_online_bucketed(&inst, 8, &params, &[]).unwrap();
        let b = solve_online(&inst, 8, &params).unwrap();
        assert_eq!(a.config, b.config);
        assert_eq!(a.throughput_tokens, b.throughput_tokens);
        // No bucket divides the batch -> infeasible.
        assert!(solve_online_bucketed(&inst, 9, &params, &[2, 4]).is_none());
    }

    #[test]
    fn decode_phase_solves_per_phase_plans() {
        // Decode on the paper instance: token conservation at one token
        // per sample makes m_e < 1, so the fine-grained split collapses
        // to r2 = 1 — while the prefill solve of the same (model,
        // testbed, split) keeps r2 > 1. The two phases genuinely need
        // different plans (the premise of phase-keyed caching).
        let params = SolverParams::default();
        let dec = Instance::decode(
            ModelConfig::deepseek_v2(8),
            Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        );
        let d = solve(&dec, &params).expect("decode feasible");
        assert_eq!(d.config.r2, 1, "decode m_e < 1 token must force r2 = 1");
        assert!(d.throughput_tokens > 0.0);
        let p = solve(&inst_deepseek(Testbed::a()), &params).unwrap();
        assert!(p.config.r2 > 1, "prefill keeps fine-grained parts");
        assert_ne!(p.config, d.config);
        // Online decode mode respects the arriving batch.
        let o = solve_online(&dec, 8, &params).expect("online decode feasible");
        assert_eq!(o.config.m_a * o.config.r1, 8);
    }

    #[test]
    #[should_panic(expected = "zero-length sequence")]
    fn zero_seq_len_rejected_at_solve_boundary() {
        let _ = Instance::new(
            ModelConfig::deepseek_v2(8),
            Testbed::a(),
            GroupSplit::new(3, 5),
            0,
        );
    }

    #[test]
    fn infeasible_split_returns_none() {
        // All experts on one 24 GB device: infeasible.
        let inst = Instance::new(
            ModelConfig::deepseek_v2(8),
            Testbed::b(),
            GroupSplit::new(7, 1),
            2048,
        );
        assert!(solve(&inst, &SolverParams::default()).is_none());
    }

    #[test]
    fn buffered_and_alloc_modes_agree() {
        // The arena + closed-form path is a de-allocation, not a
        // different search. Tolerance bound: the closed forms match the
        // engine to 1e-9 relative (pinned by simulator_vs_analytic), so
        // a probe can only flip the chosen r2 where two candidates'
        // makespans tie within that tolerance — and two candidates that
        // tie on makespan differ in final engine throughput by at most
        // the same relative order. Hence both modes must land within
        // 1e-9 relative throughput of each other (empirically they are
        // bit-identical on every paper instance).
        let params = SolverParams::default();
        for tb in Testbed::all() {
            for inst in [inst_deepseek(tb.clone()), inst_qwen(tb.clone())] {
                let buffered = solve_mode(&inst, &params, EvalMode::Buffered);
                let alloc = solve_mode(&inst, &params, EvalMode::AllocPerCandidate);
                match (buffered, alloc) {
                    (Some(b), Some(a)) => {
                        let rel = (b.throughput_tokens - a.throughput_tokens).abs()
                            / a.throughput_tokens;
                        assert!(
                            rel <= 1e-9,
                            "throughput drift on {}: buffered {} vs alloc {} (rel {rel:e}, \
                             buffered cfg {:?}, alloc cfg {:?})",
                            inst.testbed.name,
                            b.throughput_tokens,
                            a.throughput_tokens,
                            b.config,
                            a.config
                        );
                    }
                    (None, None) => {}
                    (b, a) => panic!(
                        "feasibility drift on {}: buffered={} alloc={}",
                        inst.testbed.name,
                        b.is_some(),
                        a.is_some()
                    ),
                }
            }
        }
    }

    #[test]
    fn solve_with_shared_evaluator_is_bit_identical() {
        // One evaluator carried across instances (the split-search hot
        // path: warm arenas + topology cache) must reproduce the
        // fresh-evaluator solve exactly, bit for bit.
        let params = SolverParams::default();
        let mut ev = inst_deepseek(Testbed::a()).evaluator();
        for tb in Testbed::all() {
            for inst in [inst_deepseek(tb.clone()), inst_qwen(tb.clone())] {
                let fresh = solve(&inst, &params);
                let shared = solve_with(&inst, &params, EvalMode::Buffered, &mut ev);
                match (fresh, shared) {
                    (Some(f), Some(s)) => {
                        assert_eq!(f.config, s.config, "config drift on {}", inst.testbed.name);
                        assert_eq!(f.throughput_tokens, s.throughput_tokens);
                        assert_eq!(f.makespan, s.makespan);
                        assert_eq!(f.evals, s.evals);
                    }
                    (None, None) => {}
                    (f, s) => panic!(
                        "feasibility drift on {}: fresh={} shared={}",
                        inst.testbed.name,
                        f.is_some(),
                        s.is_some()
                    ),
                }
            }
        }
        // The shared evaluator actually exercised the topology cache.
        assert!(ev.topo_hits() > 0, "expected duration-only fast-path hits across instances");
    }

    #[test]
    fn memoized_ternary_probes_fewer_candidates() {
        // The Buffered path memoizes revisited r2 probes and skips the
        // winner's redundant final simulation; the alloc baseline keeps
        // the original counting. On every feasible paper-shaped
        // instance the probe count must strictly drop.
        let params = SolverParams::default();
        for tb in Testbed::all() {
            let inst = inst_deepseek(tb.clone());
            let (Some(b), Some(a)) = (
                solve_mode(&inst, &params, EvalMode::Buffered),
                solve_mode(&inst, &params, EvalMode::AllocPerCandidate),
            ) else {
                continue;
            };
            assert!(
                b.evals < a.evals,
                "probe count did not drop on {}: buffered {} vs alloc {}",
                inst.testbed.name,
                b.evals,
                a.evals
            );
        }
    }

    #[test]
    fn evaluator_matches_one_shot_instance_evaluate() {
        let inst = inst_deepseek(Testbed::a());
        let sm = inst.stage_models();
        let mut ev = inst.evaluator();
        for (m_a, r1, r2, order) in
            [(1usize, 1usize, 1usize, Order::Asas), (2, 2, 4, Order::Aass), (4, 2, 8, Order::Asas)]
        {
            let cfg = PlanConfig::findep(m_a, r1, r2, sm.m_e(m_a as f64, r2), order);
            let (ms_a, tp_a) = inst.evaluate(cfg);
            let (ms_b, tp_b) = ev.evaluate(cfg);
            assert_eq!(ms_a, ms_b);
            assert_eq!(tp_a, tp_b);
            // The ASAS probe shortcut agrees with the engine exactly.
            if order == Order::Asas {
                assert!(
                    (ev.probe_makespan(cfg) - ms_a).abs() <= 1e-9 * ms_a,
                    "closed-form probe drifted from engine"
                );
            }
        }
    }
}
