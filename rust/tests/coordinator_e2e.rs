//! End-to-end coordinator test: serve a stream of batches through the
//! real DEP pipeline under every policy, with link-delay injection, and
//! check throughput accounting + numerical agreement.

use findep::coordinator::links::LinkDelay;
use findep::coordinator::moe::ModelHandle;
use findep::coordinator::server::{EmbeddedRequest, Policy, Server};
use findep::runtime::artifacts_dir;
use findep::sched::Order;

fn skip() -> bool {
    let missing = !artifacts_dir().join("manifest.json").exists();
    if missing {
        eprintln!("skipping: run `make artifacts` first");
    }
    missing
}

fn mk_server(eg: usize, delay: Option<LinkDelay>) -> Server {
    let model = ModelHandle::load(&artifacts_dir(), true).unwrap();
    Server::new(model, eg, delay).unwrap()
}

#[test]
fn serves_a_request_stream_under_all_policies() {
    if skip() {
        return;
    }
    let srv = mk_server(2, None);
    let s = srv.pipeline.model().seq_len;
    let m = srv.pipeline.model().model.embed;
    let policies = [
        Policy::Naive,
        Policy::PpPipe { r1: 2 },
        Policy::FinDep { r1: 2, r2: 2, order: Order::Asas },
        Policy::Adaptive,
    ];
    let mut reference: Option<Vec<Vec<f32>>> = None;
    for policy in policies {
        let mut outputs = Vec::new();
        for b in 0..3u64 {
            let reqs: Vec<EmbeddedRequest> =
                (0..4).map(|i| EmbeddedRequest::synthetic(b * 4 + i, s, m)).collect();
            let (resp, stats) = srv.serve_batch(&reqs, policy).unwrap();
            assert_eq!(resp.len(), 4);
            assert!(stats.total > 0.0);
            for r in resp {
                outputs.push(r.hidden.data);
            }
        }
        match &reference {
            None => reference = Some(outputs),
            Some(base) => {
                for (a, b) in base.iter().zip(&outputs) {
                    let diff = a
                        .iter()
                        .zip(b)
                        .map(|(x, y)| (x - y).abs())
                        .fold(0.0f32, f32::max);
                    assert!(diff < 1e-4, "policy changed numerics by {diff}");
                }
            }
        }
    }
    // 4 policies x 3 batches x 4 requests.
    assert_eq!(srv.metrics.counter("requests"), 48);
    assert_eq!(srv.metrics.counter("batches"), 12);
    assert_eq!(srv.metrics.counter("tokens"), 48 * s as u64);
}

#[test]
fn link_delay_injection_slows_naive_more_than_findep() {
    if skip() {
        return;
    }
    // Bandwidth-dominated link delay (tiny α): the pipelined schedule
    // overlaps transfer sleeps with compute, naive pays them serially.
    // (With *α-dominated* delay the opposite holds — fine-graining
    // multiplies launch costs, exactly the trade-off of §2.3 that the
    // solver navigates — so this test pins the β-dominated direction
    // only.) Generous slack: 1-core host, scheduling noise.
    let delay = Some(LinkDelay { alpha_s: 2e-5, beta_s_per_byte: 4e-7 });
    let srv = mk_server(2, delay);
    let s = srv.pipeline.model().seq_len;
    let m = srv.pipeline.model().model.embed;
    let reqs: Vec<EmbeddedRequest> =
        (0..4).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
    // Warm up both paths.
    let _ = srv.serve_batch(&reqs, Policy::Naive).unwrap();
    let _ = srv
        .serve_batch(&reqs, Policy::FinDep { r1: 2, r2: 2, order: Order::Asas })
        .unwrap();
    let mut t_naive: f64 = 0.0;
    let mut t_findep: f64 = 0.0;
    for _ in 0..3 {
        let (_, st) = srv.serve_batch(&reqs, Policy::Naive).unwrap();
        t_naive += st.total;
        let (_, st) =
            srv.serve_batch(&reqs, Policy::FinDep { r1: 2, r2: 2, order: Order::Asas }).unwrap();
        t_findep += st.total;
    }
    assert!(
        t_findep < t_naive * 1.25,
        "FinDEP ({t_findep:.4}s) should not be materially slower than naive ({t_naive:.4}s) \
         under bandwidth-dominated link delay"
    );
}

#[test]
fn adaptive_policy_resolves_and_runs() {
    if skip() {
        return;
    }
    let srv = mk_server(4, None);
    let s = srv.pipeline.model().seq_len;
    let m = srv.pipeline.model().model.embed;
    for batch_size in [1usize, 3, 7, 8] {
        let reqs: Vec<EmbeddedRequest> = (0..batch_size as u64)
            .map(|i| EmbeddedRequest::synthetic(i, s, m))
            .collect();
        let (resp, _) = srv.serve_batch(&reqs, Policy::Adaptive).unwrap();
        assert_eq!(resp.len(), batch_size.min(16));
    }
}
