//! Table 6 — online setting (§5.5): (ag, eg) is pinned, prompt lengths
//! are unpredictable, and FinDEP re-solves (r1, r2, order) per arriving
//! batch while PPPipe runs its best *static* configuration chosen for
//! the expected shape. Scenarios: mean arriving tokens 3072 and 6144.
//!
//! Run: `cargo bench --bench table6_online`

use findep::baselines::{best_pppipe, pppipe::pppipe_fixed};
use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{solve_online, Instance, SolverParams};
use findep::util::bench::Table;
use findep::util::rng::Rng;
use findep::workload::{batch_seq_len, window_batches, OnlineWorkload};

fn main() {
    let params = SolverParams::default();
    let samples_per_gpu = 4usize;
    let mut table = Table::new(
        "Table 6: online throughput (tokens/s), static best-PPPipe vs adaptive FinDEP",
        &["backbone", "testbed", "mean tokens", "PPPipe", "FinDEP", "speedup", "max solve ms"],
    );

    for (backbone, deepseek) in [("DeepSeek", true), ("Qwen", false)] {
        for tb in Testbed::all() {
            let layers = ModelConfig::paper_layers(deepseek, &tb.name[..2]);
            let model = if deepseek {
                ModelConfig::deepseek_v2(layers)
            } else {
                ModelConfig::qwen3_moe(layers)
            };
            // §5.5 splits: DeepSeek (3,5), Qwen (4,4) on A/B/C; (8,24) on D.
            let split = if tb.n_gpus >= 32 {
                GroupSplit::new(8, 24)
            } else if deepseek {
                GroupSplit::new(3, 5)
            } else {
                GroupSplit::new(4, 4)
            };
            for mean_tokens in [3072usize, 6144] {
                let workload = OnlineWorkload::paper_scenario(mean_tokens);
                let mut rng = Rng::new(7);
                let reqs = workload.generate(48, &mut rng);
                let batches = window_batches(&reqs, 0.5, 16);

                let expect =
                    Instance::new(model.clone(), tb.clone(), split, mean_tokens);
                let Some(pp_best) = best_pppipe(&expect, &params) else {
                    table.row(&[
                        backbone.into(),
                        tb.name.clone(),
                        mean_tokens.to_string(),
                        "infeasible".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                    continue;
                };

                let (mut pp_time, mut fd_time, mut tokens) = (0.0f64, 0.0f64, 0.0f64);
                let mut max_solve = 0.0f64;
                for batch in batches.iter().filter(|b| !b.is_empty()) {
                    let s = batch_seq_len(batch);
                    let inst = Instance::new(model.clone(), tb.clone(), split, s);
                    let pp = pppipe_fixed(&inst, pp_best.config.m_a, pp_best.config.r1);
                    let Some(fd) = solve_online(&inst, samples_per_gpu, &params) else {
                        continue;
                    };
                    max_solve = max_solve.max(fd.solve_seconds);
                    let batch_tokens = (samples_per_gpu * split.ag * s) as f64;
                    pp_time += batch_tokens / pp.throughput_tokens;
                    fd_time += batch_tokens / fd.throughput_tokens;
                    tokens += batch_tokens;
                }
                if tokens == 0.0 {
                    continue;
                }
                let (ppt, fdt) = (tokens / pp_time, tokens / fd_time);
                assert!(max_solve < 1.0, "online re-solve exceeded 1 s");
                table.row(&[
                    backbone.into(),
                    tb.name.clone(),
                    mean_tokens.to_string(),
                    format!("{ppt:.0}"),
                    format!("{fdt:.0}"),
                    format!("{:.3}x", fdt / ppt),
                    format!("{:.2}", max_solve * 1e3),
                ]);
            }
        }
    }
    table.print();
    println!(
        "paper Table 6 speedups: 1.00x-1.24x with the <1 s re-solve enabling per-batch \
         adaptation; the shape to check is FinDEP ≥ static PPPipe with the gap widening on \
         comm-bound testbeds and shape-varying workloads."
    );
}
