//! PPPipe — the ping-pong micro-batch pipeline of MegaScale-Infer [36]
//! (Fig. 3b), reimplemented as the paper does for fair comparison
//! (§5.4: "we provide our own reimplementation").
//!
//! PPPipe splits the mini-batch into `r1` micro-batches but has no
//! fine-grained EG split (`r2 = 1`) and no shared-expert scheduling —
//! the shared expert is fused into the attention task (§2.3: "one can
//! support including the shared expert by regarding it as a part of
//! attention"). `best_pppipe` sweeps the same memory-constrained
//! Pareto frontier as Algorithm 1 so Table 5's "optimal ep, dp, m_a and
//! r1 settings" comparison is faithful.

use crate::sched::PlanConfig;
use crate::solver::algorithm1::{Instance, Solution, SolverParams};

/// Ping-pong pipelining is double buffering: the attention and expert
/// groups alternate between **two** in-flight micro-batches (Fig. 3b;
/// §2.2 "e.g., r1 = 2 in Fig. 3b"). The faithful baseline therefore
/// caps r1 at 2; [`best_pppipe_deep`] removes the cap for the ablation
/// of how much of FinDEP's win is depth vs fine-graining.
pub const PPPIPE_R1_CAP: usize = 2;

/// Best PPPipe configuration for an instance (sweep m_a on the memory
/// Pareto frontier, r1 ∈ {1, 2} per the ping-pong discipline).
pub fn best_pppipe(inst: &Instance, params: &SolverParams) -> Option<Solution> {
    best_pppipe_capped(inst, params, PPPIPE_R1_CAP)
}

/// Ablation variant: PPPipe with arbitrary pipeline depth (an idealized
/// baseline stronger than [36]'s published system).
pub fn best_pppipe_deep(inst: &Instance, params: &SolverParams) -> Option<Solution> {
    best_pppipe_capped(inst, params, params.r1_cap)
}

fn best_pppipe_capped(inst: &Instance, params: &SolverParams, r1_cap: usize) -> Option<Solution> {
    let mem = inst.memory();
    let mut ev = inst.evaluator();
    let sm = ev.stage_models().clone();
    let mut best: Option<Solution> = None;
    let mut evals = 0usize;
    for m_a in (1..=params.ma_cap).rev() {
        let max_r1 = mem.get_max_r1(m_a, params.r1_cap.min(r1_cap));
        for r1 in 1..=max_r1 {
            let cfg = PlanConfig::pppipe(m_a, r1, sm.m_e(m_a as f64, 1));
            let (makespan, tput) = ev.evaluate(cfg);
            evals += 1;
            if best.as_ref().map_or(true, |b| tput > b.throughput_tokens) {
                best = Some(Solution {
                    config: cfg,
                    makespan,
                    throughput_tokens: tput,
                    solve_seconds: 0.0,
                    evals: 0,
                    pruned_rows: 0,
                    warm_seeded: false,
                    exhaustive: true,
                });
            }
        }
    }
    best.map(|mut b| {
        b.evals = evals;
        b
    })
}

/// PPPipe at a *fixed* (m_a, r1) — used by the online comparison
/// (Table 6) where the batch is dictated by arrivals.
pub fn pppipe_fixed(inst: &Instance, m_a: usize, r1: usize) -> Solution {
    let sm = inst.stage_models();
    let cfg = PlanConfig::pppipe(m_a, r1, sm.m_e(m_a as f64, 1));
    let (makespan, tput) = inst.evaluate(cfg);
    Solution {
        config: cfg,
        makespan,
        throughput_tokens: tput,
        solve_seconds: 0.0,
        evals: 1,
        pruned_rows: 0,
        warm_seeded: false,
        exhaustive: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};
    use crate::solver::algorithm1::solve;

    fn inst() -> Instance {
        Instance::new(ModelConfig::deepseek_v2(8), Testbed::a(), GroupSplit::new(3, 5), 2048)
    }

    #[test]
    fn pppipe_has_no_fine_graining() {
        let sol = best_pppipe(&inst(), &SolverParams::default()).unwrap();
        assert_eq!(sol.config.r2, 1);
        assert!(sol.config.fuse_shared);
        assert!(sol.throughput_tokens > 0.0);
    }

    #[test]
    fn findep_never_loses_to_pppipe() {
        // FinDEP's search space strictly contains PPPipe-with-separate-
        // shared; with the fused variant it may differ slightly, but the
        // solved FinDEP must beat or match the best PPPipe on every
        // testbed (the paper's headline claim, Table 5).
        for tb in Testbed::all() {
            let inst = Instance::new(
                ModelConfig::deepseek_v2(8),
                tb,
                GroupSplit::paper_default(&Testbed::a(), true),
                2048,
            );
            let pp = best_pppipe(&inst, &SolverParams::default()).unwrap();
            let fd = solve(&inst, &SolverParams::default()).unwrap();
            assert!(
                fd.throughput_tokens >= pp.throughput_tokens * 0.999,
                "FinDEP {} < PPPipe {} on {}",
                fd.throughput_tokens,
                pp.throughput_tokens,
                inst.cluster.name
            );
        }
    }

    #[test]
    fn pppipe_beats_naive() {
        let inst = inst();
        let pp = best_pppipe(&inst, &SolverParams::default()).unwrap();
        let nv = crate::baselines::naive::best_naive(&inst, 8).unwrap();
        assert!(pp.throughput_tokens >= nv.throughput_tokens);
    }

    #[test]
    fn fixed_config_matches_eval() {
        let inst = inst();
        let s = pppipe_fixed(&inst, 2, 2);
        assert_eq!((s.config.m_a, s.config.r1), (2, 2));
        assert!(s.makespan > 0.0);
    }
}
