//! The α-β linear time model (Eqs. 7-9): `t(x) = α + β·x`, with α the
//! fixed launch/startup overhead and β the per-unit marginal cost.

use crate::util::stats::{self, FitError, LinFit};

/// `t(x) = alpha + beta * x`, times in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearModel {
    pub alpha: f64,
    pub beta: f64,
}

impl LinearModel {
    pub fn new(alpha: f64, beta: f64) -> Self {
        assert!(alpha >= 0.0 && beta >= 0.0, "negative cost model");
        Self { alpha, beta }
    }

    /// Evaluate at workload `x` (x <= 0 still pays the launch cost once
    /// invoked; callers skip zero-size tasks entirely instead).
    pub fn eval(&self, x: f64) -> f64 {
        self.alpha + self.beta * x.max(0.0)
    }

    /// Least-squares fit from (workload, seconds) samples, clamping a
    /// (noise-induced) negative intercept to zero so the model stays a
    /// valid cost function. Returns the model and its R².
    pub fn fit(x: &[f64], y: &[f64]) -> (Self, f64) {
        Self::clamped(stats::linear_fit(x, y), x, y)
    }

    /// Strict fit for calibration inputs: errors on degenerate samples
    /// (fewer than 2 points, zero workload variance, non-finite values)
    /// instead of returning a flat fallback model that would silently
    /// poison a profile-driven solve.
    pub fn try_fit(x: &[f64], y: &[f64]) -> Result<(Self, f64), FitError> {
        Ok(Self::clamped(stats::try_linear_fit(x, y)?, x, y))
    }

    /// Clamp a raw least-squares fit into the valid cost cone. R² must
    /// describe the model actually returned: when clamping changed a
    /// coefficient, the residuals changed too, so re-score against the
    /// clamped line instead of reporting the unclamped fit's quality
    /// (which overstates it exactly when clamping mattered).
    fn clamped(fit: LinFit, x: &[f64], y: &[f64]) -> (Self, f64) {
        let LinFit { alpha, beta, r2 } = fit;
        let (ca, cb) = (alpha.max(0.0), beta.max(0.0));
        let r2 = if ca == alpha && cb == beta { r2 } else { stats::r_squared(x, y, ca, cb) };
        (Self { alpha: ca, beta: cb }, r2)
    }

    /// Scale the marginal cost (e.g. derive β_s = 3·N_shared·β_gm·S·M·H
    /// style compositions) keeping α.
    pub fn with_beta_scaled(&self, k: f64) -> Self {
        Self { alpha: self.alpha, beta: self.beta * k }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_is_affine() {
        let m = LinearModel::new(1.0, 2.0);
        assert_eq!(m.eval(0.0), 1.0);
        assert_eq!(m.eval(3.0), 7.0);
        assert_eq!(m.eval(-5.0), 1.0, "negative workloads clamp to launch cost");
    }

    #[test]
    fn fit_recovers_exact_model() {
        let x: Vec<f64> = (1..50).map(|i| i as f64 * 10.0).collect();
        let y: Vec<f64> = x.iter().map(|v| 0.25 + 0.01 * v).collect();
        let (m, r2) = LinearModel::fit(&x, &y);
        assert!((m.alpha - 0.25).abs() < 1e-9);
        assert!((m.beta - 0.01).abs() < 1e-12);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn fit_clamps_negative_intercept() {
        // Points through the origin with negative-intercept noise.
        let x = [1.0, 2.0, 3.0];
        let y = [0.9, 2.05, 3.0];
        let (m, _) = LinearModel::fit(&x, &y);
        assert!(m.alpha >= 0.0);
    }

    #[test]
    fn clamped_fit_reports_clamped_r2() {
        // A markedly negative intercept: the raw least-squares line fits
        // these points exactly (R² = 1), but the clamped model (α = 0)
        // does not — reporting the unclamped R² would claim a perfect
        // fit for a model with visible residuals.
        let x = [1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = x.iter().map(|v| -1.0 + 2.0 * v).collect();
        let raw = crate::util::stats::linear_fit(&x, &y);
        assert!((raw.r2 - 1.0).abs() < 1e-12, "raw fit is exact");
        let (m, r2) = LinearModel::fit(&x, &y);
        assert_eq!(m.alpha, 0.0, "intercept clamped");
        assert!(r2 < raw.r2, "clamped R² must drop: {r2} vs {}", raw.r2);
        assert_eq!(r2, crate::util::stats::r_squared(&x, &y, m.alpha, m.beta));
    }

    #[test]
    fn fit_without_clamping_keeps_least_squares_r2() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.1, 2.9, 4.2, 4.8];
        let raw = crate::util::stats::linear_fit(&x, &y);
        assert!(raw.alpha >= 0.0 && raw.beta >= 0.0, "no clamping in this case");
        let (_, r2) = LinearModel::fit(&x, &y);
        assert_eq!(r2, raw.r2);
    }

    #[test]
    fn try_fit_errors_on_degenerate_inputs() {
        assert!(LinearModel::try_fit(&[1.0], &[2.0]).is_err());
        assert!(LinearModel::try_fit(&[3.0, 3.0], &[1.0, 2.0]).is_err());
        assert!(LinearModel::try_fit(&[1.0, 2.0], &[f64::NAN, 1.0]).is_err());
        let (m, r2) = LinearModel::try_fit(&[1.0, 2.0, 3.0], &[1.5, 2.5, 3.5]).unwrap();
        assert!((m.beta - 1.0).abs() < 1e-12);
        assert!((m.alpha - 0.5).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn negative_model_rejected() {
        LinearModel::new(-1.0, 0.0);
    }
}
