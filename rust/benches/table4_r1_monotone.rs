//! Table 4 — throughput is monotone in r1 (m_a = 1), DeepSeek-V2 on
//! testbeds C and D, S ∈ {2048, 4096} (§5.3 protocol, same 2-layer
//! variant and splits as Table 3; brute-force (m_e, r2, order) per
//! point).
//!
//! Run: `cargo bench --bench table4_r1_monotone`

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{bruteforce, Instance};
use findep::util::bench::Table;

fn main() {
    let model = ModelConfig::deepseek_v2(2);
    let cases = [
        (Testbed::c(), GroupSplit::new(3, 5)),
        (Testbed::d(), GroupSplit::new(8, 24)),
    ];
    let mut table = Table::new(
        "Table 4: throughput (tokens/s) vs r1 (m_a=1), DeepSeek-V2, 2 layers",
        &["testbed", "S", "r1=1", "r1=2", "r1=4", "monotone?"],
    );
    for (tb, split) in cases {
        for s in [2048usize, 4096] {
            let inst = Instance::new(model.clone(), tb.clone(), split, s);
            let mut row = vec![tb.name.clone(), s.to_string()];
            let mut vals = Vec::new();
            for r1 in [1usize, 2, 4] {
                let (_, _, tput) = bruteforce::best_for_fixed_ma_r1(&inst, 1, r1, 32);
                vals.push(tput);
                row.push(format!("{tput:.2}"));
            }
            let monotone = vals.windows(2).all(|w| w[1] >= w[0] * (1.0 - 1e-9));
            row.push(if monotone { "yes".into() } else { "NO — VIOLATION".into() });
            table.row(&row);
        }
    }
    table.print();
    println!(
        "paper Table 4 (C, S=2048): 202.67 / 257.24 / 282.04 — rising in r1 with diminishing \
         returns at longer S; both properties should reproduce in shape."
    );
}
