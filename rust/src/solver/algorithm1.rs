//! Algorithm 1: FinDEP configuration search (§4.3).
//!
//! ```text
//! for m_a = MA_max downto 1:
//!     r1 = getMaxR1(...)            # memory-constrained
//!     if r1 == 0 or r1 == prev r1: continue   # Pareto-dominated
//!     for order in {ASAS, AASS}:
//!         r2*, tps = argmin_{r2} makespan(...)  # convex in 1/r2 (Thm 4)
//!         m_e = m_a·ag·top_k·S / (r2*·E)
//!         keep the best
//! ```
//!
//! Candidate evaluation goes through the discrete-event engine on the
//! materialized task DAG — the analytic closed forms of §4.2 coincide
//! with the engine on ASAS plans (pinned by
//! `rust/tests/simulator_vs_analytic.rs`), and the engine additionally
//! evaluates AASS exactly instead of by approximation.

use std::time::Instant;

use crate::config::{GroupSplit, ModelConfig, Testbed};
use crate::perfmodel::StageModels;
use crate::sched::{Order, Plan, PlanConfig};
use crate::simulator::engine::simulate;
use crate::solver::memory::MemoryModel;
use crate::util::stats::ternary_min_int;

/// A solver problem instance.
#[derive(Debug, Clone)]
pub struct Instance {
    pub model: ModelConfig,
    pub testbed: Testbed,
    pub split: GroupSplit,
    pub seq_len: usize,
}

impl Instance {
    pub fn new(model: ModelConfig, testbed: Testbed, split: GroupSplit, seq_len: usize) -> Self {
        Self { model, testbed, split, seq_len }
    }

    pub fn stage_models(&self) -> StageModels {
        StageModels::new(&self.model, &self.testbed, self.split, self.seq_len)
    }

    pub fn memory(&self) -> MemoryModel {
        MemoryModel::new(&self.model, &self.testbed, self.split, self.seq_len)
    }

    /// Evaluate one concrete configuration end-to-end (build plan +
    /// simulate), returning (makespan seconds, tokens/s).
    pub fn evaluate(&self, cfg: PlanConfig) -> (f64, f64) {
        let sm = self.stage_models();
        let plan = Plan::build(&sm, cfg, self.model.n_layers, self.split.ag, self.seq_len);
        let sim = simulate(&plan);
        (sim.makespan, sim.throughput_tokens(&plan))
    }
}

/// Search-space caps. `ma_cap` mirrors the paper's small per-GPU
/// micro-batch regime (Tables 3/4 sweep 1..4); `r1_cap`/`r2_cap` bound
/// the pipeline degrees (launch overhead makes extreme degrees useless,
/// §2.3).
#[derive(Debug, Clone, Copy)]
pub struct SolverParams {
    pub ma_cap: usize,
    pub r1_cap: usize,
    pub r2_cap: usize,
}

impl Default for SolverParams {
    fn default() -> Self {
        // The paper's experimental regime sweeps m_a and r1 over 1..4
        // (Tables 3/4); activation working sets and latency SLOs bound
        // in-flight samples well before raw KV memory does.
        Self { ma_cap: 4, r1_cap: 4, r2_cap: 64 }
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct Solution {
    pub config: PlanConfig,
    pub makespan: f64,
    pub throughput_tokens: f64,
    /// Wall time the solver itself took (the paper's <1 s claim).
    pub solve_seconds: f64,
    /// Number of (m_a, r1, order, r2) evaluations performed.
    pub evals: usize,
}

/// Optimal r2 (and its makespan) for fixed (m_a, r1, order) via ternary
/// search over the convex-in-1/r2 objective. Returns (r2, m_e, makespan,
/// evals).
fn best_r2(
    inst: &Instance,
    sm: &StageModels,
    m_a: usize,
    r1: usize,
    order: Order,
    fuse_shared: bool,
    r2_cap: usize,
) -> (usize, f64, f64, usize) {
    let mut evals = 0usize;
    let mut eval = |r2: i64| -> f64 {
        evals += 1;
        let r2 = r2 as usize;
        let m_e = sm.m_e(m_a as f64, r2);
        let mut cfg = PlanConfig::findep(m_a, r1, r2, m_e, order);
        cfg.fuse_shared = fuse_shared;
        inst.evaluate(cfg).0
    };
    // m_e below one token per expert per part is degenerate; bound r2 so
    // that m_e >= 1.
    let max_r2 = ((sm.m_e(m_a as f64, 1)).floor() as usize).clamp(1, r2_cap);
    let (r2, makespan) = ternary_min_int(1, max_r2 as i64, &mut eval);
    let r2 = r2 as usize;
    (r2, sm.m_e(m_a as f64, r2), makespan, evals)
}

/// Algorithm 1 (offline mode): maximize throughput over
/// (m_a, r1, r2, m_e, order) subject to memory.
pub fn solve(inst: &Instance, params: &SolverParams) -> Option<Solution> {
    let t0 = Instant::now();
    let sm = inst.stage_models();
    let mem = inst.memory();
    let mut best: Option<Solution> = None;
    let mut evals = 0usize;
    let mut prev_r1 = usize::MAX;

    for m_a in (1..=params.ma_cap).rev() {
        let r1 = mem.get_max_r1(m_a, params.r1_cap);
        if r1 == 0 || r1 == prev_r1 {
            // Pareto-dominated: same r1 at a smaller m_a loses by Thm 1.
            continue;
        }
        prev_r1 = r1;
        for order in Order::both() {
            // With no shared expert both orders coincide; skip AASS.
            if !sm.has_shared && order == Order::Aass {
                continue;
            }
            let (r2, m_e, _ms, e) =
                best_r2(inst, &sm, m_a, r1, order, false, params.r2_cap);
            evals += e;
            let cfg = PlanConfig::findep(m_a, r1, r2, m_e, order);
            let (makespan, tput) = inst.evaluate(cfg);
            evals += 1;
            if best.as_ref().map_or(true, |b| tput > b.throughput_tokens) {
                best = Some(Solution {
                    config: cfg,
                    makespan,
                    throughput_tokens: tput,
                    solve_seconds: 0.0,
                    evals: 0,
                });
            }
        }
    }
    best.map(|mut b| {
        b.solve_seconds = t0.elapsed().as_secs_f64();
        b.evals = evals;
        b
    })
}

/// Online mode (§5.5): the batch is fixed by what arrived (total
/// `samples_per_gpu` samples per AG GPU); adapt `r1` (divisors of the
/// per-GPU batch), `r2`, and the order, with (ag, eg) pinned.
pub fn solve_online(
    inst: &Instance,
    samples_per_gpu: usize,
    params: &SolverParams,
) -> Option<Solution> {
    let t0 = Instant::now();
    let sm = inst.stage_models();
    let mem = inst.memory();
    if samples_per_gpu == 0 || mem.max_samples_per_ag_gpu() < samples_per_gpu {
        return None;
    }
    let mut best: Option<Solution> = None;
    let mut evals = 0usize;
    for r1 in 1..=params.r1_cap.min(samples_per_gpu) {
        if samples_per_gpu % r1 != 0 {
            continue;
        }
        let m_a = samples_per_gpu / r1;
        for order in Order::both() {
            if !sm.has_shared && order == Order::Aass {
                continue;
            }
            let (r2, m_e, _ms, e) =
                best_r2(inst, &sm, m_a, r1, order, false, params.r2_cap);
            evals += e;
            let cfg = PlanConfig::findep(m_a, r1, r2, m_e, order);
            let (makespan, tput) = inst.evaluate(cfg);
            evals += 1;
            if best.as_ref().map_or(true, |b| tput > b.throughput_tokens) {
                best = Some(Solution {
                    config: cfg,
                    makespan,
                    throughput_tokens: tput,
                    solve_seconds: 0.0,
                    evals: 0,
                });
            }
        }
    }
    best.map(|mut b| {
        b.solve_seconds = t0.elapsed().as_secs_f64();
        b.evals = evals;
        b
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst_deepseek(tb: Testbed) -> Instance {
        Instance::new(ModelConfig::deepseek_v2(8), tb, GroupSplit::new(3, 5), 2048)
    }

    fn inst_qwen(tb: Testbed) -> Instance {
        Instance::new(ModelConfig::qwen3_moe(12), tb, GroupSplit::new(4, 4), 2048)
    }

    #[test]
    fn solves_all_testbeds_quickly() {
        for tb in Testbed::all() {
            let inst = inst_deepseek(tb.clone());
            let sol = solve(&inst, &SolverParams::default()).expect("feasible");
            assert!(sol.throughput_tokens > 0.0);
            assert!(sol.solve_seconds < 1.0, "solver too slow: {}s", sol.solve_seconds);
            assert!(sol.config.r1 >= 1 && sol.config.r2 >= 1);
        }
    }

    #[test]
    fn qwen_without_shared_solves() {
        let sol = solve(&inst_qwen(Testbed::b()), &SolverParams::default()).unwrap();
        assert!(!sol.config.fuse_shared);
        assert!(sol.throughput_tokens > 0.0);
    }

    #[test]
    fn solution_beats_naive_and_trivial_configs() {
        let inst = inst_deepseek(Testbed::a());
        let sol = solve(&inst, &SolverParams::default()).unwrap();
        let sm = inst.stage_models();
        let naive = inst.evaluate(PlanConfig::naive(1, sm.m_e(1.0, 1))).1;
        assert!(
            sol.throughput_tokens >= naive,
            "solver {} < naive {}",
            sol.throughput_tokens,
            naive
        );
    }

    #[test]
    fn online_respects_batch() {
        let inst = inst_deepseek(Testbed::a());
        let sol = solve_online(&inst, 8, &SolverParams::default()).unwrap();
        assert_eq!(sol.config.m_a * sol.config.r1, 8);
        // Huge batches that don't fit must be rejected.
        assert!(solve_online(&inst, 10_000_000, &SolverParams::default()).is_none());
    }

    #[test]
    fn infeasible_split_returns_none() {
        // All experts on one 24 GB device: infeasible.
        let inst = Instance::new(
            ModelConfig::deepseek_v2(8),
            Testbed::b(),
            GroupSplit::new(7, 1),
            2048,
        );
        assert!(solve(&inst, &SolverParams::default()).is_none());
    }
}
