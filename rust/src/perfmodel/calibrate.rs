//! Micro-benchmark calibration (§5.2 / Fig. 7).
//!
//! The paper runs ~2 minutes of GEMM / attention / transfer
//! micro-benchmarks, fits α-β models by least squares, and reports R².
//! This module does the same against *this* machine: the GEMM and
//! attention probes execute real HLO through the PJRT CPU client (see
//! `runtime::probe`), the transfer probe measures memcpy-through-channel
//! time. The resulting `CompModels` drive the real-execution coordinator;
//! the simulator's testbed models use the analytic constants in
//! `config::cluster` instead.

use std::fmt;
use std::time::Instant;

use crate::perfmodel::{CompModels, LinearModel};
use crate::util::stats;

/// A single calibration observation: workload and measured seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub workload: f64,
    pub seconds: f64,
}

/// Error from fitting, validating, or persisting calibration data — a
/// degenerate probe run must surface here, loudly, instead of producing
/// NaN/∞ coefficients that would panic in `LinearModel::new` or
/// silently poison a profile-driven solve.
#[derive(Debug, Clone)]
pub struct CalibrationError {
    msg: String,
}

impl CalibrationError {
    pub fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl fmt::Display for CalibrationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "calibration error: {}", self.msg)
    }
}

impl std::error::Error for CalibrationError {}

/// Fit an α-β model from samples, returning (model, R²). Errors on
/// degenerate inputs (fewer than 2 samples, zero workload variance,
/// non-finite measurements) — the strictness the profile validation
/// layer builds on.
pub fn fit(samples: &[Sample]) -> Result<(LinearModel, f64), CalibrationError> {
    let x: Vec<f64> = samples.iter().map(|s| s.workload).collect();
    let y: Vec<f64> = samples.iter().map(|s| s.seconds).collect();
    LinearModel::try_fit(&x, &y).map_err(|e| {
        CalibrationError::new(format!("cannot fit α-β model from {} samples: {e}", samples.len()))
    })
}

/// Measure `f` with `warmup` throwaway runs and `trials` timed runs,
/// returning the median time — the paper uses 10 warmup + 20 stats runs
/// per point (§5.2); callers pick their own counts.
pub fn measure<F: FnMut()>(warmup: usize, trials: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(trials);
    for _ in 0..trials {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    stats::percentile(&times, 50.0)
}

/// Calibrate a host-side "transfer" model by timing payload copies of
/// increasing size through a channel handshake (our A2E/E2A link
/// substrate). Returns (model, R², samples).
///
/// Setup stays out of the timed region: the channel is built once for
/// the whole calibration and source/destination buffers are
/// pre-allocated per size — the measured closure performs only the
/// payload copy (the link's β, bytes through memory) and the channel
/// send/recv round-trip (the link's α). The earlier version cloned the
/// source and constructed a fresh channel inside the timed closure, so
/// the fitted β mostly measured allocator throughput.
pub fn calibrate_copy_link(
    sizes: &[usize],
    warmup: usize,
    trials: usize,
) -> Result<(LinearModel, f64, Vec<Sample>), CalibrationError> {
    use std::sync::mpsc;
    let (tx, rx) = mpsc::channel::<usize>();
    let mut samples = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let src = vec![1.0f32; n / 4];
        let mut dst = vec![0.0f32; n / 4];
        let seconds = measure(warmup, trials, || {
            dst.copy_from_slice(&src);
            tx.send(n).unwrap();
            assert_eq!(rx.recv().unwrap(), n);
            std::hint::black_box(&dst);
        });
        samples.push(Sample { workload: n as f64, seconds });
    }
    let (m, r2) = fit(&samples)?;
    Ok((m, r2, samples))
}

/// Build component models from three fitted pieces.
pub fn comp_models(gemm: LinearModel, attn: LinearModel, comm: LinearModel) -> CompModels {
    CompModels { gemm, attn, comm }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_synthetic_alpha_beta() {
        let samples: Vec<Sample> = (1..40)
            .map(|i| {
                let w = i as f64 * 1e6;
                Sample { workload: w, seconds: 2e-5 + 1e-12 * w }
            })
            .collect();
        let (m, r2) = fit(&samples).unwrap();
        assert!((m.alpha - 2e-5).abs() < 1e-9);
        assert!((m.beta - 1e-12).abs() < 1e-16);
        assert!(r2 > 0.999999, "r2={r2}");
    }

    #[test]
    fn fit_rejects_degenerate_calibration_inputs() {
        // Too few points.
        assert!(fit(&[]).is_err());
        assert!(fit(&[Sample { workload: 1e6, seconds: 1e-3 }]).is_err());
        // Zero-variance workloads: every probe ran the same shape.
        let flat: Vec<Sample> =
            (0..5).map(|i| Sample { workload: 4096.0, seconds: 1e-3 + i as f64 * 1e-5 }).collect();
        assert!(fit(&flat).is_err());
        // A non-finite measurement (e.g. a timer bug) must not fit.
        let nan = vec![
            Sample { workload: 1e6, seconds: 1e-3 },
            Sample { workload: 2e6, seconds: f64::NAN },
        ];
        assert!(fit(&nan).is_err());
    }

    #[test]
    fn measure_returns_positive_median() {
        let mut x = 0u64;
        let t = measure(2, 5, || {
            for i in 0..10_000u64 {
                x = x.wrapping_add(i);
            }
        });
        assert!(t > 0.0);
        assert!(x > 0);
    }

    #[test]
    fn copy_link_calibration_is_monotone_enough() {
        // Small sizes to stay fast; we only check the fit is usable and
        // the CLI trial count is honored (3 warmup + 5 timed here).
        let (m, _r2, samples) =
            calibrate_copy_link(&[1 << 12, 1 << 14, 1 << 16, 1 << 18], 3, 5).unwrap();
        assert_eq!(samples.len(), 4);
        assert!(m.beta >= 0.0);
        assert!(m.alpha >= 0.0);
    }
}
