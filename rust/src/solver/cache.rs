//! Memoized online planning (§5.5 at serving rate).
//!
//! The online-adaptive mode re-solves the schedule per batch, but a
//! serving stream repeats a small set of shapes: the same sequence
//! bucket and padded batch size arrive over and over. [`PlanCache`]
//! memoizes [`Solution`]s per `(seq-len bucket, batch-size bucket)`
//! key, so the solver runs once per *shape* instead of once per
//! *batch* — a cache hit is a map lookup, three-plus orders of
//! magnitude cheaper than even the sub-millisecond re-solve.
//!
//! Infeasible shapes are cached too (as `None`): a batch the testbed
//! cannot hold would otherwise re-run the whole feasibility walk on
//! every arrival.
//!
//! The cache is shared across serving workers (`Arc<PlanCache>`); the
//! map lock is held across a miss's solve on purpose, so concurrent
//! workers hitting the same cold shape wait for one solve instead of
//! duplicating it.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::Phase;
use crate::perfmodel::profile::ProfileId;
use crate::solver::Solution;

/// Round up to the next power of two — the shape-bucketing used for
/// arbitrary online shapes (a 2-approximation keyspace keeps the cache
/// small under lognormal prompt lengths and token-by-token KV growth).
pub fn bucket_up(x: usize) -> usize {
    x.max(1).next_power_of_two()
}

/// A plan-cache key: serving phase + sequence bucket + batch bucket +
/// the identity of the constants the plan was solved against.
/// The phase is part of the identity, so a prefill plan and a decode
/// plan of numerically identical `(seq, batch)` can never alias — they
/// are solved against different stage models (the decode variant also
/// carries its KV bucket inside [`Phase::Decode`]). The profile
/// fingerprint is part of the identity for the same reason: a plan
/// solved against a calibration profile's measured constants must
/// never be returned for the hand-constant keyspace (or another
/// profile's), no matter how the shapes coincide — switching profiles
/// can never alias plans.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct ShapeKey {
    pub phase: Phase,
    pub seq: usize,
    pub batch: usize,
    /// [`ProfileId::HAND`] for the hand-written Table-2 constants,
    /// otherwise the calibration profile's fingerprint.
    pub profile: ProfileId,
}

impl ShapeKey {
    /// Exact-valued prefill key (serving paths with exact padded
    /// capacities — the coordinator pads to `r1 · m_a` — key on those
    /// directly). Keys the hand-constant keyspace; chain
    /// [`ShapeKey::with_profile`] for a calibrated one.
    pub fn prefill(seq: usize, batch: usize) -> Self {
        Self { phase: Phase::Prefill, seq, batch, profile: ProfileId::HAND }
    }

    /// Decode key with the KV length bucketed: the cache stays small
    /// while KV grows token by token, and one plan (solved at the
    /// bucket ceiling, i.e. conservatively) serves the whole bucket.
    pub fn decode(kv_len: usize, batch: usize) -> Self {
        Self {
            phase: Phase::Decode { kv_len: bucket_up(kv_len) },
            seq: 1,
            batch,
            profile: ProfileId::HAND,
        }
    }

    /// Re-key onto a calibration profile's keyspace.
    pub fn with_profile(mut self, profile: ProfileId) -> Self {
        self.profile = profile;
        self
    }
}

/// Cache key for an arbitrary online prefill `(seq_len, batch)` shape.
pub fn shape_key(seq_len: usize, batch: usize) -> ShapeKey {
    ShapeKey::prefill(bucket_up(seq_len), bucket_up(batch))
}

/// Cache key for an online decode `(kv_len, batch)` shape.
pub fn shape_key_decode(kv_len: usize, batch: usize) -> ShapeKey {
    ShapeKey::decode(kv_len, bucket_up(batch))
}

/// Memoized `ShapeKey -> Solution` store.
#[derive(Debug, Default)]
pub struct PlanCache {
    map: Mutex<BTreeMap<ShapeKey, Option<Solution>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Return the memoized solution for `key`, running `solve` exactly
    /// once per key on a miss (a `None` result is memoized as
    /// infeasible).
    pub fn get_or_solve(
        &self,
        key: ShapeKey,
        solve: impl FnOnce() -> Option<Solution>,
    ) -> Option<Solution> {
        let mut map = self.map.lock().unwrap();
        if let Some(cached) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return cached.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let solved = solve();
        map.insert(key, solved.clone());
        solved
    }

    /// Cached solution without solving (`None` = never solved; a cached
    /// infeasible shape reads back as `Some(None)`).
    pub fn peek(&self, key: ShapeKey) -> Option<Option<Solution>> {
        self.map.lock().unwrap().get(&key).cloned()
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of memoized shapes (feasible and infeasible).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every memoized shape (testbed constants changed).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};
    use crate::solver::{solve_online, Instance, SolverParams};

    fn paper_instance() -> Instance {
        Instance::new(ModelConfig::deepseek_v2(8), Testbed::a(), GroupSplit::new(3, 5), 2048)
    }

    #[test]
    fn bucketing_rounds_up_to_powers_of_two() {
        assert_eq!(bucket_up(0), 1);
        assert_eq!(bucket_up(1), 1);
        assert_eq!(bucket_up(5), 8);
        assert_eq!(bucket_up(8), 8);
        assert_eq!(shape_key(3000, 6), ShapeKey::prefill(4096, 8));
        assert_eq!(
            shape_key_decode(3000, 6),
            ShapeKey {
                phase: Phase::Decode { kv_len: 4096 },
                seq: 1,
                batch: 8,
                profile: ProfileId::HAND,
            }
        );
    }

    #[test]
    fn profiles_key_separate_plans() {
        // The same shape under different constant identities must be
        // distinct cache entries: a calibrated solve can never serve
        // (or be served by) the hand-constant keyspace.
        let cache = PlanCache::new();
        let params = SolverParams::default();
        let hand_key = ShapeKey::prefill(2048, 8);
        let cal_key = hand_key.with_profile(ProfileId(0x5eed));
        assert_ne!(hand_key, cal_key);
        let _ = cache.get_or_solve(hand_key, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 1);
        let _ = cache.get_or_solve(cal_key, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 2, "calibrated shape must not hit the hand entry");
        assert_eq!(cache.len(), 2);
        let _ = cache.get_or_solve(hand_key, || panic!("hand key must hit"));
        let _ = cache.get_or_solve(cal_key, || panic!("calibrated key must hit"));
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn solves_once_per_shape() {
        let cache = PlanCache::new();
        let mut solves = 0usize;
        for _ in 0..5 {
            let sol = cache.get_or_solve(ShapeKey::prefill(2048, 8), || {
                solves += 1;
                solve_online(&paper_instance(), 8, &SolverParams::default())
            });
            assert!(sol.is_some());
        }
        assert_eq!(solves, 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 4);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cached_solution_matches_fresh_solve() {
        let cache = PlanCache::new();
        let inst = paper_instance();
        let params = SolverParams::default();
        let fresh = solve_online(&inst, 8, &params).unwrap();
        let cached = cache
            .get_or_solve(ShapeKey::prefill(2048, 8), || solve_online(&inst, 8, &params))
            .unwrap();
        let hit = cache
            .get_or_solve(ShapeKey::prefill(2048, 8), || panic!("must not re-solve"))
            .unwrap();
        assert_eq!(fresh.config, cached.config);
        assert_eq!(fresh.config, hit.config);
        assert_eq!(fresh.throughput_tokens, hit.throughput_tokens);
    }

    #[test]
    fn prefill_and_decode_keys_never_alias() {
        // Numerically identical (seq, batch) values under different
        // phases are distinct cache entries: the decode solve must run
        // even though the prefill shape is already memoized (and vice
        // versa), and each phase's hit returns its own plan.
        let cache = PlanCache::new();
        let params = SolverParams::default();
        let pre_inst = paper_instance();
        let dec_inst = Instance::decode(
            ModelConfig::deepseek_v2(8),
            Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        );
        let pre_key = ShapeKey::prefill(1, 8);
        let dec_key = ShapeKey::decode(1, 8);
        assert_ne!(pre_key, dec_key, "phase must be part of the key identity");
        let pre = cache.get_or_solve(pre_key, || solve_online(&pre_inst, 8, &params)).unwrap();
        assert_eq!(cache.misses(), 1);
        let dec = cache.get_or_solve(dec_key, || solve_online(&dec_inst, 8, &params)).unwrap();
        assert_eq!(cache.misses(), 2, "decode shape must not hit the prefill entry");
        assert_eq!(cache.len(), 2);
        // Hits stay phase-local and return the phase's own plan.
        let pre_hit = cache.get_or_solve(pre_key, || panic!("prefill must hit")).unwrap();
        let dec_hit = cache.get_or_solve(dec_key, || panic!("decode must hit")).unwrap();
        assert_eq!(pre.config, pre_hit.config);
        assert_eq!(dec.config, dec_hit.config);
        assert_eq!(cache.hits(), 2);
        // Decode KV buckets key separate plans too.
        let far_key = ShapeKey::decode(100_000, 8);
        assert_ne!(far_key, dec_key);
    }

    #[test]
    fn infeasible_shapes_are_memoized() {
        let cache = PlanCache::new();
        let inst = paper_instance();
        let params = SolverParams::default();
        let mut solves = 0usize;
        for _ in 0..3 {
            let sol = cache.get_or_solve(shape_key(2048, 10_000_000), || {
                solves += 1;
                solve_online(&inst, 10_000_000, &params)
            });
            assert!(sol.is_none());
        }
        assert_eq!(solves, 1);
        assert_eq!(cache.peek(shape_key(2048, 10_000_000)), Some(None));
        cache.clear();
        assert!(cache.is_empty());
        assert!(cache.peek(shape_key(2048, 10_000_000)).is_none());
    }
}
