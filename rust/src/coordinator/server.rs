//! The serving front-end: plan selection + batch assembly + pipeline
//! execution + metrics. This is the binary's `serve` path and the
//! examples' entry point; [`crate::coordinator::batcher`] stacks the
//! continuous-batching queue on top of it.
//!
//! ## Serving hot path
//!
//! * **Planning** — `Policy::Adaptive` re-solves per *shape*, not per
//!   batch: the padded capacity `r1·m_a` is the batch-size bucket of a
//!   [`PlanCache`] key, a hit skips the solver entirely, and a miss
//!   runs [`solver::solve_online_bucketed`] (Algorithm 1's online mode
//!   restricted to compiled attention buckets), falling back to the
//!   fixed-`(m_a, r1)` brute-force only if the online solver reports
//!   the shape infeasible.
//! * **Assembly** — the padded `[B, S, M]` batch tensor is rewritten in
//!   place inside a [`BatchBuffers`] arena (PR 1's `PlanBuffers`
//!   pattern applied to serving): steady-state assembly performs no
//!   heap allocation. Responses are the ownership hand-off boundary and
//!   stay owned copies.
//! * **Oversize batches** — `serve_batch` splits a batch that exceeds
//!   the policy's capacity into capacity-sized chunks and stitches the
//!   responses back in request order; set [`Server::strict`] to restore
//!   the pre-queue "split upstream" error.

use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{
    Cluster, ClusterId, ExpertLoad, ExpertPlacement, GroupSplit, Phase, PlacementId, Testbed,
};
use crate::coordinator::faults::{FaultAction, FaultPlan};
use crate::coordinator::links::LinkDelay;
use crate::coordinator::moe::ModelHandle;
use crate::coordinator::router::{ExpertStats, Routing};
use crate::coordinator::slo::SloPolicy;
use crate::coordinator::pipeline::{ExecConfig, ForwardStats, Pipeline};
use crate::metrics::Registry;
use crate::perfmodel::profile::{CalibrationProfile, ProfileId};
use crate::runtime::tensor::Tensor;
use crate::sched::Order;
use crate::solver::{
    self, bucket_up, EvalMode, Evaluator, Instance, PlanCache, RefineToken, ShapeKey, Solution,
    SolverParams, WarmStart,
};

/// One embedded request: hidden states for a fixed-S prompt (embedding
/// lookup is out of scope for the tiny model; requests arrive as
/// `[S, M]` activations).
///
/// The phase drives *planning and accounting*, not artifact shapes: a
/// decode step still executes through the fixed-S compiled stages (the
/// tiny model is the numerics emulator), but it is scheduled under the
/// decode-phase plan — solved against the emulated testbed where
/// attention is KV-read-bound and experts see one token per sample —
/// and it counts one generated token.
#[derive(Debug, Clone)]
pub struct EmbeddedRequest {
    pub id: u64,
    pub hidden: Tensor, // [S, M]
    /// Prefill, or one autoregressive step against `kv_len` cached
    /// entries.
    pub phase: Phase,
    /// Decode steps still to run after this pass (continuous-batching
    /// re-entry in the batcher); 0 = this pass is the last.
    pub output_len: usize,
    /// Absolute response deadline. `None` (the default) = wait forever.
    /// With a deadline set, admission control sheds the request at
    /// submit when the estimated queue wait already exceeds it, and
    /// assembly fails it fast once it has expired in the queue.
    pub deadline: Option<Instant>,
}

impl EmbeddedRequest {
    /// Deterministic synthetic request.
    pub fn synthetic(id: u64, s: usize, m: usize) -> Self {
        let data: Vec<f32> = (0..s * m)
            .map(|i| {
                let x = (i as u64).wrapping_mul(2654435761).wrapping_add(id * 97);
                ((x % 199) as f32 - 99.0) * 0.005
            })
            .collect();
        Self {
            id,
            hidden: Tensor::new(vec![s, m], data),
            phase: Phase::Prefill,
            output_len: 0,
            deadline: None,
        }
    }

    /// Synthetic autoregressive request: prefill now, `output_len`
    /// decode steps to follow.
    pub fn synthetic_autoregressive(id: u64, s: usize, m: usize, output_len: usize) -> Self {
        let mut r = Self::synthetic(id, s, m);
        r.output_len = output_len;
        r
    }

    /// Attach an absolute response deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Whether the deadline (if any) has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Result for one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub hidden: Tensor,
    /// Seconds from serve/enqueue to this response. Direct
    /// `serve_batch` calls measure from call entry (all requests of a
    /// chunk share the chunk's completion time); the batcher rewrites
    /// this with the true enqueue→response time per request.
    pub latency_s: f64,
}

/// Scheduling policy for batch execution.
#[derive(Debug, Clone, Copy)]
pub enum Policy {
    Naive,
    PpPipe { r1: usize },
    FinDep { r1: usize, r2: usize, order: Order },
    /// Solve per batch shape with Algorithm 1's online mode against an
    /// emulated testbed (the online-adaptive mode of §5.5), memoized in
    /// the plan cache.
    Adaptive,
}

/// Reusable batch-assembly arena: the padded `[B, S, M]` input tensor
/// is rewritten in place per batch. The backing buffer only ever
/// grows, so at a stable serving shape assembly touches no allocator —
/// `benches/serving_speed.rs` pins this (stable data pointer across
/// steady-state batches) and measures it against the allocating
/// baseline.
#[derive(Debug)]
pub struct BatchBuffers {
    batch: Tensor,
}

impl Default for BatchBuffers {
    fn default() -> Self {
        Self::new()
    }
}

impl BatchBuffers {
    pub fn new() -> Self {
        Self { batch: Tensor::zeros(vec![0, 0, 0]) }
    }

    /// Assemble the padded `[b_total, s, m]` batch in place: request
    /// rows first, zero padding after. Requests beyond `b_total` are
    /// ignored (callers chunk upstream).
    pub fn assemble(
        &mut self,
        reqs: &[EmbeddedRequest],
        b_total: usize,
        s: usize,
        m: usize,
    ) -> &Tensor {
        let w = s * m;
        let n = reqs.len().min(b_total);
        let t = &mut self.batch;
        t.shape.clear();
        t.shape.extend_from_slice(&[b_total, s, m]);
        t.data.resize(b_total * w, 0.0);
        for (i, r) in reqs.iter().take(n).enumerate() {
            t.data[i * w..(i + 1) * w].copy_from_slice(&r.hidden.data);
        }
        for v in &mut t.data[n * w..] {
            *v = 0.0;
        }
        &self.batch
    }

    /// The seed's allocate-per-batch assembly, kept as the measured
    /// baseline for `benches/serving_speed.rs` (the same role
    /// `EvalMode::AllocPerCandidate` plays for the solver).
    pub fn assemble_alloc(
        reqs: &[EmbeddedRequest],
        b_total: usize,
        s: usize,
        m: usize,
    ) -> Tensor {
        let mut data = Vec::with_capacity(b_total * s * m);
        for r in reqs.iter().take(b_total) {
            data.extend_from_slice(&r.hidden.data);
        }
        for _ in reqs.len().min(b_total)..b_total {
            data.extend(std::iter::repeat(0.0).take(s * m));
        }
        Tensor::new(vec![b_total, s, m], data)
    }

    /// Backing-buffer identity — the steady-state no-allocation probe.
    pub fn as_ptr(&self) -> *const f32 {
        self.batch.data.as_ptr()
    }

    pub fn capacity(&self) -> usize {
        self.batch.data.capacity()
    }
}

/// The DEP server.
pub struct Server {
    pub pipeline: Pipeline,
    pub metrics: Arc<Registry>,
    /// Emulated testbed used by the Adaptive policy's solver (the tiny
    /// model's real CPU constants would make every schedule look alike;
    /// the solver plans against the testbed the deployment targets).
    /// Private on purpose: its constants and `plan_profile` must move
    /// together — every mutation goes through
    /// [`Server::set_calibration_profile`], otherwise a swapped testbed
    /// would keep hitting plans cached under the old constants.
    plan_testbed: Testbed,
    /// The cluster the Adaptive planner actually solves against — by
    /// default the single-pool wrapping of `plan_testbed` (bit-identical
    /// to the legacy Testbed path), swapped to a heterogeneous pool
    /// layout via [`Server::set_cluster`]. Private for the same reason
    /// as `plan_testbed`: it must move together with
    /// `plan_cluster_id`, the cache-key identity of its constants.
    plan_cluster: Cluster,
    /// Cache-key identity of `plan_cluster` ([`ClusterId::SINGLE`] for
    /// the default single-pool layout, the cluster fingerprint
    /// otherwise) — plans solved under different pool shapes can never
    /// alias.
    plan_cluster_id: ClusterId,
    /// The expert→EG-shard placement the Adaptive planner prices expert
    /// stages and expert-pool memory under. Defaults to the uniform
    /// placement (bit-identical to the legacy uniform-expert pricing);
    /// swapped via [`Server::set_expert_placement`] or the drift-driven
    /// [`Server::resolve_placement_if_drifted`]. Private: it must move
    /// together with `plan_load` and `plan_placement_id`.
    plan_placement: ExpertPlacement,
    /// The per-expert relative load `plan_placement` was solved for —
    /// the baseline that routed-traffic drift is measured against.
    plan_load: ExpertLoad,
    /// Cache-key identity of `plan_placement`
    /// ([`PlacementId::UNIFORM`] for the default) — plans priced under
    /// different placements can never alias.
    plan_placement_id: PlacementId,
    /// EWMA histogram of observed per-expert routing shares — shared
    /// with the pipeline, whose forward pass feeds every routed
    /// layer-chunk in; [`Server::observe_routing`] folds in external
    /// routings (e.g. the simulator's), and
    /// [`Server::resolve_placement_if_drifted`] compares the histogram
    /// to `plan_load`.
    expert_stats: Arc<Mutex<ExpertStats>>,
    /// Optional TTFT/TPOT targets: when set, prefill/decode plan
    /// solves carry the matching target as Algorithm 1's
    /// `max_makespan` cap, so the planner optimizes goodput-under-SLO
    /// instead of raw throughput. Set via [`Server::set_slo`] (which
    /// clears the plan cache — cached plans were solved uncapped).
    slo: Option<SloPolicy>,
    pub plan_split: GroupSplit,
    /// Memoize Adaptive plans per shape (disable to re-solve every
    /// batch — the cold-solve baseline of `benches/serving_speed.rs`).
    pub cache_plans: bool,
    /// Pre-queue behaviour: error on batches beyond capacity instead of
    /// splitting them into chunks.
    pub strict: bool,
    /// Identity of the constants `plan_testbed` carries — part of
    /// every plan-cache key, so plans solved against different
    /// calibration profiles (or the hand constants) can never alias
    /// even though workers share one cache.
    plan_profile: ProfileId,
    solver_params: SolverParams,
    plan_cache: Arc<PlanCache>,
    batch_buf: Mutex<BatchBuffers>,
    /// Reusable per-replica candidate evaluator: every Adaptive solve
    /// on this server shares one probe arena + engine topology cache
    /// instead of rebuilding them per shape
    /// (`benches/solver_speed.rs` pins the allocation drop). Lazily
    /// built on the first solve, re-targeted per instance.
    solve_evaluator: Mutex<Option<Evaluator>>,
    /// Online-solve latency budget, passed to the solver as its
    /// anytime budget: a solve that runs over it returns its best
    /// incumbent (flagged non-exhaustive) instead of finishing the
    /// sweep, counts `solver_budget_exceeded`, and — with
    /// [`Server::refine_plans`] — hands the rest of the sweep to a
    /// background refinement pass. `None` (the default) never
    /// truncates.
    pub solve_budget: Option<Duration>,
    /// Finish budget-truncated cached plans off the hot path: a
    /// non-exhaustive solve spawns a background full re-solve (warm
    /// from the incumbent) that atomically publishes the exhaustive
    /// plan into the same cache generation it was solved for
    /// (`plans_refined`); a generation cleared in between discards the
    /// publish. On by default; only observable with a budget set.
    pub refine_plans: bool,
}

impl Server {
    pub fn new(model: ModelHandle, eg: usize, link_delay: Option<LinkDelay>) -> Result<Server> {
        Self::with_shared(
            model,
            eg,
            link_delay,
            Arc::new(Registry::new()),
            Arc::new(PlanCache::new()),
        )
    }

    /// Construct a server sharing metrics and the plan cache with its
    /// siblings — the batcher's worker replicas all point at one
    /// registry and one cache, so a shape solved on any worker is a hit
    /// on every other.
    pub fn with_shared(
        model: ModelHandle,
        eg: usize,
        link_delay: Option<LinkDelay>,
        metrics: Arc<Registry>,
        plan_cache: Arc<PlanCache>,
    ) -> Result<Server> {
        let plan_testbed = Testbed::a();
        let plan_cluster = Cluster::single_pool(&plan_testbed);
        let plan_split = GroupSplit::new(1, eg);
        let pipeline = Pipeline::new(model, eg, link_delay)?;
        let n_experts = pipeline.model().model.n_experts;
        let expert_stats = Arc::clone(pipeline.expert_stats());
        Ok(Server {
            pipeline,
            metrics,
            plan_testbed,
            plan_cluster,
            plan_cluster_id: ClusterId::SINGLE,
            plan_placement: ExpertPlacement::uniform(n_experts, eg),
            plan_load: ExpertLoad::uniform(n_experts),
            plan_placement_id: PlacementId::UNIFORM,
            expert_stats,
            slo: None,
            plan_split,
            cache_plans: true,
            strict: false,
            plan_profile: ProfileId::HAND,
            solver_params: SolverParams { ma_cap: 4, r1_cap: 4, r2_cap: 8, ..Default::default() },
            plan_cache,
            batch_buf: Mutex::new(BatchBuffers::new()),
            solve_evaluator: Mutex::new(None),
            solve_budget: None,
            refine_plans: true,
        })
    }

    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plan_cache
    }

    /// Drive the Adaptive planner with a calibration profile's measured
    /// constants: the plan testbed's component constants are replaced
    /// via [`Testbed::from_profile`] (cluster topology kept), and every
    /// subsequent plan-cache key carries the profile's fingerprint —
    /// cached hand-constant plans stay keyed under [`ProfileId::HAND`],
    /// so switching profiles can never alias plans.
    pub fn set_calibration_profile(&mut self, profile: &CalibrationProfile) {
        self.plan_testbed = Testbed::from_profile(&self.plan_testbed, profile);
        self.plan_cluster = Cluster::from_profile(&self.plan_cluster, profile);
        self.plan_profile = profile.fingerprint();
    }

    /// The constant-identity the planner keys its cache entries with.
    pub fn plan_profile(&self) -> ProfileId {
        self.plan_profile
    }

    /// The testbed the Adaptive planner currently solves against
    /// (read-only — see [`Server::set_calibration_profile`]).
    pub fn plan_testbed(&self) -> &Testbed {
        &self.plan_testbed
    }

    /// Plan against an explicit cluster layout (heterogeneous pools,
    /// per-pool constants, cross-pool M2N). Every subsequent plan-cache
    /// key carries the cluster's fingerprint, and the cache is cleared:
    /// cached plans were solved against the old layout. The legacy
    /// single-pool default keeps keying under [`ClusterId::SINGLE`]
    /// (this setter is the only way off it).
    pub fn set_cluster(&mut self, cluster: Cluster) {
        self.plan_cluster_id = cluster.fingerprint();
        self.plan_cluster = cluster;
        self.plan_cache.clear();
    }

    /// The cluster the Adaptive planner currently solves against
    /// (read-only — see [`Server::set_cluster`]).
    pub fn plan_cluster(&self) -> &Cluster {
        &self.plan_cluster
    }

    /// The cluster-identity the planner keys its cache entries with.
    pub fn plan_cluster_id(&self) -> ClusterId {
        self.plan_cluster_id
    }

    /// Plan against an explicit expert placement and the per-expert
    /// load it was solved for. Every subsequent plan-cache key carries
    /// the placement's fingerprint, and the cache is cleared: cached
    /// plans priced expert stages and expert-pool memory under the old
    /// placement. The uniform default keeps keying under
    /// [`PlacementId::UNIFORM`] (bit-identical to the legacy pricing).
    pub fn set_expert_placement(&mut self, placement: ExpertPlacement, load: ExpertLoad) {
        let n_experts = self.pipeline.model().model.n_experts;
        assert_eq!(placement.n_experts(), n_experts, "placement/model expert count mismatch");
        assert_eq!(placement.n_shards(), self.plan_split.eg, "placement/split shard mismatch");
        assert_eq!(load.n_experts(), n_experts, "load/model expert count mismatch");
        self.plan_placement_id = placement.fingerprint();
        self.plan_placement = placement;
        self.plan_load = load;
        self.plan_cache.clear();
    }

    /// The expert placement the planner currently prices under
    /// (read-only — see [`Server::set_expert_placement`]).
    pub fn plan_placement(&self) -> &ExpertPlacement {
        &self.plan_placement
    }

    /// The per-expert load the current placement was solved for.
    pub fn plan_load(&self) -> &ExpertLoad {
        &self.plan_load
    }

    /// The placement-identity the planner keys its cache entries with.
    pub fn plan_placement_id(&self) -> PlacementId {
        self.plan_placement_id
    }

    /// Fold one routed batch into the server's expert-popularity EWMA
    /// (called from the serving loop; cheap, lock + O(assignments)).
    pub fn observe_routing(&self, routing: &Routing) {
        self.expert_stats.lock().unwrap_or_else(PoisonError::into_inner).observe(routing);
    }

    /// The observed per-expert relative load (uniform until routed
    /// batches have been observed).
    pub fn observed_expert_load(&self) -> ExpertLoad {
        self.expert_stats.lock().unwrap_or_else(PoisonError::into_inner).observed_load()
    }

    /// L∞ distance between the observed expert load and the load the
    /// current placement was solved for (in relative-load units: 0.5
    /// means some expert drifted by half the uniform share).
    pub fn placement_drift(&self) -> f64 {
        self.observed_expert_load().linf_drift(&self.plan_load)
    }

    /// Drift-driven placement re-solve: when the observed expert load
    /// has drifted more than `threshold` (L∞, relative-load units) from
    /// the load the current placement was priced under, re-run the
    /// replication search ([`solver::search_replication`], warm-pruned)
    /// against the observed load and adopt the winner. Returns `true`
    /// when a new placement was installed (which clears the plan
    /// cache). Cheap when quiescent: a single histogram read and an L∞
    /// scan.
    pub fn resolve_placement_if_drifted(&mut self, threshold: f64) -> bool {
        let observed = self.observed_expert_load();
        if observed.linf_drift(&self.plan_load) <= threshold {
            return false;
        }
        let base = Instance::on_cluster(
            self.pipeline.model().model.clone(),
            self.plan_cluster.clone(),
            self.plan_split,
            self.pipeline.model().seq_len,
        );
        let params = solver::SearchParams {
            solver: self.solver_params,
            multi_replica: false,
            ..Default::default()
        };
        match solver::search_replication(&base, &observed, &params) {
            Some(rep) => {
                self.metrics.inc("placement_resolves", 1);
                self.set_expert_placement(rep.best.placement, observed);
                true
            }
            None => false,
        }
    }

    /// Install TTFT/TPOT targets: subsequent prefill solves are capped
    /// at the TTFT target, decode solves at the TPOT target
    /// (goodput-under-SLO planning). Clears the plan cache — cached
    /// plans were solved under the previous (or no) cap, and the cap
    /// is not part of the shape key. `None` removes the targets.
    pub fn set_slo(&mut self, slo: Option<SloPolicy>) {
        if self.slo != slo {
            self.slo = slo;
            self.plan_cache.clear();
        }
    }

    /// The SLO policy in effect (read-only — see [`Server::set_slo`]).
    pub fn slo(&self) -> Option<SloPolicy> {
        self.slo
    }

    /// The solver parameters for one phase's plan solve: the shared
    /// caps plus, with an SLO installed, the phase's latency target as
    /// the makespan cap. With no SLO this is exactly
    /// `self.solver_params` — the capped path costs nothing when off.
    fn phase_params(&self, phase: Phase) -> SolverParams {
        let max_makespan = self.slo.and_then(|s| match phase {
            Phase::Prefill => s.ttft_s,
            Phase::Decode { .. } => s.tpot_s,
        });
        SolverParams { max_makespan, ..self.solver_params }
    }

    /// Re-pick the Adaptive policy's emulated (ag, eg) planning split:
    /// every split of the plan testbed (enumerated by the split-search
    /// solver layer; single instance — this server drives one pipeline
    /// replica) is scored under the *serving* objective — the exact
    /// per-shape solve the Adaptive path runs (`solve_online_bucketed`
    /// restricted to the compiled attention buckets, with the same
    /// brute-force fallback) at the largest capacity this server plans.
    /// Scoring offline instead (plain Algorithm 1) could adopt a split
    /// whose optimum needs an uncompiled `m_a`. Max capacity is a
    /// heuristic for the traffic mix: real batches also pad to smaller
    /// shapes, which only the stream itself can reveal. If no split
    /// yields a servable plan, the offline split search decides.
    /// Clears the plan cache when the split changes, since cached
    /// solutions were solved against the old split. Returns the split
    /// in effect afterwards.
    ///
    /// Splits are scored on the *prefill* serving solve: the split is
    /// picked once at startup, before the stream reveals its
    /// prefill/decode mix, and prefill is the phase whose throughput
    /// the split genuinely moves (decode plans collapse to `r2 = 1`
    /// and are KV-read-bound on the AG either way). Scoring by an
    /// observed traffic mix is future work.
    pub fn select_plan_split(&mut self) -> GroupSplit {
        let model = self.pipeline.model().model.clone();
        let seq = self.pipeline.model().seq_len;
        let capacity = self.solver_params.r1_cap * self.max_ma();
        let mut best: Option<(f64, GroupSplit)> = None;
        for cand in solver::enumerate_cluster_candidates(&self.plan_cluster, false) {
            if let Some(sol) = self.solve_shape_for_split(cand.split, capacity, Phase::Prefill) {
                if best.as_ref().map_or(true, |(t, _)| sol.throughput_tokens > *t) {
                    best = Some((sol.throughput_tokens, cand.split));
                }
            }
        }
        let split = match best {
            Some((_, s)) => Some(s),
            // No split serves the max shape: fall back to the offline
            // split search (pruned; only the winner is needed). The
            // cluster-aware search delegates to the exact legacy sweep
            // on the single-pool default.
            None => {
                let params = solver::SearchParams {
                    solver: self.solver_params,
                    multi_replica: false,
                    ..Default::default()
                };
                solver::search_cluster(&model, &self.plan_cluster, seq, Phase::Prefill, &params)
                    .map(|r| r.best.candidate.split)
            }
        };
        if let Some(split) = split {
            if split != self.plan_split {
                self.plan_split = split;
                // An explicit placement was solved for the old split's
                // shard count — fall back to uniform for the new one
                // (re-resolved on the next drift check).
                if self.plan_placement_id != PlacementId::UNIFORM {
                    let n_experts = self.pipeline.model().model.n_experts;
                    self.plan_placement = ExpertPlacement::uniform(n_experts, split.eg);
                    self.plan_load = ExpertLoad::uniform(n_experts);
                    self.plan_placement_id = PlacementId::UNIFORM;
                }
                self.plan_cache.clear();
            }
        }
        self.plan_split
    }

    /// Largest attention bucket (preferred m_a).
    fn max_ma(&self) -> usize {
        self.pipeline
            .model()
            .artifacts
            .manifest
            .ma_buckets
            .iter()
            .copied()
            .max()
            .unwrap_or(1)
    }

    /// Most requests one planned batch can hold under `policy`.
    pub fn capacity(&self, policy: Policy) -> usize {
        let max_ma = self.max_ma();
        match policy {
            Policy::Naive => max_ma,
            Policy::PpPipe { r1 } | Policy::FinDep { r1, .. } => r1 * max_ma,
            Policy::Adaptive => self.solver_params.r1_cap * max_ma,
        }
    }

    /// Smallest padded batch `r1·m_a` that covers `n` requests with a
    /// bucket m_a and `r1` within the cap — the batch-size bucket of
    /// the plan-cache key. Everything off this capacity is dominated:
    /// candidates with equal padding and equal capacity are exactly the
    /// `(m_a, r1)` pairs whose product is this value.
    fn padded_capacity(&self, n: usize) -> usize {
        let buckets = &self.pipeline.model().artifacts.manifest.ma_buckets;
        buckets
            .iter()
            .filter_map(|&m_a| {
                let r1 = n.div_ceil(m_a);
                (r1 <= self.solver_params.r1_cap).then_some(m_a * r1)
            })
            .min()
            .unwrap_or_else(|| self.max_ma() * self.solver_params.r1_cap)
    }

    /// Solve the Adaptive plan for one padded shape: Algorithm 1's
    /// online mode restricted to the compiled attention buckets, with
    /// the exhaustive fixed-`(m_a, r1)` scan as the fallback when the
    /// online solver calls the shape infeasible (e.g. an emulated
    /// testbed whose memory model rejects it). A cached neighbor (same
    /// profile/phase, capacity at least ours) warm-seeds the sweep,
    /// and the server's anytime budget bounds it — neither changes
    /// which plan an unbudgeted solve picks.
    fn solve_adaptive_shape(
        &self,
        capacity: usize,
        phase: Phase,
        key: ShapeKey,
    ) -> Option<Solution> {
        let warm = self
            .cache_plans
            .then(|| self.plan_cache.nearest(key))
            .flatten()
            .map(|s| WarmStart::from_solution(&s));
        self.solve_shape_warm(self.plan_split, capacity, phase, warm.as_ref(), self.solve_budget)
    }

    /// The planning instance for `phase` against an explicit split.
    /// Decode shapes solve a decode-phase instance whose KV length is
    /// normalized to its cache bucket's ceiling, so the plan is
    /// conservative for (and shared by) every KV in the bucket and
    /// cache-on/off runs stay byte-identical.
    fn phase_instance(&self, split: GroupSplit, phase: Phase) -> Instance {
        let model = self.pipeline.model().model.clone();
        let inst = match phase {
            Phase::Prefill => Instance::on_cluster(
                model,
                self.plan_cluster.clone(),
                split,
                self.pipeline.model().seq_len,
            ),
            Phase::Decode { kv_len } => Instance::decode_on_cluster(
                model,
                self.plan_cluster.clone(),
                split,
                bucket_up(kv_len),
            ),
        };
        // The uniform default takes the instance's own uniform
        // placement (bit-identical, no clone); an explicit placement is
        // applied only when it matches the split width — split-scoring
        // probes other (ag, eg) candidates, which a placement solved
        // for `plan_split.eg` shards cannot price.
        if self.plan_placement_id == PlacementId::UNIFORM
            || self.plan_placement.n_shards() != split.eg
        {
            inst
        } else {
            inst.with_placement(self.plan_placement.clone(), self.plan_load.clone())
        }
    }

    /// The serving solve for one padded shape against an explicit
    /// split — the scoring primitive [`Server::select_plan_split`]
    /// ranks candidate splits with, so selection and serving share one
    /// objective (split scoring passes no warm seed and no budget:
    /// selection stays exhaustive and deterministic).
    fn solve_shape_for_split(
        &self,
        split: GroupSplit,
        capacity: usize,
        phase: Phase,
    ) -> Option<Solution> {
        self.solve_shape_warm(split, capacity, phase, None, None)
    }

    /// Shared serving-solve core: Algorithm 1's online mode on this
    /// server's reusable evaluator, then the brute-force fallback.
    fn solve_shape_warm(
        &self,
        split: GroupSplit,
        capacity: usize,
        phase: Phase,
        warm: Option<&WarmStart>,
        budget: Option<Duration>,
    ) -> Option<Solution> {
        let inst = self.phase_instance(split, phase);
        let buckets = &self.pipeline.model().artifacts.manifest.ma_buckets;
        let params = SolverParams { budget, ..self.phase_params(phase) };
        let mut guard = self.solve_evaluator.lock().unwrap_or_else(PoisonError::into_inner);
        let ev = guard.get_or_insert_with(|| inst.evaluator());
        solver::solve_online_with(&inst, capacity, &params, EvalMode::Buffered, buckets, warm, ev)
            .or_else(|| self.bruteforce_shape(&inst, capacity, buckets, params.max_makespan))
    }

    /// Exhaustive reference path over the capacity-exact bucket pairs.
    /// An SLO cap filters here too: the fallback must not serve a plan
    /// the capped online solver correctly rejected.
    fn bruteforce_shape(
        &self,
        inst: &Instance,
        capacity: usize,
        buckets: &[usize],
        max_makespan: Option<f64>,
    ) -> Option<Solution> {
        let mut best: Option<Solution> = None;
        for &m_a in buckets {
            if m_a == 0 || capacity % m_a != 0 {
                continue;
            }
            let r1 = capacity / m_a;
            if r1 == 0 || r1 > self.solver_params.r1_cap {
                continue;
            }
            let (cfg, makespan, tput) = crate::solver::bruteforce::best_for_fixed_ma_r1(
                inst,
                m_a,
                r1,
                self.solver_params.r2_cap,
            );
            if max_makespan.is_some_and(|cap| makespan > cap) {
                continue;
            }
            if best.as_ref().map_or(true, |b| tput > b.throughput_tokens) {
                best = Some(Solution {
                    config: cfg,
                    makespan,
                    throughput_tokens: tput,
                    solve_seconds: 0.0,
                    evals: 0,
                    pruned_rows: 0,
                    warm_seeded: false,
                    exhaustive: true,
                });
            }
        }
        best
    }

    /// Choose (m_a, r1, ExecConfig) for an Adaptive prefill batch of
    /// `n` requests.
    pub fn plan_adaptive(&self, n: usize) -> (usize, usize, ExecConfig) {
        self.plan_adaptive_phase(n, Phase::Prefill)
    }

    /// Choose (m_a, r1, ExecConfig) for an Adaptive batch of `n`
    /// requests in `phase`. Cached per `(phase, seq len, padded
    /// capacity, constants identity, cluster identity)` shape — decode
    /// KV lengths bucket
    /// into power-of-two windows so plans are reused while the cache
    /// grows token by token, and neither prefill/decode plans nor
    /// plans solved under different calibration profiles can alias. A
    /// cache-disabled server runs the identical solve per batch, so the
    /// two modes produce byte-identical configurations — cache misses
    /// warm-seed from the nearest cached neighbor, which steers the
    /// sweep without changing its answer.
    pub fn plan_adaptive_phase(&self, n: usize, phase: Phase) -> (usize, usize, ExecConfig) {
        let capacity = self.padded_capacity(n);
        let key = match phase {
            Phase::Prefill => ShapeKey::prefill(self.pipeline.model().seq_len, capacity),
            Phase::Decode { kv_len } => ShapeKey::decode(kv_len, capacity),
        }
        .with_profile(self.plan_profile)
        .with_cluster(self.plan_cluster_id)
        .with_placement(self.plan_placement_id);
        // The cache hands back `Arc<Solution>` (a hit is a pointer
        // bump, not a deep clone under a lock); the cache-disabled
        // baseline wraps its fresh solve the same way so both arms
        // read identically below. Solve wall time is observed through
        // a cell because only a cache miss actually runs the closure.
        let solve_elapsed = std::cell::Cell::new(None::<Duration>);
        let timed_solve = || {
            let t0 = Instant::now();
            let sol = self.solve_adaptive_shape(capacity, phase, key);
            solve_elapsed.set(Some(t0.elapsed()));
            if let Some(s) = &sol {
                if s.warm_seeded {
                    self.metrics.inc("plans_warm", 1);
                }
                if s.pruned_rows > 0 {
                    self.metrics.inc("solver_rows_pruned", s.pruned_rows as u64);
                }
                if !s.exhaustive {
                    self.metrics.inc("plans_truncated", 1);
                }
            }
            sol
        };
        let (sol, refine) = if self.cache_plans {
            let (sol, token) = self.plan_cache.get_or_solve_refinable(key, timed_solve);
            (sol, Some(token))
        } else {
            (timed_solve().map(Arc::new), None)
        };
        if let (Some(budget), Some(elapsed)) = (self.solve_budget, solve_elapsed.get()) {
            if elapsed > budget {
                self.metrics.inc("solver_budget_exceeded", 1);
                self.metrics.observe("solver_budget_overrun", (elapsed - budget).as_secs_f64());
            }
        }
        // A budget-truncated plan this call solved (not a cached hit —
        // its miss already spawned one) is served as-is, and the rest
        // of its sweep moves off the hot path: a detached refinement
        // worker re-solves warm from the incumbent with no budget and
        // publishes the exhaustive plan into the generation this solve
        // was cached in.
        if let (Some(s), Some(token)) = (&sol, refine) {
            if !s.exhaustive && self.refine_plans && solve_elapsed.get().is_some() {
                self.spawn_refinement(token, key, capacity, phase, Arc::clone(s));
            }
        }
        match sol {
            Some(s) => (
                s.config.m_a,
                s.config.r1,
                ExecConfig {
                    r1: s.config.r1,
                    r2: s.config.r2,
                    order: s.config.order,
                    fuse_shared: s.config.fuse_shared,
                },
            ),
            // Degraded mode: this shape has no plan of its own (the
            // online solver and the brute-force fallback both called
            // it infeasible). Stand in the nearest cached neighbor —
            // same profile, same phase kind, capacity at least ours —
            // before resorting to the static max-capacity fallback, and
            // count the batch as degraded either way instead of
            // erroring it.
            None => {
                self.metrics.inc("plans_degraded", 1);
                if let Some(s) = self.cache_plans.then(|| self.plan_cache.nearest(key)).flatten()
                {
                    self.metrics.inc("plans_degraded_nearest", 1);
                    // The neighbor's plan was solved for a different
                    // shape (a larger batch bucket, or another seq/KV
                    // bucket): re-solve THIS phase's instance at the
                    // neighbor's capacity, warm-seeded by the neighbor
                    // — the seed row goes first and its r2 pivot is
                    // certified, so the re-solve is cheap — and serve
                    // the neighbor's config verbatim only when that
                    // shape is infeasible here too. Skipped when the
                    // neighbor shares our capacity: that exact solve
                    // just returned `None`.
                    let cap_n = s.config.m_a * s.config.r1;
                    let warm = WarmStart::from_solution(&s);
                    let re = (cap_n != capacity)
                        .then(|| {
                            self.solve_shape_warm(
                                self.plan_split,
                                cap_n,
                                phase,
                                Some(&warm),
                                self.solve_budget,
                            )
                        })
                        .flatten();
                    let c = match &re {
                        Some(r) => {
                            self.metrics.inc("plans_degraded_resolved", 1);
                            r.config
                        }
                        None => s.config,
                    };
                    (
                        c.m_a,
                        c.r1,
                        ExecConfig {
                            r1: c.r1,
                            r2: c.r2,
                            order: c.order,
                            fuse_shared: c.fuse_shared,
                        },
                    )
                } else {
                    // Precomputed static fallback: serve at max
                    // capacity with an unfused sequential plan.
                    self.metrics.inc("plans_degraded_static", 1);
                    (
                        self.max_ma(),
                        self.solver_params.r1_cap,
                        ExecConfig {
                            r1: self.solver_params.r1_cap,
                            r2: 1,
                            order: Order::Asas,
                            fuse_shared: false,
                        },
                    )
                }
            }
        }
    }

    /// Finish a budget-truncated solve off the hot path: a detached
    /// worker re-runs the full sweep (no budget, warm from the
    /// truncated incumbent — warm seeding never changes the answer, so
    /// the published plan is bit-identical to an unbudgeted cold
    /// solve) and publishes it through the [`RefineToken`] captured at
    /// the miss. The token pins the cache generation: if the cache was
    /// cleared in between, the publish lands in the orphaned
    /// generation and is invisible — all-or-nothing, never a torn mix
    /// of old- and new-generation plans. `plans_refined` counts live
    /// publishes only.
    fn spawn_refinement(
        &self,
        token: RefineToken,
        key: ShapeKey,
        capacity: usize,
        phase: Phase,
        seed: Arc<Solution>,
    ) {
        let inst = self.phase_instance(self.plan_split, phase);
        let buckets = self.pipeline.model().artifacts.manifest.ma_buckets.clone();
        // The refinement re-solve carries the same per-phase SLO cap
        // the truncated solve ran under — publishing an uncapped plan
        // over a capped entry would break the goodput contract.
        let params = SolverParams { budget: None, ..self.phase_params(phase) };
        let cache = Arc::clone(&self.plan_cache);
        let metrics = Arc::clone(&self.metrics);
        std::thread::spawn(move || {
            let warm = WarmStart::from_solution(&seed);
            let mut ev = inst.evaluator();
            let full = solver::solve_online_with(
                &inst,
                capacity,
                &params,
                EvalMode::Buffered,
                &buckets,
                Some(&warm),
                &mut ev,
            );
            if let Some(full) = full {
                if cache.publish_refined(&token, key, Arc::new(full)) {
                    metrics.inc("plans_refined", 1);
                }
            }
        });
    }

    /// Smallest m_a bucket such that `r1·m_a` covers the request count
    /// (fixed-policy path).
    fn fit_ma(&self, n: usize, r1: usize) -> usize {
        let buckets = &self.pipeline.model().artifacts.manifest.ma_buckets;
        buckets
            .iter()
            .copied()
            .filter(|&b| r1 * b >= n)
            .min()
            .unwrap_or_else(|| self.max_ma())
    }

    /// Serve a batch of requests under a policy; returns responses in
    /// request order (padding samples dropped) and the stitched
    /// pipeline stats. Batches beyond the policy's capacity are split
    /// into capacity-sized chunks and served back to back, unless
    /// [`Server::strict`] restores the pre-queue error. A batch mixing
    /// prefill and decode requests is split into a prefill chunk and a
    /// decode chunk, each scheduled under its own (separately cached)
    /// phase plan, with responses stitched back in request order.
    pub fn serve_batch(
        &self,
        reqs: &[EmbeddedRequest],
        policy: Policy,
    ) -> Result<(Vec<Response>, ForwardStats)> {
        anyhow::ensure!(!reqs.is_empty(), "empty batch");
        let s = self.pipeline.model().seq_len;
        let m = self.pipeline.model().model.embed;
        for r in reqs {
            anyhow::ensure!(
                r.hidden.data.len() == s * m,
                "request {} has {} elements, expected [S={s}, M={m}]",
                r.id,
                r.hidden.data.len()
            );
        }
        let cap = self.capacity(policy);
        anyhow::ensure!(cap > 0, "policy {policy:?} has zero capacity (r1 must be >= 1)");
        let t0 = Instant::now();

        let n_decode = reqs.iter().filter(|r| r.phase.is_decode()).count();
        if n_decode == 0 || n_decode == reqs.len() {
            return self.serve_phase_batch(reqs, policy, t0);
        }

        // Mixed window: split into the prefill chunk and the decode
        // chunk (order preserved within each class), serve each under
        // its phase plan, and stitch responses back by original
        // position. The split clones request tensors — only mixed
        // windows pay it; the single-phase steady state (all-prefill or
        // all-decode streams) keeps the zero-allocation arena path.
        let mut pre = Vec::with_capacity(reqs.len() - n_decode);
        let mut dec = Vec::with_capacity(n_decode);
        let mut dec_pos = Vec::with_capacity(n_decode);
        let mut pre_pos = Vec::with_capacity(reqs.len() - n_decode);
        for (i, r) in reqs.iter().enumerate() {
            if r.phase.is_decode() {
                dec.push(r.clone());
                dec_pos.push(i);
            } else {
                pre.push(r.clone());
                pre_pos.push(i);
            }
        }
        let mut stats = ForwardStats::default();
        let mut slots: Vec<Option<Response>> = vec![None; reqs.len()];
        for (chunk, pos) in [(&pre, &pre_pos), (&dec, &dec_pos)] {
            let (resp, st) = self.serve_phase_batch(chunk, policy, t0)?;
            stats.absorb(&st);
            for (r, &i) in resp.into_iter().zip(pos.iter()) {
                slots[i] = Some(r);
            }
        }
        let responses = slots
            .into_iter()
            .map(|r| r.expect("every request slot filled by its phase chunk"))
            .collect();
        Ok((responses, stats))
    }

    /// Representative phase of a single-phase chunk: decode chunks plan
    /// at their largest resident KV (padding model — the plan must hold
    /// the longest cache in the chunk).
    fn chunk_phase(reqs: &[EmbeddedRequest]) -> Phase {
        reqs.iter()
            .filter_map(|r| match r.phase {
                Phase::Decode { kv_len } => Some(kv_len),
                Phase::Prefill => None,
            })
            .max()
            .map_or(Phase::Prefill, |kv_len| Phase::Decode { kv_len })
    }

    /// Serve a single-phase batch, chunking it by capacity.
    fn serve_phase_batch(
        &self,
        reqs: &[EmbeddedRequest],
        policy: Policy,
        t0: Instant,
    ) -> Result<(Vec<Response>, ForwardStats)> {
        let phase = Self::chunk_phase(reqs);
        let cap = self.capacity(policy);
        if reqs.len() <= cap {
            return self.serve_chunk(reqs, policy, t0, phase);
        }
        anyhow::ensure!(
            !self.strict,
            "batch of {} exceeds serving capacity {cap}; split upstream",
            reqs.len()
        );
        let mut responses = Vec::with_capacity(reqs.len());
        let mut stats = ForwardStats::default();
        for chunk in reqs.chunks(cap) {
            let (r, st) = self.serve_chunk(chunk, policy, t0, phase)?;
            responses.extend(r);
            stats.absorb(&st);
        }
        Ok((responses, stats))
    }

    /// Serve one capacity-fitting chunk. `t0` is the serve/enqueue
    /// reference for latency (chunks of a split batch share it, so a
    /// later chunk's latency includes its wait behind earlier chunks).
    fn serve_chunk(
        &self,
        reqs: &[EmbeddedRequest],
        policy: Policy,
        t0: Instant,
        phase: Phase,
    ) -> Result<(Vec<Response>, ForwardStats)> {
        let t_chunk = Instant::now();
        let (m_a, r1, cfg) = match policy {
            Policy::Naive => {
                let m_a = self.fit_ma(reqs.len(), 1);
                (m_a, 1, ExecConfig::naive())
            }
            Policy::PpPipe { r1 } => (self.fit_ma(reqs.len(), r1), r1, ExecConfig::pppipe(r1)),
            Policy::FinDep { r1, r2, order } => {
                (self.fit_ma(reqs.len(), r1), r1, ExecConfig::findep(r1, r2, order))
            }
            Policy::Adaptive => self.plan_adaptive_phase(reqs.len(), phase),
        };
        let s = self.pipeline.model().seq_len;
        let m = self.pipeline.model().model.embed;
        let b_total = r1 * m_a;
        anyhow::ensure!(
            b_total >= reqs.len(),
            "planned batch {b_total} cannot hold {} requests; split upstream",
            reqs.len()
        );
        let (out, stats) = {
            // Poison-recover: `assemble` rewrites the arena from
            // scratch each batch, so a panic mid-assembly leaves no
            // state the next batch could observe.
            let mut buf = self.batch_buf.lock().unwrap_or_else(PoisonError::into_inner);
            let batch = buf.assemble(reqs, b_total, s, m);
            self.pipeline.forward(batch, cfg)?
        };
        // Response latency counts from the serve/enqueue reference;
        // the batch_latency histogram stays per-forward (chunk-local),
        // so split batches don't inflate it cumulatively.
        let latency = t0.elapsed().as_secs_f64();
        let chunk_latency = t_chunk.elapsed().as_secs_f64();

        let w = s * m;
        let responses: Vec<Response> = reqs
            .iter()
            .take(b_total)
            .enumerate()
            .map(|(i, r)| Response {
                id: r.id,
                hidden: Tensor::new(vec![s, m], out.data[i * w..(i + 1) * w].to_vec()),
                latency_s: latency,
            })
            .collect();

        self.metrics.inc("batches", 1);
        self.metrics.inc("requests", responses.len() as u64);
        // Token accounting follows the phase: a prefill pass processed
        // the whole prompt, a decode pass generated one token per
        // sample.
        let tok = phase.tokens_per_sample(s);
        self.metrics.inc("tokens", (responses.len() * tok) as u64);
        if phase.is_decode() {
            self.metrics.inc("decode_tokens", responses.len() as u64);
        }
        self.metrics.observe("batch_latency", chunk_latency);
        Ok((responses, stats))
    }
}

/// Thresholds of the replica health state machine.
#[derive(Debug, Clone, Copy)]
pub struct HealthConfig {
    /// Consecutive serve errors before Healthy → Degraded.
    pub degrade_after: u32,
    /// Consecutive serve errors before → Quarantined.
    pub quarantine_after: u32,
    /// A serve slower than `outlier_factor ×` the pool-wide latency
    /// EWMA counts as a latency outlier. Pool-wide on purpose: a
    /// per-replica average would adapt to a consistently slow replica
    /// and stop flagging it.
    pub outlier_factor: f64,
    /// Consecutive latency outliers before Healthy → Degraded.
    pub outlier_after: u32,
    /// How long a quarantined replica sits out before probation.
    pub cooldown: Duration,
    /// Clean serves on probation before Degraded → Healthy.
    pub probation_successes: u32,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self {
            degrade_after: 1,
            quarantine_after: 3,
            outlier_factor: 4.0,
            outlier_after: 8,
            cooldown: Duration::from_millis(250),
            probation_successes: 3,
        }
    }
}

/// Health state of one pooled replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    Healthy,
    /// Suspicious (recent errors or latency outliers, or on probation
    /// after quarantine) but still serving.
    Degraded,
    /// Sitting out a cooldown; not leased until re-admission.
    Quarantined,
}

/// Per-replica health ledger (indexed by replica id in the pool).
#[derive(Debug, Clone, Copy)]
struct Health {
    state: HealthState,
    consecutive_errors: u32,
    consecutive_outliers: u32,
    /// Re-admitted from quarantine and not yet proven healthy.
    probation: bool,
    probation_successes: u32,
    /// When the replica entered quarantine (for the quarantine_s
    /// histogram at re-admission).
    quarantined_at: Option<Instant>,
    /// Batches this replica has started serving — the fault plan's
    /// per-replica ordinal clock.
    serve_ordinal: u64,
}

impl Health {
    fn new() -> Self {
        Self {
            state: HealthState::Healthy,
            consecutive_errors: 0,
            consecutive_outliers: 0,
            probation: false,
            probation_successes: 0,
            quarantined_at: None,
            serve_ordinal: 0,
        }
    }
}

/// One pooled replica with its stable pool id (health and fault
/// schedules are keyed by id, not by pool position).
struct Replica<R> {
    id: usize,
    inner: R,
}

/// Mutable pool state behind the one pool mutex.
struct PoolState<R> {
    /// Replicas free to lease; `pop` takes from the end, and probation
    /// re-admissions insert at the front, so proven-healthy replicas
    /// are preferred while suspects only serve when demand needs them.
    free: Vec<Replica<R>>,
    /// Quarantined replicas with their release times.
    quarantined: Vec<(Instant, Replica<R>)>,
    health: Vec<Health>,
    /// Pool-wide serve-latency EWMA (the outlier reference) and how
    /// many samples shaped it (outlier detection waits out a warmup).
    ewma_latency: f64,
    ewma_n: u64,
}

/// A pool of serving replicas leased by the event-driven batcher's
/// workers: execution capacity is a handoff, not a thread's identity —
/// any parked worker can pick up any ready batch and lease whichever
/// replica is free (the retired thread-pool design bound one replica
/// to one thread for life through a channel fan-out, so a stalled
/// thread idled its replica even while batches queued).
///
/// The pool is also the resilience boundary: batch outcomes reported
/// through [`ReplicaLease::report`] drive a per-replica
/// Healthy → Degraded → Quarantined state machine, a quarantined
/// replica sits out [`HealthConfig::cooldown`] and re-enters on
/// probation, and a [`FaultPlan`] injects deterministic failures at
/// the lease boundary — [`Server`] itself never sees a fault. Leasing
/// is capacity-aware: while any replica is quarantined, waiters park
/// with a timeout bounded by the earliest release, so a pool running
/// at reduced capacity keeps serving instead of blocking on a dead
/// replica.
pub struct ReplicaPool<R = Server> {
    state: Mutex<PoolState<R>>,
    freed: Condvar,
    cfg: HealthConfig,
    faults: FaultPlan,
    metrics: Option<Arc<Registry>>,
}

impl<R> ReplicaPool<R> {
    pub fn new(replicas: Vec<R>) -> Self {
        let health = vec![Health::new(); replicas.len()];
        let free = replicas
            .into_iter()
            .enumerate()
            .map(|(id, inner)| Replica { id, inner })
            .collect();
        Self {
            state: Mutex::new(PoolState {
                free,
                quarantined: Vec::new(),
                health,
                ewma_latency: 0.0,
                ewma_n: 0,
            }),
            freed: Condvar::new(),
            cfg: HealthConfig::default(),
            faults: FaultPlan::default(),
            metrics: None,
        }
    }

    /// Override the health thresholds (builder-style).
    pub fn with_health(mut self, cfg: HealthConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Arm a deterministic fault plan (builder-style). An empty plan
    /// (the default) keeps the pool fully inert.
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Report health/fault events to a metrics registry
    /// (builder-style).
    pub fn with_metrics(mut self, metrics: Arc<Registry>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    fn inc(&self, name: &str) {
        if let Some(m) = &self.metrics {
            m.inc(name, 1);
        }
    }

    /// Recover the pool even if a holder panicked mid-update: every
    /// mutation below leaves the state structurally valid.
    fn lock(&self) -> std::sync::MutexGuard<'_, PoolState<R>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Move quarantined replicas whose cooldown has elapsed back into
    /// the free list, on probation. They enter at the *front* so
    /// proven-healthy replicas (popped from the back) stay preferred.
    fn readmit_due(&self, st: &mut PoolState<R>, now: Instant) {
        let mut i = 0;
        while i < st.quarantined.len() {
            if st.quarantined[i].0 <= now {
                let (_, rep) = st.quarantined.swap_remove(i);
                let h = &mut st.health[rep.id];
                h.state = HealthState::Degraded;
                h.probation = true;
                h.probation_successes = 0;
                h.consecutive_errors = 0;
                h.consecutive_outliers = 0;
                if let (Some(m), Some(t)) = (&self.metrics, h.quarantined_at.take()) {
                    m.observe("quarantine_s", t.elapsed().as_secs_f64());
                }
                self.inc("replica_readmitted");
                st.free.insert(0, rep);
            } else {
                i += 1;
            }
        }
    }

    /// Replicas currently parked (free) in the pool.
    pub fn available(&self) -> usize {
        self.lock().free.len()
    }

    /// Replicas currently sitting out a quarantine cooldown.
    pub fn quarantined(&self) -> usize {
        self.lock().quarantined.len()
    }

    /// Health state of replica `id` (tests and observability).
    pub fn health_state(&self, id: usize) -> HealthState {
        self.lock().health[id].state
    }

    /// Lease a replica, parking until one is free. While replicas are
    /// quarantined the park is bounded by the earliest cooldown
    /// release, so a fully-quarantined pool self-recovers instead of
    /// deadlocking.
    pub fn lease(&self) -> ReplicaLease<'_, R> {
        let mut st = self.lock();
        loop {
            let now = Instant::now();
            self.readmit_due(&mut st, now);
            if let Some(rep) = st.free.pop() {
                return ReplicaLease { pool: self, replica: Some(rep) };
            }
            st = match st.quarantined.iter().map(|(t, _)| *t).min() {
                Some(release) => {
                    let timeout = release.saturating_duration_since(now);
                    self.freed
                        .wait_timeout(st, timeout)
                        .unwrap_or_else(PoisonError::into_inner)
                        .0
                }
                None => self.freed.wait(st).unwrap_or_else(PoisonError::into_inner),
            };
        }
    }

    /// Lease a replica only if one is free right now (due quarantine
    /// re-admissions count as free).
    pub fn try_lease(&self) -> Option<ReplicaLease<'_, R>> {
        let mut st = self.lock();
        self.readmit_due(&mut st, Instant::now());
        st.free.pop().map(|rep| ReplicaLease { pool: self, replica: Some(rep) })
    }

    /// Health update from one batch outcome on replica `id`.
    fn report_outcome(&self, id: usize, ok: bool, latency_s: f64) {
        let mut st = self.lock();
        // Latency-outlier detection against the pool-wide EWMA. Only
        // successful, non-outlier serves shape the reference, so a
        // persistently slow replica cannot drag the baseline up to
        // meet itself.
        let outlier = ok
            && st.ewma_n >= 8
            && st.ewma_latency > 0.0
            && latency_s > self.cfg.outlier_factor * st.ewma_latency;
        if ok && !outlier {
            st.ewma_n += 1;
            if st.ewma_n == 1 {
                st.ewma_latency = latency_s;
            } else {
                st.ewma_latency = 0.9 * st.ewma_latency + 0.1 * latency_s;
            }
        }
        let cfg = self.cfg;
        let h = &mut st.health[id];
        if ok {
            h.consecutive_errors = 0;
            if outlier {
                h.consecutive_outliers += 1;
                if h.state == HealthState::Healthy && h.consecutive_outliers >= cfg.outlier_after
                {
                    h.state = HealthState::Degraded;
                    drop(st);
                    self.inc("replica_degraded");
                    return;
                }
            } else {
                h.consecutive_outliers = 0;
                if h.probation {
                    h.probation_successes += 1;
                    if h.probation_successes >= cfg.probation_successes {
                        h.probation = false;
                        h.state = HealthState::Healthy;
                        drop(st);
                        self.inc("replica_recovered");
                        return;
                    }
                } else if h.state == HealthState::Degraded {
                    // Degraded by errors/outliers (not probation): one
                    // clean serve clears it.
                    h.state = HealthState::Healthy;
                    drop(st);
                    self.inc("replica_recovered");
                    return;
                }
            }
        } else {
            h.consecutive_errors += 1;
            h.consecutive_outliers = 0;
            // An error during probation re-quarantines immediately —
            // the replica already used up its benefit of the doubt.
            if h.probation || h.consecutive_errors >= cfg.quarantine_after {
                h.probation = false;
                h.state = HealthState::Quarantined;
                h.quarantined_at = Some(Instant::now());
                drop(st);
                self.inc("replica_quarantined");
                return;
            }
            if h.state == HealthState::Healthy && h.consecutive_errors >= cfg.degrade_after {
                h.state = HealthState::Degraded;
                drop(st);
                self.inc("replica_degraded");
                return;
            }
        }
    }
}

/// RAII lease on one pooled replica: dereferences to the replica, and
/// returns it on drop (waking a parked leaser) — including during a
/// panic unwind, so a worker dying mid-batch never leaks its replica
/// out of the pool. A replica whose health reached Quarantined goes to
/// the quarantine bench instead, with its cooldown clock started at
/// drop.
pub struct ReplicaLease<'a, R = Server> {
    pool: &'a ReplicaPool<R>,
    replica: Option<Replica<R>>,
}

impl<R> ReplicaLease<'_, R> {
    fn rep(&self) -> &Replica<R> {
        self.replica.as_ref().expect("lease holds a replica until drop")
    }

    /// Stable pool id of the leased replica.
    pub fn replica_id(&self) -> usize {
        self.rep().id
    }

    /// Consult the fault plan for this replica's next serve and
    /// advance its per-replica batch ordinal. Inert (always
    /// [`FaultAction::None`], no counters) when no plan is armed.
    pub fn fault_action(&self) -> FaultAction {
        if self.pool.faults.is_empty() {
            return FaultAction::None;
        }
        let id = self.rep().id;
        let ordinal = {
            let mut st = self.pool.lock();
            let h = &mut st.health[id];
            let o = h.serve_ordinal;
            h.serve_ordinal += 1;
            o
        };
        let action = self.pool.faults.action(id, ordinal);
        if action != FaultAction::None {
            self.pool.inc("faults_injected");
        }
        action
    }

    /// Report this lease's batch outcome into the health state
    /// machine.
    pub fn report(&self, ok: bool, latency_s: f64) {
        let id = self.rep().id;
        self.pool.report_outcome(id, ok, latency_s);
    }
}

impl ReplicaLease<'_, Server> {
    /// Serve a batch through the resilience boundary: consult the
    /// fault plan (fail / panic / inflate latency per schedule), run
    /// the real serve for non-failing actions, and feed the outcome
    /// into the health state machine. With no fault plan armed this
    /// is exactly `serve_batch` plus a health report.
    pub fn serve_checked(
        &mut self,
        reqs: &[EmbeddedRequest],
        policy: Policy,
    ) -> Result<(Vec<Response>, ForwardStats)> {
        let action = self.fault_action();
        let t0 = Instant::now();
        match action {
            FaultAction::Fail => {
                self.report(false, 0.0);
                anyhow::bail!("injected fault: replica {} failed this serve", self.replica_id())
            }
            FaultAction::Panic => {
                self.report(false, 0.0);
                panic!("injected fault: replica {} worker panic", self.replica_id())
            }
            FaultAction::Slow(factor) => {
                let r = self.serve_batch(reqs, policy);
                let dt = t0.elapsed();
                std::thread::sleep(dt.mul_f64((factor - 1.0).max(0.0)));
                self.report(r.is_ok(), t0.elapsed().as_secs_f64());
                r
            }
            FaultAction::None => {
                let r = self.serve_batch(reqs, policy);
                self.report(r.is_ok(), t0.elapsed().as_secs_f64());
                r
            }
        }
    }
}

impl<R> Deref for ReplicaLease<'_, R> {
    type Target = R;

    fn deref(&self) -> &R {
        &self.rep().inner
    }
}

impl<R> DerefMut for ReplicaLease<'_, R> {
    fn deref_mut(&mut self) -> &mut R {
        &mut self.replica.as_mut().expect("lease holds a replica until drop").inner
    }
}

impl<R> Drop for ReplicaLease<'_, R> {
    fn drop(&mut self) {
        if let Some(rep) = self.replica.take() {
            let pool = self.pool;
            let mut st = pool.lock();
            if st.health[rep.id].state == HealthState::Quarantined {
                st.quarantined.push((Instant::now() + pool.cfg.cooldown, rep));
                drop(st);
                // Wake every waiter: whoever parked without a timeout
                // (nothing was quarantined then) must re-park with the
                // cooldown-bounded timeout, or the last free replica
                // entering quarantine would strand them forever.
                pool.freed.notify_all();
            } else {
                st.free.push(rep);
                drop(st);
                pool.freed.notify_one();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    fn server() -> Option<Server> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let model = ModelHandle::load(&dir, true).unwrap();
        Some(Server::new(model, 2, None).unwrap())
    }

    #[test]
    fn serves_batches_under_all_policies() {
        let Some(srv) = server() else { return };
        let s = srv.pipeline.model().seq_len;
        let m = srv.pipeline.model().model.embed;
        let reqs: Vec<EmbeddedRequest> =
            (0..4).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
        let mut outputs = Vec::new();
        for policy in [
            Policy::Naive,
            Policy::PpPipe { r1: 2 },
            Policy::FinDep { r1: 2, r2: 2, order: Order::Asas },
            Policy::Adaptive,
        ] {
            let (resp, stats) = srv.serve_batch(&reqs, policy).unwrap();
            assert_eq!(resp.len(), 4);
            assert!(stats.total > 0.0);
            outputs.push(resp);
        }
        // All policies produce identical numerics per request.
        for other in &outputs[1..] {
            for (a, b) in outputs[0].iter().zip(other) {
                assert_eq!(a.id, b.id);
                assert!(a.hidden.max_abs_diff(&b.hidden) < 1e-4);
            }
        }
        assert_eq!(srv.metrics.counter("requests"), 16);
    }

    #[test]
    fn budgeted_adaptive_serving_refines_to_the_exhaustive_plan() {
        let Some(mut srv) = server() else { return };
        srv.solve_budget = Some(Duration::ZERO);
        let s = srv.pipeline.model().seq_len;
        let m = srv.pipeline.model().model.embed;
        let reqs: Vec<EmbeddedRequest> =
            (0..4).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
        let (resp, _) = srv.serve_batch(&reqs, Policy::Adaptive).unwrap();
        assert_eq!(resp.len(), 4);
        // The shape is planned and cached either way; if the zero
        // budget truncated the sweep, a refinement worker finishes it
        // and publishes into the same generation.
        let key = ShapeKey::prefill(s, srv.padded_capacity(4)).with_profile(srv.plan_profile());
        let deadline = Instant::now() + Duration::from_secs(30);
        let refined = loop {
            match srv.plan_cache().peek(key) {
                Some(Some(sol)) if sol.exhaustive => break sol,
                _ => {}
            }
            assert!(Instant::now() < deadline, "refinement never published");
            std::thread::sleep(Duration::from_millis(2));
        };
        // The published plan is bit-identical to an unbudgeted solve.
        let full = srv
            .solve_shape_for_split(srv.plan_split, srv.padded_capacity(4), Phase::Prefill)
            .expect("shape solvable");
        assert_eq!(refined.config, full.config);
        assert_eq!(refined.throughput_tokens.to_bits(), full.throughput_tokens.to_bits());
    }

    #[test]
    fn drifted_routing_re_solves_expert_placement() {
        use crate::coordinator::router::ExpertGroup;
        let Some(mut srv) = server() else { return };
        // Quiescent default: uniform placement, no drift, no re-solve.
        assert_eq!(srv.plan_placement_id(), PlacementId::UNIFORM);
        assert!(srv.plan_placement().is_uniform());
        assert!(!srv.resolve_placement_if_drifted(0.25));
        // Serving feeds the pipeline's shared routing histogram.
        let s = srv.pipeline.model().seq_len;
        let m = srv.pipeline.model().model.embed;
        let reqs: Vec<EmbeddedRequest> =
            (0..2).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
        srv.serve_batch(&reqs, Policy::Naive).unwrap();
        let n_experts = srv.pipeline.model().model.n_experts;
        let observed = srv.observed_expert_load();
        assert_eq!(observed.n_experts(), n_experts);
        // Inject a heavily skewed routed stream: expert 0 takes 3·E of
        // the ~4·E assignments per batch.
        let mut groups = vec![ExpertGroup {
            expert: 0,
            token_ids: (0..3 * n_experts as u32).collect(),
            weights: vec![1.0; 3 * n_experts],
        }];
        for e in 1..n_experts {
            groups.push(ExpertGroup { expert: e, token_ids: vec![0], weights: vec![1.0] });
        }
        let skewed = Routing { groups, n_tokens: 3 * n_experts, top_k: 1 };
        for _ in 0..200 {
            srv.observe_routing(&skewed);
        }
        assert!(srv.placement_drift() > 0.25, "drift {}", srv.placement_drift());
        // The drift check adopts a placement solved for the observed
        // load; afterwards the observed load IS the plan load, so a
        // second check is quiescent again.
        assert!(srv.resolve_placement_if_drifted(0.25));
        assert_ne!(srv.plan_placement_id(), PlacementId::UNIFORM);
        assert_eq!(srv.plan_placement().n_shards(), srv.plan_split.eg);
        assert!(srv.placement_drift() < 1e-9);
        assert!(!srv.resolve_placement_if_drifted(0.25));
        assert_eq!(srv.metrics.counter("placement_resolves"), 1);
        // Serving still works under the explicit placement, and its
        // plans are keyed under the placement fingerprint.
        let (resp, _) = srv.serve_batch(&reqs, Policy::Adaptive).unwrap();
        assert_eq!(resp.len(), 2);
        let key = ShapeKey::prefill(s, srv.padded_capacity(2))
            .with_profile(srv.plan_profile())
            .with_placement(srv.plan_placement_id());
        assert!(srv.plan_cache().peek(key).is_some(), "plan not keyed under placement");
    }

    #[test]
    fn padding_does_not_leak_into_responses() {
        let Some(srv) = server() else { return };
        let s = srv.pipeline.model().seq_len;
        let m = srv.pipeline.model().model.embed;
        // 3 requests with r1=2 -> padded to 4; the 3 real responses must
        // match a 4-request run's first three.
        let reqs3: Vec<EmbeddedRequest> =
            (0..3).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
        let reqs4: Vec<EmbeddedRequest> =
            (0..4).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
        let (r3, _) = srv.serve_batch(&reqs3, Policy::PpPipe { r1: 2 }).unwrap();
        let (r4, _) = srv.serve_batch(&reqs4, Policy::PpPipe { r1: 2 }).unwrap();
        assert_eq!(r3.len(), 3);
        for (a, b) in r3.iter().zip(&r4) {
            assert!(a.hidden.max_abs_diff(&b.hidden) < 1e-5);
        }
    }

    // ---- BatchBuffers (artifact-free) --------------------------------

    fn reqs(n: usize, s: usize, m: usize) -> Vec<EmbeddedRequest> {
        (0..n as u64).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect()
    }

    #[test]
    fn arena_assembly_matches_alloc_baseline() {
        let (s, m) = (16usize, 32usize);
        let mut buf = BatchBuffers::new();
        for (n, b_total) in [(1usize, 4usize), (3, 4), (4, 4), (5, 8), (8, 8)] {
            let rs = reqs(n, s, m);
            let baseline = BatchBuffers::assemble_alloc(&rs, b_total, s, m);
            let arena = buf.assemble(&rs, b_total, s, m);
            assert_eq!(arena.shape, baseline.shape);
            assert_eq!(arena.data, baseline.data, "n={n} b_total={b_total}");
        }
    }

    #[test]
    fn arena_is_stable_in_steady_state_and_zeroes_dirty_padding() {
        let (s, m) = (16usize, 32usize);
        let mut buf = BatchBuffers::new();
        // Warm with the largest shape, then shrink: the buffer must not
        // move again.
        buf.assemble(&reqs(8, s, m), 8, s, m);
        let (ptr, cap) = (buf.as_ptr(), buf.capacity());
        for n in [1usize, 4, 8, 2, 8] {
            let b_total = n.next_power_of_two().max(4);
            buf.assemble(&reqs(n, s, m), b_total, s, m);
            assert_eq!(buf.as_ptr(), ptr, "buffer moved at n={n}");
            assert_eq!(buf.capacity(), cap, "buffer reallocated at n={n}");
        }
        // A small batch after a larger one must see zeroed padding, not
        // the previous batch's rows.
        let out = buf.assemble(&reqs(2, s, m), 4, s, m);
        assert!(out.data[2 * s * m..].iter().all(|&v| v == 0.0));
        assert_eq!(out.shape, vec![4, s, m]);
    }
}
