//! Continuous batching, event-driven: a pure planning state machine
//! ([`super::planner`]) behind one mutex, drained by condvar-parked
//! serving workers ([`super::executor`]) that lease pipeline replicas
//! from a shared pool (the EPS-MoE / MegaScale-Infer serving shape —
//! many in-flight micro-batches keep the disaggregated attention and
//! expert groups busy, with no polling cadence anywhere).
//!
//! ```text
//!   submit() ──▶ ┌─────────────────────────┐     worker 0 .. W-1
//!        │       │ Planner (one mutex)     │  (parked on the `work`
//!        │       │  bounded submit queue   │◀── condvar; window-full,
//!        │       │  decode lane (priority) │    linger-expiry, or
//!        │       │  linger window (FIFO)   │    shutdown-drain wakes
//!        │       └─────────────────────────┘    exactly one)
//!        │  decode steps ▲      │ Execute(batch)
//!        │  (KV-growing  │      ▼
//!        │   re-entry)   │   ReplicaPool lease ──▶ Server::serve_batch
//!        │               └──────┤ (shared Registry + PlanCache)
//!        ◀──── final responses ─┘
//! ```
//!
//! Invariants (unchanged from the retired thread-pool design, which
//! lives on as the measured baseline in [`super::threadpool`]):
//!
//! * **FIFO draining** — windows form strictly in arrival order; with
//!   one worker and no decode traffic, responses come back in
//!   submission order regardless of how the stream was cut into
//!   batches. Decode re-entries take priority over fresh submissions
//!   (finish what is in flight), so equal-output requests still
//!   complete in submission order.
//! * **Continuous decode batching** — a request submitted with
//!   `output_len > 0` re-enters the planner after its prefill as one
//!   decode step per output token, KV growing each step; each window
//!   may therefore mix phases, and the server schedules its prefill and
//!   decode chunks under separate phase-keyed cached plans. The client
//!   receives exactly one response, after the last step.
//! * **Backpressure** — the submit queue is bounded: `submit` parks on
//!   the `space` condvar while it is full, `try_submit` rejects (and
//!   counts `queue_rejected`). The decode re-entry lane is unbounded so
//!   workers can never deadlock against a full queue; its depth is
//!   bounded by the requests already admitted.
//! * **Event-driven idleness** — an idle batcher performs no wakeups:
//!   every worker parks until a submit, a decode re-entry, a linger
//!   expiry, or shutdown arrives (the baseline woke every 200µs to
//!   re-poll its decode lane).
//! * **Per-request latency** — each final response's `latency_s` is
//!   rewritten to the true submit→response time (prefill plus every
//!   decode step), and each queue pass's wait lands in the shared
//!   registry's `queue_wait` histogram.
//! * **Shared planning** — workers share one [`PlanCache`], so an
//!   Adaptive shape solved on any worker is a hit on all of them —
//!   prefill and decode shapes memoized separately, hits returned as
//!   `Arc<Solution>` without cloning plan bodies under a lock.
//! * **Exactly-once delivery under faults** — every admitted request
//!   terminates in exactly one of: a [`Response`], or a typed
//!   [`FailedRequest`] on the failure channel (deadline expired in
//!   queue, or retry budget exhausted). A batch whose replica fails
//!   mid-serve re-enters through the planner's front-priority retry
//!   lane ([`run_attempt`] — its drop guard covers worker panics too);
//!   replica health and deterministic fault injection live in
//!   [`super::server::ReplicaPool`] / [`super::faults`]. With no fault
//!   plan armed and no deadlines set, none of this is observable:
//!   fault-free serving is bit-identical to a batcher without the
//!   resilience layer.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{Cluster, Phase};
use crate::coordinator::executor::{run_worker, EventCore};
use crate::coordinator::faults::FaultPlan;
use crate::coordinator::links::LinkDelay;
use crate::coordinator::moe::ModelHandle;
use crate::coordinator::planner::{PlannerConfig, QueuedRequest};
pub use crate::coordinator::planner::SubmitError;
use crate::coordinator::server::{
    EmbeddedRequest, HealthConfig, Policy, ReplicaPool, Response, Server,
};
use crate::coordinator::slo::SloPolicy;
use crate::metrics::Registry;
use crate::solver::PlanCache;

/// Continuous-batcher knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// EG workers per pipeline replica.
    pub eg: usize,
    /// Optional α-β link delay per replica.
    pub link_delay: Option<LinkDelay>,
    /// Scheduling policy applied to every assembled batch.
    pub policy: Policy,
    /// Most requests per assembled batch (the size bucket cap).
    pub max_batch: usize,
    /// Bounded submit-queue depth (`submit` blocks beyond it).
    pub queue_depth: usize,
    /// Serving workers = pipeline replicas = in-flight batches.
    pub workers: usize,
    /// How long a window lingers to fill after the first request
    /// arrives.
    pub linger: Duration,
    /// Memoize Adaptive plans per shape (shared across workers).
    pub cache_plans: bool,
    /// Pick each replica's Adaptive planning split with the split-search
    /// solver layer at startup instead of the fixed `(1, eg)` view.
    pub auto_split: bool,
    /// Anytime latency budget for each replica's Adaptive solves: a
    /// solve that runs over it serves its best incumbent immediately
    /// instead of finishing the sweep. `None` (the default) never
    /// truncates.
    pub solve_budget: Option<Duration>,
    /// Finish budget-truncated cached plans in the background and
    /// publish the exhaustive plan into the shared cache (only
    /// observable with `solve_budget` set).
    pub refine_plans: bool,
    /// Optional latency SLO applied to every replica's planner:
    /// prefill plans are capped at the TTFT target and decode plans at
    /// the TPOT target, so the batcher optimizes goodput-under-SLO
    /// instead of raw throughput. `None` (the default) plans for
    /// throughput, bit-identically to a batcher without the SLO layer.
    pub slo: Option<SloPolicy>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            eg: 2,
            link_delay: None,
            policy: Policy::Adaptive,
            max_batch: 8,
            queue_depth: 64,
            workers: 2,
            linger: Duration::from_millis(1),
            cache_plans: true,
            auto_split: false,
            solve_budget: None,
            refine_plans: true,
            slo: None,
        }
    }
}

/// Resilience knobs, separate from [`BatcherConfig`] (which stays
/// `Copy`): the fault plan carries a schedule vector, and all of this
/// is optional — the defaults keep the batcher's behavior identical to
/// a batcher without a resilience layer (no faults, no sheds, failed
/// batches retried up to `max_retries` before a typed failure).
#[derive(Debug, Clone)]
pub struct ResilienceConfig {
    /// Deterministic fault schedule injected at the replica-lease
    /// boundary. Empty (the default) = fully inert.
    pub fault_plan: FaultPlan,
    /// Replica health state-machine thresholds.
    pub health: HealthConfig,
    /// Serve attempts per request beyond the first before it fails
    /// with [`RequestError::RetriesExhausted`].
    pub max_retries: u32,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        Self { fault_plan: FaultPlan::default(), health: HealthConfig::default(), max_retries: 2 }
    }
}

/// Why a request failed instead of producing a [`Response`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestError {
    /// The deadline passed while the request sat in the queue.
    DeadlineExpired,
    /// Every serve attempt hit a failing replica.
    RetriesExhausted {
        /// Total serve attempts consumed.
        attempts: u32,
    },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::DeadlineExpired => write!(f, "deadline expired in queue"),
            RequestError::RetriesExhausted { attempts } => {
                write!(f, "retries exhausted after {attempts} attempts")
            }
        }
    }
}

impl std::error::Error for RequestError {}

/// The typed terminal failure for one admitted request — delivered on
/// the failure channel, exactly once, in place of its [`Response`].
#[derive(Debug, Clone)]
pub struct FailedRequest {
    pub id: u64,
    pub error: RequestError,
    /// Seconds from submission to the failure verdict.
    pub latency_s: f64,
}

/// The continuous batcher: owns the event core and the worker pool.
/// Dropping it drains in-flight work and joins every thread.
pub struct Batcher {
    core: Arc<EventCore>,
    resp_rx: Receiver<Response>,
    fail_rx: Receiver<FailedRequest>,
    metrics: Arc<Registry>,
    plan_cache: Arc<PlanCache>,
    /// Expected `S·M` element count per request — malformed requests
    /// are rejected at submit time so they can never sink a whole
    /// assembled batch inside a worker.
    req_elems: usize,
    /// Assembly knobs the admission-control wait estimate needs.
    max_batch: usize,
    linger: Duration,
    threads: Vec<JoinHandle<()>>,
}

impl Batcher {
    /// Spin up `cfg.workers` serving replicas over one loaded model,
    /// planning against the hand-written testbed constants.
    pub fn new(model: ModelHandle, cfg: BatcherConfig) -> Result<Batcher> {
        Self::with_profile(model, cfg, None)
    }

    /// [`Batcher::new`] with every replica's Adaptive planner driven by
    /// a calibration profile's measured constants.
    pub fn with_profile(
        model: ModelHandle,
        cfg: BatcherConfig,
        profile: Option<&crate::perfmodel::profile::CalibrationProfile>,
    ) -> Result<Batcher> {
        Self::with_resilience(model, cfg, profile, ResilienceConfig::default())
    }

    /// [`Batcher::with_profile`] plus the resilience layer: a
    /// deterministic fault plan armed at the replica-lease boundary,
    /// health thresholds for the pool's state machine, and the
    /// per-request retry budget. The profile is applied before the
    /// optional auto-split selection, so the split itself is chosen
    /// under the calibrated view; its fingerprint rides every
    /// plan-cache key, keeping calibrated and hand-constant plans in
    /// disjoint keyspaces of the shared cache.
    pub fn with_resilience(
        model: ModelHandle,
        cfg: BatcherConfig,
        profile: Option<&crate::perfmodel::profile::CalibrationProfile>,
        resilience: ResilienceConfig,
    ) -> Result<Batcher> {
        Self::with_planner(model, cfg, profile, resilience, None)
    }

    /// [`Batcher::with_resilience`] plus an explicit planning cluster:
    /// every replica plans against `cluster`'s heterogeneous pools
    /// instead of the single-pool view of its hand-written testbed.
    /// `None` keeps the legacy single-pool planner. Applied before the
    /// profile (which re-derives constants per pool) and before the
    /// optional auto-split selection, so the split is chosen under the
    /// cluster's calibrated view.
    pub fn with_planner(
        model: ModelHandle,
        cfg: BatcherConfig,
        profile: Option<&crate::perfmodel::profile::CalibrationProfile>,
        resilience: ResilienceConfig,
        cluster: Option<&Cluster>,
    ) -> Result<Batcher> {
        let metrics = Arc::new(Registry::new());
        let plan_cache = Arc::new(PlanCache::new());
        let workers = cfg.workers.max(1);
        let req_elems = model.seq_len * model.model.embed;
        let prompt_len = model.seq_len;

        let core = Arc::new(EventCore::new(PlannerConfig {
            max_batch: cfg.max_batch,
            linger: cfg.linger,
            queue_depth: cfg.queue_depth,
        }));

        // The split search is deterministic in (model, plan testbed,
        // seq), so run it on the first replica only and hand the chosen
        // split to the rest — re-running it per replica would also
        // re-clear the shared plan cache under earlier replicas.
        let mut replicas = Vec::with_capacity(workers);
        let mut chosen_split = None;
        for _ in 0..workers {
            let mut server = Server::with_shared(
                model.clone(),
                cfg.eg,
                cfg.link_delay,
                metrics.clone(),
                plan_cache.clone(),
            )?;
            server.cache_plans = cfg.cache_plans;
            server.solve_budget = cfg.solve_budget;
            server.refine_plans = cfg.refine_plans;
            if let Some(cl) = cluster {
                server.set_cluster(cl.clone());
            }
            if let Some(p) = profile {
                server.set_calibration_profile(p);
            }
            server.set_slo(cfg.slo);
            if cfg.auto_split {
                match chosen_split {
                    None => chosen_split = Some(server.select_plan_split()),
                    Some(split) => server.plan_split = split,
                }
            }
            replicas.push(server);
        }
        let pool = Arc::new(
            ReplicaPool::new(replicas)
                .with_health(resilience.health)
                .with_faults(resilience.fault_plan.clone())
                .with_metrics(metrics.clone()),
        );

        let (resp_tx, resp_rx) = channel::<Response>();
        let (fail_tx, fail_rx) = channel::<FailedRequest>();
        let max_retries = resilience.max_retries;
        let mut threads = Vec::with_capacity(workers);
        for w in 0..workers {
            // Register before spawning: a submit racing the spawn must
            // never observe an empty pool and refuse legal work.
            core.register_worker();
            let core = core.clone();
            let metrics = metrics.clone();
            let pool = pool.clone();
            let resp_tx = resp_tx.clone();
            let fail_tx = fail_tx.clone();
            let policy = cfg.policy;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("findep-serve{w}"))
                    .spawn(move || {
                        let c = core.clone();
                        let m = metrics.clone();
                        run_worker(&core, &metrics, move |batch| {
                            run_attempt(
                                &c,
                                &m,
                                &resp_tx,
                                &fail_tx,
                                max_retries,
                                prompt_len,
                                batch,
                                |reqs| {
                                    // With workers == replicas the lease
                                    // is immediate; the pool exists so
                                    // execution capacity is a handoff,
                                    // not a thread's identity — and it
                                    // is the fault/health boundary.
                                    let mut lease = pool.lease();
                                    lease.serve_checked(reqs, policy).map(|(r, _stats)| r)
                                },
                            )
                        })
                    })
                    .context("spawn serving worker")?,
            );
        }

        Ok(Batcher {
            core,
            resp_rx,
            fail_rx,
            metrics,
            plan_cache,
            req_elems,
            max_batch: cfg.max_batch.max(1),
            linger: cfg.linger,
            threads,
        })
    }

    /// A malformed request must fail at the submission boundary — once
    /// assembled, `serve_batch` would reject the whole batch and every
    /// co-batched request would silently lose its response.
    fn validate(&self, req: &EmbeddedRequest) -> Result<(), SubmitError> {
        if req.hidden.data.len() != self.req_elems {
            return Err(SubmitError::Invalid {
                id: req.id,
                elems: req.hidden.data.len(),
                expected: self.req_elems,
            });
        }
        Ok(())
    }

    /// Admission-control wait estimate for a fresh submission: the
    /// batches queued ahead of it, served at the observed mean batch
    /// latency across the live workers, plus one linger window. Before
    /// any batch has completed the estimate is just the linger —
    /// admission control never sheds on a cold start.
    fn estimated_wait(&self) -> Duration {
        let batches_ahead = self.core.queued().div_ceil(self.max_batch);
        let mean = self.metrics.histogram_mean("batch_latency").unwrap_or(0.0);
        let workers = self.core.live_workers().max(1);
        self.linger + Duration::from_secs_f64(mean * batches_ahead as f64 / workers as f64)
    }

    /// Enqueue a request, parking while the queue is full
    /// (backpressure). Fails typed: [`SubmitError::Invalid`] for
    /// malformed requests, [`SubmitError::Closed`] after shutdown,
    /// [`SubmitError::Shed`] when the request carries a deadline the
    /// estimated queue wait already exceeds (shedding at admission
    /// beats serving a response nobody can use). A request with
    /// `output_len > 0` re-enters the stream as that many KV-growing
    /// decode steps after its prefill completes; the single response
    /// arrives once the last step finishes.
    pub fn submit(&self, req: EmbeddedRequest) -> Result<(), SubmitError> {
        self.validate(&req)?;
        if let Some(deadline) = req.deadline {
            let est = self.estimated_wait();
            if Instant::now() + est >= deadline {
                self.metrics.inc("requests_shed", 1);
                return Err(SubmitError::Shed { estimated_wait_s: est.as_secs_f64() });
            }
        }
        self.core.submit(req)?;
        self.metrics.inc("queued", 1);
        Ok(())
    }

    /// Non-blocking enqueue: `Ok(false)` when the queue is full (the
    /// request is rejected and counted).
    pub fn try_submit(&self, req: EmbeddedRequest) -> Result<bool, SubmitError> {
        self.validate(&req)?;
        if let Some(deadline) = req.deadline {
            let est = self.estimated_wait();
            if Instant::now() + est >= deadline {
                self.metrics.inc("requests_shed", 1);
                return Err(SubmitError::Shed { estimated_wait_s: est.as_secs_f64() });
            }
        }
        if self.core.try_submit(req)? {
            self.metrics.inc("queued", 1);
            Ok(true)
        } else {
            self.metrics.inc("queue_rejected", 1);
            Ok(false)
        }
    }

    /// Next completed response, or `None` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.resp_rx.recv_timeout(timeout).ok()
    }

    /// Next terminal request failure (deadline expiry in queue or
    /// retries exhausted), or `None` on timeout. Nothing ever arrives
    /// here while the fault plane is disarmed and no deadlines are set.
    pub fn recv_failure_timeout(&self, timeout: Duration) -> Option<FailedRequest> {
        self.fail_rx.recv_timeout(timeout).ok()
    }

    /// Drain every failure delivered so far without blocking.
    pub fn drain_failures(&self) -> Vec<FailedRequest> {
        self.fail_rx.try_iter().collect()
    }

    /// Collect up to `n` responses, waiting at most `timeout` for each.
    pub fn drain(&self, n: usize, timeout: Duration) -> Vec<Response> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match self.recv_timeout(timeout) {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    /// Collect `n` terminal outcomes — successful responses and request
    /// failures combined — waiting at most `timeout` between arrivals.
    /// Under faults or deadlines some requests end on the failure
    /// channel; waiting on `drain` alone would stall until timeout.
    pub fn drain_outcomes(
        &self,
        n: usize,
        timeout: Duration,
    ) -> (Vec<Response>, Vec<FailedRequest>) {
        let mut resps = Vec::new();
        let mut fails = Vec::new();
        'outer: while resps.len() + fails.len() < n {
            let deadline = Instant::now() + timeout;
            loop {
                if let Ok(r) = self.resp_rx.try_recv() {
                    resps.push(r);
                    continue 'outer;
                }
                if let Ok(f) = self.fail_rx.try_recv() {
                    fails.push(f);
                    continue 'outer;
                }
                if Instant::now() >= deadline {
                    break 'outer;
                }
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        (resps, fails)
    }

    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plan_cache
    }

    /// Requests anywhere in the system still owed a final response.
    pub fn open(&self) -> usize {
        self.core.open()
    }

    /// Total worker condvar wakeups since startup (an idle batcher
    /// accumulates none — the event-driven regression surface).
    pub fn wakeups(&self) -> u64 {
        self.core.wakeups()
    }

    /// Wakeups whose poll found nothing to execute.
    pub fn idle_wakeups(&self) -> u64 {
        self.core.idle_wakeups()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Close the planner: admitted submits and in-flight decode
        // loops drain (`open` reaches zero), then every worker exits.
        self.core.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Per-request bookkeeping carried across one serve attempt.
struct AttemptMeta {
    submitted: Instant,
    phase: Phase,
    output_len: usize,
    deadline: Option<Instant>,
    attempts: u32,
}

/// Drop guard over one attempt's requests: until `defuse` runs, any
/// exit path — an `Err` from the serve, or a panic unwinding through
/// it (an injected worker panic) — routes every request to
/// retry-or-fail. Retries keep their open slot and re-enter through
/// the front-priority retry lane; exhausted requests release theirs
/// and deliver a typed [`FailedRequest`]. That is the exactly-once
/// backbone: a request leaves an attempt either defused (response or
/// decode re-entry), retried, or failed — never silently dropped,
/// never duplicated.
struct Attempt<'a> {
    core: &'a EventCore,
    metrics: &'a Registry,
    fail_tx: &'a Sender<FailedRequest>,
    max_retries: u32,
    reqs: Vec<EmbeddedRequest>,
    meta: Vec<AttemptMeta>,
}

impl Attempt<'_> {
    /// Route every remaining request to retry-or-fail.
    fn fail_remaining(&mut self) {
        for (req, m) in self.reqs.drain(..).zip(self.meta.drain(..)) {
            if m.attempts < self.max_retries {
                self.metrics.inc("request_retries", 1);
                // The retry keeps holding its open slot — the shutdown
                // drain keeps waiting for it.
                self.core
                    .reenter_retry(QueuedRequest::retry(req, m.submitted, m.attempts + 1));
            } else {
                self.metrics.inc("requests_failed", 1);
                // Release before sending: once the receiver observes
                // the terminal outcome, the open-slot accounting has
                // already settled.
                self.core.release_open(1);
                let _ = self.fail_tx.send(FailedRequest {
                    id: req.id,
                    error: RequestError::RetriesExhausted { attempts: m.attempts + 1 },
                    latency_s: m.submitted.elapsed().as_secs_f64(),
                });
            }
        }
    }

    /// Take ownership of the requests for the success path.
    fn defuse(&mut self) -> (Vec<EmbeddedRequest>, Vec<AttemptMeta>) {
        (std::mem::take(&mut self.reqs), std::mem::take(&mut self.meta))
    }
}

impl Drop for Attempt<'_> {
    fn drop(&mut self) {
        if !self.reqs.is_empty() {
            self.fail_remaining();
        }
    }
}

/// Execute one assembled window through the resilience protocol:
/// expired requests fail fast before touching a replica, the rest are
/// served by `serve` (the batcher passes a leased
/// `ReplicaLease::serve_checked`; tests and the chaos bench pass
/// simulated replicas so they exercise this exact protocol), and per
/// request the outcome is exactly one of — the next KV-grown decode
/// step re-entered, the final response emitted with its true
/// submit→response latency, a front-priority retry (failed serve,
/// budget left), or a typed failure. A panic unwinding out of `serve`
/// takes the retry-or-fail path via the [`Attempt`] guard, so even an
/// injected worker panic loses no request (any surviving worker picks
/// the retries up).
#[allow(clippy::too_many_arguments)]
pub fn run_attempt<F>(
    core: &EventCore,
    metrics: &Registry,
    resp_tx: &Sender<Response>,
    fail_tx: &Sender<FailedRequest>,
    max_retries: u32,
    prompt_len: usize,
    batch: Vec<QueuedRequest>,
    serve: F,
) where
    F: FnOnce(&[EmbeddedRequest]) -> Result<Vec<Response>>,
{
    // Deadline-expired requests fail fast at assembly: serving them
    // would spend replica time on responses nobody can use.
    let now = Instant::now();
    let mut reqs = Vec::with_capacity(batch.len());
    let mut meta = Vec::with_capacity(batch.len());
    for q in batch {
        if q.req.expired(now) {
            metrics.inc("requests_expired", 1);
            core.release_open(1);
            let _ = fail_tx.send(FailedRequest {
                id: q.req.id,
                error: RequestError::DeadlineExpired,
                latency_s: q.submitted.elapsed().as_secs_f64(),
            });
            continue;
        }
        meta.push(AttemptMeta {
            submitted: q.submitted,
            phase: q.req.phase,
            output_len: q.req.output_len,
            deadline: q.req.deadline,
            attempts: q.attempts,
        });
        reqs.push(q.req);
    }
    if reqs.is_empty() {
        return;
    }
    let mut attempt = Attempt { core, metrics, fail_tx, max_retries, reqs, meta };
    let pass_started = Instant::now();
    match serve(&attempt.reqs) {
        Ok(responses) if responses.len() == attempt.reqs.len() => {
            // One serve pass emits one token per request: the pass
            // wall time is each decode request's time-per-output-token
            // for this step.
            let pass_s = pass_started.elapsed().as_secs_f64();
            let (_reqs, meta) = attempt.defuse();
            for (mut resp, m) in responses.into_iter().zip(meta) {
                // SLO latency accounting: a completed prefill pass is
                // the request's first token (TTFT = submit -> now,
                // queueing included); every completed decode pass is
                // one output token (TPOT = the pass it rode in).
                match m.phase {
                    Phase::Prefill => {
                        metrics.observe("ttft", m.submitted.elapsed().as_secs_f64())
                    }
                    Phase::Decode { .. } => metrics.observe("tpot", pass_s),
                }
                if m.output_len > 0 {
                    // Autoregressive re-entry: this pass's output is
                    // the next step's input, the KV cache grows by the
                    // entry this pass wrote. The re-entry inherits the
                    // request's open slot (and deadline) directly.
                    let next = EmbeddedRequest {
                        id: resp.id,
                        hidden: resp.hidden,
                        phase: Phase::Decode { kv_len: m.phase.next_kv_len(prompt_len) },
                        output_len: m.output_len - 1,
                        deadline: m.deadline,
                    };
                    metrics.inc("decode_steps", 1);
                    core.reenter_decode(QueuedRequest::reentry(next, m.submitted));
                    continue;
                }
                resp.latency_s = m.submitted.elapsed().as_secs_f64();
                metrics.observe("request_latency", resp.latency_s);
                // Release before sending (the accounting must settle
                // before the receiver can observe the outcome); a gone
                // receiver just means the client stopped listening.
                core.release_open(1);
                let _ = resp_tx.send(resp);
            }
        }
        Ok(short) => {
            // A serve that returns the wrong cardinality is a failed
            // attempt: pairing responses to requests would be a guess.
            metrics.inc("serve_errors", 1);
            eprintln!(
                "serving worker: batch returned {} responses for {} requests",
                short.len(),
                attempt.reqs.len()
            );
            attempt.fail_remaining();
        }
        Err(e) => {
            metrics.inc("serve_errors", 1);
            eprintln!("serving worker: batch failed: {e:#}");
            attempt.fail_remaining();
        }
    }
}
