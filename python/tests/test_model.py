"""L2 model-stage tests: kernel-path stages vs the pure-jnp oracle, full
model forward agreement, and weight/packing sanity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny_cfg():
    return configs.tiny()


@pytest.fixture(scope="module")
def weights(tiny_cfg):
    return model.init_weights(tiny_cfg, seed=0)


def test_weights_are_deterministic(tiny_cfg):
    a = model.init_weights(tiny_cfg, seed=0)
    b = model.init_weights(tiny_cfg, seed=0)
    np.testing.assert_array_equal(a[0]["wq"], b[0]["wq"])
    np.testing.assert_array_equal(a[1]["exp_down"], b[1]["exp_down"])
    c = model.init_weights(tiny_cfg, seed=1)
    assert not np.array_equal(a[0]["wq"], c[0]["wq"])


def test_weight_shapes(tiny_cfg, weights):
    cfg = tiny_cfg
    lw = weights[0]
    assert lw["wq"].shape == (cfg.n_heads * cfg.d_k, cfg.embed)
    assert lw["gate_w"].shape == (cfg.n_experts, cfg.embed)
    assert lw["exp_gate"].shape == (cfg.n_experts, cfg.ffn_hidden, cfg.embed)
    assert lw["shared_down"].shape == (cfg.embed, cfg.ffn_hidden)
    assert len(weights) == cfg.n_layers


def test_attention_stage_matches_ref(tiny_cfg, weights):
    cfg, lw = tiny_cfg, weights[0]
    rng = np.random.default_rng(3)
    h = (rng.standard_normal((2, configs.SEQ_LEN, cfg.embed)) * 0.5).astype(np.float32)
    got = model.attention_stage(
        h, lw["wq"], lw["wk"], lw["wv"], lw["wo"],
        n_heads=cfg.n_heads, d_k=cfg.d_k, d_v=cfg.d_v)
    want = ref.ref_attention_block(
        h, lw["wq"], lw["wk"], lw["wv"], lw["wo"],
        cfg.n_heads, cfg.d_k, cfg.d_v)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_single_layer_matches_oracle(tiny_cfg, weights):
    cfg = tiny_cfg
    rng = np.random.default_rng(4)
    h = (rng.standard_normal((2, configs.SEQ_LEN, cfg.embed)) * 0.5).astype(np.float32)
    got = model.moe_layer(jnp.asarray(h), weights[0], cfg.top_k)
    want = ref.ref_moe_layer(jnp.asarray(h), weights[0], cfg.top_k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_full_model_matches_oracle(tiny_cfg, weights):
    cfg = tiny_cfg
    rng = np.random.default_rng(5)
    h = (rng.standard_normal((2, configs.SEQ_LEN, cfg.embed)) * 0.5).astype(np.float32)
    got = model.model_forward(jnp.asarray(h), weights, cfg.top_k)
    want = model.reference_forward(jnp.asarray(h), weights, cfg.top_k)
    assert got.shape == (2, configs.SEQ_LEN, cfg.embed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_noshared_variant_skips_shared(tiny_cfg):
    cfg_ns = configs.tiny_noshared()
    assert cfg_ns.n_shared == 0
    w = model.init_weights(cfg_ns, seed=0)
    assert "shared_gate" not in w[0]
    rng = np.random.default_rng(6)
    h = (rng.standard_normal((1, configs.SEQ_LEN, cfg_ns.embed)) * 0.5).astype(np.float32)
    out = model.model_forward(jnp.asarray(h), w, cfg_ns.top_k)
    assert np.isfinite(np.asarray(out)).all()


def test_outputs_are_finite_and_bounded(tiny_cfg, weights):
    # No norm layers: make sure the chosen weight scale keeps the
    # residual stream sane over all layers.
    rng = np.random.default_rng(7)
    h = (rng.standard_normal((4, configs.SEQ_LEN, tiny_cfg.embed)) * 0.5).astype(np.float32)
    out = np.asarray(model.model_forward(jnp.asarray(h), weights, tiny_cfg.top_k))
    assert np.isfinite(out).all()
    assert np.abs(out).max() < 100.0


def test_pack_weights_layout(weights):
    from compile.aot import pack_weights
    flat, table = pack_weights(weights)
    assert flat.dtype == np.float32
    # Offsets are contiguous and cover the buffer exactly.
    total = 0
    for t in table:
        assert t["offset"] == total
        total += int(np.prod(t["shape"]))
    assert total == flat.size
    # A spot tensor round-trips.
    t0 = table[0]
    size = int(np.prod(t0["shape"]))
    np.testing.assert_array_equal(
        flat[t0["offset"]:t0["offset"] + size].reshape(t0["shape"]),
        weights[0]["wq"])
