//! Vendored, dependency-free stand-in for the `anyhow` crate.
//!
//! The build image's crate registry is offline, so FinDEP carries the
//! small subset of `anyhow` it actually uses: an erased [`Error`] that
//! captures the source chain as text, the [`Context`] extension trait
//! for `Result` and `Option`, and the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros. Formatting matches `anyhow`'s conventions:
//! `{e}` prints the outermost message, `{e:#}` the full `a: b: c`
//! chain, and `{e:?}` a multi-line report with a `Caused by:` section.

use std::error::Error as StdError;
use std::fmt;

/// An erased error: an ordered chain of messages, outermost context
/// first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error in an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures of `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// Coherent with the generic impl above because `Error` deliberately
// does not implement `std::error::Error` (the same trick `anyhow`
// itself relies on).
impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_modes() {
        let e: Error = Error::from(io_err()).context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");

        // Chaining context on an already-anyhow Result.
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "missing file");
    }
}
