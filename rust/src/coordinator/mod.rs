//! The DEP serving coordinator — the L3 system of the paper, running
//! for real on PJRT-CPU.
//!
//! Topology mirrors §2.2 / Fig. 2: one AG worker executes attention +
//! gate + shared-expert artifacts (AG weights are replicated, so one
//! worker faithfully represents per-GPU behaviour and whole-AG
//! throughput is `ag ×` its rate); `eg` EG workers each own
//! `E/eg` experts and execute the expert-FFN artifact per routed token
//! group. A2E and E2A are channel links with optional α-β delay
//! injection so schedule differences remain observable on a host without
//! real interconnect.
//!
//! The pipeline executor consumes a [`crate::sched::PlanConfig`]
//! (produced by Algorithm 1, PPPipe, or naive) and issues fine-grained
//! tasks in the planned order — the same vocabulary the simulator
//! executes analytically.
//!
//! [`batcher`] stacks continuous batching on top, split event-driven
//! into a planning half and an execution half: [`planner`] is the pure
//! batch-assembly state machine (bounded submit queue, priority decode
//! re-entry lane, FIFO linger window, shutdown drain), [`executor`]
//! wraps it in one mutex plus condvars and runs work-stealing workers
//! that lease [`server::ReplicaPool`] replicas per ready batch — all
//! replicas share one metrics registry and one memoized plan cache.
//! [`threadpool`] preserves the retired polling thread-pool batcher as
//! the measured baseline for `benches/event_coordinator.rs`.

pub mod batcher;
pub mod executor;
pub mod faults;
pub mod links;
pub mod moe;
pub mod pipeline;
pub mod planner;
pub mod router;
pub mod server;
pub mod slo;
pub mod threadpool;
