//! The plan-driven pipeline executor: runs a real forward pass through
//! the AOT artifacts with FinDEP's fine-grained task structure.
//!
//! Thread topology per [`Pipeline`]:
//!
//! ```text
//!   caller (AG loop: attention → gate → dispatch → shared)
//!      │  A2E link (α-β delayed, FIFO)
//!      ▼
//!   EG workers (one per logical expert device, E/eg experts each)
//!      │  E2A link
//!      ▼
//!   collector (combine: residual + weighted expert outputs + shared)
//!      │  completion channel
//!      └──▶ caller (next layer's attention input)
//! ```
//!
//! The AG loop issues tasks in the planned order (`r1` chunks, `r2`
//! parts, ASAS/AASS) so schedule quality shows up as wall-clock
//! differences; numerics are schedule-independent (pinned by the golden
//! tests).

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::links::{Link, LinkDelay, Payload};
use crate::coordinator::moe::ModelHandle;
use crate::coordinator::router::{self, ExpertGroup, ExpertStats, Routing};
use crate::runtime::tensor::Tensor;
use crate::sched::Order;

/// Pipeline execution knobs (the subset of `PlanConfig` the real
/// executor needs; `m_e` is implied by routing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecConfig {
    pub r1: usize,
    pub r2: usize,
    pub order: Order,
    /// PPPipe semantics: run the shared expert inline right after
    /// attention (blocking A2E dispatch) instead of as its own task.
    pub fuse_shared: bool,
}

impl ExecConfig {
    pub fn findep(r1: usize, r2: usize, order: Order) -> Self {
        Self { r1, r2, order, fuse_shared: false }
    }

    pub fn pppipe(r1: usize) -> Self {
        Self { r1, r2: 1, order: Order::Asas, fuse_shared: true }
    }

    pub fn naive() -> Self {
        Self { r1: 1, r2: 1, order: Order::Asas, fuse_shared: true }
    }
}

/// Work unit crossing the A2E link: one fine-grained part of one chunk.
struct A2EMsg {
    layer: usize,
    chunk: usize,
    /// (group, packed input rows)
    work: Vec<(ExpertGroup, Tensor)>,
    bytes: usize,
}

impl Payload for A2EMsg {
    fn wire_bytes(&self) -> usize {
        self.bytes
    }
}

/// Expert outputs crossing the E2A link.
struct E2AMsg {
    layer: usize,
    chunk: usize,
    results: Vec<(ExpertGroup, Tensor)>,
    bytes: usize,
}

impl Payload for E2AMsg {
    fn wire_bytes(&self) -> usize {
        self.bytes
    }
}

enum CollectMsg {
    /// Start combining a (layer, chunk): `x` is the MoE input (residual
    /// base), expecting `parts` E2A messages and `shared` contributions.
    Open { layer: usize, chunk: usize, x: Tensor, parts: usize, wants_shared: bool },
    Shared { layer: usize, chunk: usize, y: Tensor },
    Expert(E2AMsg),
}

/// Per-forward-pass timing breakdown (seconds).
#[derive(Debug, Clone, Default)]
pub struct ForwardStats {
    pub total: f64,
    pub attention: f64,
    pub gate: f64,
    pub shared: f64,
    pub dispatch: f64,
    /// Time the AG loop spent blocked waiting for combines.
    pub wait: f64,
    pub tasks_issued: usize,
}

impl ForwardStats {
    /// Accumulate another pass's stats — the chunked `serve_batch`
    /// path stitches one stats object out of its per-chunk forwards.
    pub fn absorb(&mut self, other: &ForwardStats) {
        self.total += other.total;
        self.attention += other.attention;
        self.gate += other.gate;
        self.shared += other.shared;
        self.dispatch += other.dispatch;
        self.wait += other.wait;
        self.tasks_issued += other.tasks_issued;
    }
}

/// A persistent DEP pipeline over one loaded model.
pub struct Pipeline {
    model: ModelHandle,
    pub eg: usize,
    a2e: Vec<Link<A2EMsg>>, // one per EG worker (its slice of the fabric)
    collect_tx: Sender<CollectMsg>,
    done_rx: Receiver<(usize, Tensor)>, // (chunk, combined hidden)
    workers: Vec<JoinHandle<()>>,
    collector: Option<JoinHandle<()>>,
    /// EWMA of observed per-expert routing shares, fed by every routed
    /// layer-chunk of [`Pipeline::forward`]. Shared (`Arc`) so the
    /// coordinator's drift-driven placement re-solve reads the same
    /// histogram the data plane writes.
    expert_stats: Arc<Mutex<ExpertStats>>,
}

impl Pipeline {
    /// Spawn EG workers and the collector. `link_delay` applies per
    /// direction (None = raw host speed).
    pub fn new(model: ModelHandle, eg: usize, link_delay: Option<LinkDelay>) -> Result<Pipeline> {
        assert!(eg >= 1);
        let (done_tx, done_rx) = channel::<(usize, Tensor)>();
        let (collect_tx, collect_rx) = channel::<CollectMsg>();

        // Collector thread: accumulates combines, emits next-layer
        // hidden states.
        let collector = {
            std::thread::Builder::new()
                .name("findep-collector".into())
                .spawn(move || collector_loop(collect_rx, done_tx))
                .context("spawn collector")?
        };

        // E2A link feeds the collector.
        // Each EG worker gets its own A2E lane; E2A lanes merge into the
        // collector channel through one delayed link (the link thread
        // serializes, matching the single E2A resource of §3.2).
        let (e2a_in_tx, e2a_in_rx) = channel::<E2AMsg>();
        let e2a_link_tx = {
            let collect_tx = collect_tx.clone();
            let link: Link<E2AMsg> = Link::new(e2a_in_tx, link_delay);
            // Forward link output into collector.
            let fwd = std::thread::Builder::new()
                .name("findep-e2a-fwd".into())
                .spawn(move || {
                    while let Ok(msg) = e2a_in_rx.recv() {
                        if collect_tx.send(CollectMsg::Expert(msg)).is_err() {
                            break;
                        }
                    }
                })
                .context("spawn e2a forwarder")?;
            // Keep the forwarder alive by leaking its handle into the
            // worker list later.
            (link, fwd)
        };
        let (e2a_link, e2a_fwd) = e2a_link_tx;
        let e2a_link = std::sync::Arc::new(e2a_link);

        let mut a2e = Vec::new();
        let mut workers = vec![e2a_fwd];
        for w in 0..eg {
            let (work_tx, work_rx) = channel::<A2EMsg>();
            let link = Link::new(work_tx, link_delay);
            a2e.push(link);
            let model_w = model.clone();
            let e2a = e2a_link.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("findep-eg{w}"))
                    .spawn(move || eg_worker_loop(w, model_w, work_rx, e2a))
                    .context("spawn EG worker")?,
            );
        }

        let expert_stats = Arc::new(Mutex::new(ExpertStats::new(model.model.n_experts, 0.1)));
        Ok(Pipeline {
            model,
            eg,
            a2e,
            collect_tx,
            done_rx,
            workers,
            collector: Some(collector),
            expert_stats,
        })
    }

    pub fn model(&self) -> &ModelHandle {
        &self.model
    }

    /// The shared observed expert-popularity histogram (see the field).
    pub fn expert_stats(&self) -> &Arc<Mutex<ExpertStats>> {
        &self.expert_stats
    }

    /// Run one forward pass over `batch` `[B, S, M]` with B = r1·m_a.
    /// Returns the final hidden states and the timing breakdown.
    pub fn forward(&self, batch: &Tensor, cfg: ExecConfig) -> Result<(Tensor, ForwardStats)> {
        let t_start = Instant::now();
        let mut stats = ForwardStats::default();
        let b = batch.shape[0];
        let s = batch.shape[1];
        let m = batch.shape[2];
        anyhow::ensure!(b % cfg.r1 == 0, "batch {b} not divisible by r1 {}", cfg.r1);
        let m_a = b / cfg.r1;
        anyhow::ensure!(
            self.model.engine.bucket_for("attention", m_a)? == m_a,
            "m_a {m_a} is not an attention bucket"
        );
        let t_layers = self.model.model.n_layers;
        let has_shared = self.model.model.n_shared > 0;

        // Chunk the batch along samples.
        let mut hidden: Vec<Tensor> = (0..cfg.r1)
            .map(|i| {
                let w = s * m;
                Tensor::new(
                    vec![m_a, s, m],
                    batch.data[i * m_a * w..(i + 1) * m_a * w].to_vec(),
                )
            })
            .collect();

        for layer in 0..t_layers {
            // Stage closure: attention + gate + dispatch for chunk i.
            let run_attn_dispatch = |i: usize,
                                         hidden: &mut [Tensor],
                                         stats: &mut ForwardStats|
             -> Result<()> {
                let t0 = Instant::now();
                let h = self.model.attention(layer, &hidden[i])?;
                stats.attention += t0.elapsed().as_secs_f64();
                stats.tasks_issued += 1;

                // PPPipe fuses the shared expert into the attention
                // task: it runs *before* dispatch, delaying A2E.
                let fused_shared = if cfg.fuse_shared && has_shared {
                    let x = h.reshaped(vec![m_a * s, m]);
                    let t0 = Instant::now();
                    let y = self.model.shared_expert(layer, &x)?;
                    stats.shared += t0.elapsed().as_secs_f64();
                    stats.tasks_issued += 1;
                    Some(y)
                } else {
                    None
                };

                let x = h.reshaped(vec![m_a * s, m]);
                let t0 = Instant::now();
                let (probs, idx) = self.model.gate(layer, &x)?;
                stats.gate += t0.elapsed().as_secs_f64();

                let routing = router::route(&probs, &idx, self.model.model.n_experts)?;
                self.expert_stats
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .observe(&routing);
                let parts = routing.split_parts(cfg.r2);

                self.collect_tx
                    .send(CollectMsg::Open {
                        layer,
                        chunk: i,
                        x: x.clone(),
                        parts: parts.iter().map(|p| self.lanes_used(p)).sum(),
                        wants_shared: has_shared,
                    })
                    .ok()
                    .context("collector gone")?;
                if let Some(y) = fused_shared {
                    self.collect_tx
                        .send(CollectMsg::Shared { layer, chunk: i, y })
                        .ok()
                        .context("collector gone")?;
                }

                let t0 = Instant::now();
                for part in &parts {
                    self.dispatch_part(layer, i, &x, part)?;
                }
                stats.dispatch += t0.elapsed().as_secs_f64();
                hidden[i] = h;
                Ok(())
            };

            let run_shared = |i: usize, hidden: &[Tensor], stats: &mut ForwardStats| -> Result<()> {
                if !has_shared || cfg.fuse_shared {
                    return Ok(());
                }
                let x = hidden[i].reshaped(vec![m_a * s, m]);
                let t0 = Instant::now();
                let y = self.model.shared_expert(layer, &x)?;
                stats.shared += t0.elapsed().as_secs_f64();
                stats.tasks_issued += 1;
                self.collect_tx
                    .send(CollectMsg::Shared { layer, chunk: i, y })
                    .ok()
                    .context("collector gone")?;
                Ok(())
            };

            match cfg.order {
                Order::Asas => {
                    for i in 0..cfg.r1 {
                        run_attn_dispatch(i, &mut hidden, &mut stats)?;
                        run_shared(i, &hidden, &mut stats)?;
                    }
                }
                Order::Aass => {
                    for i in 0..cfg.r1 {
                        run_attn_dispatch(i, &mut hidden, &mut stats)?;
                    }
                    for i in 0..cfg.r1 {
                        run_shared(i, &hidden, &mut stats)?;
                    }
                }
            }

            // Collect combined outputs for every chunk (they arrive as
            // their parts complete; chunks may finish out of order).
            let t0 = Instant::now();
            let mut got = 0;
            while got < cfg.r1 {
                let (chunk, h_next) = self
                    .done_rx
                    .recv()
                    .ok()
                    .context("collector channel closed")?;
                hidden[chunk] = h_next.reshaped(vec![m_a, s, m]);
                got += 1;
            }
            stats.wait += t0.elapsed().as_secs_f64();
        }

        // Reassemble the batch.
        let mut out = Vec::with_capacity(b * s * m);
        for h in &hidden {
            out.extend_from_slice(&h.data);
        }
        stats.total = t_start.elapsed().as_secs_f64();
        Ok((Tensor::new(vec![b, s, m], out), stats))
    }

    /// Number of EG lanes a part touches (collector bookkeeping).
    fn lanes_used(&self, part: &Routing) -> usize {
        let mut used = vec![false; self.eg];
        for g in &part.groups {
            used[self.worker_of(g.expert)] = true;
        }
        used.iter().filter(|&&u| u).count()
    }

    fn worker_of(&self, expert: usize) -> usize {
        let per = self.model.model.n_experts.div_ceil(self.eg);
        expert / per
    }

    /// Send one fine-grained part across A2E, splitting per EG worker.
    fn dispatch_part(&self, layer: usize, chunk: usize, x: &Tensor, part: &Routing) -> Result<()> {
        let mut per_worker: BTreeMap<usize, Vec<(ExpertGroup, Tensor)>> = BTreeMap::new();
        for g in &part.groups {
            let packed = router::pack(x, g);
            per_worker.entry(self.worker_of(g.expert)).or_default().push((g.clone(), packed));
        }
        for (w, work) in per_worker {
            let bytes: usize = work.iter().map(|(_, t)| t.numel() * 4).sum();
            self.a2e[w]
                .send(A2EMsg { layer, chunk, work, bytes })
                .ok()
                .context("EG worker gone")?;
        }
        Ok(())
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // Close A2E lanes: workers see disconnect and exit; then the E2A
        // link closes, the collector sees disconnect and exits.
        self.a2e.clear();
        let (dead_tx, _) = channel();
        self.collect_tx = dead_tx;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
    }
}

fn eg_worker_loop(
    _id: usize,
    model: ModelHandle,
    work_rx: Receiver<A2EMsg>,
    e2a: std::sync::Arc<Link<E2AMsg>>,
) {
    while let Ok(msg) = work_rx.recv() {
        let mut results = Vec::with_capacity(msg.work.len());
        for (group, x) in msg.work {
            match model.expert(msg.layer, group.expert, &x) {
                Ok(y) => results.push((group, y)),
                Err(e) => {
                    eprintln!("EG worker: expert {} failed: {e:#}", group.expert);
                    return;
                }
            }
        }
        let bytes: usize = results.iter().map(|(_, t)| t.numel() * 4).sum();
        if e2a.send(E2AMsg { layer: msg.layer, chunk: msg.chunk, results, bytes }).is_err() {
            return;
        }
    }
}

struct CombineState {
    acc: Tensor,
    parts_left: usize,
    shared_left: bool,
}

fn collector_loop(rx: Receiver<CollectMsg>, done_tx: Sender<(usize, Tensor)>) {
    let mut states: BTreeMap<(usize, usize), CombineState> = BTreeMap::new();
    while let Ok(msg) = rx.recv() {
        let key = match msg {
            CollectMsg::Open { layer, chunk, x, parts, wants_shared } => {
                // residual base: out = x + routed + shared
                let st =
                    CombineState { acc: x, parts_left: parts, shared_left: wants_shared };
                states.insert((layer, chunk), st);
                (layer, chunk)
            }
            CollectMsg::Shared { layer, chunk, y } => {
                let st = states.get_mut(&(layer, chunk)).expect("shared before open");
                for (a, b) in st.acc.data.iter_mut().zip(&y.data) {
                    *a += b;
                }
                st.shared_left = false;
                (layer, chunk)
            }
            CollectMsg::Expert(m) => {
                let st = states.get_mut(&(m.layer, m.chunk)).expect("expert before open");
                for (group, y) in &m.results {
                    router::combine_into(&mut st.acc, group, y);
                }
                st.parts_left -= 1;
                (m.layer, m.chunk)
            }
        };
        let done = states
            .get(&key)
            .map(|st| st.parts_left == 0 && !st.shared_left)
            .unwrap_or(false);
        if done {
            // Move the accumulator out without cloning (§Perf L3).
            let st = states.remove(&key).unwrap();
            if done_tx.send((key.1, st.acc)).is_err() {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    fn pipeline(eg: usize) -> Option<Pipeline> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let model = ModelHandle::load(&dir, true).unwrap();
        Some(Pipeline::new(model, eg, None).unwrap())
    }

    fn test_batch(b: usize, s: usize, m: usize) -> Tensor {
        let data: Vec<f32> =
            (0..b * s * m).map(|i| (((i * 2654435761) % 97) as f32 - 48.0) * 0.01).collect();
        Tensor::new(vec![b, s, m], data)
    }

    #[test]
    fn forward_shapes_and_stats() {
        let Some(p) = pipeline(2) else { return };
        let (s, m) = (p.model().seq_len, p.model().model.embed);
        let batch = test_batch(2, s, m);
        let (out, stats) = p.forward(&batch, ExecConfig::findep(2, 2, Order::Asas)).unwrap();
        assert_eq!(out.shape, vec![2, s, m]);
        assert!(stats.total > 0.0);
        assert!(stats.attention > 0.0);
        assert!(stats.tasks_issued > 0);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn schedules_agree_numerically() {
        // The same batch through naive / PPPipe / FinDEP (both orders)
        // must produce identical outputs: scheduling must never change
        // numerics.
        let Some(p) = pipeline(2) else { return };
        let (s, m) = (p.model().seq_len, p.model().model.embed);
        let batch = test_batch(4, s, m);
        let (base, _) = p.forward(&batch, ExecConfig::naive()).unwrap();
        for cfg in [
            ExecConfig::pppipe(2),
            ExecConfig::findep(2, 2, Order::Asas),
            ExecConfig::findep(4, 4, Order::Aass),
            ExecConfig::findep(2, 1, Order::Aass),
        ] {
            let (out, _) = p.forward(&batch, cfg).unwrap();
            let diff = out.max_abs_diff(&base);
            assert!(diff < 1e-4, "schedule changed numerics by {diff} ({cfg:?})");
        }
    }

    #[test]
    fn different_eg_counts_agree() {
        let Some(p1) = pipeline(1) else { return };
        let (s, m) = (p1.model().seq_len, p1.model().model.embed);
        let batch = test_batch(2, s, m);
        let (o1, _) = p1.forward(&batch, ExecConfig::findep(1, 1, Order::Asas)).unwrap();
        drop(p1);
        let p4 = pipeline(4).unwrap();
        let (o4, _) = p4.forward(&batch, ExecConfig::findep(2, 2, Order::Asas)).unwrap();
        assert!(o1.max_abs_diff(&o4) < 1e-4);
    }

    #[test]
    fn rejects_bad_batch_split() {
        let Some(p) = pipeline(1) else { return };
        let (s, m) = (p.model().seq_len, p.model().model.embed);
        let batch = test_batch(3, s, m);
        assert!(p.forward(&batch, ExecConfig::findep(2, 1, Order::Asas)).is_err());
    }
}
