//! Continuous-batching serving tests: the plan cache memoizes Adaptive
//! shapes without changing plans or numerics, oversize batches split
//! into chunks without padding leaks, and the queued path is
//! response-equivalent to direct `serve_batch` calls.
//!
//! All tests need the AOT artifacts (`make artifacts`) and skip
//! otherwise, matching the rest of the runtime/coordinator tier.

use std::time::Duration;

use findep::coordinator::batcher::{Batcher, BatcherConfig};
use findep::coordinator::moe::ModelHandle;
use findep::coordinator::server::{EmbeddedRequest, Policy, Response, Server};
use findep::runtime::artifacts_dir;
use findep::runtime::tensor::Tensor;
use findep::sched::Order;
use findep::util::proptest::{check, ensure, Config};

fn skip() -> bool {
    let missing = !artifacts_dir().join("manifest.json").exists();
    if missing {
        eprintln!("skipping: run `make artifacts` first");
    }
    missing
}

fn load_model() -> ModelHandle {
    ModelHandle::load(&artifacts_dir(), true).unwrap()
}

fn mk_server(eg: usize) -> Server {
    Server::new(load_model(), eg, None).unwrap()
}

fn reqs(ids: std::ops::Range<u64>, s: usize, m: usize) -> Vec<EmbeddedRequest> {
    ids.map(|i| EmbeddedRequest::synthetic(i, s, m)).collect()
}

#[test]
fn plan_cache_memoizes_byte_identical_configs() {
    if skip() {
        return;
    }
    let srv = mk_server(2);
    // First plan for a shape misses, the second hits — and both are the
    // identical configuration.
    let p1 = srv.plan_adaptive(4);
    assert_eq!(srv.plan_cache().misses(), 1);
    assert_eq!(srv.plan_cache().hits(), 0);
    let p2 = srv.plan_adaptive(4);
    assert_eq!(srv.plan_cache().misses(), 1);
    assert_eq!(srv.plan_cache().hits(), 1);
    assert_eq!(p1, p2, "cache hit changed the plan");
    // 5 and 6 requests both pad to capacity 6 (m_a=2, r1=3) -> same
    // shape key, one solve.
    let p3 = srv.plan_adaptive(6);
    assert_eq!(srv.plan_cache().misses(), 2);
    let p4 = srv.plan_adaptive(5);
    assert_eq!(srv.plan_cache().misses(), 2);
    assert_eq!(srv.plan_cache().hits(), 2);
    assert_eq!(p3, p4, "equal padded capacity must reuse the plan");

    // A cache-disabled server re-solves per batch but lands on the
    // byte-identical configuration.
    let mut cold = mk_server(2);
    cold.cache_plans = false;
    let pc1 = cold.plan_adaptive(4);
    let pc2 = cold.plan_adaptive(4);
    assert_eq!(cold.plan_cache().misses() + cold.plan_cache().hits(), 0);
    assert_eq!(p1, pc1, "cold solve disagrees with cached solve");
    assert_eq!(pc1, pc2, "cold solve is not deterministic");
}

#[test]
fn cache_disabled_run_matches_cached_run_numerically() {
    if skip() {
        return;
    }
    let cached = mk_server(2);
    let mut cold = mk_server(2);
    cold.cache_plans = false;
    let s = cached.pipeline.model().seq_len;
    let m = cached.pipeline.model().model.embed;
    let mut id = 0u64;
    for n in [4usize, 3, 7, 4, 8] {
        let batch = reqs(id..id + n as u64, s, m);
        id += n as u64;
        let (a, _) = cached.serve_batch(&batch, Policy::Adaptive).unwrap();
        let (b, _) = cold.serve_batch(&batch, Policy::Adaptive).unwrap();
        assert_eq!(a.len(), n);
        assert_eq!(b.len(), n);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            let diff = x.hidden.max_abs_diff(&y.hidden);
            assert!(diff < 1e-4, "cache changed numerics by {diff} (n={n})");
        }
    }
    assert!(cached.plan_cache().hits() > 0, "same-shape batches must hit the cache");
}

#[test]
fn oversize_batches_split_without_padding_leaks() {
    if skip() {
        return;
    }
    let srv = mk_server(2);
    let s = srv.pipeline.model().seq_len;
    let m = srv.pipeline.model().model.embed;
    // Capacity for PpPipe{r1:2} is 2 × max bucket = 8; 10 requests
    // split into chunks of 8 + 2 (the second chunk padded to 4).
    let batch = reqs(0..10, s, m);
    let (resp, stats) = srv.serve_batch(&batch, Policy::PpPipe { r1: 2 }).unwrap();
    assert_eq!(resp.len(), 10, "split batch lost responses");
    assert!(stats.total > 0.0);
    for (i, r) in resp.iter().enumerate() {
        assert_eq!(r.id, i as u64, "split batch broke request order");
        // Each response must match the same request served alone —
        // padding from either chunk must not leak in.
        let (solo, _) = srv.serve_batch(&batch[i..i + 1], Policy::Naive).unwrap();
        let diff = r.hidden.max_abs_diff(&solo[0].hidden);
        assert!(diff < 1e-4, "request {i} drifted by {diff} across the split");
    }

    // The strict flag restores the pre-queue error.
    let mut strict = mk_server(2);
    strict.strict = true;
    let err = strict.serve_batch(&batch, Policy::PpPipe { r1: 2 }).unwrap_err();
    assert!(format!("{err:#}").contains("split upstream"), "unexpected error: {err:#}");

    // A zero-capacity policy errors cleanly instead of panicking in the
    // chunk split.
    let err = srv
        .serve_batch(&batch[..1], Policy::FinDep { r1: 0, r2: 1, order: Order::Asas })
        .unwrap_err();
    assert!(format!("{err:#}").contains("zero capacity"), "unexpected error: {err:#}");
}

#[test]
fn batcher_drains_fifo_with_one_worker() {
    if skip() {
        return;
    }
    let cfg = BatcherConfig {
        workers: 1,
        max_batch: 4,
        policy: Policy::FinDep { r1: 2, r2: 2, order: Order::Asas },
        linger: Duration::from_micros(200),
        ..Default::default()
    };
    let model = load_model();
    let (s, m) = (model.seq_len, model.model.embed);
    let batcher = Batcher::new(model, cfg).unwrap();
    for i in 0..12u64 {
        batcher.submit(EmbeddedRequest::synthetic(i, s, m)).unwrap();
    }
    let resps = batcher.drain(12, Duration::from_secs(30));
    assert_eq!(resps.len(), 12, "batcher lost responses");
    for (i, r) in resps.iter().enumerate() {
        assert_eq!(r.id, i as u64, "single-worker draining must be FIFO");
        assert!(r.latency_s > 0.0, "per-request latency must be measured");
    }
    // Every request passed through the queue-wait histogram, and the
    // serving counters add up.
    assert_eq!(batcher.metrics().histogram_count("queue_wait"), 12);
    assert_eq!(batcher.metrics().counter("requests"), 12);
    assert_eq!(batcher.metrics().counter("queued"), 12);
    assert!(batcher.metrics().counter("batches_assembled") >= 1);
    // Fixed policies never consult the plan cache.
    assert_eq!(batcher.plan_cache().misses(), 0);

    // Malformed requests are rejected at the submission boundary (a
    // bad request must never sink an assembled batch in a worker).
    let bad = EmbeddedRequest {
        id: 99,
        hidden: Tensor::zeros(vec![1]),
        phase: findep::config::Phase::Prefill,
        output_len: 0,
        deadline: None,
    };
    assert!(matches!(
        batcher.submit(bad),
        Err(findep::coordinator::batcher::SubmitError::Invalid { id: 99, .. })
    ));
    assert_eq!(batcher.metrics().counter("queued"), 12, "rejected request was queued");
}

#[test]
fn queued_responses_equal_direct_serve_batch() {
    if skip() {
        return;
    }
    let model = load_model();
    let (s, m) = (model.seq_len, model.model.embed);
    let direct = Server::new(model.clone(), 2, None).unwrap();
    check("queue == direct", &Config::with_cases(5), |rng| {
        let n = 1 + rng.usize_below(12);
        let policy = match rng.usize_below(4) {
            0 => Policy::Naive,
            1 => Policy::PpPipe { r1: 2 },
            2 => Policy::FinDep { r1: 2, r2: 2, order: Order::Asas },
            _ => Policy::Adaptive,
        };
        let workers = 1 + rng.usize_below(2);
        let batch = reqs(0..n as u64, s, m);
        let (want, _) = direct
            .serve_batch(&batch, policy)
            .map_err(|e| format!("direct serve failed: {e:#}"))?;

        let cfg = BatcherConfig {
            workers,
            max_batch: 1 + rng.usize_below(8),
            policy,
            linger: Duration::from_micros(200),
            ..Default::default()
        };
        let batcher =
            Batcher::new(model.clone(), cfg).map_err(|e| format!("batcher: {e:#}"))?;
        for r in &batch {
            batcher.submit(r.clone()).map_err(|e| format!("submit: {e:#}"))?;
        }
        let mut got: Vec<Response> = batcher.drain(n, Duration::from_secs(30));
        ensure(got.len() == n, format!("lost responses: {} of {n}", got.len()))?;
        got.sort_by_key(|r| r.id);
        for (w, g) in want.iter().zip(&got) {
            ensure(w.id == g.id, format!("id mismatch {} vs {}", w.id, g.id))?;
            let diff = w.hidden.max_abs_diff(&g.hidden);
            ensure(
                diff < 1e-4,
                format!("queue changed numerics by {diff} (n={n}, {policy:?})"),
            )?;
        }
        Ok(())
    });
}
