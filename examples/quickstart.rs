//! Quickstart: the FinDEP public API in five minutes.
//!
//! 1. Describe a model + testbed (§2, Table 2).
//! 2. Build the α-β stage models (§4.1).
//! 3. Run Algorithm 1 to get the near-optimal fine-grained schedule.
//! 4. Compare against naive DEP and the best-configured PPPipe.
//! 5. Inspect the winning schedule on the discrete-event simulator.
//!
//! Run: `cargo run --release --example quickstart`

use findep::baselines::{best_naive, best_pppipe};
use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::sched::Plan;
use findep::simulator::{simulate, ScheduleTrace};
use findep::solver::{solve, Instance, SolverParams};

fn main() {
    // 1. A DeepSeek-V2-shaped MoE (shared experts) on testbed A
    //    (8×A6000), split 3 attention GPUs / 5 expert GPUs, prefill
    //    sequence length 4096.
    let model = ModelConfig::deepseek_v2(8);
    let testbed = Testbed::a();
    let split = GroupSplit::new(3, 5);
    let inst = Instance::new(model.clone(), testbed, split, 4096);

    // 2-3. Solve (Algorithm 1: Pareto frontier over (m_a, r1), convex
    //      search over r2, both AG execution orders).
    let params = SolverParams::default();
    let sol = solve(&inst, &params).expect("instance is feasible");
    println!("FinDEP schedule : {}", sol.config.describe());
    println!("  throughput    : {:.1} tokens/s", sol.throughput_tokens);
    println!("  makespan      : {:.2} ms / forward pass", sol.makespan * 1e3);
    println!("  solver        : {:.2} ms ({} evals)", sol.solve_seconds * 1e3, sol.evals);

    // 4. Baselines, each at its own best configuration.
    let naive = best_naive(&inst, params.ma_cap).unwrap();
    let pppipe = best_pppipe(&inst, &params).unwrap();
    println!("\nBaselines:");
    println!(
        "  naive DEP     : {:>9.1} tokens/s  ({})",
        naive.throughput_tokens,
        naive.config.describe()
    );
    println!(
        "  best PPPipe   : {:>9.1} tokens/s  ({})",
        pppipe.throughput_tokens,
        pppipe.config.describe()
    );
    println!(
        "  FinDEP        : {:>9.1} tokens/s  ({:.2}x over PPPipe, {:.2}x over naive)",
        sol.throughput_tokens,
        sol.throughput_tokens / pppipe.throughput_tokens,
        sol.throughput_tokens / naive.throughput_tokens
    );

    // 5. Materialize and inspect the winning schedule (first 2 layers).
    let sm = inst.stage_models();
    let plan = Plan::build(&sm, sol.config, 2, split.ag, inst.seq_len);
    let sim = simulate(&plan);
    let trace = ScheduleTrace::from_sim(&plan, &sim);
    println!("\nFinDEP schedule, first two layers (A=attn S=shared >=A2E E=expert <=E2A):");
    print!("{}", trace.ascii_gantt(100));
    println!(
        "exposed (non-overlapped) communication: {:.3} ms",
        trace.non_overlapped_comm() * 1e3
    );
}
