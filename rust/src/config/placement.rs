//! First-class expert placement and per-expert load.
//!
//! The paper's cost model assumes near-uniform gating: every expert
//! shard serves `E/eg` experts and receives `(E/eg)·m_e` tokens per
//! fine-grained part (Eqs. 3-4). Production MoE traffic is Zipf-skewed,
//! so the max-loaded shard — not the average one — sets the expert-stage
//! duration. This module makes both halves of that assumption explicit:
//!
//! * [`ExpertLoad`] — per-expert token shares, stored *relative to
//!   uniform* (`rel_e = p_e·E`, mean exactly 1). Uniform traffic is the
//!   all-ones vector, so per-shard sums of uniform load are exact small
//!   integers in f64 and the legacy closed forms are reproduced bit for
//!   bit (the foundation of `tests/placement_equivalence.rs` and the
//!   exact-tie gate in `benches/expert_skew.rs`).
//! * [`ExpertPlacement`] — which experts live on which expert-pool
//!   shard, with a per-expert replication factor `c_e ≥ 1`. The
//!   [`ExpertPlacement::uniform`] kind *is* the legacy idealized
//!   assumption (fractional `E/eg` balance, one replica each); explicit
//!   placements price the real max-loaded shard.
//!
//! The stage models consume two scalars from a placement:
//! `alpha_shard_experts()` (kernel launches per part — how many expert
//! FFNs the busiest shard runs) and `beta_shard_load(load)` (the
//! max-shard work factor `F = max_d Σ_{e∈d} rel_e/c_e`, replacing the
//! uniform `E/eg`). Replicating a hot expert divides its load across
//! its `c_e` hosts, which is exactly the lever "Fast MoE Inference via
//! Predictive Prefetching and Expert Replication" pulls; the solver
//! trades the extra HBM (accounted by `MemoryModel`) for a smaller `F`.

use crate::util::rng::Rng;

/// Structural fingerprint of an [`ExpertPlacement`] — the plan-cache
/// discriminator, exactly parallel to `ProfileId`/`ClusterId`. The
/// canonical uniform placement is the reserved [`PlacementId::UNIFORM`];
/// every explicit placement hashes its shard lists (FNV-1a), with 0
/// remapped so no explicit placement can alias the uniform slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PlacementId(pub u64);

impl PlacementId {
    /// The idealized uniform placement every legacy code path assumes.
    pub const UNIFORM: PlacementId = PlacementId(0);
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(h: &mut u64, word: u64) {
    for b in word.to_le_bytes() {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

/// Per-expert token shares, relative to uniform: `rel_e = p_e·E` where
/// `p_e` is the probability a routed assignment lands on expert `e`.
/// The vector always sums to `E` (mean 1); uniform traffic is exactly
/// `[1.0; E]`.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertLoad {
    rel: Vec<f64>,
}

impl ExpertLoad {
    /// Uniform gating: every expert receives the mean share, exactly.
    pub fn uniform(n_experts: usize) -> Self {
        assert!(n_experts > 0, "ExpertLoad over zero experts");
        Self { rel: vec![1.0; n_experts] }
    }

    /// Zipf-skewed gating: expert `e` (hottest first) receives share
    /// `∝ 1/(e+1)^s`. `s = 0` reduces to [`ExpertLoad::uniform`]
    /// exactly. A temperature-flattened Zipf `(1/(e+1)^s)^{1/τ}` is the
    /// same family at effective exponent `s/τ` — see
    /// [`LoadProfile::Zipf`].
    pub fn zipf(n_experts: usize, s: f64) -> Self {
        let weights: Vec<f64> = (0..n_experts).map(|e| ((e + 1) as f64).powf(-s)).collect();
        Self::from_weights(&weights)
    }

    /// Normalize arbitrary non-negative weights (e.g. a router's EWMA
    /// popularity histogram) into relative loads. An all-zero histogram
    /// (nothing observed yet) is uniform.
    pub fn from_weights(weights: &[f64]) -> Self {
        assert!(!weights.is_empty(), "ExpertLoad over zero experts");
        let n = weights.len();
        let sum: f64 = weights.iter().sum();
        if sum <= 0.0 {
            return Self::uniform(n);
        }
        Self { rel: weights.iter().map(|&w| w * n as f64 / sum).collect() }
    }

    pub fn n_experts(&self) -> usize {
        self.rel.len()
    }

    /// Relative load of expert `e` (1 = the uniform mean).
    pub fn rel(&self, e: usize) -> f64 {
        self.rel[e]
    }

    pub fn rels(&self) -> &[f64] {
        &self.rel
    }

    /// Probability share of expert `e` (`rel_e / E`).
    pub fn share(&self, e: usize) -> f64 {
        self.rel[e] / self.rel.len() as f64
    }

    /// Exactly the all-ones vector — the pinned special case that keeps
    /// legacy arithmetic bit-identical.
    pub fn is_uniform(&self) -> bool {
        self.rel.iter().all(|&r| r == 1.0)
    }

    /// Hottest expert's relative load — 1.0 for uniform traffic, the
    /// headline skew statistic otherwise.
    pub fn max_rel(&self) -> f64 {
        self.rel.iter().cloned().fold(0.0, f64::max)
    }

    /// L∞ distance between two load vectors in relative-load units
    /// (so a threshold of e.g. 0.5 means "some expert's share drifted
    /// by half the uniform mean"). The server's re-solve trigger.
    pub fn linf_drift(&self, other: &ExpertLoad) -> f64 {
        assert_eq!(self.rel.len(), other.rel.len(), "load drift across expert counts");
        self.rel
            .iter()
            .zip(&other.rel)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Precompute a categorical sampler over experts (CDF + binary
    /// search; allocation-free per draw after this setup).
    pub fn sampler(&self) -> ExpertLoadSampler {
        let mut cdf = Vec::with_capacity(self.rel.len());
        let mut acc = 0.0;
        for &r in &self.rel {
            acc += r;
            cdf.push(acc);
        }
        ExpertLoadSampler { cdf }
    }

    /// Monte-Carlo per-part load factors for the simulator: route
    /// `tokens_per_part` assignments per fine-grained part through this
    /// load, and return each part's realized max-shard work divided by
    /// the placement's *expected* max-shard work (mean ≈ 1, so a factor
    /// multiplies the analytic `m_e` without re-deriving coefficients).
    /// Seeded and deterministic; one counts buffer reused across parts.
    pub fn sample_part_factors(
        &self,
        placement: &ExpertPlacement,
        tokens_per_part: usize,
        n_parts: usize,
        rng: &mut Rng,
    ) -> Vec<f64> {
        assert_eq!(placement.n_experts(), self.rel.len());
        assert!(tokens_per_part > 0, "empty fine-grained part");
        let sampler = self.sampler();
        let expected =
            tokens_per_part as f64 * placement.beta_shard_load(self) / self.rel.len() as f64;
        let mut counts = vec![0.0f64; self.rel.len()];
        let mut out = Vec::with_capacity(n_parts);
        for _ in 0..n_parts {
            counts.iter_mut().for_each(|c| *c = 0.0);
            for _ in 0..tokens_per_part {
                counts[sampler.sample(rng)] += 1.0;
            }
            out.push(placement.shard_work(&counts) / expected);
        }
        out
    }
}

/// Reusable categorical sampler built by [`ExpertLoad::sampler`].
#[derive(Debug, Clone)]
pub struct ExpertLoadSampler {
    cdf: Vec<f64>,
}

impl ExpertLoadSampler {
    /// Draw one expert index (binary search on the CDF; no allocation).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let total = *self.cdf.last().expect("empty sampler");
        let u = rng.f64() * total;
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).expect("NaN in load CDF")) {
            Ok(i) | Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// Declarative gating-skew family carried by configs and workloads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadProfile {
    /// The paper's near-uniform gating assumption.
    Uniform,
    /// Zipf exponent `s` flattened by temperature `temp`: share of the
    /// rank-`e` expert `∝ (1/(e+1)^s)^{1/temp}`, i.e. effective
    /// exponent `s/temp`. `temp = 1` is plain Zipf; `temp → ∞` is
    /// uniform.
    Zipf { s: f64, temp: f64 },
}

impl LoadProfile {
    pub fn zipf(s: f64) -> Self {
        LoadProfile::Zipf { s, temp: 1.0 }
    }

    /// Materialize the per-expert load vector.
    pub fn load(&self, n_experts: usize) -> ExpertLoad {
        match *self {
            LoadProfile::Uniform => ExpertLoad::uniform(n_experts),
            LoadProfile::Zipf { s, temp } => ExpertLoad::zipf(n_experts, s / temp),
        }
    }
}

/// Expert → expert-GPU assignment with per-expert replication.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertPlacement {
    n_experts: usize,
    n_shards: usize,
    kind: PlacementKind,
}

#[derive(Debug, Clone, PartialEq)]
enum PlacementKind {
    /// The legacy idealized assumption: experts spread perfectly evenly
    /// (fractionally — `E/eg` per shard even when `eg ∤ E`), one
    /// replica each, uniform token balance. Not a concrete assignment;
    /// its model factors are the literal `E/eg` expressions of Eqs. 3-4
    /// so every legacy coefficient reproduces bit for bit.
    Uniform,
    /// A concrete assignment: `shards[d]` lists the experts hosted on
    /// shard `d` (each appearing once per shard, ids ascending);
    /// `replicas[e]` counts the shards hosting expert `e` (≥ 1).
    Explicit { shards: Vec<Vec<u32>>, replicas: Vec<u32> },
}

impl ExpertPlacement {
    /// The idealized uniform placement (see [`PlacementKind::Uniform`]).
    pub fn uniform(n_experts: usize, n_shards: usize) -> Self {
        assert!(n_experts > 0 && n_shards > 0, "degenerate placement");
        Self { n_experts, n_shards, kind: PlacementKind::Uniform }
    }

    /// A concrete unreplicated placement: contiguous blocks of
    /// `⌈E/eg⌉` experts per shard — the honest "what uniform sharding
    /// actually does" baseline that skewed traffic is priced against.
    pub fn blocked(n_experts: usize, n_shards: usize) -> Self {
        assert!(n_experts > 0 && n_shards > 0, "degenerate placement");
        let per = n_experts.div_ceil(n_shards);
        let shards: Vec<Vec<u32>> = (0..n_shards)
            .map(|d| {
                let lo = (d * per).min(n_experts);
                let hi = ((d + 1) * per).min(n_experts);
                (lo..hi).map(|e| e as u32).collect()
            })
            .collect();
        Self::from_shards(n_experts, shards)
    }

    /// Greedy skew-aware placement: hand `extra_slots` replica slots to
    /// the experts with the highest per-replica load (capped at one
    /// replica per shard), then assign all replica instances to shards
    /// LPT-style (heaviest first onto the least-loaded shard not
    /// already hosting that expert). Deterministic; ties break to the
    /// lowest expert / shard id.
    pub fn replicate_hot(load: &ExpertLoad, n_shards: usize, extra_slots: usize) -> Self {
        let n_experts = load.n_experts();
        assert!(n_shards > 0, "degenerate placement");
        let mut c = vec![1u32; n_experts];
        for _ in 0..extra_slots {
            let mut best: Option<usize> = None;
            for e in 0..n_experts {
                if (c[e] as usize) >= n_shards {
                    continue;
                }
                let gain = load.rel(e) / c[e] as f64;
                if best.map_or(true, |b| gain > load.rel(b) / c[b] as f64) {
                    best = Some(e);
                }
            }
            match best {
                Some(e) => c[e] += 1,
                None => break, // every expert already everywhere
            }
        }
        // LPT over replica instances.
        let mut items: Vec<(usize, f64)> = (0..n_experts)
            .flat_map(|e| {
                let w = load.rel(e) / c[e] as f64;
                std::iter::repeat((e, w)).take(c[e] as usize)
            })
            .collect();
        items.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut shards: Vec<Vec<u32>> = vec![Vec::new(); n_shards];
        let mut shard_load = vec![0.0f64; n_shards];
        for (e, w) in items {
            let mut dst: Option<usize> = None;
            for d in 0..n_shards {
                if shards[d].contains(&(e as u32)) {
                    continue;
                }
                if dst.map_or(true, |b| shard_load[d] < shard_load[b]) {
                    dst = Some(d);
                }
            }
            let d = dst.expect("c_e capped at n_shards, a free shard must exist");
            shards[d].push(e as u32);
            shard_load[d] += w;
        }
        for s in &mut shards {
            s.sort_unstable();
        }
        Self::from_shards(n_experts, shards)
    }

    /// Build an explicit placement from per-shard expert lists.
    pub fn from_shards(n_experts: usize, mut shards: Vec<Vec<u32>>) -> Self {
        assert!(n_experts > 0 && !shards.is_empty(), "degenerate placement");
        let n_shards = shards.len();
        let mut replicas = vec![0u32; n_experts];
        for s in &mut shards {
            s.sort_unstable();
            for w in s.windows(2) {
                assert!(w[0] != w[1], "expert {} twice on one shard", w[0]);
            }
            for &e in s.iter() {
                assert!((e as usize) < n_experts, "expert id {e} out of range");
                replicas[e as usize] += 1;
            }
        }
        for (e, &r) in replicas.iter().enumerate() {
            assert!(r >= 1, "expert {e} hosted nowhere");
        }
        Self { n_experts, n_shards, kind: PlacementKind::Explicit { shards, replicas } }
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Is this the idealized uniform placement (the legacy model)?
    pub fn is_uniform(&self) -> bool {
        matches!(self.kind, PlacementKind::Uniform)
    }

    /// Replica count of expert `e` (1 under the uniform assumption).
    pub fn replica_count(&self, e: usize) -> usize {
        assert!(e < self.n_experts);
        match &self.kind {
            PlacementKind::Uniform => 1,
            PlacementKind::Explicit { replicas, .. } => replicas[e] as usize,
        }
    }

    /// Total expert slots across all shards (`E` plus replication).
    pub fn total_slots(&self) -> usize {
        match &self.kind {
            PlacementKind::Uniform => self.n_experts,
            PlacementKind::Explicit { shards, .. } => shards.iter().map(Vec::len).sum(),
        }
    }

    /// Expert slots on the fullest shard — what `MemoryModel` charges
    /// weight bytes for. Uniform: `⌈E/eg⌉`, the legacy accounting.
    pub fn max_shard_slots(&self) -> usize {
        match &self.kind {
            PlacementKind::Uniform => self.n_experts.div_ceil(self.n_shards),
            PlacementKind::Explicit { shards, .. } => {
                shards.iter().map(Vec::len).max().unwrap_or(0)
            }
        }
    }

    /// Expert kernels the busiest shard launches per fine-grained part
    /// — the α multiplier of Eq. 3. The uniform kind keeps the paper's
    /// fractional `E/eg` so legacy coefficients reproduce bit for bit.
    pub fn alpha_shard_experts(&self) -> f64 {
        match &self.kind {
            PlacementKind::Uniform => self.n_experts as f64 / self.n_shards as f64,
            PlacementKind::Explicit { .. } => self.max_shard_slots() as f64,
        }
    }

    /// Max-shard work factor `F = max_d Σ_{e∈d} rel_e/c_e` — the β
    /// multiplier of Eqs. 3-4, replacing the uniform `E/eg`. Always
    /// `≥ E/eg` (the mean shard), with equality at perfect balance; a
    /// replicated hot expert contributes `rel_e/c_e` per host. The
    /// uniform kind returns the literal `E/eg` regardless of `load` —
    /// it *is* the legacy idealized assumption.
    pub fn beta_shard_load(&self, load: &ExpertLoad) -> f64 {
        assert_eq!(load.n_experts(), self.n_experts, "load/placement expert count mismatch");
        match &self.kind {
            PlacementKind::Uniform => self.n_experts as f64 / self.n_shards as f64,
            PlacementKind::Explicit { shards, replicas } => shards
                .iter()
                .map(|s| {
                    s.iter().map(|&e| load.rel(e as usize) / replicas[e as usize] as f64).sum()
                })
                .fold(0.0, f64::max),
        }
    }

    /// Max-shard work for a *realized* per-expert count vector (the
    /// simulator's per-part draw): `max_d Σ_{e∈d} counts_e/c_e`. The
    /// uniform kind prices its implied contiguous-block layout.
    pub fn shard_work(&self, counts: &[f64]) -> f64 {
        assert_eq!(counts.len(), self.n_experts);
        match &self.kind {
            PlacementKind::Uniform => {
                let per = self.n_experts.div_ceil(self.n_shards);
                counts
                    .chunks(per)
                    .map(|c| c.iter().sum())
                    .fold(0.0, f64::max)
            }
            PlacementKind::Explicit { shards, replicas } => shards
                .iter()
                .map(|s| {
                    s.iter().map(|&e| counts[e as usize] / replicas[e as usize] as f64).sum()
                })
                .fold(0.0, f64::max),
        }
    }

    /// Plan-cache fingerprint (see [`PlacementId`]).
    pub fn fingerprint(&self) -> PlacementId {
        match &self.kind {
            PlacementKind::Uniform => PlacementId::UNIFORM,
            PlacementKind::Explicit { shards, .. } => {
                let mut h = FNV_OFFSET;
                fnv1a(&mut h, self.n_experts as u64);
                fnv1a(&mut h, self.n_shards as u64);
                for s in shards {
                    fnv1a(&mut h, 0xffff_ffff_ffff_ffff); // shard delimiter
                    for &e in s {
                        fnv1a(&mut h, e as u64);
                    }
                }
                PlacementId(if h == 0 { 1 } else { h })
            }
        }
    }

    pub fn describe(&self) -> String {
        match &self.kind {
            PlacementKind::Uniform => {
                format!("uniform {}x{} (E/eg={:.2})", self.n_experts, self.n_shards,
                    self.alpha_shard_experts())
            }
            PlacementKind::Explicit { shards, replicas } => {
                let extra: usize = replicas.iter().map(|&c| c as usize - 1).sum();
                format!(
                    "explicit {}x{} (+{} replicas, max {} slots/shard, {} shards)",
                    self.n_experts,
                    self.n_shards,
                    extra,
                    shards.iter().map(Vec::len).max().unwrap_or(0),
                    shards.len()
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_factors_are_the_legacy_closed_form() {
        let p = ExpertPlacement::uniform(160, 5);
        assert!(p.is_uniform());
        assert_eq!(p.alpha_shard_experts().to_bits(), (160.0f64 / 5.0).to_bits());
        let load = ExpertLoad::uniform(160);
        assert_eq!(p.beta_shard_load(&load).to_bits(), (160.0f64 / 5.0).to_bits());
        // Even under skew the uniform kind keeps the idealized factor:
        // it *is* the legacy assumption, not an honest evaluation.
        let skew = ExpertLoad::zipf(160, 1.5);
        assert_eq!(p.beta_shard_load(&skew).to_bits(), (160.0f64 / 5.0).to_bits());
        assert_eq!(p.max_shard_slots(), 32);
        assert_eq!(p.fingerprint(), PlacementId::UNIFORM);
    }

    #[test]
    fn blocked_under_uniform_load_matches_uniform_exactly() {
        // 32 relative loads of exactly 1.0 sum to the exact integer
        // 32.0 == 160/5 — the bit-identity that powers the bench's
        // exact-tie gate on paper splits where eg | E.
        let p = ExpertPlacement::blocked(160, 5);
        let load = ExpertLoad::uniform(160);
        assert_eq!(p.beta_shard_load(&load).to_bits(), (160.0f64 / 5.0).to_bits());
        assert_eq!(p.max_shard_slots(), 32);
        assert_eq!(p.total_slots(), 160);
        assert_ne!(p.fingerprint(), PlacementId::UNIFORM);
    }

    #[test]
    fn zipf_load_shape() {
        let l = ExpertLoad::zipf(64, 1.2);
        // Rank-frequency monotone, mean 1, hottest well above the mean.
        for e in 1..64 {
            assert!(l.rel(e) <= l.rel(e - 1));
        }
        let sum: f64 = (0..64).map(|e| l.rel(e)).sum();
        assert!((sum - 64.0).abs() < 1e-9);
        assert!(l.max_rel() > 4.0);
        assert!(!l.is_uniform());
        // s = 0 is uniform, bit for bit.
        assert_eq!(ExpertLoad::zipf(64, 0.0), ExpertLoad::uniform(64));
        assert!(ExpertLoad::zipf(64, 0.0).is_uniform());
        // Temperature flattens toward uniform.
        let flat = LoadProfile::Zipf { s: 1.2, temp: 4.0 }.load(64);
        assert!(flat.max_rel() < l.max_rel());
    }

    #[test]
    fn replication_strictly_reduces_max_shard_load_under_skew() {
        let load = ExpertLoad::zipf(160, 1.5);
        let flat = ExpertPlacement::replicate_hot(&load, 5, 0);
        let repl = ExpertPlacement::replicate_hot(&load, 5, 8);
        let floor = 160.0 / 5.0;
        let f0 = flat.beta_shard_load(&load);
        let f8 = repl.beta_shard_load(&load);
        // The hottest expert alone (rel ≈ 64) exceeds the mean shard,
        // so no unreplicated placement can reach the floor — and
        // replication must strictly improve on it.
        assert!(f0 > floor + 1.0, "unreplicated max shard {f0} vs floor {floor}");
        assert!(f8 < f0, "replication must reduce the max shard: {f8} vs {f0}");
        assert!(f8 >= floor - 1e-9, "below the perfect-balance floor");
        assert_eq!(repl.total_slots(), 168);
        assert!(repl.replica_count(0) > 1, "hottest expert must be replicated");
    }

    #[test]
    fn replicate_hot_is_deterministic_and_valid() {
        let load = ExpertLoad::zipf(96, 1.1);
        let a = ExpertPlacement::replicate_hot(&load, 4, 6);
        let b = ExpertPlacement::replicate_hot(&load, 4, 6);
        assert_eq!(a, b);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Every expert hosted, replica counts consistent with shards.
        let hosted: usize = (0..96).map(|e| a.replica_count(e)).sum();
        assert_eq!(hosted, a.total_slots());
        assert_eq!(a.total_slots(), 96 + 6);
        // Replication cannot exceed one copy per shard.
        let every = ExpertPlacement::replicate_hot(&load, 2, 10_000);
        assert_eq!(every.total_slots(), 96 * 2);
    }

    #[test]
    fn fingerprints_do_not_alias() {
        let load = ExpertLoad::zipf(160, 1.5);
        let a = ExpertPlacement::blocked(160, 5);
        let b = ExpertPlacement::replicate_hot(&load, 5, 4);
        let c = ExpertPlacement::replicate_hot(&load, 5, 5);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(b.fingerprint(), c.fingerprint());
        for p in [&a, &b, &c] {
            assert_ne!(p.fingerprint(), PlacementId::UNIFORM);
        }
    }

    #[test]
    fn part_factor_sampling_is_seeded_and_centered() {
        let load = ExpertLoad::zipf(64, 1.0);
        let p = ExpertPlacement::replicate_hot(&load, 4, 4);
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let a = load.sample_part_factors(&p, 512, 32, &mut r1);
        let b = load.sample_part_factors(&p, 512, 32, &mut r2);
        assert_eq!(a, b, "same seed, same factors");
        let mean: f64 = a.iter().sum::<f64>() / a.len() as f64;
        assert!((mean - 1.0).abs() < 0.25, "factors center near 1, got {mean}");
        assert!(a.iter().all(|&f| f > 0.0 && f.is_finite()));
    }

    #[test]
    fn shard_work_prices_realized_counts() {
        let p = ExpertPlacement::blocked(8, 2);
        // Shard 0 hosts 0..4, shard 1 hosts 4..8.
        let mut counts = vec![0.0; 8];
        counts[0] = 10.0;
        counts[7] = 4.0;
        assert_eq!(p.shard_work(&counts), 10.0);
        // A replica of expert 0 on both shards halves its contribution.
        let two = ExpertPlacement::from_shards(
            8,
            vec![vec![0, 1, 2, 3], vec![0, 4, 5, 6, 7]],
        );
        assert_eq!(two.shard_work(&counts), 9.0); // 5 + 4 on shard 1
    }
}
