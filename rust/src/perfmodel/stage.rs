//! Component models (t_gm, t_attn, t_c) and the derived per-stage layer
//! models of §4.1.
//!
//! Workload conventions follow the paper exactly:
//! * GEMM workload `x = m·k·n` (the product of dimensions, *not* 2mkn).
//! * Attention workload `y = n_h·B·S²·(d_k + d_v)`.
//! * Communication workload `z` = bytes per machine.
//!
//! Derived coefficients (Eqs. 10-11 and the following paragraphs):
//! * `t_a(m_a)  = α_a + β_a·m_a`, α_a = 4α_gm + α_attn,
//!   β_a = β_gm·(2·S·M·n_h·d_k + 2·S·M·n_h·d_v) + β_attn·S²·n_h·(d_k+d_v)
//! * `t_s(m_a)  = α_s + β_s·m_a`, α_s = 3·N_shared·α_gm,
//!   β_s = 3·N_shared·β_gm·S·M·H
//! * `t_e(m_e)  = α_e + β_e·m_e`, α_e = 3·(E/eg)·α_gm,
//!   β_e = 3·(E/eg)·β_gm·M·H   (we keep the factor 3 in α_e that Eq. 3
//!   implies; the paper's prose drops it — a typo that only shifts the
//!   constant)
//! * `t_a2e(m_e) = α_c + β_c·(E/eg)·m_e·M·bytes`, and t_e2a = t_a2e
//!   (full-duplex symmetric links, §3.1).

use crate::config::{GroupSplit, ModelConfig, Testbed};
use crate::perfmodel::linear::LinearModel;

/// The three hardware component models fitted by micro-benchmarks
/// (§5.2 / Fig. 7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompModels {
    /// GEMM: seconds vs FLOPs (product m·k·n).
    pub gemm: LinearModel,
    /// Attention: seconds vs y = n_h·B·S²·(d_k+d_v).
    pub attn: LinearModel,
    /// Transfer: seconds vs bytes per machine.
    pub comm: LinearModel,
}

impl CompModels {
    /// Derive component models from a testbed's effective constants.
    ///
    /// The communication β folds in the inter-group fan-out: each of the
    /// `ag` senders pushes its payload across a bisection of width
    /// `min(ag, eg)` links, so effective per-byte cost scales by
    /// `ag / min(ag, eg)` — this reproduces the (eg,ag)-dependent slopes
    /// of Fig. 7b.
    pub fn from_testbed(tb: &Testbed, split: GroupSplit) -> Self {
        let fanout = split.ag as f64 / (split.ag.min(split.eg) as f64);
        Self {
            gemm: LinearModel::new(tb.alpha_comp_s, 1.0 / tb.gemm_flops),
            attn: LinearModel::new(tb.alpha_attn_s, 1.0 / tb.attn_flops),
            comm: LinearModel::new(tb.alpha_comm_s, fanout / tb.link_bw),
        }
    }
}

/// Per-stage layer models for a concrete (model, testbed, split, S).
///
/// All four stage times are linear in their micro-batch variable; this
/// struct is the entire interface between hardware and the scheduler —
/// both the analytic objective (Eq. 13) and the discrete-event simulator
/// consume stage durations from here.
#[derive(Debug, Clone, PartialEq)]
pub struct StageModels {
    /// Attention stage vs m_a (samples per AG GPU per micro-batch).
    pub t_a: LinearModel,
    /// Shared-expert stage vs m_a. Zero-duration when N_shared = 0.
    pub t_s: LinearModel,
    /// Expert FFN stage vs m_e (tokens per expert per fine-grained part).
    pub t_e: LinearModel,
    /// A2E (== E2A) transfer vs m_e.
    pub t_a2e: LinearModel,
    /// Token-conservation ratio k: m_e = k/r2 · m_a (from
    /// m_a·ag·top_k·S = m_e·r2·E, Theorem 1).
    pub k_tokens: f64,
    pub has_shared: bool,
}

impl StageModels {
    pub fn new(model: &ModelConfig, tb: &Testbed, split: GroupSplit, seq_len: usize) -> Self {
        let comp = CompModels::from_testbed(tb, split);
        Self::from_components(model, &comp, split, seq_len)
    }

    /// Build stage models from already-fitted component models (the path
    /// used after Fig.-7-style calibration).
    pub fn from_components(
        model: &ModelConfig,
        comp: &CompModels,
        split: GroupSplit,
        seq_len: usize,
    ) -> Self {
        let s = seq_len as f64;
        let m = model.embed as f64;
        let h = model.ffn_hidden as f64;
        let nh = model.n_heads as f64;
        let dk = model.d_k as f64;
        let dv = model.d_v as f64;
        let e = model.n_experts as f64;
        let eg = split.eg as f64;
        let nsh = model.n_shared as f64;
        let bytes = model.bytes_per_elem as f64;

        // Eq. 1 -> Eqs. 10-11. For MLA the Q/KV projections factor
        // through low-rank latents (DeepSeek-V2: q_lora 1536, c_KV
        // 512+64), which cuts the projection GEMM workload to roughly
        // 0.35x of the equivalent full-rank MHA projections; the S²
        // attention term keeps the paper's n_h·(d_k+d_v) form ("MLA can
        // also be modeled using similar formulations", §3.1).
        let proj_factor = match model.attention {
            crate::config::AttentionKind::Mha => 1.0,
            crate::config::AttentionKind::Mla => 0.35,
        };
        let alpha_a = 4.0 * comp.gemm.alpha + comp.attn.alpha;
        let beta_a = comp.gemm.beta
            * proj_factor
            * (2.0 * s * m * nh * dk + 2.0 * s * m * nh * dv)
            + comp.attn.beta * s * s * nh * (dk + dv);

        // Eq. 2: t_s = 3·N_shared·t_gm(m_a·S·M·H).
        let (alpha_s, beta_s) = if model.n_shared > 0 {
            (3.0 * nsh * comp.gemm.alpha, 3.0 * nsh * comp.gemm.beta * s * m * h)
        } else {
            (0.0, 0.0)
        };

        // Eq. 3: t_e = 3·(E/eg)·t_gm(m_e·M·H).
        let alpha_e = 3.0 * (e / eg) * comp.gemm.alpha;
        let beta_e = 3.0 * (e / eg) * comp.gemm.beta * m * h;

        // Eq. 4: z = (E/eg)·m_e·M elements -> bytes.
        let alpha_a2e = comp.comm.alpha;
        let beta_a2e = comp.comm.beta * (e / eg) * m * bytes;

        let k_tokens = split.ag as f64 * model.top_k as f64 * s / e;

        Self {
            t_a: LinearModel::new(alpha_a, beta_a),
            t_s: LinearModel::new(alpha_s, beta_s),
            t_e: LinearModel::new(alpha_e, beta_e),
            t_a2e: LinearModel::new(alpha_a2e, beta_a2e),
            k_tokens,
            has_shared: model.n_shared > 0,
        }
    }

    /// m_e for a given (m_a, r2) under token conservation
    /// `m_a·ag·top_k·S = m_e·r2·E` (§4.2, Theorem 1).
    pub fn m_e(&self, m_a: f64, r2: usize) -> f64 {
        self.k_tokens * m_a / r2 as f64
    }

    /// Stage durations at a concrete configuration.
    pub fn attn_time(&self, m_a: f64) -> f64 {
        self.t_a.eval(m_a)
    }

    pub fn shared_time(&self, m_a: f64) -> f64 {
        if self.has_shared {
            self.t_s.eval(m_a)
        } else {
            0.0
        }
    }

    pub fn expert_time(&self, m_e: f64) -> f64 {
        self.t_e.eval(m_e)
    }

    pub fn comm_time(&self, m_e: f64) -> f64 {
        self.t_a2e.eval(m_e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn models() -> StageModels {
        StageModels::new(
            &ModelConfig::deepseek_v2(8),
            &Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        )
    }

    #[test]
    fn stage_times_positive_and_monotone() {
        let sm = models();
        assert!(sm.attn_time(1.0) > 0.0);
        assert!(sm.attn_time(4.0) > sm.attn_time(1.0));
        assert!(sm.expert_time(256.0) > sm.expert_time(16.0));
        assert!(sm.comm_time(256.0) > sm.comm_time(16.0));
        assert!(sm.shared_time(2.0) > sm.shared_time(1.0));
    }

    #[test]
    fn no_shared_expert_means_zero_shared_time() {
        let sm = StageModels::new(
            &ModelConfig::qwen3_moe(12),
            &Testbed::b(),
            GroupSplit::new(4, 4),
            2048,
        );
        assert_eq!(sm.shared_time(8.0), 0.0);
        assert!(!sm.has_shared);
    }

    #[test]
    fn token_conservation() {
        let sm = models();
        // m_a·ag·top_k·S == m_e·r2·E
        let (m_a, r2) = (4.0, 3);
        let m_e = sm.m_e(m_a, r2);
        let lhs = m_a * 3.0 * 6.0 * 2048.0;
        let rhs = m_e * r2 as f64 * 160.0;
        assert!((lhs - rhs).abs() < 1e-6 * lhs);
    }

    #[test]
    fn alpha_composition_matches_eq10() {
        let model = ModelConfig::deepseek_v2(8);
        let tb = Testbed::a();
        let split = GroupSplit::new(3, 5);
        let comp = CompModels::from_testbed(&tb, split);
        let sm = StageModels::from_components(&model, &comp, split, 2048);
        assert!((sm.t_a.alpha - (4.0 * comp.gemm.alpha + comp.attn.alpha)).abs() < 1e-15);
        assert!((sm.t_s.alpha - 3.0 * 2.0 * comp.gemm.alpha).abs() < 1e-15);
    }

    #[test]
    fn comm_beta_scales_with_fanout() {
        let model = ModelConfig::deepseek_v2(8);
        let tb = Testbed::a();
        let even = StageModels::new(&model, &tb, GroupSplit::new(4, 4), 2048);
        let skewed = StageModels::new(&model, &tb, GroupSplit::new(6, 2), 2048);
        // More senders than receiving bisection width => higher per-byte
        // cost per machine... but also fewer experts per EG device raises
        // (E/eg). Compare per-byte comm β directly:
        let per_byte_even = even.t_a2e.beta / (160.0 / 4.0);
        let per_byte_skewed = skewed.t_a2e.beta / (160.0 / 2.0);
        assert!(per_byte_skewed > per_byte_even);
    }

    #[test]
    fn longer_sequences_cost_more_attention() {
        let model = ModelConfig::qwen3_moe(12);
        let tb = Testbed::c();
        let split = GroupSplit::new(4, 4);
        let short = StageModels::new(&model, &tb, split, 1024);
        let long = StageModels::new(&model, &tb, split, 8192);
        // Attention grows superlinearly in S (S² term), per-token compute grows.
        assert!(long.attn_time(1.0) > 8.0 * short.attn_time(1.0));
    }
}
