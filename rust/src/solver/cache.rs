//! Memoized online planning (§5.5 at serving rate).
//!
//! The online-adaptive mode re-solves the schedule per batch, but a
//! serving stream repeats a small set of shapes: the same sequence
//! bucket and padded batch size arrive over and over. [`PlanCache`]
//! memoizes [`Solution`]s per `(seq-len bucket, batch-size bucket)`
//! key, so the solver runs once per *shape* instead of once per
//! *batch* — a cache hit is a map lookup, three-plus orders of
//! magnitude cheaper than even the sub-millisecond re-solve.
//!
//! Infeasible shapes are cached too (as `None`): a batch the testbed
//! cannot hold would otherwise re-run the whole feasibility walk on
//! every arrival.
//!
//! The cache is shared across serving workers (`Arc<PlanCache>`) and
//! built read-mostly for the event-driven coordinator:
//!
//! * **Hits are shared-lock pointer bumps** — the live generation's map
//!   sits behind an `RwLock`, and entries are `Arc<Solution>`, so
//!   concurrent lookups neither serialize nor deep-clone plan bodies.
//! * **Misses solve once per shape** — a per-generation solve mutex
//!   serializes cold shapes (concurrent workers hitting the same cold
//!   shape wait for one solve instead of duplicating it), while
//!   readers of already-memoized shapes pass through untouched.
//! * **`clear()` swaps generations atomically** — the auto-split
//!   re-key path replaces the whole generation in one pointer store,
//!   so a concurrent reader either sees the complete old map or the
//!   empty new one, never a half-cleared hybrid; a solve in flight
//!   during the swap inserts into its own orphaned generation and can
//!   never pollute the new keyspace with a stale-split plan.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError, RwLock};

use crate::config::{ClusterId, Phase, PlacementId};
use crate::perfmodel::profile::ProfileId;
use crate::solver::Solution;

/// Round up to the next power of two — the shape-bucketing used for
/// arbitrary online shapes (a 2-approximation keyspace keeps the cache
/// small under lognormal prompt lengths and token-by-token KV growth).
pub fn bucket_up(x: usize) -> usize {
    x.max(1).next_power_of_two()
}

/// A plan-cache key: serving phase + sequence bucket + batch bucket +
/// the identity of the constants the plan was solved against.
/// The phase is part of the identity, so a prefill plan and a decode
/// plan of numerically identical `(seq, batch)` can never alias — they
/// are solved against different stage models (the decode variant also
/// carries its KV bucket inside [`Phase::Decode`]). The profile
/// fingerprint is part of the identity for the same reason: a plan
/// solved against a calibration profile's measured constants must
/// never be returned for the hand-constant keyspace (or another
/// profile's), no matter how the shapes coincide — switching profiles
/// can never alias plans. The cluster fingerprint joins the identity
/// for the same reason again: plans solved under different cluster
/// shapes (pool counts, device constants, link constants, role wiring)
/// can never alias, even at identical shapes and profiles. And the
/// placement fingerprint once more: plans solved under different
/// expert placements (replica sets, shard assignments) price different
/// stage models and memory budgets, so they can never alias either.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct ShapeKey {
    pub phase: Phase,
    pub seq: usize,
    pub batch: usize,
    /// [`ProfileId::HAND`] for the hand-written Table-2 constants,
    /// otherwise the calibration profile's fingerprint.
    pub profile: ProfileId,
    /// [`ClusterId::SINGLE`] for the legacy single-pool Testbed
    /// keyspace, otherwise [`crate::config::Cluster::fingerprint`].
    pub cluster: ClusterId,
    /// [`PlacementId::UNIFORM`] for the legacy uniform-expert keyspace,
    /// otherwise [`crate::config::ExpertPlacement::fingerprint`].
    pub placement: PlacementId,
}

impl ShapeKey {
    /// Exact-valued prefill key (serving paths with exact padded
    /// capacities — the coordinator pads to `r1 · m_a` — key on those
    /// directly). Keys the hand-constant keyspace; chain
    /// [`ShapeKey::with_profile`] / [`ShapeKey::with_cluster`] for a
    /// calibrated or cluster-shaped one.
    pub fn prefill(seq: usize, batch: usize) -> Self {
        Self {
            phase: Phase::Prefill,
            seq,
            batch,
            profile: ProfileId::HAND,
            cluster: ClusterId::SINGLE,
            placement: PlacementId::UNIFORM,
        }
    }

    /// Decode key with the KV length bucketed: the cache stays small
    /// while KV grows token by token, and one plan (solved at the
    /// bucket ceiling, i.e. conservatively) serves the whole bucket.
    pub fn decode(kv_len: usize, batch: usize) -> Self {
        Self {
            phase: Phase::Decode { kv_len: bucket_up(kv_len) },
            seq: 1,
            batch,
            profile: ProfileId::HAND,
            cluster: ClusterId::SINGLE,
            placement: PlacementId::UNIFORM,
        }
    }

    /// Re-key onto a calibration profile's keyspace.
    pub fn with_profile(mut self, profile: ProfileId) -> Self {
        self.profile = profile;
        self
    }

    /// Re-key onto a cluster shape's keyspace.
    pub fn with_cluster(mut self, cluster: ClusterId) -> Self {
        self.cluster = cluster;
        self
    }

    /// Re-key onto an expert placement's keyspace.
    pub fn with_placement(mut self, placement: PlacementId) -> Self {
        self.placement = placement;
        self
    }
}

/// Cache key for an arbitrary online prefill `(seq_len, batch)` shape.
pub fn shape_key(seq_len: usize, batch: usize) -> ShapeKey {
    ShapeKey::prefill(bucket_up(seq_len), bucket_up(batch))
}

/// Cache key for an online decode `(kv_len, batch)` shape.
pub fn shape_key_decode(kv_len: usize, batch: usize) -> ShapeKey {
    ShapeKey::decode(kv_len, bucket_up(batch))
}

/// One cache generation: the memoized map plus the solve serializer.
/// `clear()` retires the whole generation at once; a solve in flight
/// keeps inserting into its retired generation, which nothing reads
/// anymore.
#[derive(Debug, Default)]
struct Generation {
    map: RwLock<BTreeMap<ShapeKey, Option<Arc<Solution>>>>,
    /// Serializes cold-shape solves within the generation (one solve
    /// per key, not one per concurrently-arriving worker) without
    /// blocking hit-path readers.
    solve: Mutex<()>,
}

/// A pin on the generation a [`PlanCache::get_or_solve_refinable`]
/// miss solved into. Background refinement publishes through the
/// token, so a refined plan can only ever land in the generation its
/// budget-truncated ancestor came from: if [`PlanCache::clear`]
/// swapped generations in between, the publish lands in the orphaned
/// map nothing reads anymore — a stale-split refinement can never
/// pollute the re-keyed cache.
#[derive(Debug, Clone)]
pub struct RefineToken {
    generation: Arc<Generation>,
}

/// Memoized `ShapeKey -> Arc<Solution>` store (generational).
#[derive(Debug)]
pub struct PlanCache {
    live: RwLock<Arc<Generation>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Completed `clear()` swaps.
    generations: AtomicU64,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self {
            live: RwLock::new(Arc::new(Generation::default())),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            generations: AtomicU64::new(0),
        }
    }
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pin the live generation (a pointer bump under a shared lock —
    /// the swap in `clear()` is the only writer).
    fn generation_ref(&self) -> Arc<Generation> {
        self.live.read().unwrap_or_else(PoisonError::into_inner).clone()
    }

    /// Return the memoized solution for `key`, running `solve` exactly
    /// once per key on a miss (a `None` result is memoized as
    /// infeasible). A hit is a shared-lock lookup returning a cloned
    /// `Arc` — concurrent hits never serialize and never deep-copy the
    /// plan.
    pub fn get_or_solve(
        &self,
        key: ShapeKey,
        solve: impl FnOnce() -> Option<Solution>,
    ) -> Option<Arc<Solution>> {
        self.get_or_solve_refinable(key, solve).0
    }

    /// [`PlanCache::get_or_solve`] plus a [`RefineToken`] pinning the
    /// generation the result lives in — the handle a background
    /// refinement worker needs to later [`PlanCache::publish_refined`]
    /// the exhaustive plan a budget-truncated solve did not finish.
    pub fn get_or_solve_refinable(
        &self,
        key: ShapeKey,
        solve: impl FnOnce() -> Option<Solution>,
    ) -> (Option<Arc<Solution>>, RefineToken) {
        let generation = self.generation_ref();
        let refine = RefineToken { generation: generation.clone() };
        if let Some(cached) =
            generation.map.read().unwrap_or_else(PoisonError::into_inner).get(&key)
        {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (cached.clone(), refine);
        }
        // Cold shape: serialize against other misses so the solve runs
        // once, then re-check — a peer may have solved this exact key
        // while we waited for the solve token.
        let token = generation.solve.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(cached) =
            generation.map.read().unwrap_or_else(PoisonError::into_inner).get(&key)
        {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (cached.clone(), refine);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let solved = solve().map(Arc::new);
        generation
            .map
            .write()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(key, solved.clone());
        drop(token);
        (solved, refine)
    }

    /// Atomically publish a refined solution for `key` into the
    /// generation `token` pinned, overwriting the truncated entry the
    /// hot path is serving. Returns whether the publish is visible to
    /// readers — a publish racing a completed [`PlanCache::clear`]
    /// lands in the orphaned generation that nothing reads anymore
    /// (the exact rule in-flight solves already follow) and reports
    /// `false`. Readers never lock against this: a concurrent
    /// `get_or_solve` sees either the truncated entry or the refined
    /// one, both complete plans.
    pub fn publish_refined(&self, token: &RefineToken, key: ShapeKey, sol: Arc<Solution>) -> bool {
        token
            .generation
            .map
            .write()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(key, Some(sol));
        Arc::ptr_eq(&token.generation, &self.generation_ref())
    }

    /// Degraded-mode lookup: the nearest feasible cached plan that can
    /// stand in for `key` when its own solve failed or ran over budget.
    ///
    /// A candidate must be solved against the same profile, the same
    /// cluster shape, the same phase kind (nearest sequence bucket for prefill, any KV bucket
    /// for decode — either way the neighbor differs only in how
    /// attention-heavy its stages are), and a batch capacity **at
    /// least** the requested one — a smaller-batch plan could not
    /// physically hold the requests. Among candidates the nearest in
    /// (sequence/KV bucket, batch bucket) log2 distance wins, the
    /// sequence/KV distance weighted heaviest (×16: a one-bucket shape
    /// step changes the stage models more than any batch headroom
    /// does). Returns `None` when nothing in the live generation
    /// qualifies (callers then take their static fallback).
    pub fn nearest(&self, key: ShapeKey) -> Option<Arc<Solution>> {
        fn log2(x: usize) -> i64 {
            (usize::BITS - x.max(1).leading_zeros()) as i64
        }
        let generation = self.generation_ref();
        let map = generation.map.read().unwrap_or_else(PoisonError::into_inner);
        let mut best: Option<(i64, Arc<Solution>)> = None;
        for (k, v) in map.iter() {
            if *k == key
                || k.profile != key.profile
                || k.cluster != key.cluster
                || k.placement != key.placement
                || k.batch < key.batch
            {
                continue;
            }
            let Some(sol) = v else { continue };
            let kv_dist = match (k.phase, key.phase) {
                (Phase::Prefill, Phase::Prefill) => (log2(k.seq) - log2(key.seq)).abs(),
                (Phase::Decode { kv_len: a }, Phase::Decode { kv_len: b }) => {
                    (log2(a) - log2(b)).abs()
                }
                _ => continue,
            };
            let score = kv_dist * 16 + (log2(k.batch) - log2(key.batch)).abs();
            if best.as_ref().map_or(true, |(s, _)| score < *s) {
                best = Some((score, sol.clone()));
            }
        }
        best.map(|(_, sol)| sol)
    }

    /// Cached solution without solving (`None` = never solved; a cached
    /// infeasible shape reads back as `Some(None)`).
    pub fn peek(&self, key: ShapeKey) -> Option<Option<Arc<Solution>>> {
        self.generation_ref()
            .map
            .read()
            .unwrap_or_else(PoisonError::into_inner)
            .get(&key)
            .cloned()
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of memoized shapes (feasible and infeasible).
    pub fn len(&self) -> usize {
        self.generation_ref().map.read().unwrap_or_else(PoisonError::into_inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// How many times the cache has been cleared (generation swaps).
    pub fn generation(&self) -> u64 {
        self.generations.load(Ordering::Relaxed)
    }

    /// Drop every memoized shape (testbed constants or planning split
    /// changed) by swapping in a fresh generation — one atomic pointer
    /// store, so a concurrent reader observes either the full old map
    /// or the empty new one, and an in-flight solve completes into the
    /// retired generation instead of leaking a stale plan forward.
    pub fn clear(&self) {
        let fresh = Arc::new(Generation::default());
        *self.live.write().unwrap_or_else(PoisonError::into_inner) = fresh;
        self.generations.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};
    use crate::solver::{solve_online, Instance, SolverParams};

    fn paper_instance() -> Instance {
        Instance::new(ModelConfig::deepseek_v2(8), Testbed::a(), GroupSplit::new(3, 5), 2048)
    }

    #[test]
    fn bucketing_rounds_up_to_powers_of_two() {
        assert_eq!(bucket_up(0), 1);
        assert_eq!(bucket_up(1), 1);
        assert_eq!(bucket_up(5), 8);
        assert_eq!(bucket_up(8), 8);
        assert_eq!(shape_key(3000, 6), ShapeKey::prefill(4096, 8));
        assert_eq!(
            shape_key_decode(3000, 6),
            ShapeKey {
                phase: Phase::Decode { kv_len: 4096 },
                seq: 1,
                batch: 8,
                profile: ProfileId::HAND,
                cluster: ClusterId::SINGLE,
                placement: PlacementId::UNIFORM,
            }
        );
    }

    #[test]
    fn profiles_key_separate_plans() {
        // The same shape under different constant identities must be
        // distinct cache entries: a calibrated solve can never serve
        // (or be served by) the hand-constant keyspace.
        let cache = PlanCache::new();
        let params = SolverParams::default();
        let hand_key = ShapeKey::prefill(2048, 8);
        let cal_key = hand_key.with_profile(ProfileId(0x5eed));
        assert_ne!(hand_key, cal_key);
        let _ = cache.get_or_solve(hand_key, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 1);
        let _ = cache.get_or_solve(cal_key, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 2, "calibrated shape must not hit the hand entry");
        assert_eq!(cache.len(), 2);
        let _ = cache.get_or_solve(hand_key, || panic!("hand key must hit"));
        let _ = cache.get_or_solve(cal_key, || panic!("calibrated key must hit"));
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn clusters_key_separate_plans() {
        // The cluster fingerprint is part of the key identity exactly
        // like the profile fingerprint: the same shape solved under
        // different cluster shapes must be distinct cache entries, so a
        // plan solved for one pool layout can never serve another.
        let cache = PlanCache::new();
        let params = SolverParams::default();
        let single_key = ShapeKey::prefill(2048, 8);
        let hetero_key = single_key.with_cluster(ClusterId(0xc1));
        assert_eq!(single_key.cluster, ClusterId::SINGLE);
        assert_ne!(single_key, hetero_key);
        let _ = cache.get_or_solve(single_key, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 1);
        let _ = cache.get_or_solve(hetero_key, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 2, "hetero shape must not hit the single-pool entry");
        assert_eq!(cache.len(), 2);
        let _ = cache.get_or_solve(single_key, || panic!("single-pool key must hit"));
        let _ = cache.get_or_solve(hetero_key, || panic!("hetero key must hit"));
        assert_eq!(cache.hits(), 2);
        // Cluster and profile identities compose without aliasing.
        let both = hetero_key.with_profile(ProfileId(0x5eed));
        let _ = cache.get_or_solve(both, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn placements_key_separate_plans() {
        // The placement fingerprint joins the key identity exactly like
        // the profile and cluster fingerprints: the same shape solved
        // under different expert placements prices different stage
        // models and memory budgets, so the entries must never alias.
        let cache = PlanCache::new();
        let params = SolverParams::default();
        let uniform_key = ShapeKey::prefill(2048, 8);
        let skew_key = uniform_key.with_placement(PlacementId(0xbeef));
        assert_eq!(uniform_key.placement, PlacementId::UNIFORM);
        assert_ne!(uniform_key, skew_key);
        let _ = cache.get_or_solve(uniform_key, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 1);
        let _ = cache.get_or_solve(skew_key, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 2, "placed shape must not hit the uniform entry");
        assert_eq!(cache.len(), 2);
        let _ = cache.get_or_solve(uniform_key, || panic!("uniform key must hit"));
        let _ = cache.get_or_solve(skew_key, || panic!("placed key must hit"));
        assert_eq!(cache.hits(), 2);
        // Placement composes with profile and cluster without aliasing.
        let all = skew_key.with_profile(ProfileId(0x5eed)).with_cluster(ClusterId(0xc1));
        let _ = cache.get_or_solve(all, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.len(), 3);
        // Two distinct explicit placements never alias each other.
        let other = uniform_key.with_placement(PlacementId(0xf00d));
        let _ = cache.get_or_solve(other, || solve_online(&paper_instance(), 8, &params));
        assert_eq!(cache.misses(), 4);
        assert_eq!(cache.len(), 4);
    }

    #[test]
    fn solves_once_per_shape() {
        let cache = PlanCache::new();
        let mut solves = 0usize;
        for _ in 0..5 {
            let sol = cache.get_or_solve(ShapeKey::prefill(2048, 8), || {
                solves += 1;
                solve_online(&paper_instance(), 8, &SolverParams::default())
            });
            assert!(sol.is_some());
        }
        assert_eq!(solves, 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 4);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cached_solution_matches_fresh_solve() {
        let cache = PlanCache::new();
        let inst = paper_instance();
        let params = SolverParams::default();
        let fresh = solve_online(&inst, 8, &params).unwrap();
        let cached = cache
            .get_or_solve(ShapeKey::prefill(2048, 8), || solve_online(&inst, 8, &params))
            .unwrap();
        let hit = cache
            .get_or_solve(ShapeKey::prefill(2048, 8), || panic!("must not re-solve"))
            .unwrap();
        assert_eq!(fresh.config, cached.config);
        assert_eq!(fresh.config, hit.config);
        assert_eq!(fresh.throughput_tokens, hit.throughput_tokens);
        // A hit and its original insert share one allocation — the
        // read-mostly contract (no deep clone under any lock).
        assert!(Arc::ptr_eq(&cached, &hit));
    }

    #[test]
    fn prefill_and_decode_keys_never_alias() {
        // Numerically identical (seq, batch) values under different
        // phases are distinct cache entries: the decode solve must run
        // even though the prefill shape is already memoized (and vice
        // versa), and each phase's hit returns its own plan.
        let cache = PlanCache::new();
        let params = SolverParams::default();
        let pre_inst = paper_instance();
        let dec_inst = Instance::decode(
            ModelConfig::deepseek_v2(8),
            Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        );
        let pre_key = ShapeKey::prefill(1, 8);
        let dec_key = ShapeKey::decode(1, 8);
        assert_ne!(pre_key, dec_key, "phase must be part of the key identity");
        let pre = cache.get_or_solve(pre_key, || solve_online(&pre_inst, 8, &params)).unwrap();
        assert_eq!(cache.misses(), 1);
        let dec = cache.get_or_solve(dec_key, || solve_online(&dec_inst, 8, &params)).unwrap();
        assert_eq!(cache.misses(), 2, "decode shape must not hit the prefill entry");
        assert_eq!(cache.len(), 2);
        // Hits stay phase-local and return the phase's own plan.
        let pre_hit = cache.get_or_solve(pre_key, || panic!("prefill must hit")).unwrap();
        let dec_hit = cache.get_or_solve(dec_key, || panic!("decode must hit")).unwrap();
        assert_eq!(pre.config, pre_hit.config);
        assert_eq!(dec.config, dec_hit.config);
        assert_eq!(cache.hits(), 2);
        // Decode KV buckets key separate plans too.
        let far_key = ShapeKey::decode(100_000, 8);
        assert_ne!(far_key, dec_key);
    }

    #[test]
    fn nearest_prefers_close_kv_buckets_and_never_shrinks_batch() {
        let cache = PlanCache::new();
        let params = SolverParams::default();
        let dec_inst = Instance::decode(
            ModelConfig::deepseek_v2(8),
            Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        );
        // Memoize decode plans at two KV buckets and one bigger batch.
        let near = cache
            .get_or_solve(ShapeKey::decode(2048, 8), || solve_online(&dec_inst, 8, &params))
            .unwrap();
        let far = cache
            .get_or_solve(ShapeKey::decode(64, 16), || solve_online(&dec_inst, 16, &params))
            .unwrap();
        // Same-KV-bucket neighbor wins over the far bucket.
        let got = cache.nearest(ShapeKey::decode(4096, 8)).expect("neighbor exists");
        assert!(Arc::ptr_eq(&got, &near));
        // A candidate with a smaller batch capacity never qualifies:
        // only the batch-16 entry can hold 12 requests.
        let got = cache.nearest(ShapeKey::decode(64, 12)).expect("bigger batch exists");
        assert!(Arc::ptr_eq(&got, &far));
        assert!(cache.nearest(ShapeKey::decode(64, 32)).is_none(), "nothing can hold batch 32");
        // Phase kinds never cross: no prefill entry stands in for
        // decode (and vice versa), and profiles stay isolated.
        assert!(cache.nearest(ShapeKey::prefill(2048, 8)).is_none());
        assert!(cache.nearest(ShapeKey::decode(2048, 8).with_profile(ProfileId(7))).is_none());
        // ... and cluster shapes stay isolated the same way.
        assert!(cache.nearest(ShapeKey::decode(2048, 8).with_cluster(ClusterId(7))).is_none());
        // Never across placements.
        assert!(cache.nearest(ShapeKey::decode(2048, 8).with_placement(PlacementId(7))).is_none());
    }

    #[test]
    fn prefill_nearest_allows_seq_neighbors_with_log2_scoring() {
        let cache = PlanCache::new();
        let params = SolverParams::default();
        let inst = paper_instance();
        let near = cache
            .get_or_solve(ShapeKey::prefill(4096, 8), || solve_online(&inst, 8, &params))
            .unwrap();
        let _far = cache
            .get_or_solve(ShapeKey::prefill(256, 8), || solve_online(&inst, 8, &params))
            .unwrap();
        // Query at seq 2048: one seq bucket away beats three away.
        let got = cache.nearest(ShapeKey::prefill(2048, 8)).expect("prefill neighbor");
        assert!(Arc::ptr_eq(&got, &near));
        // Seq distance is weighted like KV distance (x16): a same-seq
        // entry four batch buckets away (score 4) still beats the
        // one-seq-bucket neighbor (score 16).
        let same_seq = cache
            .get_or_solve(ShapeKey::prefill(2048, 128), || solve_online(&inst, 128, &params))
            .unwrap();
        let got = cache.nearest(ShapeKey::prefill(2048, 8)).unwrap();
        assert!(Arc::ptr_eq(&got, &same_seq));
        // Batch capacity still never shrinks across seq buckets.
        assert!(cache.nearest(ShapeKey::prefill(4096, 256)).is_none());
    }

    #[test]
    fn refinement_publish_respects_generation_swaps() {
        let cache = PlanCache::new();
        let inst = paper_instance();
        let params = SolverParams::default();
        let key = ShapeKey::prefill(2048, 8);
        // A (nominally truncated) solve hands back the generation pin.
        let (first, token) =
            cache.get_or_solve_refinable(key, || solve_online(&inst, 8, &params));
        let first = first.unwrap();
        // Refinement lands in the pinned generation and is visible.
        let refined = Arc::new(Solution { exhaustive: true, ..(*first).clone() });
        assert!(cache.publish_refined(&token, key, refined.clone()));
        let hit = cache.get_or_solve(key, || panic!("refined entry must hit")).unwrap();
        assert!(Arc::ptr_eq(&hit, &refined), "readers must see the refined plan");
        // After clear() the pinned generation is orphaned: the publish
        // completes into the retired map and reports invisibility —
        // the re-keyed cache never serves the stale refinement.
        let (_, token2) = cache.get_or_solve_refinable(key, || solve_online(&inst, 8, &params));
        cache.clear();
        assert!(!cache.publish_refined(&token2, key, refined.clone()));
        assert!(
            cache.peek(key).is_none(),
            "orphaned refinement leaked into the live generation"
        );
    }

    #[test]
    fn infeasible_shapes_are_memoized() {
        let cache = PlanCache::new();
        let inst = paper_instance();
        let params = SolverParams::default();
        let mut solves = 0usize;
        for _ in 0..3 {
            let sol = cache.get_or_solve(shape_key(2048, 10_000_000), || {
                solves += 1;
                solve_online(&inst, 10_000_000, &params)
            });
            assert!(sol.is_none());
        }
        assert_eq!(solves, 1);
        assert_eq!(cache.peek(shape_key(2048, 10_000_000)), Some(None));
        cache.clear();
        assert!(cache.is_empty());
        assert!(cache.peek(shape_key(2048, 10_000_000)).is_none());
    }

    #[test]
    fn clear_orphans_in_flight_solves() {
        // The auto-split hazard: a solve starts, the split changes and
        // clears the cache, then the stale solve completes. The insert
        // must land in the retired generation — the re-keyed cache can
        // never serve the stale-split plan.
        let cache = PlanCache::new();
        let inst = paper_instance();
        let params = SolverParams::default();
        let key = ShapeKey::prefill(2048, 8);
        let sol = cache.get_or_solve(key, || {
            cache.clear(); // the split changed mid-solve
            solve_online(&inst, 8, &params)
        });
        assert!(sol.is_some(), "the in-flight caller still gets its plan");
        assert_eq!(cache.generation(), 1);
        assert!(cache.is_empty(), "stale solve leaked into the new generation");
        assert_eq!(cache.peek(key), None);
        // The next lookup re-solves under the new generation.
        let mut resolved = false;
        let fresh = cache.get_or_solve(key, || {
            resolved = true;
            solve_online(&inst, 8, &params)
        });
        assert!(resolved, "post-clear lookup must re-solve");
        assert_eq!(fresh.unwrap().config, sol.unwrap().config);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn generation_swap_is_all_or_nothing_for_readers() {
        // A reader that pinned the old generation keeps a fully
        // consistent view while (and after) the swap happens.
        let cache = Arc::new(PlanCache::new());
        let inst = paper_instance();
        let params = SolverParams::default();
        for batch in [2usize, 4, 8] {
            let key = ShapeKey::prefill(2048, batch);
            let _ = cache.get_or_solve(key, || solve_online(&inst, batch, &params));
        }
        assert_eq!(cache.len(), 3);
        let threads: Vec<_> = (0..4)
            .map(|i| {
                let cache = cache.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        if i == 0 {
                            cache.clear();
                        } else {
                            // Either the full old view or the empty new
                            // one; never a partially-cleared hybrid.
                            let n = cache.len();
                            assert!(n == 0 || n == 3, "half-cleared cache observed: {n} entries");
                            for batch in [2usize, 4, 8] {
                                // peek never tears either.
                                let _ = cache.peek(ShapeKey::prefill(2048, batch));
                            }
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(cache.generation() >= 1);
    }
}
