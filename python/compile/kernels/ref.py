"""Pure-jnp reference oracles for every Pallas kernel and for the full
MoE layer.

These are the CORE correctness signal: kernels are validated against
them in pytest (including hypothesis shape sweeps), and ``aot.py`` dumps
golden input/output pairs computed here for the Rust integration tests.
"""

import jax
import jax.numpy as jnp


def swish(x):
    """Swish/SiLU: x * sigmoid(x) (§3.1 Shared Expert part)."""
    return x * jax.nn.sigmoid(x)


def ref_ffn(x, w_gate, w_up, w_down):
    """SwiGLU feed-forward of one expert.

    x: [N, M]; w_gate, w_up: [H, M]; w_down: [M, H]  ->  [N, M]
    Matches the paper's expert structure: z_d = W_D · Swish(z_gate ⊗ z_up).
    """
    z_gate = x @ w_gate.T          # [N, H]
    z_up = x @ w_up.T              # [N, H]
    return (swish(z_gate) * z_up) @ w_down.T  # [N, M]


def ref_attention(q, k, v, causal=True):
    """Multi-head scaled-dot-product attention.

    q, k: [B, n_h, S, d_k]; v: [B, n_h, S, d_v]  ->  [B, n_h, S, d_v]
    """
    d_k = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d_k).astype(q.dtype)
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def ref_attention_block(h, wq, wk, wv, wo, n_heads, d_k, d_v, causal=True):
    """Full attention stage with projections and residual.

    h: [B, S, M]; wq, wk: [n_h*d_k, M]; wv: [n_h*d_v, M]; wo: [M, n_h*d_v]
    -> [B, S, M]  (residual added)
    """
    b, s, _m = h.shape
    q = (h @ wq.T).reshape(b, s, n_heads, d_k).transpose(0, 2, 1, 3)
    k = (h @ wk.T).reshape(b, s, n_heads, d_k).transpose(0, 2, 1, 3)
    v = (h @ wv.T).reshape(b, s, n_heads, d_v).transpose(0, 2, 1, 3)
    o = ref_attention(q, k, v, causal=causal)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * d_v)
    return h + o @ wo.T


def ref_gate(x, w_gate, top_k):
    """Top-k softmax gate (§2.1).

    x: [N, M]; w_gate: [E, M]  ->  (probs [N, k], idx [N, k] int32)
    Routing scores -> softmax over all experts -> top-k; kept
    probabilities are renormalized to sum to one.
    """
    scores = x @ w_gate.T                     # [N, E]
    probs = jax.nn.softmax(scores, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_p, top_i.astype(jnp.int32)


def ref_moe_layer(h, lw, top_k, causal=True):
    """One full MoE transformer layer (attention + gate + shared +
    routed experts + combine) — the end-to-end oracle for golden tests.

    h: [B, S, M]; ``lw`` is a dict with keys
      n_heads d_k d_v
      wq wk wv wo                       (attention)
      gate_w                            ([E, M])
      shared_gate shared_up shared_down (optional, single shared expert)
      exp_gate exp_up exp_down          (stacked [E, H, M] / [E, M, H])
    """
    n_heads, d_k, d_v = lw["n_heads"], lw["d_k"], lw["d_v"]
    h = ref_attention_block(h, lw["wq"], lw["wk"], lw["wv"], lw["wo"],
                            n_heads, d_k, d_v, causal=causal)
    b, s, m = h.shape
    x = h.reshape(b * s, m)

    probs, idx = ref_gate(x, lw["gate_w"], top_k)

    # Routed experts: dense-compute every expert then gather (the oracle
    # is allowed to be slow and simple).
    n_experts = lw["gate_w"].shape[0]
    all_out = jnp.stack(
        [ref_ffn(x, lw["exp_gate"][e], lw["exp_up"][e], lw["exp_down"][e])
         for e in range(n_experts)],
        axis=0,
    )  # [E, N, M]
    routed = jnp.zeros_like(x)
    for kk in range(top_k):
        sel = all_out[idx[:, kk], jnp.arange(x.shape[0])]  # [N, M]
        routed = routed + probs[:, kk:kk + 1] * sel

    out = x + routed
    if "shared_gate" in lw:
        out = out + ref_ffn(x, lw["shared_gate"], lw["shared_up"],
                            lw["shared_down"])
    return out.reshape(b, s, m)


def ref_model(h, weights, top_k, causal=True):
    """Full T-layer forward: ``weights`` is a list of per-layer dicts."""
    for lw in weights:
        h = ref_moe_layer(h, lw, top_k, causal=causal)
    return h
