//! Decode-phase serving claims:
//!
//! 1. **Decode plans beat prefill plans on decode traffic** — the
//!    acceptance gate. On the paper instance (DeepSeek-V2 8L, testbed
//!    A, split (3,5), S = kv = 2048) Algorithm 1's decode-phase solve
//!    must yield strictly higher decoded-tokens/s than running decode
//!    under the prefill-phase winning configuration: prefill optima
//!    keep r2 > 1 to overlap A2E behind big expert GEMMs, while decode
//!    conservation (one token per sample) makes every fine-grained
//!    part overhead — EPS-MoE's observation that the winning schedule
//!    is phase-dependent. A per-testbed table reports the same trio
//!    everywhere (decode can tie prefill where both collapse to
//!    r2 = 1, so the strict gate is pinned to the paper instance and a
//!    no-regression bound holds elsewhere).
//! 2. **Phase-keyed plan caching over a growing KV stream** — decoding
//!    re-solves per *KV bucket*, not per token: a 512-step stream must
//!    miss once per power-of-two KV bucket, never alias the prefill
//!    entry, and the memoized stream must be strictly faster than
//!    cold-solving every step.
//! 3. **Queue-fed autoregressive serving** (needs `make artifacts`;
//!    skipped gracefully otherwise) — requests with decode re-entry
//!    through the continuous batcher: all responses arrive, and the
//!    plan cache holds separate prefill and decode shapes.
//!
//! Emits `BENCH_decode.json`. Run: `cargo bench --bench decode_serving`

use std::time::{Duration, Instant};

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::coordinator::batcher::{Batcher, BatcherConfig};
use findep::coordinator::moe::ModelHandle;
use findep::coordinator::server::{EmbeddedRequest, Policy};
use findep::runtime::artifacts_dir;
use findep::sched::PlanConfig;
use findep::solver::{
    self, shape_key, shape_key_decode, Instance, PlanCache, Solution, SolverParams,
};
use findep::util::bench::{fmt_duration, Bencher, Table};
use findep::util::json::{to_string_pretty, Json, JsonObj};

/// Decode-phase throughput of a configuration chosen elsewhere (the
/// prefill winner, here): rebuild it under decode token conservation
/// (`m_e` is implied by routing, not carried over) and evaluate it
/// exactly on the discrete-event engine.
fn eval_on_decode(dec: &Instance, cfg: &PlanConfig) -> (f64, f64) {
    let mut ev = dec.evaluator();
    let m_e = ev.stage_models().m_e(cfg.m_a as f64, cfg.r2);
    let mut cross = PlanConfig::findep(cfg.m_a, cfg.r1, cfg.r2, m_e, cfg.order);
    cross.fuse_shared = cfg.fuse_shared;
    ev.evaluate(cross)
}

fn phase_pair(
    model: &ModelConfig,
    tb: &Testbed,
    split: GroupSplit,
    s: usize,
    kv: usize,
    params: &SolverParams,
) -> Option<(Solution, Solution, f64)> {
    let pre_inst = Instance::new(model.clone(), tb.clone(), split, s);
    let dec_inst = Instance::decode(model.clone(), tb.clone(), split, kv);
    let pre = solver::solve(&pre_inst, params)?;
    let dec = solver::solve(&dec_inst, params)?;
    let (_, cross_tput) = eval_on_decode(&dec_inst, &pre.config);
    Some((pre, dec, cross_tput))
}

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let params = SolverParams::default();
    let mut report = JsonObj::new();
    report.insert("bench", Json::Str("decode_serving".into()));
    report.insert("quick", Json::Bool(quick));

    // --- 1. Per-phase plans: decode solve vs prefill-plan-on-decode. --
    let mut table = Table::new(
        "Decode vs prefill plans (S = kv = 2048, paper splits)",
        &[
            "backbone",
            "testbed",
            "prefill plan",
            "decode plan",
            "decode tok/s",
            "prefill-plan-on-decode",
            "gain",
        ],
    );
    let mut entries: Vec<Json> = Vec::new();
    let mut paper_gate: Option<(f64, f64)> = None;
    for (backbone, deepseek) in [("DeepSeek", true), ("Qwen", false)] {
        for tb in Testbed::all() {
            let layers = ModelConfig::paper_layers(deepseek, &tb.name[..2]);
            let model = if deepseek {
                ModelConfig::deepseek_v2(layers)
            } else {
                ModelConfig::qwen3_moe(layers)
            };
            let split = if tb.n_gpus >= 32 {
                GroupSplit::new(8, 24)
            } else if deepseek {
                GroupSplit::new(3, 5)
            } else {
                GroupSplit::new(4, 4)
            };
            let Some((pre, dec, cross)) = phase_pair(&model, &tb, split, 2048, 2048, &params)
            else {
                table.row(&[
                    backbone.into(),
                    tb.name.clone(),
                    "infeasible".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            };
            // A plan solved *for* decode never loses to the prefill
            // plan replayed on decode traffic (ties allowed off the
            // paper instance — e.g. compute-rich testbeds where both
            // phases collapse to r2 = 1).
            assert!(
                dec.throughput_tokens >= cross * (1.0 - 1e-12),
                "{backbone}/{}: decode solve {} lost to prefill-plan-on-decode {}",
                tb.name,
                dec.throughput_tokens,
                cross
            );
            if deepseek && tb.name.starts_with('A') {
                paper_gate = Some((dec.throughput_tokens, cross));
            }
            table.row(&[
                backbone.into(),
                tb.name.clone(),
                pre.config.describe(),
                dec.config.describe(),
                format!("{:.0}", dec.throughput_tokens),
                format!("{cross:.0}"),
                format!("{:.2}x", dec.throughput_tokens / cross),
            ]);
            let mut e = JsonObj::new();
            e.insert("backbone", Json::Str(backbone.into()));
            e.insert("testbed", Json::Str(tb.name.clone()));
            e.insert("prefill_config", Json::Str(pre.config.describe()));
            e.insert("decode_config", Json::Str(dec.config.describe()));
            e.insert("prefill_tokens_per_s", Json::Num(pre.throughput_tokens));
            e.insert("decode_tokens_per_s", Json::Num(dec.throughput_tokens));
            e.insert("prefill_plan_on_decode_tokens_per_s", Json::Num(cross));
            e.insert("gain", Json::Num(dec.throughput_tokens / cross));
            entries.push(Json::Obj(e));
        }
    }
    table.print();
    // The acceptance gate, on the paper instance: strictly better, not
    // merely tied — prefill keeps r2 > 1 there while decode collapses
    // to r2 = 1, so the gap is real (≈6x analytically).
    let (dec_tput, cross_tput) = paper_gate.expect("paper instance must be feasible");
    assert!(
        dec_tput > cross_tput,
        "decode plan ({dec_tput} tok/s) must strictly beat prefill-plan-on-decode \
         ({cross_tput} tok/s) on the paper instance"
    );
    println!(
        "paper-instance gate: decode plan {dec_tput:.0} tok/s vs prefill-plan-on-decode \
         {cross_tput:.0} tok/s ({:.2}x)",
        dec_tput / cross_tput
    );
    report.insert("phase_plans", Json::Arr(entries));

    // --- 2. Phase-keyed caching over a KV-growing stream. -------------
    let model = ModelConfig::deepseek_v2(8);
    let tb = Testbed::a();
    let split = GroupSplit::new(3, 5);
    let steps = if quick { 96 } else { 512 };
    let prompt = 2048usize;
    let batch = 4usize;

    let solve_step = |kv: usize| {
        let inst =
            Instance::decode(model.clone(), tb.clone(), split, findep::solver::bucket_up(kv));
        solver::solve_online(&inst, batch, &params)
    };

    // Correctness: one miss per KV bucket, prefill entry never aliased.
    let cache = PlanCache::new();
    let pre_inst = Instance::new(model.clone(), tb.clone(), split, prompt);
    let pre_sol = cache.get_or_solve(shape_key(prompt, batch), || {
        solver::solve_online(&pre_inst, batch, &params)
    });
    assert!(pre_sol.is_some(), "prefill shape must be plannable");
    for step in 0..steps {
        let kv = prompt + step;
        let sol = cache.get_or_solve(shape_key_decode(kv, batch), || solve_step(kv));
        assert!(sol.is_some(), "decode step at kv={kv} must be plannable");
    }
    let kv_buckets: std::collections::BTreeSet<usize> =
        (0..steps).map(|s| findep::solver::bucket_up(prompt + s)).collect();
    assert_eq!(
        cache.misses() as usize,
        kv_buckets.len() + 1,
        "one solve per KV bucket plus the prefill shape"
    );
    assert_eq!(cache.len(), kv_buckets.len() + 1, "prefill and decode shapes must coexist");
    assert!((cache.misses() as usize) < steps, "caching must beat per-token re-solving");
    println!(
        "KV stream: {steps} decode steps -> {} bucket solves + 1 prefill shape, {} hits",
        kv_buckets.len(),
        cache.hits()
    );

    let r_cold = bencher.run("decode stream (cold solve per step)", || {
        for step in (0..steps).step_by(8) {
            let _ = solve_step(prompt + step);
        }
    });
    let stream_cache = PlanCache::new();
    let r_cached = bencher.run("decode stream (phase-keyed cache)", || {
        for step in (0..steps).step_by(8) {
            let kv = prompt + step;
            let _ = stream_cache.get_or_solve(shape_key_decode(kv, batch), || solve_step(kv));
        }
    });
    let mut t2 = Table::new(
        &format!("Decode planning over a KV-growing stream ({} sampled steps)", steps / 8),
        &["path", "mean / stream", "speedup"],
    );
    t2.row(&["cold solve".into(), fmt_duration(r_cold.mean_s()), "1.00x".into()]);
    t2.row(&[
        "phase-keyed cache".into(),
        fmt_duration(r_cached.mean_s()),
        format!("{:.0}x", r_cold.mean_s() / r_cached.mean_s()),
    ]);
    t2.print();
    assert!(
        r_cached.mean_s() < r_cold.mean_s(),
        "cached decode planning ({:.9}s) must beat per-step cold solve ({:.9}s)",
        r_cached.mean_s(),
        r_cold.mean_s()
    );
    let mut kvj = JsonObj::new();
    kvj.insert("steps", Json::Num(steps as f64));
    kvj.insert("kv_buckets", Json::Num(kv_buckets.len() as f64));
    kvj.insert("cold_mean_s", Json::Num(r_cold.mean_s()));
    kvj.insert("cached_mean_s", Json::Num(r_cached.mean_s()));
    kvj.insert("speedup", Json::Num(r_cold.mean_s() / r_cached.mean_s()));
    report.insert("kv_stream_cache", Json::Obj(kvj));

    // --- 3. Queue-fed autoregressive serving (needs artifacts). -------
    let dir = artifacts_dir();
    if dir.join("manifest.json").exists() {
        let handle = ModelHandle::load(&dir, true).expect("artifacts load");
        let (s, m) = (handle.seq_len, handle.model.embed);
        let n_requests = if quick { 8 } else { 24 };
        let out_len = if quick { 3 } else { 6 };
        let cfg = BatcherConfig {
            policy: Policy::Adaptive,
            workers: 2,
            max_batch: 8,
            queue_depth: 128,
            linger: Duration::from_micros(500),
            ..Default::default()
        };
        let batcher = Batcher::new(handle, cfg).expect("batcher");
        let t0 = Instant::now();
        for i in 0..n_requests {
            batcher
                .submit(EmbeddedRequest::synthetic_autoregressive(i as u64, s, m, out_len))
                .expect("submit");
        }
        let resps = batcher.drain(n_requests, Duration::from_secs(60));
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(resps.len(), n_requests, "autoregressive serving lost responses");
        assert_eq!(
            batcher.metrics().counter("decode_steps"),
            (n_requests * out_len) as u64,
            "every output token must run as a decode step"
        );
        assert_eq!(batcher.metrics().counter("decode_tokens"), (n_requests * out_len) as u64);
        assert!(
            batcher.plan_cache().len() >= 2,
            "prefill and decode shapes must be cached separately"
        );
        let tokens = n_requests * (s + out_len);
        println!(
            "queue-fed autoregressive: {n_requests} requests x {out_len} decode steps in \
             {dt:.2}s -> {:.1} tokens/s ({} plan shapes: prefill + decode KV buckets)",
            tokens as f64 / dt,
            batcher.plan_cache().len(),
        );
        let mut sj = JsonObj::new();
        sj.insert("requests", Json::Num(n_requests as f64));
        sj.insert("decode_steps_per_request", Json::Num(out_len as f64));
        sj.insert("wall_s", Json::Num(dt));
        sj.insert("tokens_per_s", Json::Num(tokens as f64 / dt));
        sj.insert("plan_shapes", Json::Num(batcher.plan_cache().len() as f64));
        report.insert("serving", Json::Obj(sj));
    } else {
        println!("artifacts missing: skipping queue-fed decode serving (run `make artifacts`)");
        report.insert("serving", Json::Str("skipped: artifacts missing".into()));
    }

    std::fs::write("BENCH_decode.json", to_string_pretty(&Json::Obj(report)))
        .expect("write BENCH_decode.json");
    println!("\nwrote BENCH_decode.json");
}
