//! The FinDEP configuration solver (§4).
//!
//! [`algorithm1::solve`] implements Algorithm 1: walk the
//! memory-constrained Pareto frontier of `(m_a, r1)` (Theorems 1-3 make
//! everything off the frontier dominated), solve the 1-D convex
//! subproblem in `r2` by ternary search (Theorem 4), and evaluate both
//! AASS and ASAS execution orders. Candidate evaluation runs on a
//! reusable [`algorithm1::Evaluator`] arena (no per-probe allocation)
//! with the §4.2 closed forms as the ASAS probe fast path.
//! [`bruteforce`] provides the exhaustive engine-only reference used by
//! tests and by the Tables 3/4 monotonicity experiments. [`cache`]
//! memoizes online solutions per `(seq bucket, batch bucket)` shape so
//! the serving loop solves once per shape, not once per batch — with
//! the serving phase and the calibration-profile fingerprint part of
//! the key, so prefill/decode plans and plans solved against different
//! measured constants can never alias;
//! [`algorithm1::solve_online_bucketed`] is the serving entry that
//! restricts `m_a` to the runtime's compiled attention buckets.
//! [`splitsearch`] sits above Algorithm 1: it searches the (ag, eg)
//! disaggregation split itself — plus multi-replica tilings of the
//! cluster — with analytic branch-and-bound pruning, parallel workers,
//! and cross-split topology reuse, bit-identical to the serial
//! exhaustive sweep. Re-solves are warm, pruned, and anytime
//! ([`algorithm1::WarmStart`], [`SolverParams`]'s `prune`/`budget`):
//! seeds from [`PlanCache::nearest`] steer the sweep without changing
//! the answer, the §4.2 bound prunes rows inside Algorithm 1 itself,
//! and budget-truncated incumbents are refined off the hot path via
//! [`PlanCache::publish_refined`].

pub mod algorithm1;
pub mod bruteforce;
pub mod cache;
pub mod memory;
pub mod splitsearch;

pub use algorithm1::{
    row_bound, solve, solve_mode, solve_online, solve_online_bucketed, solve_online_mode,
    solve_online_with, solve_warm, solve_with, EvalMode, Evaluator, Instance, Solution,
    SolverParams, WarmStart,
};
pub use cache::{bucket_up, shape_key, shape_key_decode, PlanCache, RefineToken, ShapeKey};
pub use crate::config::placement::PlacementId;
pub use crate::perfmodel::profile::ProfileId;
pub use memory::MemoryModel;
pub use splitsearch::{
    carve, enumerate_cluster_candidates, instance_bound, search as search_splits, search_cluster,
    search_replication, search_serial as search_splits_serial, throughput_bound_cluster,
    CarvePlan, PlacementSolution, ReplicationReport, ReplicationStats, SearchParams, SearchReport,
    SearchStats, SplitCandidate, SplitSolution, TrafficMix,
};
