//! Artifact-set loading: `manifest.json` + `weights.bin` + HLO text
//! files, as written by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::runtime::tensor::Tensor;
use crate::util::json::{self, Json};

/// One stage artifact entry from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub stage: String,
    pub bucket: usize,
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelConfig,
    pub model_noshared: ModelConfig,
    pub seq_len: usize,
    pub ma_buckets: Vec<usize>,
    pub ffn_buckets: Vec<usize>,
    pub artifacts: Vec<ArtifactEntry>,
    pub weights_file: PathBuf,
    pub tensor_table: Vec<(String, Vec<usize>, usize)>, // name, shape, offset (f32)
    pub golden: PathBuf,
    pub golden_noshared: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;

        let usizes = |j: &Json| -> Vec<usize> {
            j.as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default()
        };

        let mut artifacts = Vec::new();
        for a in v.get("artifacts").as_arr().unwrap_or(&[]) {
            artifacts.push(ArtifactEntry {
                stage: a.get("stage").as_str().unwrap_or("").to_string(),
                bucket: a.get("bucket").as_usize().context("artifact bucket")?,
                path: dir.join(a.get("path").as_str().context("artifact path")?),
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }

        let mut tensor_table = Vec::new();
        for t in v.get("weights").get("tensors").as_arr().unwrap_or(&[]) {
            tensor_table.push((
                t.get("name").as_str().context("tensor name")?.to_string(),
                usizes(t.get("shape")),
                t.get("offset").as_usize().context("tensor offset")?,
            ));
        }

        Ok(Manifest {
            model: ModelConfig::from_json(v.get("model"))?,
            model_noshared: ModelConfig::from_json(v.get("model_noshared"))?,
            seq_len: v.get("seq_len").as_usize().context("seq_len")?,
            ma_buckets: usizes(v.get("ma_buckets")),
            ffn_buckets: usizes(v.get("ffn_buckets")),
            artifacts,
            weights_file: dir.join(v.get("weights").get("file").as_str().unwrap_or("weights.bin")),
            tensor_table,
            golden: dir.join(v.get("golden").as_str().unwrap_or("golden.json")),
            golden_noshared: dir
                .join(v.get("golden_noshared").as_str().unwrap_or("golden_noshared.json")),
        })
    }
}

/// The model weights, loaded once and addressed by manifest name
/// (`layer{t}.{tensor}`); stacked expert tensors are sliced per expert.
#[derive(Debug)]
pub struct Weights {
    tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn load(manifest: &Manifest) -> Result<Weights> {
        let bytes = std::fs::read(&manifest.weights_file)
            .with_context(|| format!("reading {}", manifest.weights_file.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "weights.bin not a multiple of 4 bytes");
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut tensors = BTreeMap::new();
        for (name, shape, offset) in &manifest.tensor_table {
            let n: usize = shape.iter().product();
            anyhow::ensure!(offset + n <= floats.len(), "tensor {name} out of bounds");
            tensors.insert(
                name.clone(),
                Tensor::new(shape.clone(), floats[*offset..offset + n].to_vec()),
            );
        }
        Ok(Weights { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("missing weight tensor '{name}'"))
    }

    /// Slice expert `e` out of a stacked `[E, ...]` tensor.
    pub fn expert_slice(&self, name: &str, e: usize) -> Result<Tensor> {
        let t = self.get(name)?;
        anyhow::ensure!(t.rank() >= 2 && e < t.shape[0], "bad expert slice {name}[{e}]");
        let w: usize = t.shape[1..].iter().product();
        Ok(Tensor::new(t.shape[1..].to_vec(), t.data[e * w..(e + 1) * w].to_vec()))
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }
}

/// A golden end-to-end case from `golden.json`.
#[derive(Debug, Clone)]
pub struct Golden {
    pub batch: usize,
    pub seq: usize,
    pub embed: usize,
    pub input: Tensor,
    pub output: Tensor,
    pub atol: f32,
}

impl Golden {
    pub fn load(path: &Path) -> Result<Golden> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text)?;
        let batch = v.get("batch").as_usize().context("batch")?;
        let seq = v.get("seq").as_usize().context("seq")?;
        let embed = v.get("embed").as_usize().context("embed")?;
        let floats = |key: &str| -> Result<Vec<f32>> {
            Ok(v.get(key)
                .as_arr()
                .context("golden array")?
                .iter()
                .filter_map(|x| x.as_f64().map(|f| f as f32))
                .collect())
        };
        Ok(Golden {
            batch,
            seq,
            embed,
            input: Tensor::new(vec![batch, seq, embed], floats("input")?),
            output: Tensor::new(vec![batch, seq, embed], floats("output")?),
            atol: v.get("atol").as_f64().unwrap_or(2e-3) as f32,
        })
    }
}

/// Convenience bundle: manifest + weights together.
#[derive(Debug)]
pub struct ArtifactSet {
    pub manifest: Manifest,
    pub weights: Weights,
    pub dir: PathBuf,
}

impl ArtifactSet {
    pub fn load(dir: &Path) -> Result<ArtifactSet> {
        let manifest = Manifest::load(dir)?;
        let weights = Weights::load(&manifest)?;
        Ok(ArtifactSet { manifest, weights, dir: dir.to_path_buf() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_and_weights_load() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let set = ArtifactSet::load(&artifacts_dir()).unwrap();
        assert_eq!(set.manifest.model.name, "tiny");
        assert_eq!(set.manifest.model.n_experts, 8);
        assert!(set.manifest.artifacts.len() >= 10);
        // Every weight tensor named by the table is loadable.
        let wq = set.weights.get("layer0.wq").unwrap();
        assert_eq!(wq.shape, vec![64, 64]);
        // Expert slicing.
        let e3 = set.weights.expert_slice("layer0.exp_gate", 3).unwrap();
        assert_eq!(e3.shape, vec![128, 64]);
        let e0 = set.weights.expert_slice("layer0.exp_gate", 0).unwrap();
        assert_ne!(e0.data, e3.data);
    }

    #[test]
    fn golden_loads() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let g = Golden::load(&m.golden).unwrap();
        assert_eq!(g.input.shape, vec![g.batch, g.seq, g.embed]);
        assert_eq!(g.output.numel(), g.input.numel());
        assert!(g.atol > 0.0);
    }
}
