//! Micro-benchmark harness substrate (`criterion` is not vendored).
//!
//! Provides warmup + timed iteration with basic robust statistics, plus a
//! markdown table printer used by every `rust/benches/*` binary to emit
//! the paper's tables in a uniform format.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time, seconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn p50_s(&self) -> f64 {
        stats::percentile(&self.samples, 50.0)
    }

    pub fn std_s(&self) -> f64 {
        stats::std_dev(&self.samples)
    }

    pub fn mean_ns(&self) -> f64 {
        self.mean_s() * 1e9
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12} ± {:>10}  (p50 {:>12}, n={})",
            self.name,
            fmt_duration(self.mean_s()),
            fmt_duration(self.std_s()),
            fmt_duration(self.p50_s()),
            self.samples.len()
        )
    }
}

/// Human duration formatting.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Benchmark runner with warmup and adaptive iteration count.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: usize,
    pub min_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

impl Bencher {
    /// Fast settings for CI-style runs.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(150),
            max_iters: 2_000,
            min_iters: 3,
        }
    }

    /// Run `f` repeatedly, returning per-iteration timings.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup phase.
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup && warm_iters < self.max_iters {
            f();
            warm_iters += 1;
        }
        // Measure phase.
        let mut samples = Vec::new();
        let m0 = Instant::now();
        while (m0.elapsed() < self.measure || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        BenchResult { name: name.to_string(), samples }
    }
}

/// Markdown table builder for bench reports (paper-table shaped output).
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    /// Render as GitHub-flavoured markdown with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n### {}\n\n", self.title));
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            max_iters: 1000,
            min_iters: 3,
        };
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.samples.len() >= 3);
        assert!(r.mean_s() > 0.0);
        assert!(acc != 1); // keep the work observable
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5e-9), "2.5 ns");
        assert_eq!(fmt_duration(3.25e-6), "3.25 µs");
        assert_eq!(fmt_duration(4.5e-3), "4.50 ms");
        assert_eq!(fmt_duration(1.5), "1.500 s");
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.rows_str(&["1", "2"]);
        t.rows_str(&["333", "4"]);
        let s = t.render();
        assert!(s.contains("### Demo"));
        assert!(s.contains("| a   | bb |"));
        assert!(s.contains("| 333 | 4  |"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.rows_str(&["only-one"]);
    }
}
