//! Event-driven coordinator vs the retired polling thread pool.
//!
//! 1. **Simulated decode workload** (always runs, model-free) — the
//!    same autoregressive request stream through (a) the event core
//!    with condvar-parked workers and (b) the retired
//!    [`assembler_loop`] + channel fan-out, both with a no-op
//!    executor so the measured difference is pure coordination cost.
//!    Gates: the event loop's mean queue wait is strictly lower and
//!    its request throughput at least matches the baseline.
//! 2. **Idle cost** (always runs) — both designs sit idle; the event
//!    core must perform near-zero wakeups while the baseline burns a
//!    poll every 200µs ([`DECODE_POLL`]).
//! 3. **Queue-fed serving** (needs `make artifacts`) — the real
//!    [`Batcher`] vs [`ThreadPoolBatcher`] on the AOT testbed model,
//!    plus the bit-identity gate: with `workers=1, max_batch=1,
//!    linger=0` both paths must return responses bit-identical to a
//!    serial [`Server::serve_batch`] oracle, in FIFO order.
//!
//! Emits `BENCH_event_coordinator.json`.
//!
//! Run: `cargo bench --bench event_coordinator`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use findep::coordinator::batcher::{Batcher, BatcherConfig};
use findep::coordinator::executor::{run_worker, EventCore};
use findep::coordinator::moe::ModelHandle;
use findep::coordinator::planner::{PlannerConfig, QueuedRequest};
use findep::coordinator::server::{EmbeddedRequest, Policy, Server};
use findep::coordinator::threadpool::{assembler_loop, ThreadPoolBatcher, DECODE_POLL};
use findep::metrics::Registry;
use findep::runtime::artifacts_dir;
use findep::util::bench::{fmt_duration, Table};
use findep::util::json::{to_string_pretty, Json, JsonObj};

const WORKERS: usize = 2;
const MAX_BATCH: usize = 8;
const QUEUE_DEPTH: usize = 64;
const LINGER: Duration = Duration::from_micros(200);

/// Queue-wait statistics and wall time for one coordination design
/// over the whole measured stream.
struct SideStats {
    requests: u64,
    wall_s: f64,
    qw_mean_s: f64,
    qw_p99_s: f64,
    qw_max_s: f64,
    wakeups: u64,
}

impl SideStats {
    fn req_per_s(&self) -> f64 {
        self.requests as f64 / self.wall_s
    }

    fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("requests", Json::Num(self.requests as f64));
        o.insert("wall_s", Json::Num(self.wall_s));
        o.insert("req_per_s", Json::Num(self.req_per_s()));
        o.insert("queue_wait_mean_s", Json::Num(self.qw_mean_s));
        o.insert("queue_wait_p99_s", Json::Num(self.qw_p99_s));
        o.insert("queue_wait_max_s", Json::Num(self.qw_max_s));
        o.insert("wakeups", Json::Num(self.wakeups as f64));
        o.insert("idle_wakeups", Json::Num(0.0));
        Json::Obj(o)
    }

    fn row(&self, name: &str) -> Vec<String> {
        vec![
            name.into(),
            format!("{:.0}", self.req_per_s()),
            fmt_duration(self.qw_mean_s),
            fmt_duration(self.qw_p99_s),
            fmt_duration(self.qw_max_s),
            format!("{}", self.wakeups),
        ]
    }
}

fn qw(metrics: &Registry, requests: u64, wall_s: f64, wakeups: u64) -> SideStats {
    SideStats {
        requests,
        wall_s,
        qw_mean_s: metrics.histogram_mean("queue_wait").unwrap_or(0.0),
        qw_p99_s: metrics.histogram_percentile("queue_wait", 99.0).unwrap_or(0.0),
        qw_max_s: metrics.histogram_max("queue_wait").unwrap_or(0.0),
        wakeups,
    }
}

// ---- side A: the event core with a no-op executor ----------------------

fn event_workers(
    core: &Arc<EventCore>,
    metrics: &Arc<Registry>,
    done: Sender<u64>,
) -> Vec<JoinHandle<()>> {
    (0..WORKERS)
        .map(|_| {
            core.register_worker();
            let core = core.clone();
            let metrics = metrics.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let c = core.clone();
                run_worker(&core, &metrics, move |batch| {
                    let n = batch.len();
                    for q in batch {
                        if q.req.output_len > 0 {
                            let mut next = q.req;
                            next.output_len -= 1;
                            c.add_open(1);
                            c.reenter_decode(QueuedRequest::reentry(next, q.submitted));
                        } else {
                            let _ = done.send(q.req.id);
                        }
                    }
                    c.release_open(n);
                });
            })
        })
        .collect()
}

fn event_round(n: u64, out_len: usize, rounds: usize) -> SideStats {
    let metrics = Arc::new(Registry::new());
    let mut wall_s = 0.0;
    let mut wakeups = 0;
    for round in 0..=rounds {
        let core = Arc::new(EventCore::new(PlannerConfig {
            max_batch: MAX_BATCH,
            linger: LINGER,
            queue_depth: QUEUE_DEPTH,
        }));
        // Round 0 is warmup: measure into a throwaway registry.
        let m = if round == 0 { Arc::new(Registry::new()) } else { metrics.clone() };
        let (done_tx, done_rx) = channel();
        let threads = event_workers(&core, &m, done_tx);
        let t0 = Instant::now();
        for i in 0..n {
            core.submit(EmbeddedRequest::synthetic_autoregressive(i, 2, 2, out_len)).unwrap();
        }
        for _ in 0..n {
            done_rx.recv_timeout(Duration::from_secs(60)).expect("event round finished");
        }
        let dt = t0.elapsed().as_secs_f64();
        core.close();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(core.open(), 0);
        if round > 0 {
            wall_s += dt;
            wakeups += core.wakeups();
        }
    }
    qw(&metrics, n * rounds as u64, wall_s, wakeups)
}

// ---- side B: the retired polling assembler with the same executor ------

fn baseline_workers(
    work_rx: &Arc<Mutex<Receiver<Vec<QueuedRequest>>>>,
    decode_tx: &Sender<QueuedRequest>,
    open: &Arc<AtomicUsize>,
    done: Sender<u64>,
) -> Vec<JoinHandle<()>> {
    (0..WORKERS)
        .map(|_| {
            let work_rx = work_rx.clone();
            let decode_tx = decode_tx.clone();
            let open = open.clone();
            let done = done.clone();
            std::thread::spawn(move || loop {
                let batch = {
                    let rx = work_rx.lock().unwrap();
                    rx.recv()
                };
                let Ok(batch) = batch else { return };
                let n = batch.len();
                for q in batch {
                    if q.req.output_len > 0 {
                        let mut next = q.req;
                        next.output_len -= 1;
                        open.fetch_add(1, Ordering::SeqCst);
                        let _ = decode_tx.send(QueuedRequest::reentry(next, q.submitted));
                    } else {
                        let _ = done.send(q.req.id);
                    }
                }
                open.fetch_sub(n, Ordering::SeqCst);
            })
        })
        .collect()
}

fn baseline_round(n: u64, out_len: usize, rounds: usize) -> SideStats {
    let metrics = Arc::new(Registry::new());
    let mut wall_s = 0.0;
    let mut wakeups = 0;
    for round in 0..=rounds {
        let m = if round == 0 { Arc::new(Registry::new()) } else { metrics.clone() };
        let (submit_tx, submit_rx) = sync_channel::<QueuedRequest>(QUEUE_DEPTH);
        let (decode_tx, decode_rx) = channel::<QueuedRequest>();
        let (work_tx, work_rx) = sync_channel::<Vec<QueuedRequest>>(WORKERS);
        let work_rx = Arc::new(Mutex::new(work_rx));
        let open = Arc::new(AtomicUsize::new(0));
        let assembler = {
            let m = m.clone();
            let open = open.clone();
            std::thread::spawn(move || {
                assembler_loop(submit_rx, decode_rx, work_tx, MAX_BATCH, LINGER, open, m)
            })
        };
        let (done_tx, done_rx) = channel();
        let threads = baseline_workers(&work_rx, &decode_tx, &open, done_tx);
        drop(decode_tx);
        let t0 = Instant::now();
        for i in 0..n {
            open.fetch_add(1, Ordering::SeqCst);
            submit_tx.send(QueuedRequest::fresh(EmbeddedRequest::synthetic_autoregressive(
                i, 2, 2, out_len,
            )))
            .unwrap();
        }
        for _ in 0..n {
            done_rx.recv_timeout(Duration::from_secs(60)).expect("baseline round finished");
        }
        let dt = t0.elapsed().as_secs_f64();
        // Close the submit side: the assembler drains (open is already
        // 0) and the work channel closes behind it.
        drop(submit_tx);
        assembler.join().unwrap();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(open.load(Ordering::SeqCst), 0);
        if round > 0 {
            wall_s += dt;
            // `m` is the shared registry across measured rounds, so the
            // counter is already the cumulative total.
            wakeups = m.counter("poll_wakeups");
        }
    }
    qw(&metrics, n * rounds as u64, wall_s, wakeups)
}

// ---- idle cost ---------------------------------------------------------

fn idle_cost(window: Duration) -> (u64, u64) {
    let core = Arc::new(EventCore::new(PlannerConfig {
        max_batch: MAX_BATCH,
        linger: LINGER,
        queue_depth: QUEUE_DEPTH,
    }));
    let metrics = Arc::new(Registry::new());
    let (done_tx, _done_rx) = channel();
    let threads = event_workers(&core, &metrics, done_tx);
    std::thread::sleep(window);
    let event_wakeups = core.wakeups();
    core.close();
    for t in threads {
        t.join().unwrap();
    }

    let m = Arc::new(Registry::new());
    let (submit_tx, submit_rx) = sync_channel::<QueuedRequest>(QUEUE_DEPTH);
    let (decode_tx, decode_rx) = channel::<QueuedRequest>();
    let (work_tx, work_rx) = sync_channel::<Vec<QueuedRequest>>(WORKERS);
    let open = Arc::new(AtomicUsize::new(0));
    let assembler = {
        let m = m.clone();
        let open = open.clone();
        std::thread::spawn(move || {
            assembler_loop(submit_rx, decode_rx, work_tx, MAX_BATCH, LINGER, open, m)
        })
    };
    std::thread::sleep(window);
    let baseline_polls = m.counter("poll_wakeups");
    drop(submit_tx);
    drop(decode_tx);
    drop(work_rx);
    assembler.join().unwrap();
    (event_wakeups, baseline_polls)
}

// ---- real serving (artifact-gated) -------------------------------------

fn serve_stream(
    submit: impl Fn(EmbeddedRequest) -> anyhow::Result<()>,
    drain: impl Fn(usize) -> Vec<findep::coordinator::server::Response>,
    n: u64,
    s: usize,
    m: usize,
    out_len: usize,
) -> (f64, Vec<findep::coordinator::server::Response>) {
    let t0 = Instant::now();
    for i in 0..n {
        submit(EmbeddedRequest::synthetic_autoregressive(i, s, m, out_len)).expect("submit");
    }
    let resps = drain(n as usize);
    (t0.elapsed().as_secs_f64(), resps)
}

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let mut report = JsonObj::new();
    report.insert("bench", Json::Str("event_coordinator".into()));
    report.insert("quick", Json::Bool(quick));

    // --- 1. Simulated decode workload: coordination cost only. --------
    let (n, out_len, rounds) = if quick { (16u64, 4usize, 2usize) } else { (32, 8, 5) };
    let event = event_round(n, out_len, rounds);
    let baseline = baseline_round(n, out_len, rounds);
    let mut table = Table::new(
        &format!(
            "Simulated decode workload ({n} reqs x {out_len} steps x {rounds} rounds, \
             no-op executor, {WORKERS} workers)"
        ),
        &["coordinator", "req/s", "queue wait mean", "p99", "max", "wakeups"],
    );
    table.row(&event.row("event core"));
    table.row(&baseline.row("polling pool"));
    table.print();
    // The acceptance gates. Every decode re-entry in the baseline waits
    // for a 200µs poll tick before assembly; the event core is woken by
    // the re-entry itself, so both margins are structural, not noise.
    assert!(
        event.qw_mean_s < baseline.qw_mean_s,
        "event core queue wait ({:.9}s) must be strictly below the polling baseline ({:.9}s)",
        event.qw_mean_s,
        baseline.qw_mean_s
    );
    assert!(
        event.req_per_s() >= baseline.req_per_s(),
        "event core throughput ({:.1} req/s) must at least match the baseline ({:.1} req/s)",
        event.req_per_s(),
        baseline.req_per_s()
    );
    let mut sim = JsonObj::new();
    sim.insert("requests", Json::Num((n * rounds as u64) as f64));
    sim.insert("decode_steps_per_request", Json::Num(out_len as f64));
    sim.insert("event", event.to_json());
    sim.insert("baseline", baseline.to_json());
    sim.insert("queue_wait_ratio", Json::Num(baseline.qw_mean_s / event.qw_mean_s.max(1e-12)));
    sim.insert("speedup", Json::Num(event.req_per_s() / baseline.req_per_s()));
    report.insert("simulated", Json::Obj(sim));

    // --- 2. Idle cost: parked condvars vs the 200µs poll. -------------
    let window = if quick { Duration::from_millis(150) } else { Duration::from_millis(300) };
    let (event_wakeups, baseline_polls) = idle_cost(window);
    println!(
        "\nidle for {window:?}: event core {event_wakeups} wakeups, \
         polling baseline {baseline_polls} poll ticks"
    );
    assert!(
        event_wakeups <= 8,
        "idle event core woke {event_wakeups} times; workers must park"
    );
    assert!(
        baseline_polls > 100,
        "baseline should poll at the {DECODE_POLL:?} cadence while idle, saw {baseline_polls}"
    );
    let mut idle = JsonObj::new();
    idle.insert("window_s", Json::Num(window.as_secs_f64()));
    idle.insert("event_wakeups", Json::Num(event_wakeups as f64));
    idle.insert("baseline_poll_ticks", Json::Num(baseline_polls as f64));
    report.insert("idle", Json::Obj(idle));

    // --- 3. Real serving + the bit-identity oracle gate. --------------
    let dir = artifacts_dir();
    if dir.join("manifest.json").exists() {
        let model = ModelHandle::load(&dir, true).expect("artifacts load");
        let (s, m) = (model.seq_len, model.model.embed);

        // Bit-identity: one request per window on one worker pins the
        // batch composition, so both batchers must reproduce the serial
        // oracle bit for bit, in FIFO order.
        let oracle_n = 8u64;
        let direct = Server::new(model.clone(), 2, None).expect("oracle server");
        let mut want = Vec::new();
        for i in 0..oracle_n {
            let req = EmbeddedRequest::synthetic(i, s, m);
            let (mut resp, _) =
                direct.serve_batch(std::slice::from_ref(&req), Policy::Adaptive).expect("oracle");
            want.push(resp.remove(0));
        }
        let serial_cfg = BatcherConfig {
            workers: 1,
            max_batch: 1,
            linger: Duration::ZERO,
            policy: Policy::Adaptive,
            ..Default::default()
        };
        let check = |name: &str, got: &[findep::coordinator::server::Response]| {
            assert_eq!(got.len(), want.len(), "{name}: lost responses");
            for (i, (w, g)) in want.iter().zip(got).enumerate() {
                assert_eq!(g.id, i as u64, "{name}: broke FIFO order");
                assert_eq!(
                    w.hidden.data, g.hidden.data,
                    "{name}: response {i} is not bit-identical to the serial oracle"
                );
            }
        };
        {
            let b = Batcher::new(model.clone(), serial_cfg).expect("event batcher");
            for i in 0..oracle_n {
                b.submit(EmbeddedRequest::synthetic(i, s, m)).expect("submit");
            }
            check("event batcher", &b.drain(oracle_n as usize, Duration::from_secs(60)));
        }
        {
            let b = ThreadPoolBatcher::new(model.clone(), serial_cfg).expect("pool batcher");
            for i in 0..oracle_n {
                b.submit(EmbeddedRequest::synthetic(i, s, m)).expect("submit");
            }
            check("polling batcher", &b.drain(oracle_n as usize, Duration::from_secs(60)));
        }
        println!("\nbit-identity: both batchers match the serial oracle on {oracle_n} requests");
        let mut oracle = JsonObj::new();
        oracle.insert("requests", Json::Num(oracle_n as f64));
        oracle.insert("bit_identical", Json::Bool(true));
        report.insert("oracle", Json::Obj(oracle));

        // Decode-heavy serving through both coordinators.
        let n_requests = if quick { 24u64 } else { 64 };
        let real_out = 2usize;
        let cfg = BatcherConfig {
            workers: WORKERS,
            max_batch: MAX_BATCH,
            queue_depth: 128,
            linger: Duration::from_micros(500),
            policy: Policy::Adaptive,
            ..Default::default()
        };
        let event_b = Batcher::new(model.clone(), cfg).expect("event batcher");
        let (dt, resps) = serve_stream(
            |r| Ok(event_b.submit(r)?),
            |k| event_b.drain(k, Duration::from_secs(60)),
            n_requests,
            s,
            m,
            real_out,
        );
        assert_eq!(resps.len(), n_requests as usize, "event batcher lost responses");
        let ev = qw(event_b.metrics(), n_requests, dt, event_b.wakeups());
        drop(event_b);

        let pool_b = ThreadPoolBatcher::new(model.clone(), cfg).expect("pool batcher");
        let (dt, resps) = serve_stream(
            |r| pool_b.submit(r),
            |k| pool_b.drain(k, Duration::from_secs(60)),
            n_requests,
            s,
            m,
            real_out,
        );
        assert_eq!(resps.len(), n_requests as usize, "pool batcher lost responses");
        let pl = qw(pool_b.metrics(), n_requests, dt, pool_b.poll_wakeups());
        drop(pool_b);

        let mut table = Table::new(
            &format!(
                "Queue-fed serving ({n_requests} reqs x {real_out} decode steps, \
                 {WORKERS} workers, adaptive + plan cache)"
            ),
            &["coordinator", "req/s", "queue wait mean", "p99", "max", "wakeups"],
        );
        table.row(&ev.row("event batcher"));
        table.row(&pl.row("polling batcher"));
        table.print();
        // Quick mode runs too few requests to gate CI on a wall-clock
        // ordering over the real pipeline (same policy as
        // serving_speed); the simulated gate above holds in every mode.
        if !quick {
            assert!(
                ev.qw_mean_s < pl.qw_mean_s,
                "real-path queue wait: event ({:.9}s) must beat polling ({:.9}s)",
                ev.qw_mean_s,
                pl.qw_mean_s
            );
            assert!(
                ev.req_per_s() >= pl.req_per_s(),
                "real-path throughput: event ({:.1} req/s) must match polling ({:.1} req/s)",
                ev.req_per_s(),
                pl.req_per_s()
            );
        }
        let mut serving = JsonObj::new();
        serving.insert("requests", Json::Num(n_requests as f64));
        serving.insert("decode_steps_per_request", Json::Num(real_out as f64));
        serving.insert("event", ev.to_json());
        serving.insert("baseline", pl.to_json());
        report.insert("serving", Json::Obj(serving));
    } else {
        println!("\nartifacts missing: skipping queue-fed serving (run `make artifacts`)");
        report.insert("serving", Json::Str("skipped: artifacts missing".into()));
    }

    std::fs::write("BENCH_event_coordinator.json", to_string_pretty(&Json::Obj(report)))
        .expect("write BENCH_event_coordinator.json");
    println!("\nwrote BENCH_event_coordinator.json");
}
