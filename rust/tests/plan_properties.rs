//! Property tests for the plan builder (the arena refactor's safety
//! net): for random `PlanConfig`s over random positive stage models,
//!
//! 1. `Plan::build` always produces an acyclic DAG (deps + issue-order
//!    edges) whose dependency edges are exactly rules 6-9;
//! 2. `Plan::build_into` into a continuously-reused `PlanBuffers` arena
//!    is task-for-task identical to a fresh `Plan::build`.

use std::collections::HashMap;

use findep::perfmodel::{LinearModel, StageModels};
use findep::sched::{Order, Plan, PlanBuffers, PlanConfig, TaskKind};
use findep::util::proptest::{self, Config};
use findep::util::rng::Rng;

fn random_models(rng: &mut Rng) -> StageModels {
    StageModels {
        t_a: LinearModel::new(rng.range_f64(1e-6, 2e-3), rng.range_f64(1e-6, 2e-3)),
        t_s: LinearModel::new(rng.range_f64(0.0, 1e-3), rng.range_f64(0.0, 1e-3)),
        t_e: LinearModel::new(rng.range_f64(1e-6, 2e-3), rng.range_f64(1e-7, 1e-4)),
        t_a2e: LinearModel::new(rng.range_f64(1e-6, 2e-3), rng.range_f64(1e-7, 1e-4)),
        k_tokens: rng.range_f64(2.0, 400.0),
        has_shared: rng.bool(0.6),
    }
}

fn random_config(rng: &mut Rng, sm: &StageModels) -> PlanConfig {
    let m_a = 1 + rng.usize_below(6);
    let r1 = 1 + rng.usize_below(5);
    let r2 = 1 + rng.usize_below(8);
    let order = if rng.bool(0.5) { Order::Asas } else { Order::Aass };
    let mut cfg = PlanConfig::findep(m_a, r1, r2, sm.m_e(m_a as f64, r2), order);
    cfg.fuse_shared = rng.bool(0.2);
    cfg
}

/// O(1) task-identity index (the plan's own `find` is O(n) and too slow
/// for a property sweep).
fn index_map(plan: &Plan) -> HashMap<(TaskKind, u32, u32, u32), u32> {
    plan.tasks
        .iter()
        .enumerate()
        .map(|(i, t)| ((t.kind, t.layer, t.chunk, t.part), i as u32))
        .collect()
}

/// Expected rule-6..9 dependency set for task `i`, straight from the
/// paper's constraints (independent of the builder's index arithmetic).
fn expected_deps(
    plan: &Plan,
    idx: &HashMap<(TaskKind, u32, u32, u32), u32>,
    i: usize,
) -> Vec<u32> {
    let t = &plan.tasks[i];
    let (layer, chunk, part) = (t.layer, t.chunk, t.part);
    let find = |kind, l, c, p| *idx.get(&(kind, l, c, p)).expect("referenced task exists");
    match t.kind {
        TaskKind::Attention => {
            if layer == 0 {
                Vec::new()
            } else {
                // Rule 9: all E2A parts of the same chunk one layer
                // down, plus (if scheduled) its shared segment.
                let mut d: Vec<u32> = (0..plan.config.r2 as u32)
                    .map(|j| find(TaskKind::E2A, layer - 1, chunk, j))
                    .collect();
                if plan.has_shared_tasks {
                    d.push(find(TaskKind::SharedExpert, layer - 1, chunk, 0));
                }
                d
            }
        }
        // Rule 6.
        TaskKind::SharedExpert => vec![find(TaskKind::Attention, layer, chunk, 0)],
        TaskKind::A2E => vec![find(TaskKind::Attention, layer, chunk, 0)],
        // Rule 7.
        TaskKind::Expert => vec![find(TaskKind::A2E, layer, chunk, part)],
        // Rule 8.
        TaskKind::E2A => vec![find(TaskKind::Expert, layer, chunk, part)],
    }
}

/// Kahn's algorithm over deps + per-resource issue-order edges.
fn is_acyclic(plan: &Plan) -> bool {
    let n = plan.n_tasks();
    let mut indeg = vec![0usize; n];
    let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
    for i in 0..n {
        indeg[i] = plan.deps(i).len();
        for &d in plan.deps(i) {
            dependents[d as usize].push(i as u32);
        }
    }
    for q in &plan.issue_order {
        for w in q.windows(2) {
            dependents[w[0] as usize].push(w[1]);
            indeg[w[1] as usize] += 1;
        }
    }
    let mut ready: Vec<usize> =
        indeg.iter().enumerate().filter(|(_, &d)| d == 0).map(|(i, _)| i).collect();
    let mut done = 0usize;
    while let Some(i) = ready.pop() {
        done += 1;
        for &nx in &dependents[i] {
            indeg[nx as usize] -= 1;
            if indeg[nx as usize] == 0 {
                ready.push(nx as usize);
            }
        }
    }
    done == n
}

#[test]
fn build_respects_rules_6_to_9_and_stays_acyclic() {
    proptest::check("plan-rules-acyclic", &Config::with_cases(120), |rng| {
        let sm = random_models(rng);
        let cfg = random_config(rng, &sm);
        let layers = 1 + rng.usize_below(5);
        let ag = 1 + rng.usize_below(6);
        let plan = Plan::build(&sm, cfg, layers, ag, 1024);
        let idx = index_map(&plan);
        for i in 0..plan.n_tasks() {
            let mut got: Vec<u32> = plan.deps(i).to_vec();
            let mut want = expected_deps(&plan, &idx, i);
            got.sort_unstable();
            want.sort_unstable();
            proptest::ensure(
                got == want,
                format!(
                    "deps of {} are {:?}, rules 6-9 require {:?} ({})",
                    plan.tasks[i].label(),
                    got,
                    want,
                    cfg.describe()
                ),
            )?;
        }
        proptest::ensure(
            is_acyclic(&plan),
            format!("cyclic plan for {}", cfg.describe()),
        )
    });
}

#[test]
fn build_into_is_identical_to_fresh_build() {
    // One arena reused across every random case: any stale state left
    // behind by a previous (differently-shaped) build would show up as
    // an inequality here.
    let mut buf = PlanBuffers::new();
    proptest::check("build-into-identity", &Config::with_cases(120), |rng| {
        let sm = random_models(rng);
        let cfg = random_config(rng, &sm);
        let layers = 1 + rng.usize_below(5);
        let ag = 1 + rng.usize_below(6);
        let fresh = Plan::build(&sm, cfg, layers, ag, 1024);
        let reused = Plan::build_into(&mut buf, &sm, cfg, layers, ag, 1024);
        proptest::ensure(
            *reused == fresh,
            format!("build_into drifted from build for {}", cfg.describe()),
        )?;
        // Task-for-task field check (catches PartialEq blind spots).
        for i in 0..fresh.n_tasks() {
            proptest::ensure(
                fresh.deps(i) == reused.deps(i),
                format!("dep slice {i} differs for {}", cfg.describe()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn build_into_batches_are_deterministic_across_arena_histories() {
    // The same config built through arenas with different histories must
    // agree (the arena cannot leak capacity-dependent behaviour).
    let sm = StageModels {
        t_a: LinearModel::new(1e-4, 1e-4),
        t_s: LinearModel::new(5e-5, 5e-5),
        t_e: LinearModel::new(1e-4, 1e-6),
        t_a2e: LinearModel::new(1e-4, 1e-6),
        k_tokens: 32.0,
        has_shared: true,
    };
    let big = PlanConfig::findep(4, 4, 8, sm.m_e(4.0, 8), Order::Aass);
    let small = PlanConfig::findep(1, 1, 1, sm.m_e(1.0, 1), Order::Asas);

    let mut warm_big = PlanBuffers::new();
    Plan::build_into(&mut warm_big, &sm, big, 6, 3, 2048);
    let via_big = Plan::build_into(&mut warm_big, &sm, small, 6, 3, 2048).clone();

    let mut cold = PlanBuffers::new();
    let via_cold = Plan::build_into(&mut cold, &sm, small, 6, 3, 2048).clone();

    assert_eq!(via_big, via_cold);
    assert_eq!(via_cold, Plan::build(&sm, small, 6, 3, 2048));
}
