//! Deterministic PRNG substrate (the `rand` crate is not vendored).
//!
//! SplitMix64 core with helpers for the distributions the workload
//! generators and property tests need: uniform ints/floats, normal
//! (Box-Muller), exponential, Poisson, lognormal, shuffles.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes; most
/// importantly fully deterministic across platforms for reproducible
/// experiments.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second output of Box-Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed, spare_normal: None }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and std-dev.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal parameterised by the *target* mean/std of the output
    /// distribution (convenient for "mean prompt length 3072" workloads).
    pub fn lognormal_mean_std(&mut self, mean: f64, std: f64) -> f64 {
        let var = std * std;
        let mu = (mean * mean / (var + mean * mean).sqrt()).ln();
        let sigma = (1.0 + var / (mean * mean)).ln().sqrt();
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Inter-arrival gaps
    /// of a Poisson process.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson-distributed count (Knuth for small mean, normal approx for
    /// large mean).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 60.0 {
            return self.normal_with(mean, mean.sqrt()).round().max(0.0) as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-distributed rank in `[0, n)`: `P(k) ∝ 1/(k+1)^s`, rank 0
    /// hottest. `s = 0` degenerates to uniform. Inverse transform over
    /// the finite support — no heap allocation at all (two O(n) scans
    /// per draw); bulk samplers should precompute a CDF instead (see
    /// `config::placement::ExpertLoad::sampler`).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        assert!(n > 0, "zipf(0)");
        let mut total = 0.0;
        for k in 0..n {
            total += ((k + 1) as f64).powf(-s);
        }
        let u = self.f64() * total;
        let mut cum = 0.0;
        for k in 0..n {
            cum += ((k + 1) as f64).powf(-s);
            if u < cum {
                return k;
            }
        }
        n - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.poisson(4.5) as f64).sum::<f64>() / n as f64;
        assert!((m - 4.5).abs() < 0.1, "mean={m}");
    }

    #[test]
    fn lognormal_targets_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.lognormal_mean_std(3072.0, 1024.0)).sum::<f64>() / n as f64;
        assert!((m - 3072.0).abs() / 3072.0 < 0.05, "mean={m}");
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean={m}");
    }

    #[test]
    fn zipf_is_deterministic_and_rank_frequency_monotone() {
        // Same seed, same draw sequence.
        let mut a = Rng::new(31);
        let mut b = Rng::new(31);
        for _ in 0..200 {
            assert_eq!(a.zipf(40, 1.1), b.zipf(40, 1.1));
        }
        // Rank-frequency monotone: over many draws, lower ranks appear
        // at least as often as higher ranks (checked on rank buckets to
        // damp sampling noise), and rank 0 clearly dominates the tail.
        let mut r = Rng::new(33);
        let mut counts = [0u32; 16];
        for _ in 0..40_000 {
            counts[r.zipf(16, 1.2)] += 1;
        }
        let bucket: Vec<u32> = counts.chunks(4).map(|c| c.iter().sum()).collect();
        for w in bucket.windows(2) {
            assert!(w[0] > w[1], "bucket frequencies must decrease: {bucket:?}");
        }
        assert!(counts[0] > 4 * counts[15], "head must dominate tail: {counts:?}");
        // s = 0 is uniform: every rank seen, no systematic head bias.
        let mut u = Rng::new(35);
        let mut ucounts = [0u32; 8];
        for _ in 0..16_000 {
            ucounts[u.zipf(8, 0.0)] += 1;
        }
        for &c in &ucounts {
            assert!((c as f64 - 2000.0).abs() < 300.0, "uniform at s=0: {ucounts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle left input unchanged");
    }
}
