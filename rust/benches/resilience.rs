//! Chaos bench: serving under the resilience layer.
//!
//! 1. **Simulated chaos** (always runs, model-free) — the real event
//!    core, worker loop, and `run_attempt` delivery protocol over a
//!    fault-injecting unit-replica pool whose "serve" is a fixed sleep
//!    plus echo. Three runs:
//!    * *fault-free* — the inertness gate: zero resilience counters,
//!      no failures, responses echo bit-identically;
//!    * *reference plan* ([`FaultPlan::reference`]: 1 permanent + 1
//!      transient + 1 slow of 4 replicas) — the delivery gates: no
//!      request lost or duplicated, and throughput at least 0.5x the
//!      fault-free run;
//!    * *deadlines* — expired requests fail fast with the typed
//!      `DeadlineExpired` error while the rest complete.
//! 2. **Real serving** (needs `make artifacts`) — the [`Batcher`] on
//!    the AOT testbed model, fault-free vs the reference plan, with
//!    the same exactly-once gate, plus admission-control shedding
//!    returning typed [`SubmitError::Shed`].
//!
//! Emits `BENCH_resilience.json`.
//!
//! Run: `cargo bench --bench resilience`

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use findep::coordinator::batcher::{
    run_attempt, Batcher, BatcherConfig, FailedRequest, RequestError, ResilienceConfig,
    SubmitError,
};
use findep::coordinator::executor::{run_worker, EventCore};
use findep::coordinator::faults::{FaultAction, FaultPlan};
use findep::coordinator::moe::ModelHandle;
use findep::coordinator::planner::PlannerConfig;
use findep::coordinator::server::{EmbeddedRequest, HealthConfig, Policy, ReplicaPool, Response};
use findep::metrics::Registry;
use findep::runtime::artifacts_dir;
use findep::sched::Order;
use findep::util::bench::Table;
use findep::util::json::{to_string_pretty, Json, JsonObj};

const WORKERS: usize = 4;
const MAX_BATCH: usize = 4;
/// Simulated per-batch serve time (the sleep standing in for the DEP
/// pipeline) — long enough that a 2x slow replica is visible, short
/// enough that the bench stays sub-second.
const SERVE: Duration = Duration::from_micros(300);

struct SimOutcome {
    resps: Vec<Response>,
    fails: Vec<FailedRequest>,
    wall_s: f64,
    metrics: Arc<Registry>,
}

impl SimOutcome {
    fn req_per_s(&self) -> f64 {
        self.resps.len() as f64 / self.wall_s
    }

    fn to_json(&self) -> Json {
        let m = &self.metrics;
        let mut o = JsonObj::new();
        o.insert("completed", Json::Num(self.resps.len() as f64));
        o.insert("failed", Json::Num(self.fails.len() as f64));
        o.insert("wall_s", Json::Num(self.wall_s));
        o.insert("req_per_s", Json::Num(self.req_per_s()));
        for c in [
            "faults_injected",
            "request_retries",
            "requests_failed",
            "requests_expired",
            "replica_degraded",
            "replica_quarantined",
            "replica_readmitted",
            "replica_recovered",
        ] {
            o.insert(c, Json::Num(m.counter(c) as f64));
        }
        Json::Obj(o)
    }

    fn row(&self, name: &str) -> Vec<String> {
        vec![
            name.into(),
            format!("{:.0}", self.req_per_s()),
            format!("{}", self.resps.len()),
            format!("{}", self.fails.len()),
            format!("{}", self.metrics.counter("request_retries")),
            format!("{}", self.metrics.counter("faults_injected")),
            format!("{}", self.metrics.counter("replica_quarantined")),
        ]
    }
}

fn echo(reqs: &[EmbeddedRequest]) -> Vec<Response> {
    reqs.iter()
        .map(|r| Response { id: r.id, hidden: r.hidden.clone(), latency_s: 0.0 })
        .collect()
}

/// Run `n` requests (`out_len` decode steps each) through the full
/// delivery protocol over a fault-injecting unit-replica pool. Every
/// `expired_every`-th request (if set) carries an already-expired
/// deadline, so its expiry is deterministic, not timing-dependent.
fn sim_run(n: u64, out_len: usize, plan: FaultPlan, expired_every: Option<u64>) -> SimOutcome {
    let core = Arc::new(EventCore::new(PlannerConfig {
        max_batch: MAX_BATCH,
        linger: Duration::from_micros(200),
        queue_depth: 32,
    }));
    let metrics = Arc::new(Registry::new());
    let pool = Arc::new(
        ReplicaPool::new(vec![(); WORKERS])
            .with_health(HealthConfig {
                cooldown: Duration::from_millis(5),
                ..HealthConfig::default()
            })
            .with_faults(plan)
            .with_metrics(metrics.clone()),
    );
    let (resp_tx, resp_rx) = channel::<Response>();
    let (fail_tx, fail_rx) = channel::<FailedRequest>();
    let mut threads = Vec::new();
    for _ in 0..WORKERS {
        core.register_worker();
        let core = core.clone();
        let metrics = metrics.clone();
        let pool = pool.clone();
        let resp_tx = resp_tx.clone();
        let fail_tx = fail_tx.clone();
        threads.push(std::thread::spawn(move || {
            let c = core.clone();
            let m = metrics.clone();
            run_worker(&core, &metrics, move |batch| {
                run_attempt(&c, &m, &resp_tx, &fail_tx, 8, 2, batch, |reqs| {
                    let lease = pool.lease();
                    match lease.fault_action() {
                        FaultAction::Fail => {
                            lease.report(false, 0.0);
                            Err(anyhow::anyhow!("injected fault"))
                        }
                        FaultAction::Panic => {
                            lease.report(false, 0.0);
                            panic!("injected worker panic")
                        }
                        FaultAction::Slow(factor) => {
                            std::thread::sleep(SERVE.mul_f64(factor));
                            lease.report(true, SERVE.mul_f64(factor).as_secs_f64());
                            Ok(echo(reqs))
                        }
                        FaultAction::None => {
                            std::thread::sleep(SERVE);
                            lease.report(true, SERVE.as_secs_f64());
                            Ok(echo(reqs))
                        }
                    }
                })
            });
        }));
    }
    let past = Instant::now() - Duration::from_millis(1);
    let t0 = Instant::now();
    for i in 0..n {
        let mut req = EmbeddedRequest::synthetic_autoregressive(i, 2, 2, out_len);
        if expired_every.is_some_and(|k| i % k == 0) {
            req = req.with_deadline(past);
        }
        core.submit(req).expect("submit");
    }
    let mut resps = Vec::new();
    let mut fails = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(60);
    while resps.len() + fails.len() < n as usize && Instant::now() < deadline {
        if let Ok(r) = resp_rx.try_recv() {
            resps.push(r);
            continue;
        }
        if let Ok(f) = fail_rx.try_recv() {
            fails.push(f);
            continue;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(
        resps.len() + fails.len(),
        n as usize,
        "simulated stack timed out: {} responses + {} failures of {n}",
        resps.len(),
        fails.len(),
    );
    assert_eq!(core.open(), 0, "terminal outcomes must settle the open-slot accounting");
    core.close();
    for t in threads {
        t.join().unwrap();
    }
    SimOutcome { resps, fails, wall_s, metrics }
}

/// Exactly-once: every id in 0..n appears exactly once across the
/// response and failure channels.
fn assert_exactly_once(label: &str, n: u64, resps: &[Response], fails: &[FailedRequest]) {
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).chain(fails.iter().map(|f| f.id)).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>(), "{label}: lost or duplicated requests");
}

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let mut report = JsonObj::new();
    report.insert("bench", Json::Str("resilience".into()));
    report.insert("quick", Json::Bool(quick));

    // --- 1. Simulated chaos: the delivery protocol under faults. ------
    let (n, out_len) = if quick { (24u64, 1usize) } else { (96, 2) };

    let clean = sim_run(n, out_len, FaultPlan::default(), None);
    assert!(clean.fails.is_empty(), "fault-free run must not fail requests");
    assert_exactly_once("fault-free", n, &clean.resps, &clean.fails);
    for r in &clean.resps {
        let want = EmbeddedRequest::synthetic(r.id, 2, 2);
        assert_eq!(r.hidden.data, want.hidden.data, "fault-free echo must be bit-identical");
    }
    // Inertness: with no fault plan and no deadlines, the resilience
    // layer leaves no trace — the fault-free path is byte-for-byte the
    // pre-resilience batcher.
    for c in [
        "faults_injected",
        "request_retries",
        "requests_failed",
        "requests_expired",
        "replica_degraded",
        "replica_quarantined",
    ] {
        assert_eq!(clean.metrics.counter(c), 0, "counter {c} moved on a fault-free run");
    }

    let faulted = sim_run(n, out_len, FaultPlan::reference(WORKERS), None);
    assert_exactly_once("reference plan", n, &faulted.resps, &faulted.fails);
    let ratio = faulted.req_per_s() / clean.req_per_s();
    assert!(
        ratio >= 0.5,
        "reference-plan throughput ({:.0} req/s) fell below 0.5x fault-free ({:.0} req/s)",
        faulted.req_per_s(),
        clean.req_per_s()
    );
    assert!(faulted.metrics.counter("faults_injected") > 0, "the reference plan must fire");

    let expired_every = 3u64;
    let dl = sim_run(n, out_len, FaultPlan::default(), Some(expired_every));
    assert_exactly_once("deadline run", n, &dl.resps, &dl.fails);
    let want_expired: Vec<u64> = (0..n).filter(|i| i % expired_every == 0).collect();
    let mut got_expired: Vec<u64> = dl.fails.iter().map(|f| f.id).collect();
    got_expired.sort_unstable();
    assert_eq!(got_expired, want_expired, "exactly the expired requests must fail");
    assert!(
        dl.fails.iter().all(|f| f.error == RequestError::DeadlineExpired),
        "expired requests must carry the typed DeadlineExpired error"
    );
    assert_eq!(dl.metrics.counter("requests_expired"), want_expired.len() as u64);

    let mut table = Table::new(
        &format!(
            "Simulated chaos ({n} reqs x {out_len} decode steps, {WORKERS} unit replicas, \
             {:?} serve)",
            SERVE
        ),
        &["run", "req/s", "completed", "failed", "retries", "faults", "quarantines"],
    );
    table.row(&clean.row("fault-free"));
    table.row(&faulted.row("reference plan"));
    table.row(&dl.row(&format!("deadlines (1/{expired_every} expired)")));
    table.print();
    println!("reference-plan throughput ratio vs fault-free: {ratio:.2} (gate: >= 0.50)");

    let mut sim = JsonObj::new();
    sim.insert("requests", Json::Num(n as f64));
    sim.insert("decode_steps_per_request", Json::Num(out_len as f64));
    sim.insert("fault_free", clean.to_json());
    sim.insert("reference", faulted.to_json());
    sim.insert("deadlines", dl.to_json());
    sim.insert("throughput_ratio", Json::Num(ratio));
    report.insert("simulated", Json::Obj(sim));

    // --- 2. Real serving under the reference plan. --------------------
    let dir = artifacts_dir();
    if dir.join("manifest.json").exists() {
        let model = ModelHandle::load(&dir, true).expect("artifacts load");
        let (s, m) = (model.seq_len, model.model.embed);
        let total = if quick { 16usize } else { 48 };
        let cfg = BatcherConfig {
            workers: WORKERS,
            max_batch: MAX_BATCH,
            queue_depth: 64,
            linger: Duration::from_micros(500),
            policy: Policy::FinDep { r1: 2, r2: 2, order: Order::Asas },
            ..Default::default()
        };

        let run = |resilience: ResilienceConfig| {
            let b = Batcher::with_resilience(model.clone(), cfg, None, resilience)
                .expect("batcher");
            let t0 = Instant::now();
            for i in 0..total {
                b.submit(EmbeddedRequest::synthetic(i as u64, s, m)).expect("submit");
            }
            let (resps, fails) = b.drain_outcomes(total, Duration::from_secs(60));
            let wall_s = t0.elapsed().as_secs_f64();
            let metrics = b.metrics().clone();
            (resps, fails, wall_s, metrics)
        };

        let (c_resps, c_fails, c_wall, c_metrics) = run(ResilienceConfig::default());
        assert!(c_fails.is_empty(), "real fault-free run must not fail requests");
        assert_exactly_once("real fault-free", total as u64, &c_resps, &c_fails);
        for c in ["faults_injected", "request_retries", "requests_failed", "requests_shed"] {
            assert_eq!(c_metrics.counter(c), 0, "counter {c} moved on a real fault-free run");
        }

        let (f_resps, f_fails, f_wall, f_metrics) = run(ResilienceConfig {
            fault_plan: FaultPlan::reference(WORKERS),
            health: HealthConfig {
                cooldown: Duration::from_millis(20),
                ..HealthConfig::default()
            },
            max_retries: 8,
        });
        assert_exactly_once("real reference plan", total as u64, &f_resps, &f_fails);
        let real_ratio = (f_resps.len() as f64 / f_wall) / (c_resps.len() as f64 / c_wall);
        println!(
            "\nreal serving: fault-free {:.1} req/s, reference plan {:.1} req/s \
             (ratio {real_ratio:.2}, {} retries, {} faults injected)",
            c_resps.len() as f64 / c_wall,
            f_resps.len() as f64 / f_wall,
            f_metrics.counter("request_retries"),
            f_metrics.counter("faults_injected"),
        );
        // Quick mode serves too few batches for a stable wall-clock
        // ratio over the real pipeline (same policy as the
        // event_coordinator bench); the simulated gate above holds in
        // every mode.
        if !quick {
            assert!(
                real_ratio >= 0.5,
                "real reference-plan throughput ratio {real_ratio:.2} fell below 0.5"
            );
        }

        // Admission-control shedding: a request whose deadline already
        // passed is refused with the typed Shed error, never queued.
        let b = Batcher::with_resilience(model.clone(), cfg, None, ResilienceConfig::default())
            .expect("batcher");
        let past = Instant::now() - Duration::from_millis(1);
        let shed_n = 4u64;
        for i in 0..shed_n {
            let req = EmbeddedRequest::synthetic(i, s, m).with_deadline(past);
            match b.submit(req) {
                Err(SubmitError::Shed { estimated_wait_s }) => {
                    assert!(estimated_wait_s >= 0.0);
                }
                other => panic!("expected Shed, got {other:?}"),
            }
        }
        assert_eq!(b.metrics().counter("requests_shed"), shed_n);
        assert_eq!(b.metrics().counter("queued"), 0, "shed requests must never enqueue");
        println!("admission control: {shed_n} expired submissions shed with typed errors");

        let mut real = JsonObj::new();
        real.insert("requests", Json::Num(total as f64));
        real.insert("fault_free_req_per_s", Json::Num(c_resps.len() as f64 / c_wall));
        real.insert("reference_req_per_s", Json::Num(f_resps.len() as f64 / f_wall));
        real.insert("throughput_ratio", Json::Num(real_ratio));
        real.insert("reference_failed", Json::Num(f_fails.len() as f64));
        real.insert("reference_retries", Json::Num(f_metrics.counter("request_retries") as f64));
        real.insert("shed", Json::Num(shed_n as f64));
        report.insert("real", Json::Obj(real));
    } else {
        println!("\nartifacts missing: skipping real serving (run `make artifacts`)");
        report.insert("real", Json::Str("skipped: artifacts missing".into()));
    }

    std::fs::write("BENCH_resilience.json", to_string_pretty(&Json::Obj(report)))
        .expect("write BENCH_resilience.json");
    println!("\nwrote BENCH_resilience.json");
}
