"""AOT pipeline tests: HLO text emission, artifact completeness, and
manifest consistency (the contract the Rust runtime consumes)."""

import json
import os

import pytest

from compile import aot, configs


def test_hlo_text_emission_attention():
    text = aot.lower_attention(configs.tiny(), m_a=1, seq=configs.SEQ_LEN)
    assert text.startswith("HloModule"), text[:60]
    assert "f32[1,16,64]" in text
    # HLO text format, not a serialized proto.
    assert "ENTRY" in text


def test_hlo_text_emission_gate_and_ffn():
    gate = aot.lower_gate(configs.tiny(), n=16)
    assert "f32[16,64]" in gate and "s32" in gate, "gate must emit int32 indices"
    ffn = aot.lower_ffn(configs.tiny(), n=8)
    assert "f32[8,64]" in ffn
    assert "f32[128,64]" in ffn  # weight params present


def test_build_writes_complete_artifact_set(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build(out)
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    # Every artifact the manifest references exists and is non-empty.
    for a in manifest["artifacts"]:
        p = os.path.join(out, a["path"])
        assert os.path.getsize(p) > 100, a["path"]
    assert os.path.exists(os.path.join(out, manifest["weights"]["file"]))
    assert os.path.exists(os.path.join(out, manifest["golden"]))
    assert os.path.exists(os.path.join(out, manifest["golden_noshared"]))
    # Expected bucket coverage.
    stages = {(a["stage"], a["bucket"]) for a in manifest["artifacts"]}
    for m_a in configs.MA_BUCKETS:
        assert ("attention", m_a) in stages
    for n in configs.FFN_BUCKETS:
        assert ("ffn", n) in stages
    # Weight table offsets are sane.
    offsets = [t["offset"] for t in manifest["weights"]["tensors"]]
    assert offsets == sorted(offsets)
    # Golden case parses and has matching lengths.
    with open(os.path.join(out, "golden.json")) as f:
        g = json.load(f)
    n = g["batch"] * g["seq"] * g["embed"]
    assert len(g["input"]) == n and len(g["output"]) == n
    assert g["kernel_vs_ref_maxdiff"] < 1e-3


def test_manifest_model_config_round_trip():
    cfg = configs.tiny()
    d = cfg.to_json_dict()
    assert d["n_experts"] == 8 and d["top_k"] == 2 and d["n_shared"] == 1
    ns = configs.tiny_noshared().to_json_dict()
    assert ns["n_shared"] == 0
