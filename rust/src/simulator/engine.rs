//! The discrete-event engine.
//!
//! Semantics (matching §3.2's job-shop model):
//! * each resource executes its issue queue in order, non-preemptively;
//! * a task starts at the max of (a) its resource becoming free after the
//!   previous queued task and (b) all of its Eq.-5 dependencies
//!   finishing;
//! * zero-duration tasks (e.g. absent shared experts) still sequence
//!   correctly but occupy no time.
//!
//! The engine runs a Kahn-style ready propagation over the union of
//! dependency edges and resource-order edges, which yields the exact
//! fixed point of the recurrences in §4.2 in O(V + E).

use crate::sched::{Plan, Resource};

/// Execution schedule of one plan.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Start time per task (seconds), same indexing as `plan.tasks`.
    pub start: Vec<f64>,
    /// Finish time per task.
    pub finish: Vec<f64>,
    pub makespan: f64,
}

impl SimResult {
    /// Tokens/s for the simulated forward pass.
    pub fn throughput_tokens(&self, plan: &Plan) -> f64 {
        plan.total_tokens / self.makespan
    }
}

/// Simulate a plan. Panics on cyclic plans (construction bug) — every
/// plan produced by `Plan::build` is acyclic by construction and this is
/// enforced by tests.
pub fn simulate(plan: &Plan) -> SimResult {
    let n = plan.tasks.len();
    let mut indeg: Vec<u32> = plan.tasks.iter().map(|t| t.deps.len() as u32).collect();
    // Dependents adjacency (deps + resource-order edges).
    let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (i, t) in plan.tasks.iter().enumerate() {
        for &d in &t.deps {
            dependents[d as usize].push(i as u32);
        }
    }
    // Resource predecessor edges.
    let mut res_pred: Vec<Option<u32>> = vec![None; n];
    for q in &plan.issue_order {
        for w in q.windows(2) {
            res_pred[w[1] as usize] = Some(w[0]);
            dependents[w[0] as usize].push(w[1]);
            indeg[w[1] as usize] += 1;
        }
    }

    let mut start = vec![0.0f64; n];
    let mut finish = vec![0.0f64; n];
    let mut ready: Vec<u32> =
        (0..n as u32).filter(|&i| indeg[i as usize] == 0).collect();
    let mut done = 0usize;
    while let Some(i) = ready.pop() {
        let i = i as usize;
        let t = &plan.tasks[i];
        let mut s = 0.0f64;
        for &d in &t.deps {
            s = s.max(finish[d as usize]);
        }
        if let Some(p) = res_pred[i] {
            s = s.max(finish[p as usize]);
        }
        start[i] = s;
        finish[i] = s + t.duration;
        done += 1;
        for &nidx in &dependents[i] {
            indeg[nidx as usize] -= 1;
            if indeg[nidx as usize] == 0 {
                ready.push(nidx);
            }
        }
    }
    assert_eq!(done, n, "plan contains a cycle");
    let makespan = finish.iter().copied().fold(0.0f64, f64::max);
    SimResult { start, finish, makespan }
}

/// Busy intervals of one resource, sorted by start time.
pub fn resource_intervals(plan: &Plan, sim: &SimResult, res: Resource) -> Vec<(f64, f64)> {
    let mut iv: Vec<(f64, f64)> = plan.issue_order[res.index()]
        .iter()
        .map(|&t| (sim.start[t as usize], sim.finish[t as usize]))
        .filter(|(s, f)| f > s)
        .collect();
    iv.sort_by(|a, b| a.partial_cmp(b).unwrap());
    iv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};
    use crate::perfmodel::StageModels;
    use crate::sched::{Order, PlanConfig, TaskKind};

    fn models() -> StageModels {
        StageModels::new(&ModelConfig::deepseek_v2(4), &Testbed::a(), GroupSplit::new(3, 5), 2048)
    }

    fn build(m_a: usize, r1: usize, r2: usize, order: Order, layers: usize) -> Plan {
        let sm = models();
        let m_e = sm.m_e(m_a as f64, r2);
        Plan::build(&sm, PlanConfig::findep(m_a, r1, r2, m_e, order), layers, 3, 2048)
    }

    #[test]
    fn sequential_naive_matches_hand_sum() {
        let sm = models();
        let m_e = sm.m_e(2.0, 1);
        let plan = Plan::build(&sm, PlanConfig::naive(2, m_e), 1, 3, 2048);
        let sim = simulate(&plan);
        // naive, 1 layer: attn(+shared fused) -> a2e -> expert -> e2a
        let expect = sm.attn_time(2.0) + sm.shared_time(2.0)
            + sm.comm_time(m_e) + sm.expert_time(m_e) + sm.comm_time(m_e);
        assert!((sim.makespan - expect).abs() < 1e-12, "{} vs {}", sim.makespan, expect);
    }

    #[test]
    fn dependencies_respected() {
        let plan = build(2, 2, 3, Order::Asas, 3);
        let sim = simulate(&plan);
        for (i, t) in plan.tasks.iter().enumerate() {
            for &d in &t.deps {
                assert!(
                    sim.start[i] >= sim.finish[d as usize] - 1e-12,
                    "task {} starts before dep {} finishes",
                    plan.tasks[i].label(),
                    plan.tasks[d as usize].label()
                );
            }
        }
    }

    #[test]
    fn resources_never_overlap() {
        for order in Order::both() {
            let plan = build(2, 3, 2, order, 4);
            let sim = simulate(&plan);
            for res in Resource::ALL {
                let iv = resource_intervals(&plan, &sim, res);
                for w in iv.windows(2) {
                    assert!(
                        w[1].0 >= w[0].1 - 1e-12,
                        "overlap on {:?}: {:?} then {:?}",
                        res,
                        w[0],
                        w[1]
                    );
                }
            }
        }
    }

    #[test]
    fn pipelining_beats_naive() {
        let sm = models();
        let m_e1 = sm.m_e(4.0, 1);
        let naive = Plan::build(&sm, PlanConfig::naive(4, m_e1), 4, 3, 2048);
        let pp = Plan::build(&sm, PlanConfig::pppipe(2, 2, sm.m_e(2.0, 1)), 4, 3, 2048);
        let t_naive = simulate(&naive).makespan;
        let t_pp = simulate(&pp).makespan;
        assert!(t_pp < t_naive, "pppipe {t_pp} !< naive {t_naive}");
    }

    #[test]
    fn fine_graining_can_help() {
        // Same (m_a, r1), FinDEP r2>1 must not be slower than r2=1 when
        // kernel-launch overhead is small relative to transfer time.
        let sm = models();
        let c1 = PlanConfig::findep(2, 2, 1, sm.m_e(2.0, 1), Order::Asas);
        let c4 = PlanConfig::findep(2, 2, 4, sm.m_e(2.0, 4), Order::Asas);
        let t1 = simulate(&Plan::build(&sm, c1, 4, 3, 2048)).makespan;
        let t4 = simulate(&Plan::build(&sm, c4, 4, 3, 2048)).makespan;
        assert!(t4 <= t1 * 1.02, "r2=4 {t4} much worse than r2=1 {t1}");
    }

    #[test]
    fn zero_duration_shared_tasks_are_free() {
        // Qwen-style (no shared): ASAS and AASS must coincide.
        let m = ModelConfig::qwen3_moe(4);
        let sm = StageModels::new(&m, &Testbed::a(), GroupSplit::new(4, 4), 2048);
        let m_e = sm.m_e(2.0, 2);
        let a = simulate(&Plan::build(&sm, PlanConfig::findep(2, 2, 2, m_e, Order::Asas), 4, 4, 2048));
        let b = simulate(&Plan::build(&sm, PlanConfig::findep(2, 2, 2, m_e, Order::Aass), 4, 4, 2048));
        assert!((a.makespan - b.makespan).abs() < 1e-12);
    }

    #[test]
    fn makespan_equals_last_finish() {
        let plan = build(1, 2, 2, Order::Aass, 2);
        let sim = simulate(&plan);
        let last = sim.finish.iter().copied().fold(0.0f64, f64::max);
        assert_eq!(sim.makespan, last);
        assert!(sim.throughput_tokens(&plan) > 0.0);
    }
}
