//! # FinDEP
//!
//! A reproduction of *"Efficient MoE Inference with Fine-Grained
//! Scheduling of Disaggregated Expert Parallelism"* (CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the DEP serving coordinator: request batching,
//!   token→expert routing, attention-group / expert-group worker
//!   topology, A2E/E2A links, the FinDEP schedule solver (Algorithm 1),
//!   the PPPipe and naive-DEP baselines, a calibrated discrete-event
//!   cluster simulator, workload generators, and metrics.
//! * **L2 (`python/compile/model.py`)** — JAX stage functions (attention,
//!   gate, shared expert, expert FFN) AOT-lowered to HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels called by L2.
//!
//! Python never runs on the request path: `rust/src/runtime` loads the
//! AOT artifacts via the PJRT C API (`xla` crate) once at startup.
//!
//! Start with [`solver::algorithm1::solve`] for the paper's contribution,
//! [`simulator::engine::Simulator`] for the evaluation substrate, and
//! [`coordinator::server::Server`] for the real serving path.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod perfmodel;
pub mod runtime;
pub mod sched;
pub mod simulator;
pub mod solver;
pub mod util;
pub mod workload;
