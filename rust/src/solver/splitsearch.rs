//! Split search: promote the (ag, eg) disaggregation ratio — and
//! multi-replica tilings of the cluster — from an ablation sweep to a
//! first-class solver layer.
//!
//! The paper's Algorithm 1 solves one fixed [`GroupSplit`]; §5's
//! deployments (and MegaScale-Infer's placement search) pick the split
//! itself. [`search`] enumerates every feasible split of a testbed,
//! plus placements that tile the cluster with `k` identical instances
//! of an `n/k`-GPU split, runs Algorithm 1 on each, and returns the
//! global argmax by total tokens/s. Three compounding optimisations
//! keep the enlarged space cheaper than a cold sweep:
//!
//! 1. **Branch-and-bound pruning.** Every candidate gets an optimistic
//!    throughput upper bound from the §4.2 closed forms alone (no DAG,
//!    no engine): the engine's makespan is at least the busiest
//!    resource's total occupancy, which per layer is at least
//!    `F = max(X, Y)` evaluated at `r2 = 1` and the largest
//!    memory-feasible `m_a` (the per-part launch overheads `r2·α` only
//!    grow with r2, and Theorem 1 makes the ratio `m_a / F(m_a)`
//!    non-decreasing). Candidates whose bound cannot beat the incumbent
//!    are skipped without ever building a model; best-bound-first
//!    ordering tightens the incumbent early.
//! 2. **Parallel search** across candidates on `std::thread::scope`
//!    workers (no new dependencies), with a shared atomic incumbent.
//!    The final winner is reduced deterministically — max total
//!    throughput, ties to the lowest candidate index — so the result is
//!    bit-identical to [`search_serial`]'s strict-improvement sweep at
//!    any thread count, and pruning can never change it: a pruned
//!    candidate is strictly below some evaluated throughput, hence
//!    strictly below the winner.
//! 3. **Topology reuse.** Each worker carries one [`Evaluator`] across
//!    candidates ([`solve_warm`]): candidate plans of different splits
//!    share task-DAG topologies and differ only in durations, so the
//!    engine serves them from its per-shape CSR cache
//!    (`sched::TopologyKey`) through the duration-only fast path.
//!
//! [`search_serial`] is the reference: the pre-existing behaviour of
//! `benches/ablations.rs` — a serial, cold, unpruned Algorithm-1 solve
//! per split — kept as the oracle for tests and the baseline
//! `benches/split_search.rs` measures against.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::config::{Cluster, ExpertLoad, ExpertPlacement, GroupSplit, ModelConfig, Phase, Testbed};
use crate::solver::algorithm1::{
    self, solve_warm, EvalMode, Evaluator, Instance, Solution, SolverParams, WarmStart,
};
use crate::solver::memory::MemoryModel;

/// One placement candidate: `replicas` identical instances, each owning
/// `split.ag + split.eg` GPUs of the testbed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitCandidate {
    pub replicas: usize,
    pub split: GroupSplit,
}

impl SplitCandidate {
    pub fn describe(&self) -> String {
        if self.replicas == 1 {
            format!("({},{})", self.split.ag, self.split.eg)
        } else {
            format!("{}x({},{})", self.replicas, self.split.ag, self.split.eg)
        }
    }
}

/// Split-search knobs on top of the inner Algorithm-1 parameters.
#[derive(Debug, Clone, Copy)]
pub struct SearchParams {
    pub solver: SolverParams,
    /// Worker threads; 0 = all available cores.
    pub threads: usize,
    /// Branch-and-bound pruning on the analytic throughput bound.
    pub prune: bool,
    /// Include multi-replica tilings of the cluster.
    pub multi_replica: bool,
}

impl Default for SearchParams {
    fn default() -> Self {
        Self { solver: SolverParams::default(), threads: 0, prune: true, multi_replica: true }
    }
}

/// One solved candidate.
#[derive(Debug, Clone)]
pub struct SplitSolution {
    pub candidate: SplitCandidate,
    /// Algorithm 1's solution for a single instance of the candidate.
    pub per_instance: Solution,
    /// Cluster-wide tokens/s: `replicas × per-instance throughput`.
    pub total_throughput: f64,
}

/// Search diagnostics.
#[derive(Debug, Clone, Copy, Default)]
pub struct SearchStats {
    /// Candidates enumerated.
    pub candidates: usize,
    /// Candidates skipped by the branch-and-bound test.
    pub pruned: usize,
    /// Candidates that were infeasible (bound 0 or Algorithm 1 `None`).
    pub infeasible: usize,
    /// Candidates actually solved to a feasible solution.
    pub solved: usize,
    /// Total Algorithm-1 probe evaluations across solved candidates.
    pub evals: usize,
    /// (m_a, r1) rows pruned *inside* Algorithm 1 across solved
    /// candidates (the incumbent-seeded inner bound, not the
    /// candidate-level bound counted in `pruned`).
    pub row_pruned: usize,
    /// Worker threads used.
    pub threads: usize,
    /// Wall time of the whole search.
    pub solve_seconds: f64,
}

/// Search output: the winner plus every solved candidate (in canonical
/// candidate order — the per-split table `benches/ablations.rs` prints).
#[derive(Debug, Clone)]
pub struct SearchReport {
    pub best: SplitSolution,
    pub evaluated: Vec<SplitSolution>,
    pub stats: SearchStats,
}

/// All placement candidates of an `n_gpus` testbed in canonical order:
/// replicas ascending (1 first), then ag ascending. `replicas` must
/// divide `n_gpus` and leave at least 2 GPUs per instance (both groups
/// non-empty).
pub fn enumerate_candidates(n_gpus: usize, multi_replica: bool) -> Vec<SplitCandidate> {
    let mut out = Vec::new();
    let max_r = if multi_replica { n_gpus / 2 } else { 1 };
    for replicas in 1..=max_r.max(1) {
        if n_gpus % replicas != 0 {
            continue;
        }
        let per = n_gpus / replicas;
        if per < 2 {
            continue;
        }
        for split in GroupSplit::enumerate(per) {
            out.push(SplitCandidate { replicas, split });
        }
    }
    out
}

/// The testbed one instance of a `replicas`-way tiling sees: same
/// per-GPU constants, `n_gpus / replicas` GPUs. (Conservative for
/// multi-node testbeds — a tile that fits inside one node would see
/// better links than the cluster-wide constants assume.)
fn instance_testbed(tb: &Testbed, replicas: usize) -> Testbed {
    let mut t = tb.clone();
    t.n_gpus = tb.n_gpus / replicas;
    t
}

/// Optimistic tokens/s upper bound for one *instance* of a split, from
/// the §4.2 closed forms only. Admissible: for every configuration
/// Algorithm 1 can evaluate, the engine's makespan over `T` layers is
/// at least `T · r1 · F(m_a, r2)` (each resource executes its tasks
/// non-preemptively), `F` at fixed `m_a` is minimized at `r2 = 1`
/// (the per-part launch overheads scale with r2 while the `β` terms are
/// conserved), and `m_a / F(m_a, 1)` is non-decreasing in `m_a`
/// (Theorem 1), so the bound evaluated at the largest memory-feasible
/// `m_a` dominates every candidate. Returns 0.0 for infeasible splits.
pub fn throughput_bound(
    model: &ModelConfig,
    tb: &Testbed,
    split: GroupSplit,
    seq_len: usize,
    params: &SolverParams,
) -> f64 {
    let mem = MemoryModel::new(model, tb, split, seq_len);
    if !mem.eg_feasible() {
        return 0.0;
    }
    let ma_max = mem.max_samples_per_ag_gpu().min(params.ma_cap);
    if ma_max == 0 {
        return 0.0;
    }
    let sm = crate::perfmodel::StageModels::new(model, tb, split, seq_len);
    // The shared §4.2 row bound ([`algorithm1::row_bound`]) evaluated
    // at the largest memory-feasible m_a: F = max(X, r2·Y) at r2 = 1 is
    // the per-layer pipeline period floor, and Theorem 1 makes
    // m_a / F(m_a, 1) non-decreasing, so this dominates every row. In
    // the AG-bound regime the bound is *tight* (an ASAS schedule
    // achieves makespan = T·r1·X exactly), and the engine computes that
    // makespan in a different summation order than the closed form —
    // within ~1e-14 relative (pinned by simulator_vs_analytic); the
    // bound's 1e-9 relative inflation keeps admissibility through
    // floating point, and candidates differ by far more, so no pruning
    // is lost.
    algorithm1::row_bound(&sm, ma_max, split.ag, seq_len, model.n_layers)
}

/// The serial reference sweep: cold Algorithm-1 solve per candidate,
/// strict-improvement argmax in canonical order — no pruning, no
/// parallelism, no cross-candidate arena reuse. This is what
/// `benches/ablations.rs` did before the solver layer existed; tests
/// use it as the oracle and `benches/split_search.rs` as the baseline.
pub fn search_serial(
    model: &ModelConfig,
    testbed: &Testbed,
    seq_len: usize,
    params: &SearchParams,
) -> Option<SplitSolution> {
    let mut best: Option<SplitSolution> = None;
    for candidate in enumerate_candidates(testbed.n_gpus, params.multi_replica) {
        let tb = instance_testbed(testbed, candidate.replicas);
        let inst = Instance::new(model.clone(), tb, candidate.split, seq_len);
        let Some(sol) = algorithm1::solve(&inst, &params.solver) else { continue };
        let total = candidate.replicas as f64 * sol.throughput_tokens;
        if best.as_ref().map_or(true, |b| total > b.total_throughput) {
            best = Some(SplitSolution { candidate, per_instance: sol, total_throughput: total });
        }
    }
    best
}

/// The optimised search: branch-and-bound pruned, parallel,
/// topology-reusing. Bit-identical winner to [`search_serial`] at any
/// thread count (see the module docs for why pruning and scheduling
/// races cannot change the argmax). Returns `None` when no candidate
/// is feasible.
pub fn search(
    model: &ModelConfig,
    testbed: &Testbed,
    seq_len: usize,
    params: &SearchParams,
) -> Option<SearchReport> {
    let t0 = Instant::now();
    let candidates = enumerate_candidates(testbed.n_gpus, params.multi_replica);
    let bounds: Vec<f64> = candidates
        .iter()
        .map(|c| {
            let tb = instance_testbed(testbed, c.replicas);
            c.replicas as f64 * throughput_bound(model, &tb, c.split, seq_len, &params.solver)
        })
        .collect();
    // Best-bound-first: the strongest candidates set the incumbent
    // early, so weaker ones prune without solving.
    let mut visit: Vec<usize> = (0..candidates.len()).collect();
    visit.sort_by(|&a, &b| bounds[b].total_cmp(&bounds[a]).then(a.cmp(&b)));

    let requested = if params.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        params.threads
    };
    let threads = requested.clamp(1, candidates.len().max(1));

    let cursor = AtomicUsize::new(0);
    // Incumbent total throughput as f64 bits — non-negative floats
    // order identically to their bit patterns, so fetch_max works.
    let incumbent = AtomicU64::new(0);
    let pruned = AtomicUsize::new(0);
    let infeasible = AtomicUsize::new(0);
    let evals = AtomicUsize::new(0);
    let row_pruned = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, SplitSolution)>> = Mutex::new(Vec::new());

    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut ev: Option<Evaluator> = None;
                loop {
                    let next = cursor.fetch_add(1, Ordering::Relaxed);
                    if next >= visit.len() {
                        break;
                    }
                    let idx = visit[next];
                    let candidate = candidates[idx];
                    let bound = bounds[idx];
                    if bound <= 0.0 {
                        infeasible.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    if params.prune {
                        let inc = f64::from_bits(incumbent.load(Ordering::Acquire));
                        if bound < inc {
                            pruned.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    }
                    let tb = instance_testbed(testbed, candidate.replicas);
                    let inst = Instance::new(model.clone(), tb, candidate.split, seq_len);
                    let ev = ev.get_or_insert_with(|| Evaluator::new(&inst));
                    // Reuse the incumbent *inside* Algorithm 1: a hard
                    // per-instance floor of incumbent/replicas lets the
                    // inner sweep bound-prune rows and screen final
                    // engine evaluations that cannot affect the global
                    // argmax. Losing candidates may come back degraded
                    // or `None`; the winner cannot (its best row sits
                    // at or above every floor any worker installs), so
                    // the deterministic reduction is unchanged.
                    let warm = if params.prune {
                        let inc = f64::from_bits(incumbent.load(Ordering::Acquire));
                        if inc > 0.0 {
                            Some(WarmStart::incumbent(inc / candidate.replicas as f64))
                        } else {
                            None
                        }
                    } else {
                        None
                    };
                    match solve_warm(&inst, &params.solver, EvalMode::Buffered, ev, warm.as_ref())
                    {
                        None => {
                            if warm.is_some() {
                                // Every row fell to the incumbent floor:
                                // skipped work, not infeasibility.
                                pruned.fetch_add(1, Ordering::Relaxed);
                            } else {
                                infeasible.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Some(sol) => {
                            evals.fetch_add(sol.evals, Ordering::Relaxed);
                            row_pruned.fetch_add(sol.pruned_rows, Ordering::Relaxed);
                            let total = candidate.replicas as f64 * sol.throughput_tokens;
                            incumbent.fetch_max(total.to_bits(), Ordering::AcqRel);
                            results.lock().unwrap().push((
                                idx,
                                SplitSolution {
                                    candidate,
                                    per_instance: sol,
                                    total_throughput: total,
                                },
                            ));
                        }
                    }
                }
            });
        }
    });

    let mut solved = results.into_inner().unwrap();
    solved.sort_by_key(|(idx, _)| *idx);
    // Deterministic reduction: canonical order + strict improvement —
    // exactly search_serial's rule, so ties break to the lowest index.
    let mut best: Option<SplitSolution> = None;
    for (_, s) in &solved {
        if best.as_ref().map_or(true, |b| s.total_throughput > b.total_throughput) {
            best = Some(s.clone());
        }
    }
    let stats = SearchStats {
        candidates: candidates.len(),
        pruned: pruned.into_inner(),
        infeasible: infeasible.into_inner(),
        solved: solved.len(),
        evals: evals.into_inner(),
        row_pruned: row_pruned.into_inner(),
        threads,
        solve_seconds: t0.elapsed().as_secs_f64(),
    };
    best.map(|best| SearchReport {
        best,
        evaluated: solved.into_iter().map(|(_, s)| s).collect(),
        stats,
    })
}

/// All placement candidates of a (possibly heterogeneous) cluster in
/// canonical order. A single-pool cluster delegates to
/// [`enumerate_candidates`] exactly — same space, same order — so the
/// compat path stays bit-identical to the testbed search. A multi-pool
/// cluster sizes each role from its own inventory: `replicas` must
/// divide both pools (replicas are identical), and within a replica's
/// share `ag ≤ attn_share`, `eg ≤ expert_share` are *independently*
/// sized — unlike the homogeneous space, partial use is enumerated,
/// because shrinking `ag` below the share cuts the M2N fan-out
/// (`ag / min(ag, eg)`) and can win in comm-bound regimes.
pub fn enumerate_cluster_candidates(cl: &Cluster, multi_replica: bool) -> Vec<SplitCandidate> {
    if cl.is_single_pool() {
        return enumerate_candidates(cl.n_gpus(), multi_replica);
    }
    let na = cl.attn().n_gpus;
    let ne = cl.expert().n_gpus;
    let mut out = Vec::new();
    let max_r = if multi_replica { na.min(ne) } else { 1 };
    for replicas in 1..=max_r.max(1) {
        if replicas == 0 || na % replicas != 0 || ne % replicas != 0 {
            continue;
        }
        let (pa, pe) = (na / replicas, ne / replicas);
        if pa < 1 || pe < 1 {
            continue;
        }
        for ag in 1..=pa {
            for eg in 1..=pe {
                out.push(SplitCandidate { replicas, split: GroupSplit::new(ag, eg) });
            }
        }
    }
    out
}

/// Build the phase-appropriate solver instance for one candidate on a
/// cluster. (The stage/memory models never read pool *counts*, only
/// per-device and link constants, so the un-tiled cluster evaluates
/// identically to `cl.tile(replicas)` — mirroring how
/// [`instance_testbed`] only adjusts `n_gpus` for bookkeeping.)
fn cluster_instance(
    model: &ModelConfig,
    cl: &Cluster,
    split: GroupSplit,
    seq_len: usize,
    phase: Phase,
) -> Instance {
    match phase {
        Phase::Prefill => Instance::on_cluster(model.clone(), cl.clone(), split, seq_len),
        Phase::Decode { kv_len } => {
            Instance::decode_on_cluster(model.clone(), cl.clone(), split, kv_len)
        }
    }
}

/// [`throughput_bound`] generalized to clusters and phases: per-pool
/// memory feasibility, cluster-derived stage models, and the shared
/// §4.2 row bound at the largest memory-feasible `m_a`. Admissible for
/// the capped (goodput) objective too — a latency cap only removes
/// candidates, never raises a row's throughput.
pub fn throughput_bound_cluster(
    model: &ModelConfig,
    cl: &Cluster,
    split: GroupSplit,
    seq_len: usize,
    phase: Phase,
    params: &SolverParams,
) -> f64 {
    let s = match phase {
        Phase::Prefill => seq_len,
        Phase::Decode { .. } => 1,
    };
    let mem = MemoryModel::for_cluster(model, cl, split, s, phase);
    if !mem.eg_feasible() {
        return 0.0;
    }
    let ma_max = mem.max_samples_per_ag_gpu().min(params.ma_cap);
    if ma_max == 0 {
        return 0.0;
    }
    let sm = crate::perfmodel::StageModels::for_cluster(model, cl, split, s, phase);
    algorithm1::row_bound(&sm, ma_max, split.ag, s, model.n_layers)
}

/// Heterogeneity-aware placement search: enumerate
/// [`enumerate_cluster_candidates`], bound-prune against a running
/// incumbent, solve survivors through Algorithm 1 (which carries any
/// [`SolverParams::max_makespan`] latency cap — set it to search for
/// goodput-under-SLO instead of peak tokens/s), and reduce in canonical
/// candidate order with strict improvement. On a single-pool cluster
/// the space, the models, and therefore the winner are exactly the
/// testbed search's ([`search_serial`] / [`search`]); pinned by
/// `tests/cluster_equivalence.rs`.
pub fn search_cluster(
    model: &ModelConfig,
    cl: &Cluster,
    seq_len: usize,
    phase: Phase,
    params: &SearchParams,
) -> Option<SearchReport> {
    let t0 = Instant::now();
    let candidates = enumerate_cluster_candidates(cl, params.multi_replica);
    let bounds: Vec<f64> = candidates
        .iter()
        .map(|c| {
            c.replicas as f64
                * throughput_bound_cluster(model, cl, c.split, seq_len, phase, &params.solver)
        })
        .collect();
    // Best-bound-first visit order tightens the incumbent early; the
    // canonical-order reduction below keeps the winner order-free.
    let mut visit: Vec<usize> = (0..candidates.len()).collect();
    visit.sort_by(|&a, &b| bounds[b].total_cmp(&bounds[a]).then(a.cmp(&b)));

    let mut pruned = 0usize;
    let mut infeasible = 0usize;
    let mut evals = 0usize;
    let mut row_pruned = 0usize;
    let mut inc = 0.0f64;
    let mut ev: Option<Evaluator> = None;
    let mut solved: Vec<(usize, SplitSolution)> = Vec::new();
    for &idx in &visit {
        let candidate = candidates[idx];
        if bounds[idx] <= 0.0 {
            infeasible += 1;
            continue;
        }
        if params.prune && bounds[idx] < inc {
            pruned += 1;
            continue;
        }
        let inst = cluster_instance(model, cl, candidate.split, seq_len, phase);
        let ev = ev.get_or_insert_with(|| Evaluator::new(&inst));
        let warm = if params.prune && inc > 0.0 {
            Some(WarmStart::incumbent(inc / candidate.replicas as f64))
        } else {
            None
        };
        match solve_warm(&inst, &params.solver, EvalMode::Buffered, ev, warm.as_ref()) {
            None => {
                if warm.is_some() {
                    pruned += 1;
                } else {
                    infeasible += 1;
                }
            }
            Some(sol) => {
                evals += sol.evals;
                row_pruned += sol.pruned_rows;
                let total = candidate.replicas as f64 * sol.throughput_tokens;
                if total > inc {
                    inc = total;
                }
                solved.push((
                    idx,
                    SplitSolution { candidate, per_instance: sol, total_throughput: total },
                ));
            }
        }
    }

    solved.sort_by_key(|(idx, _)| *idx);
    let mut best: Option<SplitSolution> = None;
    for (_, s) in &solved {
        if best.as_ref().map_or(true, |b| s.total_throughput > b.total_throughput) {
            best = Some(s.clone());
        }
    }
    let stats = SearchStats {
        candidates: candidates.len(),
        pruned,
        infeasible,
        solved: solved.len(),
        evals,
        row_pruned,
        threads: 1,
        solve_seconds: t0.elapsed().as_secs_f64(),
    };
    best.map(|best| SearchReport {
        best,
        evaluated: solved.into_iter().map(|(_, s)| s).collect(),
        stats,
    })
}

/// Traffic mix the carve search balances against: what fraction of the
/// token demand is prompt (prefill) work, and at what shapes.
#[derive(Debug, Clone, Copy)]
pub struct TrafficMix {
    /// Prompt length of prefill batches.
    pub prefill_seq: usize,
    /// KV length decode batches run against.
    pub decode_kv: usize,
    /// Fraction of total token demand that is prefill (prompt) tokens,
    /// in [0, 1]. The remainder is decode (generated) tokens.
    pub prefill_frac: f64,
}

/// One cluster carve: a disjoint partition of every pool's GPUs into a
/// prefill-serving partition and a decode-serving partition, each with
/// its own placement solution.
#[derive(Debug, Clone)]
pub struct CarvePlan {
    /// GPUs of each pool (cluster pool order) assigned to prefill.
    pub prefill_gpus: Vec<usize>,
    /// GPUs of each pool assigned to decode (the complement).
    pub decode_gpus: Vec<usize>,
    pub prefill: SplitSolution,
    pub decode: SplitSolution,
    /// Sustainable total tokens/s at the traffic mix: the largest rate
    /// `T` with `T·prefill_frac ≤ prefill capacity` and
    /// `T·(1 − prefill_frac) ≤ decode capacity`.
    pub goodput: f64,
    /// Partitions enumerated (diagnostic).
    pub partitions: usize,
}

/// A pool-count sub-cluster: same specs and wiring, `counts[i]` GPUs in
/// pool `i`.
fn sub_cluster(cl: &Cluster, counts: &[usize]) -> Cluster {
    let mut c = cl.clone();
    for (p, &n) in c.pools.iter_mut().zip(counts) {
        p.n_gpus = n;
    }
    c
}

/// "Given N mixed GPUs and this traffic, carve the cluster": SplitWise-
/// style phase disaggregation *across* replicas. Enumerates every
/// disjoint partition of each pool's GPUs into a prefill-heavy and a
/// decode-heavy side, runs [`search_cluster`] per side at the mix's
/// shapes, and maximizes the balanced goodput — the token rate at which
/// neither side falls behind the traffic mix. Strict improvement in
/// canonical (odometer) partition order keeps the result deterministic.
pub fn carve(
    model: &ModelConfig,
    cl: &Cluster,
    mix: &TrafficMix,
    params: &SearchParams,
) -> Option<CarvePlan> {
    let caps: Vec<usize> = cl.pools.iter().map(|p| p.n_gpus).collect();
    // The rate one side supports given its share of the traffic: a
    // side with no demand never constrains the carve.
    let rate = |capacity: f64, frac: f64| {
        if frac <= 0.0 {
            f64::INFINITY
        } else {
            capacity / frac
        }
    };
    let mut best: Option<CarvePlan> = None;
    let mut partitions = 0usize;
    let mut alloc = vec![0usize; caps.len()];
    loop {
        partitions += 1;
        let rest: Vec<usize> = caps.iter().zip(&alloc).map(|(c, a)| c - a).collect();
        let pre_cl = sub_cluster(cl, &alloc);
        let dec_cl = sub_cluster(cl, &rest);
        // Both sides need a non-empty attention and expert share to
        // serve at all; skip the search when one side is bare.
        let viable = |c: &Cluster| c.attn().n_gpus >= 1 && c.expert().n_gpus >= 1;
        if viable(&pre_cl) && viable(&dec_cl) {
            let pre = search_cluster(model, &pre_cl, mix.prefill_seq, Phase::Prefill, params);
            let dec = search_cluster(
                model,
                &dec_cl,
                1,
                Phase::Decode { kv_len: mix.decode_kv },
                params,
            );
            if let (Some(pre), Some(dec)) = (pre, dec) {
                let goodput = rate(pre.best.total_throughput, mix.prefill_frac)
                    .min(rate(dec.best.total_throughput, 1.0 - mix.prefill_frac));
                if goodput.is_finite()
                    && goodput > 0.0
                    && best.as_ref().map_or(true, |b| goodput > b.goodput)
                {
                    best = Some(CarvePlan {
                        prefill_gpus: alloc.clone(),
                        decode_gpus: rest,
                        prefill: pre.best,
                        decode: dec.best,
                        goodput,
                        partitions: 0,
                    });
                }
            }
        }
        // Odometer over per-pool allocations.
        let mut i = 0;
        loop {
            if i == alloc.len() {
                if let Some(b) = &mut best {
                    b.partitions = partitions;
                }
                return best;
            }
            if alloc[i] < caps[i] {
                alloc[i] += 1;
                break;
            }
            alloc[i] = 0;
            i += 1;
        }
    }
}

/// One solved candidate of the replication search: a concrete expert
/// placement (the replication budget it spends) plus Algorithm 1's
/// solution priced under it.
#[derive(Debug, Clone)]
pub struct PlacementSolution {
    /// Extra expert slots (replicas beyond one copy per expert) the
    /// placement spends across the expert group.
    pub extra_slots: usize,
    pub placement: ExpertPlacement,
    pub solution: Solution,
}

/// Replication-search diagnostics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplicationStats {
    /// Replication budgets enumerated (including dominated ones).
    pub candidates: usize,
    /// Candidates actually solved by Algorithm 1.
    pub solved: usize,
    /// Candidates skipped by the admissible bound against the incumbent.
    pub bound_pruned: usize,
    /// Candidates skipped by exact dominance (no smaller max-shard load
    /// than an earlier, cheaper placement).
    pub dominated: usize,
    /// Largest replication budget the memory headroom allowed.
    pub max_extra: usize,
}

/// Result of [`search_replication`].
#[derive(Debug, Clone)]
pub struct ReplicationReport {
    pub best: PlacementSolution,
    pub stats: ReplicationStats,
}

/// Optimistic tokens/s upper bound for one concrete [`Instance`]
/// (placement included) from the §4.2 closed forms only — the
/// admissible-bound extension of [`throughput_bound`] to placed
/// instances: the placed stage models' coefficients feed the same
/// `row_bound`, whose admissibility argument (non-preemptive resource
/// occupancy, Theorem 1 monotonicity in `m_a`) is placement-agnostic.
/// Returns 0.0 when the placement's replica weights don't fit.
pub fn instance_bound(inst: &Instance, params: &SolverParams) -> f64 {
    let mem = inst.memory();
    if !mem.eg_feasible() {
        return 0.0;
    }
    let ma_max = mem.max_samples_per_ag_gpu().min(params.ma_cap);
    if ma_max == 0 {
        return 0.0;
    }
    let sm = inst.stage_models();
    algorithm1::row_bound(&sm, ma_max, inst.split.ag, inst.seq_len, inst.model.n_layers)
}

/// Search the expert-replication factor as a plan dimension: sweep the
/// replication budget (extra expert slots across the expert group) from
/// 0 up to the expert pool's memory headroom, price each greedy
/// [`ExpertPlacement::replicate_hot`] placement with Algorithm 1 under
/// `load`, and return the strict-improvement argmax (ties to the
/// smallest budget).
///
/// Three exact screens keep the sweep cheap without changing the
/// winner:
/// * **Dominance.** Stage coefficients depend on a placement only
///   through its max-shard load `F` (β terms) and max-shard slots (α
///   terms), and both weakly grow when a budget increase fails to
///   reduce `F` — so a candidate whose `F` is not strictly below every
///   cheaper evaluated candidate's is dominated and skipped unsolved.
/// * **Admissible bound.** [`instance_bound`] against the running
///   incumbent (same argument as the split search: a pruned candidate
///   sits strictly below an evaluated throughput).
/// * **Floor stop.** `F ≥ E/eg` always (the mean shard), so once a
///   candidate reaches the perfect-balance floor no larger budget can
///   improve it and the sweep ends.
///
/// Under exactly-uniform observed load the baseline candidate is the
/// canonical [`ExpertPlacement::uniform`] — which sits at the floor, so
/// the search returns the legacy uniform plan bit for bit (the
/// exact-tie gate of `benches/expert_skew.rs`). Under skew the baseline
/// is the honest unreplicated `replicate_hot(load, eg, 0)`.
pub fn search_replication(
    base: &Instance,
    load: &ExpertLoad,
    params: &SearchParams,
) -> Option<ReplicationReport> {
    let eg = base.split.eg;
    let n_experts = base.model.n_experts;
    assert_eq!(load.n_experts(), n_experts, "load/model expert mismatch");
    let floor = n_experts as f64 / eg as f64;

    // Replication budget ceiling: per-shard slot headroom of the
    // uniform layout times the shard count, capped at full replication
    // (`c_e = eg` everywhere). Each candidate is still individually
    // gated by its own memory feasibility inside the solve.
    let mem = MemoryModel::for_cluster(
        &base.model,
        &base.cluster,
        base.split,
        base.seq_len,
        base.phase,
    );
    let max_extra = (mem.eg_slot_headroom() * eg).min(n_experts * (eg - 1));

    let mut stats = ReplicationStats { max_extra, ..Default::default() };
    let mut best: Option<PlacementSolution> = None;
    let mut best_f = f64::INFINITY;
    let mut last_placement: Option<ExpertPlacement> = None;
    let mut ev: Option<Evaluator> = None;

    for extra in 0..=max_extra {
        stats.candidates += 1;
        let placement = if extra == 0 && load.is_uniform() {
            ExpertPlacement::uniform(n_experts, eg)
        } else {
            ExpertPlacement::replicate_hot(load, eg, extra)
        };
        // The greedy is nested in `extra`: once it saturates (every
        // expert on every shard) all larger budgets repeat the same
        // placement — stop.
        if last_placement.as_ref() == Some(&placement) {
            stats.candidates -= 1;
            break;
        }
        let f_load = placement.beta_shard_load(load);
        let at_floor = f_load <= floor * (1.0 + 1e-12);
        last_placement = Some(placement.clone());

        // Exact dominance: no strict max-shard-load improvement over a
        // cheaper candidate means every coefficient is at least as bad.
        if f_load >= best_f && best.is_some() {
            stats.dominated += 1;
            if at_floor {
                break;
            }
            continue;
        }

        let inst = base.clone().with_placement(placement.clone(), load.clone());
        // Admissible bound against the incumbent (strict: equality
        // cannot beat a strict-improvement argmax).
        if params.prune {
            if let Some(b) = &best {
                if instance_bound(&inst, &params.solver) <= b.solution.throughput_tokens {
                    stats.bound_pruned += 1;
                    if at_floor {
                        break;
                    }
                    continue;
                }
            }
        }

        let ev = ev.get_or_insert_with(|| Evaluator::new(&inst));
        let warm = if params.prune {
            best.as_ref().map(|b| WarmStart::incumbent(b.solution.throughput_tokens))
        } else {
            None
        };
        match solve_warm(&inst, &params.solver, EvalMode::Buffered, ev, warm.as_ref()) {
            None => {
                // Infeasible (replica weights don't fit) or floored out
                // by the incumbent — either way not a winner.
            }
            Some(sol) => {
                stats.solved += 1;
                if best
                    .as_ref()
                    .map_or(true, |b| sol.throughput_tokens > b.solution.throughput_tokens)
                {
                    best_f = f_load;
                    best = Some(PlacementSolution {
                        extra_slots: extra,
                        placement,
                        solution: sol,
                    });
                } else if f_load < best_f {
                    // Lower max-shard load that still lost (α launch
                    // overhead outweighed it): later budgets must beat
                    // this F to be worth solving.
                    best_f = f_load;
                }
            }
        }
        if at_floor {
            break;
        }
    }
    best.map(|best| ReplicationReport { best, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case() -> (ModelConfig, Testbed) {
        (ModelConfig::deepseek_v2(4), Testbed::a())
    }

    #[test]
    fn enumeration_is_canonical() {
        let c = enumerate_candidates(8, true);
        // 7 single-instance splits + 3 of (2x4 GPUs) + 1 of (4x2 GPUs).
        assert_eq!(c.len(), 11);
        assert_eq!(c[0], SplitCandidate { replicas: 1, split: GroupSplit::new(1, 7) });
        assert_eq!(c[7], SplitCandidate { replicas: 2, split: GroupSplit::new(1, 3) });
        assert_eq!(c[10], SplitCandidate { replicas: 4, split: GroupSplit::new(1, 1) });
        assert_eq!(enumerate_candidates(8, false).len(), 7);
        // 32 GPUs: 31 + 15 + 7 + 3 + 1.
        assert_eq!(enumerate_candidates(32, true).len(), 57);
        // A 2-GPU cluster has exactly one placement.
        assert_eq!(enumerate_candidates(2, true).len(), 1);
    }

    #[test]
    fn search_finds_feasible_winner_with_stats() {
        let (model, tb) = case();
        let report = search(&model, &tb, 2048, &SearchParams::default()).expect("feasible");
        assert!(report.best.total_throughput > 0.0);
        assert_eq!(
            report.best.total_throughput,
            report.best.candidate.replicas as f64 * report.best.per_instance.throughput_tokens
        );
        assert_eq!(report.stats.candidates, 11);
        assert_eq!(
            report.stats.solved + report.stats.pruned + report.stats.infeasible,
            report.stats.candidates
        );
        assert_eq!(report.stats.solved, report.evaluated.len());
        // evaluated is in canonical candidate order.
        for w in report.evaluated.windows(2) {
            let key = |s: &SplitSolution| (s.candidate.replicas, s.candidate.split.ag);
            assert!(key(&w[0]) < key(&w[1]));
        }
    }

    #[test]
    fn bounds_dominate_solutions() {
        let (model, tb) = case();
        let params = SearchParams { prune: false, ..Default::default() };
        let report = search(&model, &tb, 2048, &params).unwrap();
        for s in &report.evaluated {
            let itb = instance_testbed(&tb, s.candidate.replicas);
            let b = s.candidate.replicas as f64
                * throughput_bound(&model, &itb, s.candidate.split, 2048, &params.solver);
            assert!(
                b >= s.total_throughput,
                "bound {b} < achieved {} on {}",
                s.total_throughput,
                s.candidate.describe()
            );
        }
    }

    #[test]
    fn fully_infeasible_model_returns_none() {
        // Experts far beyond every split's EG memory on 24 GB cards.
        let model = ModelConfig::deepseek_v2(64);
        let tb = Testbed::b();
        assert!(search(&model, &tb, 2048, &SearchParams::default()).is_none());
        assert!(search_serial(&model, &tb, 2048, &SearchParams::default()).is_none());
    }

    #[test]
    fn cluster_enumeration_delegates_for_single_pool() {
        let cl = Cluster::single_pool(&Testbed::a());
        assert_eq!(enumerate_cluster_candidates(&cl, true), enumerate_candidates(8, true));
        assert_eq!(enumerate_cluster_candidates(&cl, false), enumerate_candidates(8, false));
    }

    #[test]
    fn cluster_enumeration_sizes_roles_independently() {
        let cl = Cluster::reference_hetero(); // 4 attn + 12 expert
        let c = enumerate_cluster_candidates(&cl, true);
        // r=1: 4·12, r=2: 2·6, r=4: 1·3 (r=3 does not divide 4).
        assert_eq!(c.len(), 48 + 12 + 3);
        // Canonical: replicas ascending, then ag, then eg.
        assert_eq!(c[0], SplitCandidate { replicas: 1, split: GroupSplit::new(1, 1) });
        assert_eq!(c[47], SplitCandidate { replicas: 1, split: GroupSplit::new(4, 12) });
        assert_eq!(c[48], SplitCandidate { replicas: 2, split: GroupSplit::new(1, 1) });
        for cand in &c {
            assert!(cand.replicas * cand.split.ag <= 4);
            assert!(cand.replicas * cand.split.eg <= 12);
        }
        assert_eq!(enumerate_cluster_candidates(&cl, false).len(), 48);
    }

    #[test]
    fn single_pool_cluster_search_matches_testbed_search_bitwise() {
        let (model, tb) = case();
        let params = SearchParams::default();
        let serial = search_serial(&model, &tb, 2048, &params).unwrap();
        let report =
            search_cluster(&model, &Cluster::single_pool(&tb), 2048, Phase::Prefill, &params)
                .unwrap();
        assert_eq!(report.best.candidate, serial.candidate);
        assert_eq!(report.best.per_instance.config, serial.per_instance.config);
        assert_eq!(
            report.best.total_throughput.to_bits(),
            serial.total_throughput.to_bits(),
            "single-pool cluster search must be the testbed search bit for bit"
        );
    }

    #[test]
    fn hetero_cluster_search_finds_feasible_winner() {
        let model = ModelConfig::deepseek_v2(4);
        let cl = Cluster::reference_hetero();
        let report = search_cluster(&model, &cl, 2048, Phase::Prefill, &SearchParams::default())
            .expect("feasible");
        let c = report.best.candidate;
        assert!(report.best.total_throughput > 0.0);
        assert!(c.replicas * c.split.ag <= cl.attn().n_gpus);
        assert!(c.replicas * c.split.eg <= cl.expert().n_gpus);
        // Bounds dominate on the cluster space too.
        for s in &report.evaluated {
            let b = s.candidate.replicas as f64
                * throughput_bound_cluster(
                    &model,
                    &cl,
                    s.candidate.split,
                    2048,
                    Phase::Prefill,
                    &SearchParams::default().solver,
                );
            assert!(b >= s.total_throughput, "bound < achieved on {}", s.candidate.describe());
        }
        // Decode-phase search works on the same space.
        let dec =
            search_cluster(&model, &cl, 1, Phase::Decode { kv_len: 2048 }, &SearchParams::default())
                .expect("decode feasible");
        assert!(dec.best.total_throughput > 0.0);
    }

    #[test]
    fn carve_partitions_sum_to_inventory_and_balance_the_mix() {
        let model = ModelConfig::deepseek_v2(4);
        let cl = Cluster::single_pool(&Testbed::a());
        let mix = TrafficMix { prefill_seq: 2048, decode_kv: 2048, prefill_frac: 0.5 };
        let plan = carve(&model, &cl, &mix, &SearchParams::default()).expect("carvable");
        assert_eq!(plan.prefill_gpus.len(), 1);
        assert_eq!(plan.prefill_gpus[0] + plan.decode_gpus[0], 8);
        assert!(plan.prefill_gpus[0] >= 2 && plan.decode_gpus[0] >= 2);
        assert!(plan.goodput > 0.0);
        assert!(plan.partitions > 0);
        // The balanced goodput is exactly the binding side's rate.
        let pre_rate = plan.prefill.total_throughput / 0.5;
        let dec_rate = plan.decode.total_throughput / 0.5;
        assert_eq!(plan.goodput, pre_rate.min(dec_rate));
        // A different mix re-balances: the carve stays a full disjoint
        // partition and its goodput is still the binding side's rate.
        let heavy = TrafficMix { prefill_frac: 0.9, ..mix };
        let hp = carve(&model, &cl, &heavy, &SearchParams::default()).unwrap();
        assert_eq!(hp.prefill_gpus[0] + hp.decode_gpus[0], 8);
        let pre_rate = hp.prefill.total_throughput / 0.9;
        // (1.0 - 0.9) rather than 0.1: mirror carve's arithmetic exactly.
        let dec_rate = hp.decode.total_throughput / (1.0 - 0.9);
        assert_eq!(hp.goodput, pre_rate.min(dec_rate));
    }
}
