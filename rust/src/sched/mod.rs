//! Schedule representation (§3.2) and the analytic timing machinery of
//! §4.2.
//!
//! A [`plan::Plan`] is the concrete object FinDEP, PPPipe, and naive DEP
//! all produce: the full set of fine-grained tasks for a forward pass
//! (attention / shared-expert / A2E / expert / E2A per `(layer, chunk,
//! part)`), their Eq.-5 precedence edges (flat-pooled, arena-rebuildable
//! via [`plan::PlanBuffers`]), and a fixed issue order per exclusive
//! resource. The simulator executes plans; the analytic module evaluates
//! the ASAS closed forms (X, Y, F, G, Eq. 13) without building the
//! graph — the solver uses those closed forms as its candidate-probe
//! fast path.

pub mod analytic;
pub mod plan;

pub use plan::{Order, Plan, PlanBuffers, PlanConfig, Resource, Task, TaskKind, TopologyKey};
