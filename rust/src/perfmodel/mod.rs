//! α-β performance models (§3.1, §4.1).
//!
//! Everything the solver and simulator know about hardware flows through
//! these models: `t_gm(x) = α_gm + β_gm·x` for GEMM (x = FLOPs),
//! `t_attn(y)` for self-attention, `t_c(z) = α_c + β_c·z` for A2E/E2A
//! transfers (z = bytes), composed into per-stage layer models
//! `t_a(m_a), t_s(m_a), t_e(m_e), t_a2e(m_e)` exactly as Eqs. 1-4 and
//! 10-11 do.

pub mod calibrate;
pub mod linear;
pub mod profile;
pub mod stage;

pub use calibrate::CalibrationError;
pub use linear::LinearModel;
pub use profile::{CalibrationProfile, ComponentFit, ProfileId, ProfileThresholds};
pub use stage::{CompModels, StageModels};
