//! The uniform→placed expert refactor's correctness oracle: the
//! identity placement (the [`ExpertPlacement::uniform`] kind under a
//! uniform [`ExpertLoad`]) must be bit-identical to the legacy
//! uniform-expert derivation across every paper instance and both
//! serving phases — stage-model coefficients, Algorithm-1 solutions,
//! replication-search winners, and expert-pool memory accounting. The
//! uniform kind performs literally the same f64 arithmetic as the old
//! `E/eg` closed forms; these tests pin that, so skew-aware placement
//! can never drift the Table-2 reproductions.

use findep::config::{
    Cluster, ExpertLoad, ExpertPlacement, GroupSplit, ModelConfig, Phase, PlacementId, Testbed,
};
use findep::perfmodel::StageModels;
use findep::solver::{self, Instance, MemoryModel, PlanCache, SearchParams, ShapeKey, Solution};

/// The 8 paper instances: every Table-2 testbed × both model families,
/// at the §5.4 layer counts the testbed's memory admits.
fn paper_instances() -> Vec<(ModelConfig, Testbed)> {
    let mut out = Vec::new();
    for tb in Testbed::all() {
        for deepseek in [true, false] {
            let layers = ModelConfig::paper_layers(deepseek, &tb.name[..2]);
            let model = if deepseek {
                ModelConfig::deepseek_v2(layers)
            } else {
                ModelConfig::qwen3_moe(layers)
            };
            out.push((model, tb.clone()));
        }
    }
    out
}

fn phases() -> [Phase; 2] {
    [Phase::Prefill, Phase::Decode { kv_len: 2048 }]
}

fn phase_instance(model: &ModelConfig, cl: &Cluster, split: GroupSplit, phase: Phase) -> Instance {
    match phase {
        Phase::Prefill => Instance::on_cluster(model.clone(), cl.clone(), split, 2048),
        Phase::Decode { kv_len } => {
            Instance::decode_on_cluster(model.clone(), cl.clone(), split, kv_len)
        }
    }
}

fn assert_solutions_identical(a: &Solution, b: &Solution, tag: &str) {
    assert_eq!(a.config, b.config, "{tag}");
    assert_eq!(a.throughput_tokens.to_bits(), b.throughput_tokens.to_bits(), "{tag}");
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{tag}");
}

#[test]
fn stage_models_bit_identical_under_identity_placement() {
    // The placed derivation fed the identity placement against the
    // legacy uniform path: every α/β coefficient must be equal — the
    // uniform kind short-circuits to the literal `E/eg` expressions.
    for (model, tb) in paper_instances() {
        let cl = Cluster::single_pool(&tb);
        let split = GroupSplit::paper_default(&tb, model.has_shared_expert());
        let placement = ExpertPlacement::uniform(model.n_experts, split.eg);
        let load = ExpertLoad::uniform(model.n_experts);
        for phase in phases() {
            let legacy = StageModels::for_cluster(&model, &cl, split, 2048, phase);
            let placed =
                StageModels::for_cluster_placed(&model, &cl, split, 2048, phase, &placement, &load);
            assert_eq!(legacy, placed, "{} on {} {phase:?}", model.name, tb.name);
        }
    }
}

#[test]
fn solves_bit_identical_under_identity_placement() {
    // End to end through Algorithm 1: the default instance (which
    // carries the identity placement implicitly) against one with the
    // identity placement installed explicitly. Same winning config,
    // same throughput and makespan to the last bit, same feasibility.
    let params = solver::SolverParams::default();
    for (model, tb) in paper_instances() {
        let cl = Cluster::single_pool(&tb);
        let split = GroupSplit::paper_default(&tb, model.has_shared_expert());
        for phase in phases() {
            let implicit = phase_instance(&model, &cl, split, phase);
            let explicit = implicit.clone().with_placement(
                ExpertPlacement::uniform(model.n_experts, split.eg),
                ExpertLoad::uniform(model.n_experts),
            );
            let tag = format!("{} on {} {phase:?}", model.name, tb.name);
            match (solver::solve(&implicit, &params), solver::solve(&explicit, &params)) {
                (Some(a), Some(b)) => assert_solutions_identical(&a, &b, &tag),
                (None, None) => {}
                (a, b) => panic!(
                    "feasibility drift on {tag}: implicit={} explicit={}",
                    a.is_some(),
                    b.is_some()
                ),
            }
        }
    }
}

#[test]
fn replication_search_under_uniform_load_returns_the_uniform_plan() {
    // The exact-tie guarantee: with exactly-uniform observed load the
    // replication search's baseline candidate is the canonical uniform
    // placement, which sits at the perfect-balance floor — so the
    // search must stop there and return the legacy plan bit for bit.
    let params = SearchParams::default();
    for (model, tb) in paper_instances() {
        let cl = Cluster::single_pool(&tb);
        let split = GroupSplit::paper_default(&tb, model.has_shared_expert());
        let load = ExpertLoad::uniform(model.n_experts);
        for phase in phases() {
            let inst = phase_instance(&model, &cl, split, phase);
            let tag = format!("{} on {} {phase:?}", model.name, tb.name);
            let legacy = solver::solve(&inst, &params.solver);
            let report = solver::search_replication(&inst, &load, &params);
            match (legacy, report) {
                (Some(a), Some(r)) => {
                    assert!(r.best.placement.is_uniform(), "{tag}");
                    assert_eq!(r.best.extra_slots, 0, "{tag}");
                    assert_eq!(r.best.placement.fingerprint(), PlacementId::UNIFORM, "{tag}");
                    assert_solutions_identical(&a, &r.best.solution, &tag);
                }
                (None, None) => {}
                (a, r) => panic!(
                    "feasibility drift on {tag}: solve={} replication={}",
                    a.is_some(),
                    r.is_some()
                ),
            }
        }
    }
}

#[test]
fn memory_accounting_identical_under_identity_placement() {
    // The uniform placement charges exactly the legacy
    // `n_layers · ⌈E/eg⌉ · expert_param_bytes` against the expert pool;
    // a replicated placement charges strictly more.
    for (model, tb) in paper_instances() {
        let cl = Cluster::single_pool(&tb);
        let split = GroupSplit::paper_default(&tb, model.has_shared_expert());
        let mem = MemoryModel::for_cluster(&model, &cl, split, 2048, Phase::Prefill);
        let legacy = model.n_layers
            * model.n_experts.div_ceil(split.eg)
            * model.expert_param_bytes();
        assert_eq!(mem.eg_weight_bytes(), legacy, "{} on {}", model.name, tb.name);
        // One extra replica of the hottest expert can only grow (or
        // keep, if it lands on a non-max shard) the fullest shard.
        let skew = ExpertLoad::zipf(model.n_experts, 1.5);
        let replicated = mem
            .clone()
            .with_placement(ExpertPlacement::replicate_hot(&skew, split.eg, split.eg));
        assert!(
            replicated.eg_weight_bytes() >= legacy,
            "{} on {}: replicas must not shrink weight bytes",
            model.name,
            tb.name
        );
    }
}

#[test]
fn plan_cache_isolates_placement_fingerprints() {
    // Integration-level cache isolation with *real* fingerprints: the
    // uniform placement keys under PlacementId::UNIFORM, every distinct
    // explicit placement under its own id, and entries never alias.
    let load = ExpertLoad::zipf(32, 1.2);
    let a = ExpertPlacement::replicate_hot(&load, 4, 0);
    let b = ExpertPlacement::replicate_hot(&load, 4, 4);
    assert_ne!(a.fingerprint(), b.fingerprint());
    assert_ne!(a.fingerprint(), PlacementId::UNIFORM);
    assert_eq!(ExpertPlacement::uniform(32, 4).fingerprint(), PlacementId::UNIFORM);

    let cache = PlanCache::new();
    let keys = [
        ShapeKey::prefill(2048, 8),
        ShapeKey::prefill(2048, 8).with_placement(a.fingerprint()),
        ShapeKey::prefill(2048, 8).with_placement(b.fingerprint()),
    ];
    let mut solves = 0usize;
    for (i, &key) in keys.iter().enumerate() {
        let marker = (i + 1) as f64;
        let sol = cache.get_or_solve(key, || {
            solves += 1;
            Some(Solution {
                config: findep::sched::PlanConfig::findep(
                    1,
                    1,
                    1,
                    marker,
                    findep::sched::Order::Asas,
                ),
                makespan: marker,
                throughput_tokens: marker,
                solve_seconds: 0.0,
                evals: 0,
                pruned_rows: 0,
                warm_seeded: false,
                exhaustive: true,
            })
        });
        assert_eq!(sol.expect("stub solution").makespan, marker);
    }
    assert_eq!(solves, 3, "every placement fingerprint must miss separately");
    assert_eq!(cache.len(), 3);
    // Hits resolve to their own placement's entry.
    for (i, &key) in keys.iter().enumerate() {
        let hit = cache.get_or_solve(key, || panic!("must be a hit"));
        assert_eq!(hit.expect("cached").makespan, (i + 1) as f64);
    }
}
