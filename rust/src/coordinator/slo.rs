//! SLO targets and goodput accounting for the serving coordinator.
//!
//! A [`SloPolicy`] carries TTFT/TPOT percentile targets. It acts in two
//! places:
//!
//! * **Planning** — the server turns the per-phase latency target into
//!   Algorithm 1's `max_makespan` cap
//!   ([`crate::solver::SolverParams::max_makespan`]): prefill plans are
//!   capped by the TTFT target (a prefill batch's modeled makespan is
//!   the time to its first tokens), decode plans by the TPOT target (a
//!   decode pass emits one token per in-flight request). The solver
//!   then maximizes throughput *subject to* the cap — goodput-optimal
//!   rather than throughput-optimal planning.
//! * **Reporting** — [`SloPolicy::evaluate`] reads the observed
//!   `ttft` / `tpot` histograms off a [`Registry`] via
//!   [`Registry::histogram_percentile`] and grades each target,
//!   yielding an [`SloReport`] with attainment flags and the measured
//!   percentiles; `goodput` then discounts raw throughput by the
//!   fraction of requests meeting their targets.

use crate::metrics::Registry;
use crate::util::json::{Json, JsonObj};

/// TTFT/TPOT percentile targets (seconds; `None` leaves that phase
/// uncapped and ungraded).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloPolicy {
    /// Time-to-first-token target: caps prefill-plan makespans and
    /// grades the observed `ttft` histogram.
    pub ttft_s: Option<f64>,
    /// Time-per-output-token target: caps decode-plan makespans and
    /// grades the observed `tpot` histogram.
    pub tpot_s: Option<f64>,
    /// Attainment percentile in (0, 100] — the percentile of the
    /// observed distribution that must sit at or under the target
    /// (paper-style "p99 TTFT under X ms").
    pub percentile: f64,
}

impl SloPolicy {
    pub fn new(ttft_s: Option<f64>, tpot_s: Option<f64>, percentile: f64) -> Self {
        Self { ttft_s, tpot_s, percentile }
    }

    /// Does this policy constrain anything at all?
    pub fn is_active(&self) -> bool {
        self.ttft_s.is_some() || self.tpot_s.is_some()
    }

    /// Grade the observed latency distributions against the targets.
    pub fn evaluate(&self, metrics: &Registry) -> SloReport {
        let grade = |target: Option<f64>, name: &str| -> (Option<f64>, Option<bool>) {
            let observed = metrics.histogram_percentile(name, self.percentile);
            let met = match (target, observed) {
                (Some(t), Some(o)) => Some(o <= t),
                // A target with no observations is vacuously met (no
                // request missed it); no target means nothing to grade.
                (Some(_), None) => Some(true),
                (None, _) => None,
            };
            (observed, met)
        };
        let (ttft_observed, ttft_met) = grade(self.ttft_s, "ttft");
        let (tpot_observed, tpot_met) = grade(self.tpot_s, "tpot");
        SloReport { policy: *self, ttft_observed, ttft_met, tpot_observed, tpot_met }
    }
}

/// The outcome of grading one [`SloPolicy`] against observed serving
/// latencies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloReport {
    pub policy: SloPolicy,
    /// Observed TTFT at the policy percentile (`None`: no prefill
    /// completions recorded).
    pub ttft_observed: Option<f64>,
    /// Whether the TTFT target held (`None`: no target set).
    pub ttft_met: Option<bool>,
    /// Observed TPOT at the policy percentile (`None`: no decode
    /// passes recorded).
    pub tpot_observed: Option<f64>,
    /// Whether the TPOT target held (`None`: no target set).
    pub tpot_met: Option<bool>,
}

impl SloReport {
    /// Every configured target held (vacuously true with no targets).
    pub fn met(&self) -> bool {
        self.ttft_met.unwrap_or(true) && self.tpot_met.unwrap_or(true)
    }

    /// Throughput discounted by SLO attainment: the fraction of
    /// requests whose latency met every configured target, times raw
    /// throughput. With no targets this is raw throughput (factor 1).
    pub fn goodput(&self, throughput: f64, metrics: &Registry) -> f64 {
        throughput * self.attainment(metrics)
    }

    /// Fraction in [0, 1] of recorded samples meeting their targets
    /// (the min across configured dimensions — a request must meet
    /// both to count as good).
    pub fn attainment(&self, metrics: &Registry) -> f64 {
        let frac = |target: Option<f64>, name: &str| -> Option<f64> {
            let t = target?;
            Some(metrics.histogram_fraction_le(name, t).unwrap_or(1.0))
        };
        let ttft = frac(self.policy.ttft_s, "ttft");
        let tpot = frac(self.policy.tpot_s, "tpot");
        match (ttft, tpot) {
            (Some(a), Some(b)) => a.min(b),
            (Some(a), None) | (None, Some(a)) => a,
            (None, None) => 1.0,
        }
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        let optb = |v: Option<bool>| v.map(Json::Bool).unwrap_or(Json::Null);
        let mut o = JsonObj::new();
        o.insert("percentile", Json::Num(self.policy.percentile));
        o.insert("ttft_target_s", opt(self.policy.ttft_s));
        o.insert("ttft_observed_s", opt(self.ttft_observed));
        o.insert("ttft_met", optb(self.ttft_met));
        o.insert("tpot_target_s", opt(self.policy.tpot_s));
        o.insert("tpot_observed_s", opt(self.tpot_observed));
        o.insert("tpot_met", optb(self.tpot_met));
        o.insert("met", Json::Bool(self.met()));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grades_targets_against_observed_percentiles() {
        let m = Registry::new();
        for i in 0..100 {
            m.observe("ttft", 0.010 + i as f64 * 0.001); // 10ms..109ms
            m.observe("tpot", 0.002);
        }
        // p50 TTFT is ~60ms: a 200ms target holds, a 20ms one fails.
        let loose = SloPolicy::new(Some(0.200), Some(0.005), 50.0).evaluate(&m);
        assert_eq!(loose.ttft_met, Some(true));
        assert_eq!(loose.tpot_met, Some(true));
        assert!(loose.met());
        let tight = SloPolicy::new(Some(0.020), None, 50.0).evaluate(&m);
        assert_eq!(tight.ttft_met, Some(false));
        assert_eq!(tight.tpot_met, None, "no TPOT target, nothing to grade");
        assert!(!tight.met());
        // Attainment discounts throughput by the failing fraction:
        // ~11 of 100 TTFT samples sit at or under 20ms.
        let att = tight.attainment(&m);
        assert!(att > 0.05 && att < 0.20, "attainment {att}");
        assert!(tight.goodput(1000.0, &m) < 200.0);
        assert_eq!(loose.attainment(&m), 1.0);
        assert_eq!(loose.goodput(1000.0, &m), 1000.0);
    }

    #[test]
    fn empty_registry_is_vacuously_met() {
        let m = Registry::new();
        let r = SloPolicy::new(Some(0.1), Some(0.01), 99.0).evaluate(&m);
        assert_eq!(r.ttft_observed, None);
        assert_eq!(r.ttft_met, Some(true), "no request missed the target");
        assert!(r.met());
        assert_eq!(r.attainment(&m), 1.0);
    }

    #[test]
    fn inactive_policy_constrains_nothing() {
        let p = SloPolicy::new(None, None, 99.0);
        assert!(!p.is_active());
        let m = Registry::new();
        m.observe("ttft", 100.0);
        let r = p.evaluate(&m);
        assert!(r.met());
        assert_eq!(r.goodput(42.0, &m), 42.0);
    }
}
