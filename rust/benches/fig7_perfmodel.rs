//! Figure 7 — verification of the α-β performance models (§5.2).
//!
//! The paper fits t_gm / t_attn on GEMM and attention micro-benchmarks
//! and t_c on transfer micro-benchmarks, reporting R² ≥ 0.994 on every
//! fit. We regenerate the experiment against *this* host: real GEMM and
//! attention computations through the PJRT CPU client (the same
//! execution stack the serving path uses), and channel transfers for
//! the link model — 10 warmup + 20 timed trials per point like the
//! paper, then a least-squares fit and R².
//!
//! Run: `cargo bench --bench fig7_perfmodel`

use findep::perfmodel::calibrate::{self, Sample};
use findep::runtime::probe;
use findep::util::bench::Table;

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let (warmup, trials) = if quick { (2, 5) } else { (10, 20) };
    let client = xla::PjRtClient::cpu().expect("PJRT CPU client");

    // --- Fig. 7a: GEMM sweep over the matrix sizes the MLA uses. -------
    let gemm_shapes: &[(usize, usize, usize)] = &[
        (16, 64, 64),
        (32, 64, 128),
        (64, 128, 128),
        (128, 128, 256),
        (128, 256, 256),
        (256, 256, 256),
        (256, 256, 512),
        (256, 512, 512),
    ];
    let mut gemm_samples: Vec<Sample> = Vec::new();
    let mut t = Table::new(
        "Fig. 7a (GEMM): measured vs fitted",
        &["m x k x n", "workload (FLOPs)", "measured", "fitted", "rel err"],
    );
    for &(m, k, n) in gemm_shapes {
        let s = probe::gemm_sample(&client, m, k, n, warmup, trials).expect("gemm probe");
        gemm_samples.push(s);
    }
    let (gemm_model, gemm_r2) = calibrate::fit(&gemm_samples).expect("gemm fit");
    for (s, &(m, k, n)) in gemm_samples.iter().zip(gemm_shapes) {
        let fit = gemm_model.eval(s.workload);
        t.row(&[
            format!("{m}x{k}x{n}"),
            format!("{:.2e}", s.workload),
            format!("{:.3} ms", s.seconds * 1e3),
            format!("{:.3} ms", fit * 1e3),
            format!("{:+.1}%", (fit - s.seconds) / s.seconds * 100.0),
        ]);
    }
    t.print();
    println!(
        "t_gm(x) = {:.3e} + {:.3e}·x   R² = {:.6}   (paper Fig. 7a: α_gm=0.17, β_gm=8.59e-11, R²=0.9971)",
        gemm_model.alpha, gemm_model.beta, gemm_r2
    );

    // --- Fig. 7a (attention part). --------------------------------------
    let attn_shapes: &[(usize, usize, usize)] =
        &[(4, 16, 16), (8, 32, 16), (8, 64, 16), (16, 64, 32), (16, 128, 32), (32, 128, 32)];
    let mut attn_samples = Vec::new();
    for &(hb, s, d) in attn_shapes {
        attn_samples
            .push(probe::attention_sample(&client, hb, s, d, warmup, trials).expect("attn probe"));
    }
    let (attn_model, attn_r2) = calibrate::fit(&attn_samples).expect("attention fit");
    let mut t = Table::new(
        "Fig. 7a (attention): measured vs fitted",
        &["heads·batch, S, d", "workload", "measured", "fitted"],
    );
    for (s, &(hb, sq, d)) in attn_samples.iter().zip(attn_shapes) {
        t.row(&[
            format!("{hb}, {sq}, {d}"),
            format!("{:.2e}", s.workload),
            format!("{:.3} ms", s.seconds * 1e3),
            format!("{:.3} ms", attn_model.eval(s.workload) * 1e3),
        ]);
    }
    t.print();
    println!(
        "t_attn(y) = {:.3e} + {:.3e}·y   R² = {:.6}   (paper: α=0.15, β=1.54e-11)",
        attn_model.alpha, attn_model.beta, attn_r2
    );

    // --- Fig. 7b: transfer model. ----------------------------------------
    let sizes: Vec<usize> = if quick {
        vec![1 << 14, 1 << 16, 1 << 18, 1 << 20]
    } else {
        vec![1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 23]
    };
    let (comm_model, comm_r2, comm_samples) =
        calibrate::calibrate_copy_link(&sizes, warmup, trials).expect("transfer calibration");
    let mut t = Table::new(
        "Fig. 7b (A2E/E2A transfer): measured vs fitted",
        &["bytes", "measured", "fitted"],
    );
    for s in &comm_samples {
        t.row(&[
            format!("{:.0}", s.workload),
            format!("{:.3} ms", s.seconds * 1e3),
            format!("{:.3} ms", comm_model.eval(s.workload) * 1e3),
        ]);
    }
    t.print();
    println!(
        "t_c(z) = {:.3e} + {:.3e}·z   R² = {:.6}   (paper Fig. 7b: R² ∈ [0.994, 0.99999])",
        comm_model.alpha, comm_model.beta, comm_r2
    );

    // The paper's acceptance bar: linear models fit well. Flag clearly
    // if this host disagrees.
    let bar = 0.95;
    for (name, r2) in [("GEMM", gemm_r2), ("attention", attn_r2), ("transfer", comm_r2)] {
        if r2 < bar {
            println!("WARNING: {name} fit R² = {r2:.4} below {bar} — noisy host?");
        }
    }
    println!(
        "\nsummary: R²(gemm)={gemm_r2:.4} R²(attn)={attn_r2:.4} R²(comm)={comm_r2:.4} \
         — paper reports ≥0.994 on all fits; linear α-β models hold on this host too."
    );
}
