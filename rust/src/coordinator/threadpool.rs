//! The retired thread-and-channel batcher, preserved verbatim as the
//! measured baseline for the event-driven coordinator
//! ([`super::planner`] / [`super::executor`]): a bounded
//! `sync_channel` submit queue in front of a dedicated assembler
//! thread that busy-polls the decode re-entry lane at 200µs
//! ([`DECODE_POLL`]) and fans assembled batches out to per-thread
//! pipeline replicas over one `Mutex<Receiver>`.
//!
//! Behaviourally equivalent to [`super::batcher::Batcher`] (same FIFO,
//! linger, decode-re-entry, backpressure, and drain-on-shutdown
//! semantics); the differences are purely mechanical and are exactly
//! what `benches/event_coordinator.rs` measures:
//!
//! * idle threads wake every `DECODE_POLL` instead of parking — the
//!   `poll_wakeups` counter records every fruitless timeout so the
//!   bench (and the idle regression test) can compare against the
//!   event core's near-zero wakeups;
//! * batch assembly is a thread, not a state machine, so every request
//!   crosses two channel hops (submit → assembler → worker) before
//!   serving instead of one lock acquisition.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
    TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::Phase;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::moe::ModelHandle;
use crate::coordinator::planner::QueuedRequest;
use crate::coordinator::server::{EmbeddedRequest, Policy, Response, Server};
use crate::metrics::Registry;
use crate::solver::PlanCache;

/// How often the assembler re-polls the decode re-entry lane while
/// blocked waiting for fresh submissions.
pub const DECODE_POLL: Duration = Duration::from_micros(200);

/// The polling thread-pool batcher (baseline). Owns the queue, the
/// assembler, and the worker pool; dropping it drains in-flight work
/// and joins every thread. Same API surface as
/// [`super::batcher::Batcher`].
pub struct ThreadPoolBatcher {
    submit_tx: Option<SyncSender<QueuedRequest>>,
    resp_rx: Receiver<Response>,
    metrics: Arc<Registry>,
    plan_cache: Arc<PlanCache>,
    req_elems: usize,
    /// Requests still owed a final response (in the queue, in flight,
    /// or looping through decode re-entry).
    open: Arc<AtomicUsize>,
    threads: Vec<JoinHandle<()>>,
}

impl ThreadPoolBatcher {
    pub fn new(model: ModelHandle, cfg: BatcherConfig) -> Result<ThreadPoolBatcher> {
        let metrics = Arc::new(Registry::new());
        let plan_cache = Arc::new(PlanCache::new());
        let workers = cfg.workers.max(1);
        let max_batch = cfg.max_batch.max(1);
        let req_elems = model.seq_len * model.model.embed;

        let (submit_tx, submit_rx) = sync_channel::<QueuedRequest>(cfg.queue_depth.max(1));
        // Decode re-entry lane: unbounded on purpose — a worker must
        // never block re-entering its own output while the assembler
        // blocks handing it the next batch (that cycle would deadlock
        // the pool).
        let (decode_tx, decode_rx) = channel::<QueuedRequest>();
        let open = Arc::new(AtomicUsize::new(0));
        // Bounded work channel: the assembler runs at most `workers`
        // batches ahead of the slowest replica.
        let (work_tx, work_rx) = sync_channel::<Vec<QueuedRequest>>(workers);
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (resp_tx, resp_rx) = channel::<Response>();

        let mut threads = Vec::with_capacity(workers + 1);
        {
            let metrics = metrics.clone();
            let linger = cfg.linger;
            let open = open.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("findep-poolbatch".into())
                    .spawn(move || {
                        assembler_loop(
                            submit_rx, decode_rx, work_tx, max_batch, linger, open, metrics,
                        )
                    })
                    .context("spawn batch assembler")?,
            );
        }
        for w in 0..workers {
            let mut server = Server::with_shared(
                model.clone(),
                cfg.eg,
                cfg.link_delay,
                metrics.clone(),
                plan_cache.clone(),
            )?;
            server.cache_plans = cfg.cache_plans;
            let work_rx = work_rx.clone();
            let resp_tx = resp_tx.clone();
            let decode_tx = decode_tx.clone();
            let open = open.clone();
            let policy = cfg.policy;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("findep-poolserve{w}"))
                    .spawn(move || worker_loop(server, policy, work_rx, resp_tx, decode_tx, open))
                    .context("spawn serving worker")?,
            );
        }

        Ok(ThreadPoolBatcher {
            submit_tx: Some(submit_tx),
            resp_rx,
            metrics,
            plan_cache,
            req_elems,
            open,
            threads,
        })
    }

    fn validate(&self, req: &EmbeddedRequest) -> Result<()> {
        anyhow::ensure!(
            req.hidden.data.len() == self.req_elems,
            "request {} has {} elements, expected {} (S·M)",
            req.id,
            req.hidden.data.len(),
            self.req_elems
        );
        Ok(())
    }

    /// Enqueue a request, blocking while the queue is full.
    pub fn submit(&self, req: EmbeddedRequest) -> Result<()> {
        self.validate(&req)?;
        let tx = self.submit_tx.as_ref().context("batcher closed")?;
        self.open.fetch_add(1, Ordering::SeqCst);
        if tx.send(QueuedRequest::fresh(req)).is_err() {
            self.open.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!("batcher workers gone");
        }
        self.metrics.inc("queued", 1);
        Ok(())
    }

    /// Non-blocking enqueue: `Ok(false)` when the queue is full.
    pub fn try_submit(&self, req: EmbeddedRequest) -> Result<bool> {
        self.validate(&req)?;
        let tx = self.submit_tx.as_ref().context("batcher closed")?;
        self.open.fetch_add(1, Ordering::SeqCst);
        match tx.try_send(QueuedRequest::fresh(req)) {
            Ok(()) => {
                self.metrics.inc("queued", 1);
                Ok(true)
            }
            Err(TrySendError::Full(_)) => {
                self.open.fetch_sub(1, Ordering::SeqCst);
                self.metrics.inc("queue_rejected", 1);
                Ok(false)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.open.fetch_sub(1, Ordering::SeqCst);
                anyhow::bail!("batcher workers gone")
            }
        }
    }

    /// Next completed response, or `None` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.resp_rx.recv_timeout(timeout).ok()
    }

    /// Collect up to `n` responses, waiting at most `timeout` for each.
    pub fn drain(&self, n: usize, timeout: Duration) -> Vec<Response> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match self.recv_timeout(timeout) {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plan_cache
    }

    /// Fruitless 200µs poll timeouts since startup (the idle-cost
    /// counter the event-driven design eliminates).
    pub fn poll_wakeups(&self) -> u64 {
        self.metrics.counter("poll_wakeups")
    }
}

impl Drop for ThreadPoolBatcher {
    fn drop(&mut self) {
        // Close the queue: the assembler drains what's pending, then
        // the work channel closes and every worker exits.
        self.submit_tx.take();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Pop the next request for assembly. Decode re-entries take priority
/// over fresh submissions. Blocks until something arrives; returns
/// `None` only when the submit side has closed *and* no request still
/// owes a response (`open == 0`), so pending decode loops always
/// drain. Every fruitless timeout counts one `poll_wakeups`.
fn next_request(
    submit_rx: &Receiver<QueuedRequest>,
    decode_rx: &Receiver<QueuedRequest>,
    open: &AtomicUsize,
    metrics: &Registry,
) -> Option<QueuedRequest> {
    loop {
        if let Ok(q) = decode_rx.try_recv() {
            return Some(q);
        }
        match submit_rx.recv_timeout(DECODE_POLL) {
            Ok(q) => return Some(q),
            Err(RecvTimeoutError::Timeout) => {
                metrics.inc("poll_wakeups", 1);
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // Submissions closed: drain the in-flight decode work. A
    // disconnected decode lane means every worker has exited — no step
    // can ever arrive again, so stop even if `open` never reached zero
    // (a crashed worker's requests are lost either way; spinning here
    // would hang shutdown).
    loop {
        match decode_rx.recv_timeout(DECODE_POLL) {
            Ok(q) => return Some(q),
            Err(RecvTimeoutError::Disconnected) => return None,
            Err(RecvTimeoutError::Timeout) => metrics.inc("poll_wakeups", 1),
        }
        if open.load(Ordering::SeqCst) == 0 {
            return None;
        }
    }
}

/// FIFO batch assembly with a linger window: take the first request
/// (blocking), then fill up to `max_batch` from whatever arrives within
/// `linger` — decode re-entries first, then fresh submissions.
///
/// Public so `benches/event_coordinator.rs` can drive the *actual*
/// retired assembly loop (not a reconstruction) against the event core
/// with a model-free executor.
pub fn assembler_loop(
    submit_rx: Receiver<QueuedRequest>,
    decode_rx: Receiver<QueuedRequest>,
    work_tx: SyncSender<Vec<QueuedRequest>>,
    max_batch: usize,
    linger: Duration,
    open: Arc<AtomicUsize>,
    metrics: Arc<Registry>,
) {
    let mut submit_open = true;
    loop {
        let Some(first) = next_request(&submit_rx, &decode_rx, &open, &metrics) else {
            return; // closed and fully drained
        };
        let mut batch = Vec::with_capacity(max_batch);
        batch.push(first);
        let deadline = Instant::now() + linger;
        while batch.len() < max_batch {
            if let Ok(q) = decode_rx.try_recv() {
                batch.push(q);
                continue;
            }
            if submit_open {
                match submit_rx.try_recv() {
                    Ok(q) => {
                        batch.push(q);
                        continue;
                    }
                    Err(TryRecvError::Disconnected) => submit_open = false,
                    Err(TryRecvError::Empty) => {}
                }
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            if submit_open {
                match submit_rx.recv_timeout(remaining.min(DECODE_POLL)) {
                    Ok(q) => batch.push(q),
                    Err(RecvTimeoutError::Timeout) => metrics.inc("poll_wakeups", 1),
                    Err(RecvTimeoutError::Disconnected) => submit_open = false,
                }
            } else {
                // Only decode re-entries can still arrive; poll them at
                // the same cadence for the rest of the window.
                std::thread::sleep(remaining.min(DECODE_POLL));
                metrics.inc("poll_wakeups", 1);
            }
        }
        for q in &batch {
            metrics.observe("queue_wait", q.enqueued.elapsed().as_secs_f64());
        }
        metrics.inc("batches_assembled", 1);
        metrics.observe("batch_fill", batch.len() as f64);
        if work_tx.send(batch).is_err() {
            return; // all workers gone
        }
    }
}

/// Releases a batch's `open` slots when dropped — including during a
/// panic unwind.
struct OpenSlots<'a> {
    open: &'a AtomicUsize,
    n: usize,
}

impl Drop for OpenSlots<'_> {
    fn drop(&mut self) {
        self.open.fetch_sub(self.n, Ordering::SeqCst);
    }
}

/// One serving replica: pop the next assembled batch, serve it, then
/// per request either re-enqueue the next KV-grown decode step (output
/// remaining) or emit the final response with its true
/// submit→response latency.
fn worker_loop(
    server: Server,
    policy: Policy,
    work_rx: Arc<Mutex<Receiver<Vec<QueuedRequest>>>>,
    resp_tx: Sender<Response>,
    decode_tx: Sender<QueuedRequest>,
    open: Arc<AtomicUsize>,
) {
    let prompt_len = server.pipeline.model().seq_len;
    loop {
        // Hold the lock only for the pop; serving runs unlocked so the
        // other replicas pipeline their own batches meanwhile.
        let batch = {
            let rx = work_rx.lock().unwrap();
            rx.recv()
        };
        let Ok(batch) = batch else { return };
        let mut reqs = Vec::with_capacity(batch.len());
        let mut meta = Vec::with_capacity(batch.len());
        for q in batch {
            meta.push((q.submitted, q.req.phase, q.req.output_len));
            reqs.push(q.req);
        }
        let slots = OpenSlots { open: &open, n: reqs.len() };
        match server.serve_batch(&reqs, policy) {
            Ok((responses, _stats)) => {
                for (mut resp, (submitted, phase, output_len)) in responses.into_iter().zip(meta) {
                    if output_len > 0 {
                        let next = EmbeddedRequest {
                            id: resp.id,
                            hidden: resp.hidden,
                            phase: Phase::Decode { kv_len: phase.next_kv_len(prompt_len) },
                            output_len: output_len - 1,
                            deadline: None,
                        };
                        server.metrics.inc("decode_steps", 1);
                        open.fetch_add(1, Ordering::SeqCst);
                        if decode_tx.send(QueuedRequest::reentry(next, submitted)).is_err() {
                            // Assembler gone mid-shutdown: the request
                            // can never finish, release its slot.
                            open.fetch_sub(1, Ordering::SeqCst);
                        }
                        continue;
                    }
                    resp.latency_s = submitted.elapsed().as_secs_f64();
                    server.metrics.observe("request_latency", resp.latency_s);
                    if resp_tx.send(resp).is_err() {
                        return; // guard releases the batch's slots
                    }
                }
            }
            Err(e) => {
                server.metrics.inc("serve_errors", 1);
                eprintln!("serving worker: batch failed: {e:#}");
            }
        }
        drop(slots);
    }
}
