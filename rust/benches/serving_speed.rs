//! Serving-speed claims for the continuous-batching layer:
//!
//! 1. **Memoized online planning** — re-solving the §5.5 online
//!    schedule per *batch* vs once per *shape* through the
//!    [`PlanCache`]. On the paper instance the cached path must be
//!    strictly faster than the per-batch cold solve (the acceptance
//!    gate; a hit is a map lookup against a full Algorithm-1 walk, so
//!    this holds by orders of magnitude even in quick mode).
//! 2. **Allocation-free batch assembly** — the [`BatchBuffers`] arena
//!    vs the seed's allocate-per-batch assembly, asserted the same way
//!    `solver_speed.rs` asserts the buffered solver path wins, plus a
//!    direct steady-state probe: across a thousand mixed-shape batches
//!    the arena's data pointer and capacity must not change (no
//!    per-batch heap allocation once warm).
//! 3. **Queue-fed serving** — requests/s through the bounded queue +
//!    batcher + worker replicas under all four policies, and Adaptive
//!    with the plan cache on vs off (needs `make artifacts`; skipped
//!    gracefully otherwise).
//!
//! Emits a `BENCH_serving.json` trajectory file with the measured
//! series for dashboard-style tracking across PRs.
//!
//! Run: `cargo bench --bench serving_speed`

use std::time::{Duration, Instant};

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::coordinator::batcher::{Batcher, BatcherConfig};
use findep::coordinator::moe::ModelHandle;
use findep::coordinator::server::{BatchBuffers, EmbeddedRequest, Policy};
use findep::runtime::artifacts_dir;
use findep::sched::Order;
use findep::solver::{shape_key, solve_online, Instance, PlanCache, SolverParams};
use findep::util::bench::{fmt_duration, Bencher, Table};
use findep::util::json::{to_string_pretty, Json, JsonObj};
use findep::util::stats;

/// First `n` per-iteration samples as a JSON trajectory array.
fn trajectory(samples: &[f64], n: usize) -> Json {
    Json::Arr(samples.iter().take(n).map(|&s| Json::Num(s)).collect())
}

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let mut report = JsonObj::new();
    report.insert("bench", Json::Str("serving_speed".into()));
    report.insert("quick", Json::Bool(quick));

    // --- 1. Plan cache: per-batch cold solve vs memoized solve on the
    //     paper instance. ---------------------------------------------
    let inst = Instance::new(
        ModelConfig::deepseek_v2(8),
        Testbed::a(),
        GroupSplit::new(3, 5),
        3072,
    );
    let params = SolverParams::default();
    // A serving stream repeats a handful of padded batch shapes.
    let stream: Vec<usize> =
        [4usize, 8, 2, 4, 16, 8, 4, 2].iter().copied().cycle().take(64).collect();

    // Correctness first: the memoized solution per shape is the cold
    // solution, config-identical.
    let check = PlanCache::new();
    for &b in &stream {
        let cold = solve_online(&inst, b, &params);
        let cached =
            check.get_or_solve(shape_key(inst.seq_len, b), || solve_online(&inst, b, &params));
        match (cold, cached) {
            (Some(c), Some(h)) => assert_eq!(c.config, h.config, "cache changed the plan"),
            (None, None) => {}
            _ => panic!("cache changed feasibility for batch {b}"),
        }
    }

    let r_cold = bencher.run("online solve per batch (cold)", || {
        for &b in &stream {
            let _ = solve_online(&inst, b, &params);
        }
    });
    let cache = PlanCache::new();
    let r_cached = bencher.run("online solve per shape (cached)", || {
        for &b in &stream {
            let _ = cache
                .get_or_solve(shape_key(inst.seq_len, b), || solve_online(&inst, b, &params));
        }
    });
    let mut table = Table::new(
        &format!("Adaptive planning, {}-batch serving stream (paper instance)", stream.len()),
        &["path", "mean / stream", "per batch", "speedup"],
    );
    table.row(&[
        "cold solve".into(),
        fmt_duration(r_cold.mean_s()),
        fmt_duration(r_cold.mean_s() / stream.len() as f64),
        "1.00x".into(),
    ]);
    table.row(&[
        "plan cache".into(),
        fmt_duration(r_cached.mean_s()),
        fmt_duration(r_cached.mean_s() / stream.len() as f64),
        format!("{:.0}x", r_cold.mean_s() / r_cached.mean_s()),
    ]);
    table.print();
    println!(
        "plan cache after run: {} hits / {} misses across {} shapes",
        cache.hits(),
        cache.misses(),
        cache.len()
    );
    // The acceptance gate: cached-solve planning is strictly faster
    // than the per-batch cold solve. The margin is enormous (map
    // lookup vs full solve), so this is asserted in quick mode too.
    assert!(
        r_cached.mean_s() < r_cold.mean_s(),
        "plan cache ({:.9}s) must beat per-batch cold solve ({:.9}s)",
        r_cached.mean_s(),
        r_cold.mean_s()
    );
    assert_eq!(cache.len() as u64, cache.misses(), "each shape must be solved exactly once");
    let mut pc = JsonObj::new();
    pc.insert("stream_len", Json::Num(stream.len() as f64));
    pc.insert("cold_mean_s", Json::Num(r_cold.mean_s()));
    pc.insert("cached_mean_s", Json::Num(r_cached.mean_s()));
    pc.insert("speedup", Json::Num(r_cold.mean_s() / r_cached.mean_s()));
    pc.insert("shapes", Json::Num(cache.len() as f64));
    pc.insert("cold_trajectory_s", trajectory(&r_cold.samples, 32));
    pc.insert("cached_trajectory_s", trajectory(&r_cached.samples, 32));
    report.insert("plan_cache", Json::Obj(pc));

    // --- 2. Batch assembly: arena vs allocate-per-batch. --------------
    let (s, m) = (16usize, 64usize);
    let reqs: Vec<EmbeddedRequest> =
        (0..8u64).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
    let mut buf = BatchBuffers::new();

    // Direct no-allocation probe: once warm at the largest shape, a
    // thousand mixed-fill batches must not move or grow the buffer.
    buf.assemble(&reqs, 8, s, m);
    let (ptr, cap) = (buf.as_ptr(), buf.capacity());
    for _ in 0..1000 {
        buf.assemble(&reqs[..5], 8, s, m);
        buf.assemble(&reqs[..3], 4, s, m);
        buf.assemble(&reqs, 8, s, m);
    }
    assert_eq!(buf.as_ptr(), ptr, "steady-state assembly reallocated the arena");
    assert_eq!(buf.capacity(), cap, "steady-state assembly grew the arena");
    println!("arena probe: 3000 mixed-shape batches, zero reallocations");

    let r_alloc = bencher.run("assemble (alloc per batch)", || {
        let t = BatchBuffers::assemble_alloc(&reqs[..5], 8, s, m);
        std::hint::black_box(&t);
        let t = BatchBuffers::assemble_alloc(&reqs, 8, s, m);
        std::hint::black_box(&t);
    });
    let r_arena = bencher.run("assemble (arena)", || {
        let t = buf.assemble(&reqs[..5], 8, s, m);
        std::hint::black_box(t);
        let t = buf.assemble(&reqs, 8, s, m);
        std::hint::black_box(t);
    });
    let mut table = Table::new(
        "Batch assembly (two batches per iteration, S=16 M=64 B=8)",
        &["path", "mean", "p50", "speedup"],
    );
    table.row(&[
        "alloc per batch".into(),
        fmt_duration(r_alloc.mean_s()),
        fmt_duration(r_alloc.p50_s()),
        "1.00x".into(),
    ]);
    table.row(&[
        "BatchBuffers arena".into(),
        fmt_duration(r_arena.mean_s()),
        fmt_duration(r_arena.p50_s()),
        format!("{:.2}x", r_alloc.mean_s() / r_arena.mean_s()),
    ]);
    table.print();
    // Quick mode runs too few iterations to gate CI on a timing
    // ordering (same policy as solver_speed); the pointer probe above
    // asserts the no-allocation claim directly in every mode.
    if !quick {
        assert!(
            r_arena.mean_s() < r_alloc.mean_s(),
            "arena assembly ({:.9}s) must beat allocate-per-batch ({:.9}s)",
            r_arena.mean_s(),
            r_alloc.mean_s()
        );
    }
    let mut asm = JsonObj::new();
    asm.insert("alloc_mean_s", Json::Num(r_alloc.mean_s()));
    asm.insert("arena_mean_s", Json::Num(r_arena.mean_s()));
    asm.insert("speedup", Json::Num(r_alloc.mean_s() / r_arena.mean_s()));
    report.insert("assembly", Json::Obj(asm));

    // --- 3. Queue-fed serving (real pipeline; needs artifacts). -------
    let dir = artifacts_dir();
    if dir.join("manifest.json").exists() {
        let model = ModelHandle::load(&dir, true).expect("artifacts load");
        let (s, m) = (model.seq_len, model.model.embed);
        let n_requests = if quick { 32 } else { 96 };
        let policies: [(&str, Policy, bool); 5] = [
            ("naive", Policy::Naive, true),
            ("pppipe(r1=2)", Policy::PpPipe { r1: 2 }, true),
            ("findep(2,2,ASAS)", Policy::FinDep { r1: 2, r2: 2, order: Order::Asas }, true),
            ("adaptive (cold solve)", Policy::Adaptive, false),
            ("adaptive (plan cache)", Policy::Adaptive, true),
        ];
        let mut table = Table::new(
            &format!("Queue-fed serving, {n_requests} requests, 2 workers, max batch 8"),
            &["policy", "req/s", "p50 latency ms", "queue wait ms", "cache hit/miss"],
        );
        let mut entries: Vec<Json> = Vec::new();
        for (name, policy, cache_plans) in policies {
            let cfg = BatcherConfig {
                policy,
                cache_plans,
                workers: 2,
                max_batch: 8,
                queue_depth: 128,
                linger: Duration::from_micros(500),
                ..Default::default()
            };
            let batcher = Batcher::new(model.clone(), cfg).expect("batcher");
            let t0 = Instant::now();
            for i in 0..n_requests {
                batcher.submit(EmbeddedRequest::synthetic(i as u64, s, m)).expect("submit");
            }
            let resps = batcher.drain(n_requests, Duration::from_secs(30));
            let dt = t0.elapsed().as_secs_f64();
            assert_eq!(resps.len(), n_requests, "{name}: lost responses");
            let lat: Vec<f64> = resps.iter().map(|r| r.latency_s).collect();
            let rps = n_requests as f64 / dt;
            let qw = batcher.metrics().histogram_mean("queue_wait").unwrap_or(0.0) * 1e3;
            let (hits, misses) =
                (batcher.plan_cache().hits(), batcher.plan_cache().misses());
            table.row(&[
                name.to_string(),
                format!("{rps:.1}"),
                format!("{:.2}", stats::percentile(&lat, 50.0) * 1e3),
                format!("{qw:.3}"),
                format!("{hits}/{misses}"),
            ]);
            let mut e = JsonObj::new();
            e.insert("policy", Json::Str(name.into()));
            e.insert("requests", Json::Num(n_requests as f64));
            e.insert("req_per_s", Json::Num(rps));
            e.insert("p50_latency_s", Json::Num(stats::percentile(&lat, 50.0)));
            e.insert("p95_latency_s", Json::Num(stats::percentile(&lat, 95.0)));
            e.insert("queue_wait_mean_s", Json::Num(qw * 1e-3));
            e.insert("plan_cache_hits", Json::Num(hits as f64));
            e.insert("plan_cache_misses", Json::Num(misses as f64));
            e.insert("latency_trajectory_s", trajectory(&lat, 32));
            entries.push(Json::Obj(e));
        }
        table.print();
        report.insert("serving", Json::Arr(entries));
    } else {
        println!("artifacts missing: skipping queue-fed serving (run `make artifacts`)");
        report.insert("serving", Json::Str("skipped: artifacts missing".into()));
    }

    std::fs::write("BENCH_serving.json", to_string_pretty(&Json::Obj(report)))
        .expect("write BENCH_serving.json");
    println!("\nwrote BENCH_serving.json");
}
