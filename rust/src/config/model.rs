//! MoE model configurations (Table 1 notation).
//!
//! Presets cover the paper's two backbones — DeepSeek-V2 (with shared
//! experts, MLA-shaped heads) and Qwen3-MoE (no shared experts) — in the
//! reduced-layer variants used in §5.4, plus a `tiny` configuration whose
//! AOT artifacts execute for real on the PJRT CPU runtime.

use crate::util::json::{Json, JsonObj};

/// Serving phase of a request / batch (MegaScale-Infer's disaggregated
/// split serves both; EPS-MoE shows the winning pipeline schedule
/// differs between them, so the solver must see the phase).
///
/// * **Prefill** processes the whole prompt at once: `S` tokens per
///   sample per forward pass, writing `S` KV entries.
/// * **Decode** is one autoregressive step: 1 token per sample, reading
///   the `kv_len` cached entries (and this step's fresh one) and
///   writing 1 — attention turns memory-bound on the KV reads and the
///   expert GEMMs shrink to one token per sample.
///
/// The variant order (`Prefill < Decode`, decode ordered by `kv_len`)
/// gives the derived `Ord` used by phase-keyed plan-cache keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    Prefill,
    Decode {
        /// KV entries already cached per sample (prompt + generated so
        /// far) that this step reads.
        kv_len: usize,
    },
}

impl Phase {
    pub fn is_decode(self) -> bool {
        matches!(self, Phase::Decode { .. })
    }

    /// Tokens one sample contributes to a forward pass of this phase:
    /// the whole prompt for prefill, one generated token for decode.
    pub fn tokens_per_sample(self, seq_len: usize) -> usize {
        match self {
            Phase::Prefill => seq_len,
            Phase::Decode { .. } => 1,
        }
    }

    /// KV entries resident per sample while this phase executes:
    /// prefill writes `seq_len`; decode holds `kv_len` cached plus the
    /// entry it writes.
    pub fn kv_resident(self, seq_len: usize) -> usize {
        match self {
            Phase::Prefill => seq_len,
            Phase::Decode { kv_len } => kv_len + 1,
        }
    }

    /// KV length the *next* decode step of the same request reads —
    /// the single source of the KV-growth rule: a prefill pass leaves
    /// `prompt_len` cached entries, each decode step adds the one it
    /// wrote. Shared by the workload generator and the coordinator's
    /// decode re-entry.
    pub fn next_kv_len(self, prompt_len: usize) -> usize {
        match self {
            Phase::Prefill => prompt_len,
            Phase::Decode { kv_len } => kv_len + 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode { .. } => "decode",
        }
    }
}

/// Attention flavour. Both are modeled through `t_attn`/`t_gm` (§3.1);
/// the flavour matters for workload coefficients and KV-cache size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttentionKind {
    /// Multi-Head Attention (Qwen3-MoE).
    Mha,
    /// Multi-Head Latent Attention (DeepSeek-V2); modeled with the same
    /// GEMM+attention decomposition per the paper ("other attention
    /// variants like MLA can also be modeled using similar formulations").
    Mla,
}

/// An MoE transformer configuration, using the paper's notation
/// (Table 1): `M` embedding size, `H` expert hidden size, `E` routed
/// experts, `top_k` experts per token, `T` layers.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Embedding size per token (M).
    pub embed: usize,
    /// Hidden size of the expert feed-forward layer (H).
    pub ffn_hidden: usize,
    /// Total number of routed (non-shared) experts (E).
    pub n_experts: usize,
    /// Experts activated per token (top_k).
    pub top_k: usize,
    /// Number of shared experts (N_shared; 0 = no shared expert).
    pub n_shared: usize,
    /// Number of transformer layers (T).
    pub n_layers: usize,
    /// Attention heads (n_h).
    pub n_heads: usize,
    /// Key head dimension (d_k).
    pub d_k: usize,
    /// Value head dimension (d_v).
    pub d_v: usize,
    pub attention: AttentionKind,
    /// Bytes per parameter/activation element (2 = bf16/fp16).
    pub bytes_per_elem: usize,
}

impl ModelConfig {
    /// DeepSeek-V2-shaped backbone (shared experts present). Dimensions
    /// follow DeepSeek-V2 236B's MoE blocks: M=5120, expert hidden 1536,
    /// 160 routed experts top-6, 2 shared experts, 128 MLA heads with
    /// d_k=192 (incl. decoupled RoPE) and d_v=128. Layer count is the
    /// experiment knob (§5.4 uses 8/4/16-layer variants).
    pub fn deepseek_v2(n_layers: usize) -> Self {
        Self {
            name: format!("deepseek-v2-{n_layers}L"),
            embed: 5120,
            ffn_hidden: 1536,
            n_experts: 160,
            top_k: 6,
            n_shared: 2,
            n_layers,
            n_heads: 128,
            d_k: 192,
            d_v: 128,
            attention: AttentionKind::Mla,
            bytes_per_elem: 2,
        }
    }

    /// Qwen3-235B-A22B-shaped backbone (no shared experts): M=4096,
    /// expert hidden 1536, 128 routed experts top-8, 64 GQA heads,
    /// d_k=d_v=128. §5.4 uses 24/12/48-layer variants.
    pub fn qwen3_moe(n_layers: usize) -> Self {
        Self {
            name: format!("qwen3-moe-{n_layers}L"),
            embed: 4096,
            ffn_hidden: 1536,
            n_experts: 128,
            top_k: 8,
            n_shared: 0,
            n_layers,
            n_heads: 64,
            d_k: 128,
            d_v: 128,
            attention: AttentionKind::Mha,
            bytes_per_elem: 2,
        }
    }

    /// Tiny configuration whose artifacts run for real on CPU-PJRT.
    /// Shared expert present (DeepSeek-style routing semantics).
    pub fn tiny() -> Self {
        Self {
            name: "tiny".into(),
            embed: 64,
            ffn_hidden: 128,
            n_experts: 8,
            top_k: 2,
            n_shared: 1,
            n_layers: 2,
            n_heads: 4,
            d_k: 16,
            d_v: 16,
            attention: AttentionKind::Mha,
            bytes_per_elem: 4, // f32 on CPU
        }
    }

    /// Tiny Qwen-style configuration (no shared expert).
    pub fn tiny_noshared() -> Self {
        let mut c = Self::tiny();
        c.name = "tiny-noshared".into();
        c.n_shared = 0;
        c
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "deepseek-v2" => Some(Self::deepseek_v2(16)),
            "qwen3-moe" => Some(Self::qwen3_moe(48)),
            "tiny" => Some(Self::tiny()),
            "tiny-noshared" => Some(Self::tiny_noshared()),
            _ => None,
        }
    }

    /// The paper's per-testbed layer counts (§5.4): DeepSeek-V2 uses an
    /// 8-layer config on testbed A, 4 on B, 16 on C/D; Qwen3-MoE uses
    /// 24 / 12 / 48 — sized so the sharded experts fit each testbed's
    /// device memory.
    pub fn paper_layers(deepseek: bool, testbed_name: &str) -> usize {
        let tb = testbed_name.chars().next().unwrap_or('A').to_ascii_uppercase();
        match (deepseek, tb) {
            (true, 'A') => 8,
            (true, 'B') => 4,
            (true, _) => 16,
            (false, 'A') => 24,
            (false, 'B') => 12,
            (false, _) => 48,
        }
    }

    /// Paper-faithful preset for a testbed (see [`Self::paper_layers`]).
    pub fn paper_preset(name: &str, testbed_name: &str) -> Option<Self> {
        match name {
            "deepseek-v2" => Some(Self::deepseek_v2(Self::paper_layers(true, testbed_name))),
            "qwen3-moe" => Some(Self::qwen3_moe(Self::paper_layers(false, testbed_name))),
            other => Self::by_name(other),
        }
    }

    pub fn has_shared_expert(&self) -> bool {
        self.n_shared > 0
    }

    /// Parameter bytes of the attention stack for one layer, replicated
    /// on every AG device (Q/K/V/O projections).
    pub fn attn_param_bytes_per_layer(&self) -> usize {
        let proj = self.embed * self.n_heads * (2 * self.d_k + 2 * self.d_v);
        proj * self.bytes_per_elem
    }

    /// Parameter bytes of one expert (gate + up + down projections).
    pub fn expert_param_bytes(&self) -> usize {
        3 * self.embed * self.ffn_hidden * self.bytes_per_elem
    }

    /// KV-cache bytes one token occupies in one layer. MLA stores the
    /// compressed latent (c_KV + decoupled RoPE key, 512+64 dims in
    /// DeepSeek-V2) instead of per-head K/V. The per-layer form is what
    /// the decode cost model needs: a decode step streams this many
    /// bytes per cached token per layer through the attention kernel.
    pub fn kv_bytes_per_token_layer(&self) -> usize {
        let per_token = match self.attention {
            AttentionKind::Mha => self.n_heads * (self.d_k + self.d_v),
            AttentionKind::Mla => 512 + 64,
        };
        per_token * self.bytes_per_elem
    }

    /// KV-cache bytes for one sample of sequence length `s` across all
    /// layers (resident on its AG device for the whole forward pass).
    pub fn kv_bytes_per_sample(&self, s: usize) -> usize {
        self.n_layers * s * self.kv_bytes_per_token_layer()
    }

    /// Serialize to JSON (mirrors python/compile/configs.py).
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("name", Json::Str(self.name.clone()));
        o.insert("embed", Json::Num(self.embed as f64));
        o.insert("ffn_hidden", Json::Num(self.ffn_hidden as f64));
        o.insert("n_experts", Json::Num(self.n_experts as f64));
        o.insert("top_k", Json::Num(self.top_k as f64));
        o.insert("n_shared", Json::Num(self.n_shared as f64));
        o.insert("n_layers", Json::Num(self.n_layers as f64));
        o.insert("n_heads", Json::Num(self.n_heads as f64));
        o.insert("d_k", Json::Num(self.d_k as f64));
        o.insert("d_v", Json::Num(self.d_v as f64));
        o.insert(
            "attention",
            Json::Str(match self.attention {
                AttentionKind::Mha => "mha".into(),
                AttentionKind::Mla => "mla".into(),
            }),
        );
        o.insert("bytes_per_elem", Json::Num(self.bytes_per_elem as f64));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let get = |k: &str| -> anyhow::Result<usize> {
            v.get(k).as_usize().ok_or_else(|| anyhow::anyhow!("model config: missing/invalid '{k}'"))
        };
        Ok(Self {
            name: v.get("name").as_str().unwrap_or("unnamed").to_string(),
            embed: get("embed")?,
            ffn_hidden: get("ffn_hidden")?,
            n_experts: get("n_experts")?,
            top_k: get("top_k")?,
            n_shared: get("n_shared")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_k: get("d_k")?,
            d_v: get("d_v")?,
            attention: match v.get("attention").as_str() {
                Some("mla") => AttentionKind::Mla,
                _ => AttentionKind::Mha,
            },
            bytes_per_elem: get("bytes_per_elem").unwrap_or(2),
        })
    }

    /// Sanity checks used by constructors of dependent machinery.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.embed > 0 && self.ffn_hidden > 0, "zero dims");
        anyhow::ensure!(self.n_experts >= 1, "need at least one expert");
        anyhow::ensure!(self.top_k >= 1 && self.top_k <= self.n_experts, "bad top_k");
        anyhow::ensure!(self.n_layers >= 1, "need at least one layer");
        anyhow::ensure!(self.n_heads >= 1 && self.d_k > 0 && self.d_v > 0, "bad attention dims");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for m in [
            ModelConfig::deepseek_v2(16),
            ModelConfig::qwen3_moe(48),
            ModelConfig::tiny(),
            ModelConfig::tiny_noshared(),
        ] {
            m.validate().unwrap();
        }
    }

    #[test]
    fn shared_expert_flags() {
        assert!(ModelConfig::deepseek_v2(8).has_shared_expert());
        assert!(!ModelConfig::qwen3_moe(12).has_shared_expert());
        assert!(!ModelConfig::tiny_noshared().has_shared_expert());
    }

    #[test]
    fn json_round_trip() {
        let m = ModelConfig::deepseek_v2(8);
        let j = m.to_json();
        let back = ModelConfig::from_json(&j).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn memory_accounting_scales() {
        let m = ModelConfig::tiny();
        assert_eq!(m.expert_param_bytes(), 3 * 64 * 128 * 4);
        assert!(m.kv_bytes_per_sample(2048) > m.kv_bytes_per_sample(1024));
    }

    #[test]
    fn by_name_lookup() {
        assert!(ModelConfig::by_name("deepseek-v2").is_some());
        assert!(ModelConfig::by_name("nope").is_none());
    }

    #[test]
    fn phase_token_and_kv_accounting() {
        let s = 2048;
        assert_eq!(Phase::Prefill.tokens_per_sample(s), s);
        assert_eq!(Phase::Decode { kv_len: 4096 }.tokens_per_sample(s), 1);
        // Prefill writes S entries; decode reads kv_len and writes 1.
        assert_eq!(Phase::Prefill.kv_resident(s), s);
        assert_eq!(Phase::Decode { kv_len: 4096 }.kv_resident(s), 4097);
        assert!(Phase::Decode { kv_len: 1 }.is_decode() && !Phase::Prefill.is_decode());
        // The derived order separates phases and sorts decode by KV —
        // the property the phase-keyed plan cache relies on.
        assert!(Phase::Prefill < Phase::Decode { kv_len: 0 });
        assert!(Phase::Decode { kv_len: 64 } < Phase::Decode { kv_len: 65 });
    }
}
