//! Tiny declarative CLI parser (`clap` is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands, with auto-generated `--help`.

use std::collections::{BTreeMap, BTreeSet};

/// One declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative argument specification for one (sub)command.
#[derive(Debug, Default)]
pub struct Spec {
    name: String,
    about: String,
    opts: Vec<Opt>,
}

impl Spec {
    pub fn new(name: &str, about: &str) -> Self {
        Self { name: name.into(), about: about.into(), opts: Vec::new() }
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: false, default: None });
        self
    }

    /// Declare a `--key <value>` option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: Some(default.into()) });
        self
    }

    /// Declare a required `--key <value>` option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let def = match &o.default {
                Some(d) if o.takes_value => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("  --{}{val:<12} {}{def}\n", o.name, o.help));
        }
        s
    }

    /// Parse a raw arg list (without argv[0]).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut set_keys: BTreeSet<String> = BTreeSet::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.insert(o.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if opt.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    set_keys.insert(key.clone());
                    values.insert(key, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{key} does not take a value"));
                    }
                    flags.push(key);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        // Check required options.
        for o in &self.opts {
            if o.takes_value && o.default.is_none() && !values.contains_key(o.name) {
                return Err(format!("missing required --{}\n\n{}", o.name, self.usage()));
            }
        }
        Ok(Parsed { values, set_keys, flags, positional })
    }
}

/// Parse result with typed accessors.
#[derive(Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    set_keys: BTreeSet<String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, key: &str) -> &str {
        self.values.get(key).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn get_usize(&self, key: &str) -> usize {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }

    pub fn get_u64(&self, key: &str) -> u64 {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }

    pub fn get_f64(&self, key: &str) -> f64 {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be a number"))
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// True when the user wrote `--key ...` explicitly (as opposed to
    /// the value coming from the declared default).
    pub fn was_set(&self, key: &str) -> bool {
        self.set_keys.contains(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new("test", "a test command")
            .opt("model", "tiny", "model preset")
            .opt("steps", "10", "number of steps")
            .flag("verbose", "chatty output")
            .req("out", "output path")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = spec().parse(&sv(&["--out", "x.json", "--steps", "25"])).unwrap();
        assert_eq!(p.get("model"), "tiny");
        assert_eq!(p.get_usize("steps"), 25);
        assert_eq!(p.get("out"), "x.json");
        assert!(!p.has_flag("verbose"));
        assert!(p.was_set("steps"));
        assert!(!p.was_set("model"), "default value must not count as user-set");
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = spec().parse(&sv(&["--out=y", "--verbose", "pos1"])).unwrap();
        assert_eq!(p.get("out"), "y");
        assert!(p.has_flag("verbose"));
        assert_eq!(p.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&sv(&["--model", "x"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(spec().parse(&sv(&["--out", "x", "--nope"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = spec().parse(&sv(&["--help"])).unwrap_err();
        assert!(err.contains("model preset"));
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(spec().parse(&sv(&["--out", "x", "--verbose=1"])).is_err());
    }
}
