//! Solver-speed claim (§4.3 / §5.4): "the solver completes in under
//! 1 second" and its complexity is O(C·d(M)) — fast enough for
//! per-request online adaptation.
//!
//! Benchmarks Algorithm 1 wall time across every (model, testbed, S)
//! instance of the evaluation plus the online variant, scales the
//! search caps to show the growth is benign, and measures the buffered
//! candidate-evaluation hot path (arena reuse + ASAS closed-form
//! probes) against the original allocate-per-candidate baseline — both
//! paths are run and reported, and the buffered path must win.
//!
//! Run: `cargo bench --bench solver_speed`

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{
    solve, solve_mode, solve_online, solve_online_with, EvalMode, Instance, SolverParams,
};
use findep::util::bench::{fmt_duration, Bencher, Table};

/// Counting wrapper over the system allocator: the shared-evaluator
/// claim below is about allocator traffic, so measure it directly
/// instead of inferring it from wall time.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn paper_instances() -> Vec<(String, Instance)> {
    let mut out = Vec::new();
    for tb in Testbed::all() {
        for (deepseek, name) in [(true, "deepseek"), (false, "qwen")] {
            let layers = ModelConfig::paper_layers(deepseek, &tb.name[..2]);
            let model = if deepseek {
                ModelConfig::deepseek_v2(layers)
            } else {
                ModelConfig::qwen3_moe(layers)
            };
            let split = GroupSplit::paper_default(&tb, deepseek);
            out.push((
                format!("{name}/{}", tb.name),
                Instance::new(model, tb.clone(), split, 4096),
            ));
        }
    }
    out
}

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let params = SolverParams::default();

    let mut table = Table::new(
        "Algorithm 1 solve time (must stay << 1 s)",
        &["instance", "mean", "p50", "evals", "throughput (tok/s)"],
    );
    for (label, inst) in paper_instances() {
        let Some(sol) = solve(&inst, &params) else { continue };
        let r = bencher.run(&label, || {
            let _ = solve(&inst, &params);
        });
        assert!(r.mean_s() < 1.0, "solver exceeded 1 s on {label}");
        table.row(&[
            label,
            fmt_duration(r.mean_s()),
            fmt_duration(r.p50_s()),
            sol.evals.to_string(),
            format!("{:.0}", sol.throughput_tokens),
        ]);
    }
    table.print();

    // --- Buffered arena vs per-candidate allocation (the hot-path
    //     refactor's measured claim). --------------------------------
    let mut table = Table::new(
        "Algorithm 1 search wall time: per-candidate allocation vs buffered arena",
        &["instance", "alloc baseline", "buffered", "speedup", "probes"],
    );
    let (mut sum_alloc, mut sum_buffered) = (0.0f64, 0.0f64);
    let mut probes = String::new();
    for (label, inst) in paper_instances() {
        let sol_alloc = solve_mode(&inst, &params, EvalMode::AllocPerCandidate);
        let sol_buf = solve_mode(&inst, &params, EvalMode::Buffered);
        match (&sol_alloc, &sol_buf) {
            (Some(a), Some(b)) => {
                // The de-allocation must be behaviour-preserving: 1e-9
                // relative, the analytic-vs-engine agreement bound
                // (see buffered_and_alloc_modes_agree in solver tests).
                let rel =
                    (a.throughput_tokens - b.throughput_tokens).abs() / a.throughput_tokens;
                assert!(
                    rel <= 1e-9,
                    "modes disagree on throughput on {label}: alloc {} vs buffered {}",
                    a.throughput_tokens,
                    b.throughput_tokens
                );
                // The buffered path memoizes revisited r2 probes inside
                // each ternary search and skips the winner's redundant
                // final simulation — the probe count must strictly drop
                // against the alloc baseline's original counting.
                assert!(
                    b.evals < a.evals,
                    "probe count did not drop on {label}: buffered {} vs alloc {}",
                    b.evals,
                    a.evals
                );
                probes = format!("{} -> {}", a.evals, b.evals);
            }
            (None, None) => continue,
            _ => panic!("feasibility disagreement between modes on {label}"),
        }
        let r_alloc = bencher.run(&format!("{label}/alloc"), || {
            let _ = solve_mode(&inst, &params, EvalMode::AllocPerCandidate);
        });
        let r_buf = bencher.run(&format!("{label}/buffered"), || {
            let _ = solve_mode(&inst, &params, EvalMode::Buffered);
        });
        sum_alloc += r_alloc.mean_s();
        sum_buffered += r_buf.mean_s();
        table.row(&[
            label,
            fmt_duration(r_alloc.mean_s()),
            fmt_duration(r_buf.mean_s()),
            format!("{:.2}x", r_alloc.mean_s() / r_buf.mean_s()),
            std::mem::take(&mut probes),
        ]);
    }
    table.print();
    println!(
        "aggregate Algorithm-1 search wall time: alloc {} vs buffered {} -> {:.2}x",
        fmt_duration(sum_alloc),
        fmt_duration(sum_buffered),
        sum_alloc / sum_buffered
    );
    // Quick mode runs too few iterations to gate CI on a timing
    // ordering; the full run enforces the hot-path claim.
    if !quick {
        assert!(
            sum_buffered < sum_alloc,
            "buffered path ({sum_buffered:.6}s) must beat the per-candidate-allocation \
             baseline ({sum_alloc:.6}s)"
        );
    }

    // Online variant (the per-batch re-solve of Table 6).
    let inst = Instance::new(
        ModelConfig::deepseek_v2(8),
        Testbed::a(),
        GroupSplit::new(3, 5),
        3072,
    );
    let r = bencher.run("solve_online(batch=4/gpu)", || {
        let _ = solve_online(&inst, 4, &params);
    });
    println!("online re-solve: {}", r.report());
    assert!(r.mean_s() < 1.0);

    // --- Shared-evaluator online re-solves (the serving loop's
    //     steady state): a re-solve on a caller-held evaluator must
    //     not rebuild the probe arenas + topology cache, so its
    //     allocation count must drop strictly below a fresh-evaluator
    //     solve's — and the answer must not move. ---------------------
    let mut ev = inst.evaluator();
    let first = solve_online_with(&inst, 4, &params, EvalMode::Buffered, &[], None, &mut ev)
        .expect("online shape feasible");
    let a0 = allocs();
    let shared = solve_online_with(&inst, 4, &params, EvalMode::Buffered, &[], None, &mut ev)
        .expect("online shape feasible");
    let shared_allocs = allocs() - a0;
    let a1 = allocs();
    let fresh = solve_online(&inst, 4, &params).expect("online shape feasible");
    let fresh_allocs = allocs() - a1;
    assert_eq!(shared.config, first.config);
    assert_eq!(shared.config, fresh.config);
    assert_eq!(
        shared.throughput_tokens.to_bits(),
        fresh.throughput_tokens.to_bits(),
        "shared-evaluator re-solve changed the answer"
    );
    assert!(
        shared_allocs < fresh_allocs,
        "shared-evaluator re-solve must allocate less than a fresh solve \
         ({shared_allocs} vs {fresh_allocs} allocations)"
    );
    println!(
        "online re-solve allocations: fresh evaluator {fresh_allocs} -> shared evaluator \
         {shared_allocs} ({:.1}x fewer)",
        fresh_allocs as f64 / shared_allocs.max(1) as f64
    );

    // Cap scaling: the Pareto-frontier walk keeps growth benign.
    let mut table =
        Table::new("solve time vs search caps", &["ma_cap", "r1_cap", "r2_cap", "mean"]);
    for (ma, r1, r2) in [(4usize, 4usize, 16usize), (8, 8, 32), (16, 8, 64), (32, 8, 128)] {
        let p = SolverParams { ma_cap: ma, r1_cap: r1, r2_cap: r2, ..Default::default() };
        let r = bencher.run(&format!("caps {ma}/{r1}/{r2}"), || {
            let _ = solve(&inst, &p);
        });
        table.row(&[
            ma.to_string(),
            r1.to_string(),
            r2.to_string(),
            fmt_duration(r.mean_s()),
        ]);
        assert!(r.mean_s() < 1.0, "solver exceeded 1 s at caps {ma}/{r1}/{r2}");
    }
    table.print();
    println!("paper claim: solver < 1 s on every instance — holds with large margin here.");
}
