//! Model and cluster configuration types.

pub mod cluster;
pub mod model;
pub mod placement;

pub use cluster::{Cluster, ClusterId, GpuPool, GpuSpec, GroupSplit, M2nModel, Testbed};
pub use model::{AttentionKind, ModelConfig, Phase};
pub use placement::{ExpertLoad, ExpertLoadSampler, ExpertPlacement, LoadProfile, PlacementId};
