//! Property-testing substrate (`proptest` is not vendored).
//!
//! A seeded random-case driver: generate N random cases from a `Rng`,
//! check an invariant on each, and on failure report the *case seed* so
//! the failing case is reproducible with `FINDEP_PROP_SEED=<seed>`.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, base_seed: 0xF1DE_F1DE }
    }
}

impl Config {
    pub fn with_cases(cases: usize) -> Self {
        Self { cases, ..Self::default() }
    }
}

/// Run `prop` on `cfg.cases` independently-seeded RNGs. `prop` returns
/// `Err(msg)` (or panics) to signal a failing case.
///
/// If the env var `FINDEP_PROP_SEED` is set, only that single case seed
/// is run — the reproduction path for a previous failure.
pub fn check<F>(name: &str, cfg: &Config, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Ok(seed_str) = std::env::var("FINDEP_PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("FINDEP_PROP_SEED must be a u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on FINDEP_PROP_SEED={seed}: {msg}");
        }
        return;
    }
    for case in 0..cfg.cases {
        // Derive a per-case seed that is stable but decorrelated.
        let seed = cfg
            .base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64 + 1);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{} \
                 (reproduce with FINDEP_PROP_SEED={seed}): {msg}",
                cfg.cases
            );
        }
    }
}

/// Convenience assertion macro-alike for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality with context.
pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} != {b} (tol {tol}, diff {})", (a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivially-true", &Config::with_cases(17), |rng| {
            n += 1;
            ensure(rng.f64() < 1.0, "f64 must be < 1")
        });
        assert_eq!(n, 17);
    }

    #[test]
    #[should_panic(expected = "FINDEP_PROP_SEED")]
    fn failing_property_reports_seed() {
        check("always-false", &Config::with_cases(3), |_| Err("nope".into()));
    }

    #[test]
    fn ensure_close_scales_tolerance() {
        assert!(ensure_close(1000.0, 1000.5, 1e-3, "x").is_ok());
        assert!(ensure_close(1.0, 1.5, 1e-3, "x").is_err());
        assert!(ensure_close(0.0, 0.0, 1e-12, "x").is_ok());
    }
}
