//! Host tensors: the plain-`Vec<f32>` representation that crosses
//! coordinator channels, with conversions to/from `xla::Literal`.

use anyhow::{ensure, Result};

/// A dense row-major f32 host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Leading-dimension size (row count for 2-D).
    pub fn dim0(&self) -> usize {
        *self.shape.first().unwrap_or(&0)
    }

    /// Row stride for a 2-D/3-D tensor: product of trailing dims.
    pub fn row_len(&self) -> usize {
        self.shape.iter().skip(1).product()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.row_len();
        &self.data[i * w..(i + 1) * w]
    }

    /// Gather rows into a new 2-D tensor (router pack path).
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        let w = self.row_len();
        let mut data = Vec::with_capacity(idx.len() * w);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Tensor::new(vec![idx.len(), w], data)
    }

    /// Pad the leading dimension up to `n` with zero rows.
    pub fn pad_rows_to(&self, n: usize) -> Tensor {
        ensure_ok(n >= self.dim0());
        let w = self.row_len();
        let mut data = self.data.clone();
        data.resize(n * w, 0.0);
        let mut shape = self.shape.clone();
        shape[0] = n;
        Tensor::new(shape, data)
    }

    /// Take the first `n` rows.
    pub fn truncate_rows(&self, n: usize) -> Tensor {
        ensure_ok(n <= self.dim0());
        let w = self.row_len();
        let mut shape = self.shape.clone();
        shape[0] = n;
        Tensor::new(shape, self.data[..n * w].to_vec())
    }

    /// Reinterpret shape (same element count).
    pub fn reshaped(&self, shape: Vec<usize>) -> Tensor {
        Tensor::new(shape, self.data.clone())
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        // Single-copy construction straight from the host buffer (§Perf
        // L3: vec1+reshape costs two copies and a shape pass).
        let bytes = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.shape,
            bytes,
        )?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        ensure!(
            dims.iter().product::<usize>() == data.len(),
            "literal shape/data mismatch"
        );
        Ok(Tensor::new(dims, data))
    }

    /// Max |a - b| across two tensors of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

fn ensure_ok(cond: bool) {
    assert!(cond, "tensor row-op bounds violated");
}

/// Int32 host tensor (gate indices).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn from_literal(lit: &xla::Literal) -> Result<TensorI32> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<i32>()?;
        Ok(TensorI32 { shape: dims, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_ops() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[3., 4.]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
        let p = t.pad_rows_to(5);
        assert_eq!(p.shape, vec![5, 2]);
        assert_eq!(&p.data[6..], &[0.0; 4]);
        let back = p.truncate_rows(3);
        assert_eq!(back, t);
    }

    #[test]
    fn diff_and_reshape() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![1., 2.5, 3., 4.]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert_eq!(a.reshaped(vec![4]).shape, vec![4]);
        assert_eq!(a.row_len(), 2);
    }

    #[test]
    fn literal_round_trip() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
