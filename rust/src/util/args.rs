//! Tiny declarative CLI parser (`clap` is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands, with auto-generated `--help`.
//!
//! Options declare a value type ([`Spec::opt`] for strings,
//! [`Spec::opt_uint`] / [`Spec::opt_float`] for numbers) and every
//! value — defaults included — is validated once, up front, in
//! [`Spec::parse`]: a malformed `--seq abc` fails the invocation with
//! an error naming the flag and carrying the usage text, instead of
//! panicking later inside a typed getter mid-command. The typed
//! getters on [`Parsed`] read the already-validated values; the only
//! way they can panic is reading a key the spec never declared with
//! that type — a programmer error, not user input.

use std::collections::{BTreeMap, BTreeSet};

/// Value type a declared option must parse as (checked at parse time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ArgKind {
    Str,
    UInt,
    Float,
}

/// One declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
    kind: ArgKind,
}

/// Declarative argument specification for one (sub)command.
#[derive(Debug, Default)]
pub struct Spec {
    name: String,
    about: String,
    opts: Vec<Opt>,
}

impl Spec {
    pub fn new(name: &str, about: &str) -> Self {
        Self { name: name.into(), about: about.into(), opts: Vec::new() }
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: false, default: None, kind: ArgKind::Str });
        self
    }

    /// Declare a `--key <value>` string option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            takes_value: true,
            default: Some(default.into()),
            kind: ArgKind::Str,
        });
        self
    }

    /// Declare a `--key <n>` non-negative-integer option with a
    /// default; its value is validated at parse time and read with
    /// [`Parsed::get_usize`] / [`Parsed::get_u64`].
    pub fn opt_uint(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            takes_value: true,
            default: Some(default.into()),
            kind: ArgKind::UInt,
        });
        self
    }

    /// Declare a `--key <x>` finite-number option with a default; its
    /// value is validated at parse time and read with
    /// [`Parsed::get_f64`].
    pub fn opt_float(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            takes_value: true,
            default: Some(default.into()),
            kind: ArgKind::Float,
        });
        self
    }

    /// Declare a required `--key <value>` option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: None, kind: ArgKind::Str });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let def = match &o.default {
                Some(d) if o.takes_value => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("  --{}{val:<12} {}{def}\n", o.name, o.help));
        }
        s
    }

    /// Parse a raw arg list (without argv[0]).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut set_keys: BTreeSet<String> = BTreeSet::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.insert(o.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if opt.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    set_keys.insert(key.clone());
                    values.insert(key, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{key} does not take a value"));
                    }
                    flags.push(key);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        // Check required options.
        for o in &self.opts {
            if o.takes_value && o.default.is_none() && !values.contains_key(o.name) {
                return Err(format!("missing required --{}\n\n{}", o.name, self.usage()));
            }
        }
        // Up-front type validation: every numeric option's value —
        // user-supplied or default — must parse, so the typed getters
        // below never see a malformed string.
        let mut uints: BTreeMap<String, u64> = BTreeMap::new();
        let mut floats: BTreeMap<String, f64> = BTreeMap::new();
        for o in &self.opts {
            let Some(v) = values.get(o.name) else { continue };
            match o.kind {
                ArgKind::Str => {}
                ArgKind::UInt => {
                    let n = v.parse::<u64>().map_err(|_| {
                        format!(
                            "--{} must be a non-negative integer, got '{v}'\n\n{}",
                            o.name,
                            self.usage()
                        )
                    })?;
                    uints.insert(o.name.to_string(), n);
                }
                ArgKind::Float => {
                    let x = v
                        .parse::<f64>()
                        .ok()
                        .filter(|x| x.is_finite())
                        .ok_or_else(|| {
                            format!(
                                "--{} must be a finite number, got '{v}'\n\n{}",
                                o.name,
                                self.usage()
                            )
                        })?;
                    floats.insert(o.name.to_string(), x);
                }
            }
        }
        Ok(Parsed { values, uints, floats, set_keys, flags, positional })
    }
}

/// Parse result with typed accessors.
#[derive(Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    /// Parse-time-validated values of `opt_uint` options.
    uints: BTreeMap<String, u64>,
    /// Parse-time-validated values of `opt_float` options.
    floats: BTreeMap<String, f64>,
    set_keys: BTreeSet<String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, key: &str) -> &str {
        self.values.get(key).map(|s| s.as_str()).unwrap_or("")
    }

    /// Validated integer value of an [`Spec::opt_uint`] option. Panics
    /// only when `key` was never declared as an integer option — a
    /// spec bug, unreachable from user input (malformed values already
    /// failed [`Spec::parse`]).
    pub fn get_usize(&self, key: &str) -> usize {
        self.get_u64(key) as usize
    }

    /// See [`Parsed::get_usize`].
    pub fn get_u64(&self, key: &str) -> u64 {
        *self
            .uints
            .get(key)
            .unwrap_or_else(|| panic!("--{key} was not declared with opt_uint (spec bug)"))
    }

    /// Validated float value of an [`Spec::opt_float`] option. Panics
    /// only when `key` was never declared as a float option — a spec
    /// bug, unreachable from user input.
    pub fn get_f64(&self, key: &str) -> f64 {
        *self
            .floats
            .get(key)
            .unwrap_or_else(|| panic!("--{key} was not declared with opt_float (spec bug)"))
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// True when the user wrote `--key ...` explicitly (as opposed to
    /// the value coming from the declared default).
    pub fn was_set(&self, key: &str) -> bool {
        self.set_keys.contains(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new("test", "a test command")
            .opt("model", "tiny", "model preset")
            .opt_uint("steps", "10", "number of steps")
            .opt_float("rate", "1.5", "a rate")
            .flag("verbose", "chatty output")
            .req("out", "output path")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = spec().parse(&sv(&["--out", "x.json", "--steps", "25"])).unwrap();
        assert_eq!(p.get("model"), "tiny");
        assert_eq!(p.get_usize("steps"), 25);
        assert_eq!(p.get_u64("steps"), 25);
        assert_eq!(p.get_f64("rate"), 1.5, "float default validated and readable");
        assert_eq!(p.get("out"), "x.json");
        assert!(!p.has_flag("verbose"));
        assert!(p.was_set("steps"));
        assert!(!p.was_set("model"), "default value must not count as user-set");
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = spec().parse(&sv(&["--out=y", "--verbose", "pos1"])).unwrap();
        assert_eq!(p.get("out"), "y");
        assert!(p.has_flag("verbose"));
        assert_eq!(p.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&sv(&["--model", "x"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(spec().parse(&sv(&["--out", "x", "--nope"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = spec().parse(&sv(&["--help"])).unwrap_err();
        assert!(err.contains("model preset"));
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(spec().parse(&sv(&["--out", "x", "--verbose=1"])).is_err());
    }

    #[test]
    fn malformed_numbers_fail_at_parse_with_usage() {
        // A bad integer fails the whole invocation, names the flag,
        // and carries the usage text — no panic, no getter involved.
        let err = spec().parse(&sv(&["--out", "x", "--steps", "abc"])).unwrap_err();
        assert!(err.contains("--steps"), "error must name the flag: {err}");
        assert!(err.contains("non-negative integer"));
        assert!(err.contains("model preset"), "error must carry the usage text");
        // Negative integers are rejected for uint options.
        assert!(spec().parse(&sv(&["--out", "x", "--steps", "-3"])).is_err());
        // Bad and non-finite floats are rejected too.
        let err = spec().parse(&sv(&["--out", "x", "--rate", "fast"])).unwrap_err();
        assert!(err.contains("--rate") && err.contains("finite number"));
        assert!(spec().parse(&sv(&["--out", "x", "--rate", "NaN"])).is_err());
        assert!(spec().parse(&sv(&["--out", "x", "--rate=inf"])).is_err());
        // Equals syntax validates identically.
        assert!(spec().parse(&sv(&["--out", "x", "--steps=1.5"])).is_err());
        // And a well-formed value still parses.
        let p = spec().parse(&sv(&["--out", "x", "--steps=42", "--rate=-0.25"])).unwrap();
        assert_eq!(p.get_usize("steps"), 42);
        assert_eq!(p.get_f64("rate"), -0.25);
    }

    #[test]
    #[should_panic(expected = "spec bug")]
    fn numeric_getter_on_string_option_is_a_spec_bug() {
        // `model` is declared as a string: reading it numerically is a
        // programmer error and panics regardless of the value.
        let p = spec().parse(&sv(&["--out", "x"])).unwrap();
        let _ = p.get_usize("model");
    }
}
