//! Trace-driven calibration profiles: fit → persist → solve (§5.2).
//!
//! `findep calibrate` fits the four hardware component models (GEMM,
//! attention, transfer, HBM streaming) on the running host; this module
//! closes the loop the ROADMAP's "trace-driven calibration" item asked
//! for by making those fits a first-class, serializable artifact:
//!
//! * [`CalibrationProfile`] — the four [`ComponentFit`]s (fitted α and
//!   sustained throughput, the R² of each fit, and the raw samples
//!   behind it) plus host metadata, round-tripped bit-exactly through
//!   `util::json` (`calibrate --out profile.json` writes it, `solve
//!   --profile profile.json` reads it back).
//! * [`Testbed::from_profile`] / [`CompModels::from_profile`] — swap a
//!   testbed's hand-written Table-2 constants for the measured ones
//!   while keeping its cluster topology (GPU count, memory, link kind):
//!   the entire solving/serving stack downstream is untouched, so a
//!   profile whose constants equal Table-2's produces *bit-identical*
//!   plans (`benches/calibration.rs` gates this).
//! * [`CalibrationProfile::validate`] — the gate between a measurement
//!   and a solve: per-component R² thresholds, sample-count minimums,
//!   and finite/positive coefficient checks reject degenerate fits
//!   before they can poison a plan.
//! * [`ProfileId`] — a fingerprint of the constants a plan was solved
//!   against. It participates in plan-cache keys ([`ShapeKey`]), so
//!   switching profiles mid-stream can never alias cached plans;
//!   `ProfileId::HAND` (zero) is reserved for the hand-written
//!   constants.
//!
//! [`ShapeKey`]: crate::solver::ShapeKey

use crate::config::{GroupSplit, ModelConfig, Phase, Testbed};
use crate::perfmodel::calibrate::{CalibrationError, Sample};
use crate::perfmodel::stage::StageModels;
use crate::perfmodel::LinearModel;
use crate::util::json::{self, Json, JsonObj};

/// Profile schema version (bumped on incompatible layout changes).
pub const PROFILE_VERSION: usize = 1;

/// Identity of the constants a plan was solved against: `HAND` for the
/// hand-written Table-2 values, otherwise a calibration profile's
/// fingerprint. Part of every plan-cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct ProfileId(pub u64);

impl ProfileId {
    /// The hand-constant (un-calibrated) keyspace.
    pub const HAND: ProfileId = ProfileId(0);
}

/// One fitted hardware component: the α-β line rewritten as (launch
/// overhead, sustained throughput), the R² of the *clamped* fit, and
/// the raw observations behind it.
#[derive(Debug, Clone, PartialEq)]
pub struct ComponentFit {
    /// Fitted launch/startup overhead α, seconds.
    pub alpha_s: f64,
    /// Fitted sustained throughput `1/β`: workload units per second
    /// (FLOP/s for compute components, bytes/s for transfer and HBM).
    /// Stored in testbed form so a synthetic profile built from Table-2
    /// constants feeds them back without a double reciprocal.
    pub unit_per_s: f64,
    /// R² of the clamped least-squares fit.
    pub r2: f64,
    /// Raw (workload, seconds) calibration observations.
    pub samples: Vec<Sample>,
}

impl ComponentFit {
    /// Wrap a fitted model. Errors on a degenerate slope (β ≤ 0 — e.g.
    /// clamped to zero by noise — has no finite throughput).
    pub fn from_fit(
        model: LinearModel,
        r2: f64,
        samples: Vec<Sample>,
    ) -> Result<Self, CalibrationError> {
        if !model.beta.is_finite() || model.beta <= 0.0 {
            return Err(CalibrationError::new(format!(
                "degenerate fit: β = {} has no finite throughput",
                model.beta
            )));
        }
        Ok(Self { alpha_s: model.alpha, unit_per_s: 1.0 / model.beta, r2, samples })
    }

    /// Synthetic component from testbed-style constants (used to build
    /// Table-2-equivalent profiles); two exact on-line samples keep the
    /// validation layer satisfied.
    pub fn from_constants(alpha_s: f64, unit_per_s: f64) -> Self {
        let samples = [1.0, 2.0]
            .iter()
            .map(|&w| Sample { workload: w, seconds: alpha_s + w / unit_per_s })
            .collect();
        Self { alpha_s, unit_per_s, r2: 1.0, samples }
    }

    /// The α-β model this component contributes (`β = 1/unit_per_s`).
    pub fn model(&self) -> LinearModel {
        LinearModel::new(self.alpha_s, 1.0 / self.unit_per_s)
    }

    fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("alpha_s", Json::Num(self.alpha_s));
        o.insert("unit_per_s", Json::Num(self.unit_per_s));
        o.insert("r2", Json::Num(self.r2));
        let samples = self
            .samples
            .iter()
            .map(|s| {
                let mut so = JsonObj::new();
                so.insert("workload", Json::Num(s.workload));
                so.insert("seconds", Json::Num(s.seconds));
                Json::Obj(so)
            })
            .collect();
        o.insert("samples", Json::Arr(samples));
        Json::Obj(o)
    }

    fn from_json(name: &str, v: &Json) -> Result<Self, CalibrationError> {
        let num = |key: &str| {
            v.get(key)
                .as_f64()
                .ok_or_else(|| CalibrationError::new(format!("{name}.{key}: missing number")))
        };
        let samples = v
            .get("samples")
            .as_arr()
            .ok_or_else(|| CalibrationError::new(format!("{name}.samples: missing array")))?
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let field = |key: &str| {
                    s.get(key).as_f64().ok_or_else(|| {
                        CalibrationError::new(format!("{name}.samples[{i}].{key}: missing number"))
                    })
                };
                Ok(Sample { workload: field("workload")?, seconds: field("seconds")? })
            })
            .collect::<Result<Vec<_>, CalibrationError>>()?;
        Ok(Self {
            alpha_s: num("alpha_s")?,
            unit_per_s: num("unit_per_s")?,
            r2: num("r2")?,
            samples,
        })
    }

    fn validate(&self, name: &str, th: &ProfileThresholds) -> Result<(), CalibrationError> {
        let fail = |msg: String| Err(CalibrationError::new(format!("component {name}: {msg}")));
        if self.samples.len() < th.min_samples {
            return fail(format!(
                "{} samples, need at least {}",
                self.samples.len(),
                th.min_samples
            ));
        }
        if !self.alpha_s.is_finite() || self.alpha_s < 0.0 {
            return fail(format!("launch overhead α = {} is not a valid cost", self.alpha_s));
        }
        if !self.unit_per_s.is_finite() || self.unit_per_s <= 0.0 {
            return fail(format!("throughput {} units/s is degenerate", self.unit_per_s));
        }
        if !self.r2.is_finite() || self.r2 < th.min_r2 {
            return fail(format!("R² = {} below the {} acceptance bar", self.r2, th.min_r2));
        }
        for (i, s) in self.samples.iter().enumerate() {
            if !s.workload.is_finite() || !s.seconds.is_finite() || s.seconds < 0.0 {
                return fail(format!("sample {i} is degenerate ({s:?})"));
            }
        }
        Ok(())
    }
}

/// Acceptance gate for profile-driven solving. The paper reports
/// R² ≥ 0.994 on every fit (§5.2); we default to a looser 0.9 so CI
/// hosts with noisy neighbours still pass while genuinely broken fits
/// (clamped slopes, non-linear regimes) are rejected.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProfileThresholds {
    /// Minimum per-component R² of the clamped fit.
    pub min_r2: f64,
    /// Minimum raw samples behind each component.
    pub min_samples: usize,
}

impl Default for ProfileThresholds {
    fn default() -> Self {
        Self { min_r2: 0.9, min_samples: 2 }
    }
}

/// A persisted calibration run: four fitted components plus host
/// metadata. This is the unit `calibrate --out` writes and every
/// `--profile` flag reads back.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationProfile {
    pub version: usize,
    /// Host tag (hostname or operator-supplied label).
    pub host: String,
    /// Unix seconds at fit time (0 for synthetic profiles).
    pub created_unix_s: u64,
    /// Timed trials per probe point.
    pub trials: usize,
    /// GEMM: seconds vs FLOPs → (α_gm, achieved FLOP/s).
    pub gemm: ComponentFit,
    /// Attention: seconds vs y = n_h·B·S²·(d_k+d_v) → (α_attn, FLOP/s).
    pub attn: ComponentFit,
    /// Transfer: seconds vs bytes → (α_c, link bytes/s).
    pub comm: ComponentFit,
    /// Memory streaming: seconds vs bytes → (α≈0, HBM bytes/s) — the
    /// decode-phase KV-read bound. Only the throughput is applied by
    /// [`Testbed::from_profile`]; the fitted α is recorded for
    /// inspection (and excluded from the fingerprint accordingly).
    pub hbm: ComponentFit,
}

impl CalibrationProfile {
    /// Synthetic profile whose constants are exactly a testbed's — the
    /// bit-identity reference of `benches/calibration.rs` (feeding it
    /// back through [`Testbed::from_profile`] must reproduce the hand
    /// constants bit for bit) and a convenient valid-profile fixture.
    pub fn from_testbed(tb: &Testbed) -> Self {
        Self {
            version: PROFILE_VERSION,
            host: format!("synthetic:{}", tb.name),
            created_unix_s: 0,
            trials: 0,
            gemm: ComponentFit::from_constants(tb.alpha_comp_s, tb.gemm_flops),
            attn: ComponentFit::from_constants(tb.alpha_attn_s, tb.attn_flops),
            comm: ComponentFit::from_constants(tb.alpha_comm_s, tb.link_bw),
            hbm: ComponentFit::from_constants(0.0, tb.hbm_bw),
        }
    }

    /// Gate the profile for solving: every component must clear the R²
    /// bar, carry enough samples, and have finite, positive constants.
    pub fn validate(&self, th: &ProfileThresholds) -> Result<(), CalibrationError> {
        if self.version != PROFILE_VERSION {
            return Err(CalibrationError::new(format!(
                "profile version {} != supported {PROFILE_VERSION}",
                self.version
            )));
        }
        self.gemm.validate("gemm", th)?;
        self.attn.validate("attn", th)?;
        self.comm.validate("comm", th)?;
        self.hbm.validate("hbm", th)?;
        Ok(())
    }

    /// Deterministic fingerprint of the solving-relevant constants
    /// (FNV-1a over the α/throughput bit patterns). Never collides with
    /// [`ProfileId::HAND`]: a zero hash is remapped, so a calibrated
    /// plan can never alias a hand-constant plan in the cache.
    pub fn fingerprint(&self) -> ProfileId {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |bits: u64| {
            for b in bits.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        mix(self.version as u64);
        for c in [&self.gemm, &self.attn, &self.comm] {
            mix(c.alpha_s.to_bits());
            mix(c.unit_per_s.to_bits());
        }
        // The HBM component contributes only its throughput: its fitted
        // α is recorded for inspection but never applied by
        // [`Testbed::from_profile`] (decode KV reads are modeled as
        // pure streaming), so it must not differentiate cache keys —
        // two profiles whose applied constants coincide would otherwise
        // duplicate bit-identical plans in the shared cache.
        mix(self.hbm.unit_per_s.to_bits());
        ProfileId(if h == 0 { 1 } else { h })
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("version", Json::Num(self.version as f64));
        o.insert("host", Json::Str(self.host.clone()));
        o.insert("created_unix_s", Json::Num(self.created_unix_s as f64));
        o.insert("trials", Json::Num(self.trials as f64));
        o.insert("gemm", self.gemm.to_json());
        o.insert("attn", self.attn.to_json());
        o.insert("comm", self.comm.to_json());
        o.insert("hbm", self.hbm.to_json());
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<Self, CalibrationError> {
        let version = v
            .get("version")
            .as_usize()
            .ok_or_else(|| CalibrationError::new("profile.version: missing number"))?;
        Ok(Self {
            version,
            host: v.get("host").as_str().unwrap_or("unknown").to_string(),
            created_unix_s: v.get("created_unix_s").as_usize().unwrap_or(0) as u64,
            trials: v.get("trials").as_usize().unwrap_or(0),
            gemm: ComponentFit::from_json("gemm", v.get("gemm"))?,
            attn: ComponentFit::from_json("attn", v.get("attn"))?,
            comm: ComponentFit::from_json("comm", v.get("comm"))?,
            hbm: ComponentFit::from_json("hbm", v.get("hbm"))?,
        })
    }

    /// Write the profile as pretty JSON (the `calibrate --out` format).
    pub fn save(&self, path: &std::path::Path) -> Result<(), CalibrationError> {
        std::fs::write(path, json::to_string_pretty(&self.to_json()) + "\n")
            .map_err(|e| CalibrationError::new(format!("write {}: {e}", path.display())))
    }

    /// Read a profile back (parse errors and malformed layouts surface
    /// as [`CalibrationError`]; validation is a separate, explicit
    /// step so tooling can inspect rejected profiles).
    pub fn load(path: &std::path::Path) -> Result<Self, CalibrationError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| CalibrationError::new(format!("read {}: {e}", path.display())))?;
        let v = json::parse(&text)
            .map_err(|e| CalibrationError::new(format!("parse {}: {e}", path.display())))?;
        Self::from_json(&v)
    }
}

/// One row of the calibrated-vs-hand stage-time comparison
/// ([`stage_deltas`]).
#[derive(Debug, Clone, PartialEq)]
pub struct StageDelta {
    pub stage: &'static str,
    /// Stage time under the hand-written Table-2 constants, seconds.
    pub hand_s: f64,
    /// Stage time under the profile's measured constants, seconds.
    pub calibrated_s: f64,
}

impl StageDelta {
    /// Relative change, percent (positive = calibrated is slower).
    pub fn delta_pct(&self) -> f64 {
        (self.calibrated_s - self.hand_s) / self.hand_s * 100.0
    }
}

/// Report how far the measured constants move each stage model of
/// `phase` from the hand-written ones, evaluated at the reference
/// point `m_a = 1` (one sample per AG GPU) and its token-conserving
/// `m_e = k/r2` at `r2 = 1` — the sanity check printed by
/// `solve --profile`. The phase matters: a decode comparison derives
/// the autoregressive stage models, whose attention β carries the
/// KV-read bound — so a calibrated HBM throughput shows up in the
/// attention row instead of (misleadingly) nowhere.
pub fn stage_deltas(
    model: &ModelConfig,
    base: &Testbed,
    profile: &CalibrationProfile,
    split: GroupSplit,
    seq_len: usize,
    phase: Phase,
) -> Vec<StageDelta> {
    let cal_tb = Testbed::from_profile(base, profile);
    let hand = StageModels::for_phase(model, base, split, seq_len, phase);
    let cal = StageModels::for_phase(model, &cal_tb, split, seq_len, phase);
    let m_a = 1.0;
    let m_e = hand.m_e(m_a, 1);
    let mut rows = vec![
        StageDelta {
            stage: "attention t_a",
            hand_s: hand.attn_time(m_a),
            calibrated_s: cal.attn_time(m_a),
        },
        StageDelta {
            stage: "expert t_e",
            hand_s: hand.expert_time(m_e),
            calibrated_s: cal.expert_time(m_e),
        },
        StageDelta {
            stage: "transfer t_a2e",
            hand_s: hand.comm_time(m_e),
            calibrated_s: cal.comm_time(m_e),
        },
    ];
    if hand.has_shared {
        rows.insert(
            1,
            StageDelta {
                stage: "shared t_s",
                hand_s: hand.shared_time(m_a),
                calibrated_s: cal.shared_time(m_a),
            },
        );
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> CalibrationProfile {
        CalibrationProfile::from_testbed(&Testbed::a())
    }

    #[test]
    fn synthetic_profile_passes_validation() {
        profile().validate(&ProfileThresholds::default()).unwrap();
    }

    #[test]
    fn json_round_trip_is_bit_identical() {
        let p = profile();
        let text = json::to_string_pretty(&p.to_json());
        let back = CalibrationProfile::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.fingerprint(), p.fingerprint());
        // The solving-relevant constants round-trip bitwise.
        assert_eq!(back.gemm.unit_per_s.to_bits(), p.gemm.unit_per_s.to_bits());
        assert_eq!(back.attn.alpha_s.to_bits(), p.attn.alpha_s.to_bits());
    }

    #[test]
    fn validation_rejects_low_r2_and_degenerate_fits() {
        let th = ProfileThresholds::default();
        let mut p = profile();
        p.attn.r2 = 0.5;
        let err = p.validate(&th).unwrap_err().to_string();
        assert!(err.contains("attn"), "error names the component: {err}");
        assert!(err.contains("R²"), "error names the failure: {err}");

        let mut p = profile();
        p.comm.unit_per_s = f64::INFINITY;
        assert!(p.validate(&th).is_err());
        let mut p = profile();
        p.gemm.alpha_s = -1e-6;
        assert!(p.validate(&th).is_err());
        let mut p = profile();
        p.hbm.samples.clear();
        assert!(p.validate(&th).is_err());
        let mut p = profile();
        p.gemm.samples[0].seconds = f64::NAN;
        assert!(p.validate(&th).is_err());
        let mut p = profile();
        p.version = PROFILE_VERSION + 1;
        assert!(p.validate(&th).is_err());
    }

    #[test]
    fn component_fit_rejects_degenerate_slope() {
        assert!(ComponentFit::from_fit(LinearModel::new(1e-6, 0.0), 1.0, vec![]).is_err());
        let ok = ComponentFit::from_fit(
            LinearModel::new(2e-5, 1e-12),
            0.999,
            vec![Sample { workload: 1.0, seconds: 2e-5 }],
        )
        .unwrap();
        assert_eq!(ok.unit_per_s, 1e12);
        assert_eq!(ok.model().beta, 1e-12);
    }

    #[test]
    fn fingerprints_separate_profiles_and_reserve_hand() {
        let a = profile();
        let mut b = profile();
        b.gemm.unit_per_s *= 0.5;
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), ProfileId::HAND);
        assert_ne!(b.fingerprint(), ProfileId::HAND);
        // Metadata (host, samples) does not shift the identity — only
        // the solving-relevant constants do.
        let mut c = profile();
        c.host = "elsewhere".into();
        c.gemm.samples.push(Sample { workload: 3.0, seconds: 4.0 });
        assert_eq!(a.fingerprint(), c.fingerprint());
        // ...and neither does the HBM α, which `Testbed::from_profile`
        // never applies (only the HBM throughput reaches a solve).
        let mut d = profile();
        d.hbm.alpha_s = 123e-6;
        assert_eq!(a.fingerprint(), d.fingerprint());
        let mut e = profile();
        e.hbm.unit_per_s *= 2.0;
        assert_ne!(a.fingerprint(), e.fingerprint());
    }

    #[test]
    fn stage_deltas_zero_for_table2_equivalent_profile() {
        let model = ModelConfig::deepseek_v2(8);
        let tb = Testbed::a();
        let split = GroupSplit::new(3, 5);
        for phase in [Phase::Prefill, Phase::Decode { kv_len: 4096 }] {
            let rows = stage_deltas(&model, &tb, &profile(), split, 2048, phase);
            assert_eq!(rows.len(), 4, "deepseek has a shared expert");
            for r in &rows {
                assert_eq!(r.hand_s.to_bits(), r.calibrated_s.to_bits(), "{} {phase:?}", r.stage);
            }
        }
        // A perturbed profile moves exactly the stages its component
        // feeds: halving link bandwidth doubles only the transfer β.
        let mut slow_link = profile();
        slow_link.comm.unit_per_s /= 2.0;
        let rows = stage_deltas(&model, &tb, &slow_link, split, 2048, Phase::Prefill);
        for r in &rows {
            if r.stage == "transfer t_a2e" {
                assert!(r.delta_pct() > 0.0, "slower link must slow the transfer");
            } else {
                assert_eq!(r.hand_s.to_bits(), r.calibrated_s.to_bits(), "{}", r.stage);
            }
        }
    }

    #[test]
    fn stage_deltas_surface_hbm_in_the_decode_attention_row() {
        // Decode attention is KV-read-bound, so a slower measured HBM
        // must show in the decode comparison's attention row — and
        // nowhere in the prefill one (which never touches hbm_bw).
        let model = ModelConfig::deepseek_v2(8);
        let tb = Testbed::a();
        let split = GroupSplit::new(3, 5);
        let mut slow_hbm = profile();
        slow_hbm.hbm.unit_per_s /= 4.0;
        let decode = Phase::Decode { kv_len: 4096 };
        let rows = stage_deltas(&model, &tb, &slow_hbm, split, 2048, decode);
        let attn = rows.iter().find(|r| r.stage == "attention t_a").unwrap();
        assert!(attn.delta_pct() > 0.0, "slower HBM must slow decode attention");
        for r in rows.iter().filter(|r| r.stage != "attention t_a") {
            assert_eq!(r.hand_s.to_bits(), r.calibrated_s.to_bits(), "{}", r.stage);
        }
        for r in stage_deltas(&model, &tb, &slow_hbm, split, 2048, Phase::Prefill) {
            assert_eq!(r.hand_s.to_bits(), r.calibrated_s.to_bits(), "prefill {}", r.stage);
        }
    }
}
