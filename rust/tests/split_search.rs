//! Integration: the split-search solver layer vs the serial exhaustive
//! sweep. The parallel, pruned, topology-reusing search is a pure
//! optimisation — on every paper instance it must return a winner
//! bit-identical to the serial cold sweep at any thread count, and
//! pruning must never discard the true argmax (checked against the
//! pruning-disabled oracle).

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{search_splits, search_splits_serial, SearchParams, SplitSolution};

fn paper_cases() -> Vec<(String, ModelConfig, Testbed, usize)> {
    let mut out = Vec::new();
    for tb in Testbed::all() {
        for (model, name) in [
            (ModelConfig::deepseek_v2(8), "deepseek"),
            (ModelConfig::qwen3_moe(12), "qwen"),
        ] {
            out.push((format!("{name}/{}", tb.name), model, tb.clone(), 2048));
        }
    }
    out
}

fn assert_same_winner(label: &str, a: &SplitSolution, b: &SplitSolution) {
    assert_eq!(a.candidate, b.candidate, "placement drift on {label}");
    assert_eq!(a.per_instance.config, b.per_instance.config, "config drift on {label}");
    assert_eq!(
        a.per_instance.throughput_tokens, b.per_instance.throughput_tokens,
        "per-instance throughput drift on {label}"
    );
    assert_eq!(a.per_instance.makespan, b.per_instance.makespan, "makespan drift on {label}");
    assert_eq!(a.total_throughput, b.total_throughput, "total throughput drift on {label}");
}

#[test]
fn search_matches_serial_sweep_at_any_thread_count() {
    for (label, model, tb, seq) in paper_cases() {
        let serial = search_splits_serial(&model, &tb, seq, &SearchParams::default());
        for threads in [1usize, 2, 3, 8] {
            let params = SearchParams { threads, ..Default::default() };
            let searched = search_splits(&model, &tb, seq, &params);
            match (&serial, &searched) {
                (Some(s), Some(o)) => {
                    assert_same_winner(&format!("{label} t={threads}"), s, &o.best)
                }
                (None, None) => {}
                (s, o) => panic!(
                    "feasibility drift on {label} t={threads}: serial={} search={}",
                    s.is_some(),
                    o.is_some()
                ),
            }
        }
    }
}

#[test]
fn pruning_never_discards_the_argmax() {
    for (label, model, tb, seq) in paper_cases() {
        let oracle = search_splits(
            &model,
            &tb,
            seq,
            &SearchParams { prune: false, threads: 2, ..Default::default() },
        );
        let pruned = search_splits(
            &model,
            &tb,
            seq,
            &SearchParams { prune: true, threads: 2, ..Default::default() },
        );
        match (&oracle, &pruned) {
            (Some(o), Some(p)) => {
                assert_same_winner(&label, &o.best, &p.best);
                // The oracle solves everything it doesn't mark
                // infeasible; pruning only ever removes work.
                assert_eq!(o.stats.pruned, 0);
                assert!(p.stats.solved <= o.stats.solved, "pruning added work on {label}");
            }
            (None, None) => {}
            (o, p) => panic!(
                "feasibility drift on {label}: oracle={} pruned={}",
                o.is_some(),
                p.is_some()
            ),
        }
    }
}

#[test]
fn multi_replica_tilings_can_win_and_scale_totals() {
    // Every solved candidate's total is exactly replicas × per-instance
    // throughput, and single-replica restriction is honoured.
    let (model, tb) = (ModelConfig::deepseek_v2(8), Testbed::a());
    let full = search_splits(&model, &tb, 2048, &SearchParams::default()).expect("feasible");
    for s in &full.evaluated {
        assert_eq!(
            s.total_throughput,
            s.candidate.replicas as f64 * s.per_instance.throughput_tokens
        );
        assert_eq!(s.candidate.replicas * (s.candidate.split.ag + s.candidate.split.eg), 8);
    }
    let single = search_splits(
        &model,
        &tb,
        2048,
        &SearchParams { multi_replica: false, ..Default::default() },
    )
    .expect("feasible");
    assert!(single.evaluated.iter().all(|s| s.candidate.replicas == 1));
    assert_eq!(single.stats.candidates, 7);
    // The unrestricted search can only do better or equal.
    assert!(full.best.total_throughput >= single.best.total_throughput);
}

#[test]
fn paper_default_split_is_at_or_near_the_optimum() {
    // §5.3's chosen splits should be competitive with the searched
    // optimum on the single-replica space (the paper picked them by
    // exactly this sweep).
    let (model, tb) = (ModelConfig::deepseek_v2(8), Testbed::a());
    // Pruning is off so `evaluated` holds every feasible split, not just
    // the ones that could still beat the incumbent.
    let report = search_splits(
        &model,
        &tb,
        2048,
        &SearchParams { multi_replica: false, prune: false, ..Default::default() },
    )
    .expect("feasible");
    let paper = GroupSplit::paper_default(&tb, true);
    let paper_tput = report
        .evaluated
        .iter()
        .find(|s| s.candidate.split == paper)
        .map(|s| s.total_throughput)
        .expect("paper split is feasible");
    assert!(
        paper_tput >= 0.5 * report.best.total_throughput,
        "paper split {paper_tput} implausibly far from searched optimum {}",
        report.best.total_throughput
    );
}
