//! Token→expert routing: pack tokens per expert for A2E dispatch,
//! combine expert outputs (gate-weighted) on return — the data-plane
//! half of the MoE layer that the paper's EG confinement property
//! (§2.2) relies on.

use crate::runtime::tensor::{Tensor, TensorI32};

/// Tokens routed to one expert.
#[derive(Debug, Clone)]
pub struct ExpertGroup {
    pub expert: usize,
    /// Row indices into the flattened token tensor.
    pub token_ids: Vec<u32>,
    /// Gate weight per routed token (aligned with `token_ids`).
    pub weights: Vec<f32>,
}

/// Routing decision for a token block: per-expert groups.
#[derive(Debug, Clone)]
pub struct Routing {
    pub groups: Vec<ExpertGroup>,
    pub n_tokens: usize,
    pub top_k: usize,
}

/// Build per-expert token groups from gate outputs.
/// `probs`, `idx`: [N, top_k].
pub fn route(probs: &Tensor, idx: &TensorI32, n_experts: usize) -> Routing {
    let n = probs.shape[0];
    let k = probs.shape[1];
    let mut groups: Vec<ExpertGroup> = (0..n_experts)
        .map(|e| ExpertGroup { expert: e, token_ids: Vec::new(), weights: Vec::new() })
        .collect();
    for t in 0..n {
        for j in 0..k {
            let e = idx.data[t * k + j] as usize;
            debug_assert!(e < n_experts, "expert index out of range");
            groups[e].token_ids.push(t as u32);
            groups[e].weights.push(probs.data[t * k + j]);
        }
    }
    groups.retain(|g| !g.token_ids.is_empty());
    Routing { groups, n_tokens: n, top_k: k }
}

impl Routing {
    /// Token count conservation: total routed assignments == N·top_k.
    pub fn total_assignments(&self) -> usize {
        self.groups.iter().map(|g| g.token_ids.len()).sum()
    }

    /// Split this routing into `parts` fine-grained parts along the
    /// token dimension (the r2 split of §2.3: "the expert part processes
    /// samples token by token ... we can further partition along the
    /// token dimension"). Tokens [0, N) are cut into contiguous ranges;
    /// each part keeps only the group slices whose tokens fall in its
    /// range, so parts are disjoint and their union is the original
    /// routing.
    pub fn split_parts(&self, parts: usize) -> Vec<Routing> {
        let parts = parts.clamp(1, self.n_tokens.max(1));
        let per = self.n_tokens.div_ceil(parts);
        (0..parts)
            .map(|p| {
                let lo = (p * per) as u32;
                let hi = (((p + 1) * per).min(self.n_tokens)) as u32;
                let groups: Vec<ExpertGroup> = self
                    .groups
                    .iter()
                    .filter_map(|g| {
                        let sel: Vec<usize> = g
                            .token_ids
                            .iter()
                            .enumerate()
                            .filter(|(_, &t)| t >= lo && t < hi)
                            .map(|(i, _)| i)
                            .collect();
                        if sel.is_empty() {
                            return None;
                        }
                        Some(ExpertGroup {
                            expert: g.expert,
                            token_ids: sel.iter().map(|&i| g.token_ids[i]).collect(),
                            weights: sel.iter().map(|&i| g.weights[i]).collect(),
                        })
                    })
                    .collect();
                Routing { groups, n_tokens: self.n_tokens, top_k: self.top_k }
            })
            .collect()
    }
}

/// Gather the input rows for one expert group.
pub fn pack(x: &Tensor, group: &ExpertGroup) -> Tensor {
    x.gather_rows(&group.token_ids.iter().map(|&t| t as usize).collect::<Vec<_>>())
}

/// Scatter-accumulate one expert's outputs into the combine buffer with
/// gate weighting: `acc[token] += w · y[row]`.
pub fn combine_into(acc: &mut Tensor, group: &ExpertGroup, y: &Tensor) {
    let m = acc.row_len();
    debug_assert_eq!(y.row_len(), m);
    debug_assert_eq!(y.dim0(), group.token_ids.len());
    for (row, (&t, &w)) in group.token_ids.iter().zip(&group.weights).enumerate() {
        let dst = &mut acc.data[t as usize * m..(t as usize + 1) * m];
        let src = &y.data[row * m..(row + 1) * m];
        for (d, s) in dst.iter_mut().zip(src) {
            *d += w * s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{self, Config};
    use crate::util::rng::Rng;

    fn mk_gate(rng: &mut Rng, n: usize, e: usize, k: usize) -> (Tensor, TensorI32) {
        let mut probs = Vec::new();
        let mut idx = Vec::new();
        for _ in 0..n {
            // Distinct experts per token, renormalized weights.
            let mut experts: Vec<i32> = (0..e as i32).collect();
            rng.shuffle(&mut experts);
            let raw: Vec<f64> = (0..k).map(|_| rng.range_f64(0.1, 1.0)).collect();
            let s: f64 = raw.iter().sum();
            for j in 0..k {
                probs.push((raw[j] / s) as f32);
                idx.push(experts[j]);
            }
        }
        (
            Tensor::new(vec![n, k], probs),
            TensorI32 { shape: vec![n, k], data: idx },
        )
    }

    #[test]
    fn routing_conserves_assignments() {
        let mut rng = Rng::new(3);
        let (p, i) = mk_gate(&mut rng, 32, 8, 2);
        let r = route(&p, &i, 8);
        assert_eq!(r.total_assignments(), 32 * 2);
        for g in &r.groups {
            assert!(!g.token_ids.is_empty());
            assert_eq!(g.token_ids.len(), g.weights.len());
        }
    }

    #[test]
    fn split_parts_partition_tokens() {
        let mut rng = Rng::new(5);
        let (p, i) = mk_gate(&mut rng, 33, 8, 2);
        let r = route(&p, &i, 8);
        for parts in [1usize, 2, 3, 5] {
            let split = r.split_parts(parts);
            let total: usize = split.iter().map(|s| s.total_assignments()).sum();
            assert_eq!(total, r.total_assignments(), "parts={parts}");
            // Disjoint token ranges.
            for (a, b) in split.iter().zip(split.iter().skip(1)) {
                let max_a = a.groups.iter().flat_map(|g| &g.token_ids).max();
                let min_b = b.groups.iter().flat_map(|g| &g.token_ids).min();
                if let (Some(&ma), Some(&mb)) = (max_a, min_b) {
                    assert!(ma < mb);
                }
            }
        }
    }

    #[test]
    fn pack_combine_is_weighted_permutation_inverse() {
        // Property: routing with identity experts (y = x) and weights
        // summing to 1 per token reconstructs x exactly.
        proptest::check("pack-combine-inverse", &Config::with_cases(40), |rng| {
            let n = 1 + rng.usize_below(40);
            let e = 2 + rng.usize_below(8);
            let k = 1 + rng.usize_below(2.min(e));
            let m = 4;
            let (p, i) = mk_gate(rng, n, e, k);
            let x = Tensor::new(
                vec![n, m],
                (0..n * m).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect(),
            );
            let r = route(&p, &i, e);
            let mut acc = Tensor::zeros(vec![n, m]);
            for g in &r.groups {
                let xg = pack(&x, g);
                combine_into(&mut acc, g, &xg); // identity "expert"
            }
            proptest::ensure(
                acc.max_abs_diff(&x) < 2e-6,
                format!("reconstruction error {}", acc.max_abs_diff(&x)),
            )
        });
    }

    #[test]
    fn split_respects_part_count_bounds() {
        let mut rng = Rng::new(9);
        let (p, i) = mk_gate(&mut rng, 4, 4, 1);
        let r = route(&p, &i, 4);
        // More parts than tokens clamps to token count.
        let split = r.split_parts(100);
        assert!(split.len() <= 4);
    }
}
